"""Smoke the Workload->cost path end to end: map a small dataset, scale
its measured counters to paper magnitude, and print the full system table
through the unified ``core/costmodel.py`` interface for BOTH registered
backends (analytic closed forms and the discrete-event simulator), plus
the sim-vs-analytic agreement on the MARS path.

    PYTHONPATH=src python scripts/smoke_ssdmodel.py
"""
import numpy as np
from repro.core import MarsConfig, build_index, Mapper
from repro.core import costmodel, ssd_model, workload
from repro.signal import datasets, simulate

spec = datasets.DATASETS["D2"]
cfg = datasets.config_for(spec).with_mode("ms_fixed")
ref, reads = datasets.build(spec, cfg)
idx = build_index(ref.events_concat, ref.n_events, cfg)
out = Mapper(idx, cfg).map_signals(reads.signals, chunk=64)
w = workload.from_counters(out.counters, cfg, idx.nbytes)
# scale to paper dataset magnitude
w = w.scale(spec.scale_factor)

for name in sorted(costmodel.MODELS):
    m = costmodel.get_model(name)
    res = {s: m.system_latency_energy(s, w) for s in ssd_model.SYSTEMS}
    rh2 = res["RH2"]
    print(f"--- cost model: {m.name} ---")
    print(f"{'system':14s} {'total_s':>10s} {'speedup_vs_RH2':>15s} {'energy_red':>11s}")
    for s, r in res.items():
        print(f"{s:14s} {r['total']:10.2f} {rh2['total']/r['total']:15.1f} {rh2['energy']/r['energy']:11.1f}")
    if m.name == "analytic":
        ana = res
    print()

# the two backends must agree on the MARS path (degenerate configs <1%;
# the default contended config stays close because flash/compute overlap
# dominates both)
mars_a = ana["MARS"]["total"]
mars_s = costmodel.get_model("sim").system_latency_energy("MARS", w)["total"]
rel = abs(mars_s - mars_a) / mars_a
print(f"MARS total: analytic={mars_a:.3f}s sim={mars_s:.3f}s "
      f"(rel err {100 * rel:.2f}%)")
assert rel < 0.05, f"sim diverged from analytic by {100 * rel:.1f}%"

# serving twins agree below saturation
sv_a = costmodel.get_model("analytic").serving_virtual(8, 4.0)
sv_s = costmodel.get_model("sim").serving_virtual(8, 4.0)
print(f"serving p50: analytic={sv_a['p50']:.2f} sim={sv_s['p50']:.2f}")

print("\npaper targets: MARS vs RH2 28x (energy 180x); vs BC 93x (427x); vs GenPIP 40x (72x); vs MS-EXT 3.1x; vs MS-SIMDRAM latency 21.4x faster, energy 3.5x worse")
rh2, m_, bc, gp, ext, sd = (ana["RH2"], ana["MARS"], ana["BC"],
                            ana["GenPIP"], ana["MS-EXT"], ana["MS-SIMDRAM"])
print(f"ours: MARS vs RH2 {rh2['total']/m_['total']:.1f}x ({rh2['energy']/m_['energy']:.0f}x) | vs BC {bc['total']/m_['total']:.1f}x ({bc['energy']/m_['energy']:.0f}x) | vs GenPIP {gp['total']/m_['total']:.1f}x ({gp['energy']/m_['energy']:.0f}x) | vs EXT {ext['total']/m_['total']:.1f}x | vs SIMDRAM {sd['total']/m_['total']:.1f}x")
