import numpy as np
from repro.core import MarsConfig, build_index, Mapper
from repro.core import ssd_model, workload
from repro.signal import datasets, simulate

spec = datasets.DATASETS["D2"]
cfg = datasets.config_for(spec).with_mode("ms_fixed")
ref, reads = datasets.build(spec, cfg)
idx = build_index(ref.events_concat, ref.n_events, cfg)
out = Mapper(idx, cfg).map_signals(reads.signals, chunk=64)
w = workload.from_counters(out.counters, cfg, idx.nbytes)
# scale to paper dataset magnitude
w = w.scale(spec.scale_factor)
res = {}
for s in ssd_model.SYSTEMS:
    res[s] = ssd_model.system_latency_energy(s, w)
rh2 = res["RH2"]
print(f"{'system':14s} {'total_s':>10s} {'speedup_vs_RH2':>15s} {'energy_red':>11s}")
for s, r in res.items():
    print(f"{s:14s} {r['total']:10.2f} {rh2['total']/r['total']:15.1f} {rh2['energy']/r['energy']:11.1f}")
print("\npaper targets: MARS vs RH2 28x (energy 180x); vs BC 93x (427x); vs GenPIP 40x (72x); vs MS-EXT 3.1x; vs MS-SIMDRAM latency 21.4x faster, energy 3.5x worse")
m, bc, gp, ext, sd = res["MARS"], res["BC"], res["GenPIP"], res["MS-EXT"], res["MS-SIMDRAM"]
print(f"ours: MARS vs RH2 {rh2['total']/m['total']:.1f}x ({rh2['energy']/m['energy']:.0f}x) | vs BC {bc['total']/m['total']:.1f}x ({bc['energy']/m['energy']:.0f}x) | vs GenPIP {gp['total']/m['total']:.1f}x ({gp['energy']/m['energy']:.0f}x) | vs EXT {ext['total']/m['total']:.1f}x | vs SIMDRAM {sd['total']/m['total']:.1f}x")
