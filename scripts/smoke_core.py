"""Dev smoke: end-to-end mapping accuracy on synthetic genomes, all modes."""
import sys, time
import numpy as np
from repro.core import MarsConfig, build_index, Mapper, score_accuracy
from repro.signal import simulate

ref_len = int(sys.argv[1]) if len(sys.argv) > 1 else 100_000
n_reads = int(sys.argv[2]) if len(sys.argv) > 2 else 64
cfg0 = MarsConfig()
ref = simulate.make_reference(ref_len, seed=0)
reads = simulate.sample_reads(ref, n_reads, signal_len=cfg0.signal_len,
                              seed=1, junk_frac=0.1)
for mode in ("rh2", "ms_float", "ms_fixed"):
    cfg = cfg0.with_mode(mode)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    mapper = Mapper(idx, cfg)
    t0 = time.time()
    out = mapper.map_signals(reads.signals, chunk=64)
    dt = time.time() - t0
    acc = score_accuracy(out, reads.true_pos, reads.true_strand,
                         reads.mappable, reads.n_bases, ref.n_events)
    print(f"{mode:10s} P={acc['precision']:.3f} R={acc['recall']:.3f} "
          f"F1={acc['f1']:.3f} tp={acc['tp']} fp={acc['fp']} fn={acc['fn']} t={dt:.1f}s")
