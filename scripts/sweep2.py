import itertools
import numpy as np
from repro.core import MarsConfig, build_index, Mapper, score_accuracy
from repro.signal import simulate

ref = simulate.make_reference(100_000, seed=0)
for q, w, tau in itertools.product((3, 4), (5, 6, 7), (2.0, 2.5)):
    cfg = MarsConfig(quant_bits=q, seed_width=w, tstat_threshold=tau,
                     min_chain_score=4.0, peak_window=3).with_mode("ms_fixed")
    reads = simulate.sample_reads(ref, 64, signal_len=cfg.signal_len, seed=1,
                                  junk_frac=0.1)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    out = Mapper(idx, cfg).map_signals(reads.signals, chunk=64)
    acc = score_accuracy(out, reads.true_pos, reads.true_strand,
                         reads.mappable, reads.n_bases, ref.n_events)
    hits = out.counters["n_hits_raw"] / 64
    hpost = out.counters["n_hits_postfreq"] / 64
    print(f"q={q} w={w} tau={tau}: P={acc['precision']:.3f} R={acc['recall']:.3f} "
          f"F1={acc['f1']:.3f} hits/read={hits:.0f} postfreq={hpost:.0f}")
