#!/usr/bin/env python
"""Dump the kernel-backend supports matrix: for a panel of configs, which
registered backend actually serves each stage of each requested plan, and
whether the fused cheap-phase mega-kernel engages or the chunk program
falls back to the per-stage ladder.

    PYTHONPATH=src python scripts/kernel_support.py
    scripts/bench_pipeline.py --support          # same output

A stage prints its serving backend name; a stage whose requested backend
exists but whose ``supports`` gate rejected the config prints
``reference (<name> unsupported)`` so silent fallbacks are visible.  The
``fused_cheap`` row shows the whole-phase resolution from
``stages.fused_cheap_backend`` — "fused:<name>" when the mega-kernel will
run, otherwise why not (plan mismatch or supports gate).
"""
from __future__ import annotations

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

BACKENDS = ("pallas", "ring", "a2a", "tiered")


def _configs():
    from repro.core.config import MarsConfig
    base = MarsConfig(hash_bits=12)
    return (
        ("ms_fixed", base.with_mode("ms_fixed")),
        ("ms_float", base.with_mode("ms_float")),
        ("rh2", base.with_mode("rh2")),
        # wide t-stat window: overflows the int32 fixed-point t-stat, so
        # the fixed kernels' supports gates must reject it
        ("ms_fixed_w13", base.with_mode("ms_fixed").replace(tstat_window=13)),
    )


def _fused_row(stages, plan, cfg) -> str:
    b = stages.fused_cheap_backend(plan, cfg)
    if b is not None:
        return f"fused:{b.name}"
    # explain which leg of the engagement test failed
    by_stage = dict(plan)
    names = {by_stage[s] for s in stages.CHEAP_STAGES}
    cand = [fb for fb in getattr(stages, "_FUSED_CHEAP", {}).values()
            if fb.name in names]
    if not cand:
        return "per-stage (no fused kernel in plan)"
    fb = cand[0]
    if fb.supports is not None and not fb.supports(cfg):
        return f"per-stage ({fb.name} supports gate rejected cfg)"
    return "per-stage (plan shape mismatch)"


def main(argv=None) -> int:
    del argv
    from repro.core import stages
    for cfg_name, cfg in _configs():
        print(f"=== config {cfg_name} (fixed_point={cfg.fixed_point}, "
              f"early_quantization={cfg.early_quantization}, "
              f"tstat_window={cfg.tstat_window}) ===")
        for backend in BACKENDS:
            plan = stages.resolve_plan(cfg, backend)
            cells = []
            for stage, name in plan:
                if name == backend or name == stages.REFERENCE and (
                        stage, backend) not in stages._REGISTRY:
                    cells.append(f"{stage}={name}")
                else:
                    cells.append(f"{stage}={name} ({backend} unsupported)")
            print(f"  plan {backend:7s}: " + "  ".join(cells))
            if backend == stages.PALLAS:
                print(f"  {'fused_cheap':12s}: {_fused_row(stages, plan, cfg)}")
        print()
    print("registered fused cheap-phase kernels: "
          + (", ".join(sorted(stages._FUSED_CHEAP)) or "(none)"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
