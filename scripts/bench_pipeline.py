#!/usr/bin/env python
"""Measure the mapping pipeline's per-stage-group timings and persist them
to BENCH_pipeline.json at the repo root (the per-PR perf trajectory file).

    scripts/bench_pipeline.py             # measure quick + full profiles
    scripts/bench_pipeline.py --quick     # measure the quick profile only
    scripts/bench_pipeline.py --check     # quick measurement, compared to
                                          # the committed baseline: exits 1
                                          # if the chaining- OR cheap-phase
                                          # time regressed > 20% (skips
                                          # cleanly when no baseline exists)

Profiles are compared like-for-like (quick vs quick), so --check is immune
to the workload-size difference between profiles.  See EXPERIMENTS.md for
how to read the file.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

DEFAULT_OUT = REPO / "BENCH_pipeline.json"

PROFILES = {
    "quick": dict(n_reads=16, ref_events=8_000, junk_frac=0.5, repeats=5),
    "full": dict(n_reads=32, ref_events=20_000, junk_frac=0.5, repeats=7),
}

REGRESSION_TOL = 1.20      # --check fails beyond +20% chain-phase time
CHECK_BACKEND = "reference"     # backend whose chain_gate ratio is gated
CHECK_REPEATS = 25


def measure(profiles, **kw):
    from benchmarks import microbench
    out = {}
    for name in profiles:
        params = {**PROFILES[name], **kw}
        print(f"[bench_pipeline] measuring profile {name!r} "
              f"({params}) ...", flush=True)
        out[name] = microbench.run(**params)
        ref = out[name]["backends"]["reference"]
        print(f"[bench_pipeline] {name}: chain_pre={ref['chain_pre']*1e3:.2f}ms "
              f"chain_fast={ref['chain_fast']*1e3:.2f}ms "
              f"speedup={ref['chain_speedup']:.2f}x", flush=True)
        print(f"[bench_pipeline] {name}: cheap_pre={ref['cheap_pre']*1e3:.2f}ms "
              f"cheap_fast={ref['cheap_fast']*1e3:.2f}ms "
              f"speedup={ref['cheap_speedup']:.2f}x", flush=True)
    return out


def write(path: pathlib.Path, measured) -> None:
    # each profile record carries its own git_sha (stamped by
    # microbench.run), so profiles retained from an earlier run keep the
    # SHA they were actually measured at
    rec = {"schema": 1, "profiles": {}}
    if path.exists():
        try:
            old = json.loads(path.read_text())
            rec["profiles"] = old.get("profiles", {})
        except json.JSONDecodeError:
            pass
    rec["created_unix"] = int(time.time())
    rec["profiles"].update(measured)
    path.write_text(json.dumps(rec, indent=2, sort_keys=True) + "\n")
    print(f"[bench_pipeline] wrote {path}")


def measure_gate():
    """The interleaved pre/fast ratios on the quick workload — one record
    per gated phase (chain and cheap), both machine-speed independent (see
    microbench.bench_chain_ratio / bench_cheap_ratio)."""
    from benchmarks import microbench
    params = PROFILES["quick"]
    print(f"[bench_pipeline] measuring interleaved chain+cheap pre/fast "
          f"ratios ({params}) ...", flush=True)
    cfg, signals, arrays = microbench.make_workload(
        params["n_reads"], params["ref_events"], params["junk_frac"])
    chain = microbench.bench_chain_ratio(cfg, signals, arrays, CHECK_BACKEND,
                                         rounds=CHECK_REPEATS)
    chain["backend"] = CHECK_BACKEND
    cheap = microbench.bench_cheap_ratio(cfg, signals, arrays, CHECK_BACKEND,
                                         rounds=CHECK_REPEATS)
    cheap["backend"] = CHECK_BACKEND
    return chain, cheap


def check(path: pathlib.Path) -> int:
    """Regression gate on the chaining AND cheap phases, machine-speed
    independent: compares the median interleaved pre/fast speedup ratio of
    each phase against the baseline's identically-measured ``chain_gate`` /
    ``cheap_gate`` records.  A >20% rise in either phase's normalized time
    fails; a phase whose baseline record is absent skips cleanly."""
    if not path.exists():
        print(f"[bench_pipeline] no baseline at {path}; skipping "
              "regression check")
        return 0
    base = json.loads(path.read_text())
    prof = base.get("profiles", {}).get("quick", {})
    if not (prof.get("chain_gate") or prof.get("cheap_gate")):
        print("[bench_pipeline] baseline has no quick 'chain_gate'/"
              "'cheap_gate' record; skipping")
        return 0
    chain_cur, cheap_cur = measure_gate()
    failed = 0
    for phase, cur in (("chain", chain_cur), ("cheap", cheap_cur)):
        gate = prof.get(f"{phase}_gate")
        if not gate:
            print(f"[bench_pipeline] baseline has no quick '{phase}_gate' "
                  "record; skipping that phase")
            continue
        baseline = gate[f"{phase}_speedup_median"]
        current = cur[f"{phase}_speedup_median"]
        ratio = baseline / current          # >1: normalized time grew
        print(f"[bench_pipeline] {phase} speedup ({cur['backend']}): "
              f"baseline {baseline:.2f}x, current {current:.2f}x "
              f"-> normalized {phase} time {ratio:.2f}x")
        if ratio > REGRESSION_TOL:
            print(f"[bench_pipeline] FAIL: {phase} phase regressed "
                  f">{(REGRESSION_TOL - 1) * 100:.0f}%")
            failed = 1
    if not failed:
        print("[bench_pipeline] OK")
    return failed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="measure only the quick profile")
    ap.add_argument("--check", action="store_true",
                    help="compare a quick measurement against the committed "
                         "baseline instead of writing it")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    if args.check:
        return check(args.out)
    profiles = ("quick",) if args.quick else ("quick", "full")
    measured = measure(profiles)
    # every write refreshes the gate baselines with the same interleaved
    # estimators --check uses, so the comparison is like-for-like
    chain_gate, cheap_gate = measure_gate()
    measured["quick"]["chain_gate"] = chain_gate
    measured["quick"]["cheap_gate"] = cheap_gate
    write(args.out, measured)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
