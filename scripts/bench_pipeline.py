#!/usr/bin/env python
"""Measure the mapping pipeline's per-stage-group timings and persist them
to BENCH_pipeline.json at the repo root (the per-PR perf trajectory file).

    scripts/bench_pipeline.py             # measure quick + full profiles
    scripts/bench_pipeline.py --quick     # measure the quick profile only
                                          # (also skips the pallas serving
                                          # group — interpret-mode kernels
                                          # through the driver loop, ~22s)
    scripts/bench_pipeline.py --check     # quick measurement, compared to
                                          # the committed baseline: exits 1
                                          # if the chaining, cheap, serving,
                                          # tiered-cache, fused-kernel OR
                                          # multi-tenant fairness phase
                                          # regressed > 20% (skips cleanly
                                          # when no baseline exists)
    scripts/bench_pipeline.py --compiled  # opt-in: re-measure the quick
                                          # profile in compiled (non-
                                          # interpret) kernel mode and store
                                          # it under a hardware-keyed
                                          # ``compiled_<backend>`` profile;
                                          # prints a note and exits 0 on
                                          # CPU-only hosts where kernels
                                          # only run in interpret mode
    scripts/bench_pipeline.py --support   # print the kernel-backend
                                          # supports matrix (which
                                          # registered backends engage per
                                          # config) and exit

Profiles are compared like-for-like (quick vs quick), so --check is immune
to the workload-size difference between profiles.  The gate compares
interleaved pre/fast speedup RATIOS (never absolute ms), so it is safe on
CI runners whose absolute speed differs from the machine that measured the
committed baseline; each record still carries a ``machine`` hardware key
so cross-machine comparisons are visible.  ``BENCH_GATE_PCT`` overrides
the 20% tolerance (e.g. BENCH_GATE_PCT=35 on noisy shared runners).

The quick profile deliberately runs the pallas backend (and the fused
mega-kernel group) on a REDUCED read grid (``pallas_reduced_reads``):
interpret-mode kernels are ~100x slower than compiled ones, and the gate
ratios are per-read-normalized so the reduction keeps them honest.  Every
record carries ``grid_reads``/``grid_reduced`` markers so a reduced grid
is never mistaken for the full one.  See EXPERIMENTS.md for how to read
the file.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO))

DEFAULT_OUT = REPO / "BENCH_pipeline.json"

PROFILES = {
    # quick caps the interpret-mode pallas groups (incl. the fused kernel)
    # to a reduced read grid; records are marked grid_reduced=True
    "quick": dict(n_reads=16, ref_events=8_000, junk_frac=0.5, repeats=5,
                  pallas_reduced_reads=8),
    "full": dict(n_reads=32, ref_events=20_000, junk_frac=0.5, repeats=7),
}

GATE_PHASES = ("chain", "cheap", "serving", "cache", "fused", "fairness")
CHECK_BACKEND = "reference"     # backend whose gate ratios are gated
CHECK_REPEATS = 25
# the fused gate times interpret-mode pallas kernels (slow), so it runs
# fewer interleaved rounds than the jnp-only phases; the fairness gate is
# a deterministic virtual-clock count ratio — one round is exact
PHASE_ROUNDS = {"fused": 9, "fairness": 1}
# the fused gate is pallas-vs-pallas by construction (fused mega-kernel
# against the per-stage pallas program); the others gate CHECK_BACKEND
PHASE_BACKEND = {"fused": "pallas"}


def gate_tol() -> float:
    """Gate tolerance as a ratio: 1 + BENCH_GATE_PCT/100 (default 20%)."""
    return 1.0 + float(os.environ.get("BENCH_GATE_PCT", "20")) / 100.0


def hardware_key() -> dict:
    """The hardware/software fingerprint stamped into every measured
    profile and gate record (microbench.hardware_key): profiles retained
    from an earlier run keep the machine they were actually measured on."""
    from benchmarks import microbench
    return microbench.hardware_key()


def measure(profiles, **kw):
    from benchmarks import microbench
    out = {}
    for name in profiles:
        params = {**PROFILES[name], **kw}
        print(f"[bench_pipeline] measuring profile {name!r} "
              f"({params}) ...", flush=True)
        out[name] = microbench.run(**params)
        ref = out[name]["backends"]["reference"]
        print(f"[bench_pipeline] {name}: chain_pre={ref['chain_pre']*1e3:.2f}ms "
              f"chain_fast={ref['chain_fast']*1e3:.2f}ms "
              f"speedup={ref['chain_speedup']:.2f}x", flush=True)
        print(f"[bench_pipeline] {name}: cheap_pre={ref['cheap_pre']*1e3:.2f}ms "
              f"cheap_fast={ref['cheap_fast']*1e3:.2f}ms "
              f"speedup={ref['cheap_speedup']:.2f}x", flush=True)
        print(f"[bench_pipeline] {name}: serving_pre={ref['serving_pre']*1e3:.2f}ms "
              f"serving_fast={ref['serving_fast']*1e3:.2f}ms "
              f"speedup={ref['serving_speedup']:.2f}x "
              f"({ref['serving_streams_per_sec']:.1f} streams/s, "
              f"p99={ref['serving_p99_virtual']:.2f} virtual)", flush=True)
        fused = out[name]["fused"]
        print(f"[bench_pipeline] {name}: fused={fused['fused_fast']*1e3:.2f}ms "
              f"per-stage={fused['fused_pre']*1e3:.2f}ms "
              f"fused_gate={fused['fused_speedup']:.2f}x "
              f"({fused['fused_n_reads']} reads, {fused['fused_mode']} mode)",
              flush=True)
        fair = out[name]["fairness"]
        print(f"[bench_pipeline] {name}: fairness acme victims "
              f"legacy={fair['fairness_acme_victims_legacy']} "
              f"budgeted={fair['fairness_acme_victims_fair']} "
              f"isolation={fair['fairness_speedup']:.1f}x "
              f"(flood sheds={fair['fairness_flood_shed_fair']})",
              flush=True)
        cache = out[name]["cache"]
        print(f"[bench_pipeline] {name}: cache_resident="
              f"{cache['cache_resident']*1e3:.2f}ms "
              f"cache_tiered={cache['cache_tiered']*1e3:.2f}ms "
              f"ratio={cache['cache_speedup']:.2f}x "
              f"(hit_rate={cache['cache_hit_rate']:.2f}, "
              f"paged={cache['cache_paged_bytes']/2**20:.1f} MiB, "
              f"{cache['cache_slots']}/{cache['cache_n_tiles']} tiles "
              "resident)", flush=True)
    return out


def write(path: pathlib.Path, measured) -> None:
    # each profile record carries its own git_sha (stamped by
    # microbench.run), so profiles retained from an earlier run keep the
    # SHA they were actually measured at
    rec = {"schema": 1, "profiles": {}}
    if path.exists():
        try:
            old = json.loads(path.read_text())
            rec["profiles"] = old.get("profiles", {})
        except json.JSONDecodeError:
            pass
    rec["created_unix"] = int(time.time())
    rec["profiles"].update(measured)
    path.write_text(json.dumps(rec, indent=2, sort_keys=True) + "\n")
    print(f"[bench_pipeline] wrote {path}")


def measure_gate():
    """The interleaved pre/fast ratios on the quick workload — one record
    per gated phase (chain, cheap, serving, cache, fused, fairness), all
    machine-speed independent (see microbench.bench_chain_ratio /
    bench_cheap_ratio / bench_serving_ratio / bench_cache_ratio /
    bench_fused_ratio; bench_fairness_ratio is a deterministic
    virtual-clock count ratio rather than a timing)."""
    from benchmarks import microbench
    params = PROFILES["quick"]
    print(f"[bench_pipeline] measuring interleaved {'/'.join(GATE_PHASES)} "
          f"pre/fast ratios ({params}) ...", flush=True)
    cfg, signals, arrays = microbench.make_workload(
        params["n_reads"], params["ref_events"], params["junk_frac"])
    fns = dict(chain=microbench.bench_chain_ratio,
               cheap=microbench.bench_cheap_ratio,
               serving=microbench.bench_serving_ratio,
               cache=microbench.bench_cache_ratio,
               fused=microbench.bench_fused_ratio,
               fairness=microbench.bench_fairness_ratio)
    gates = {}
    for phase in GATE_PHASES:
        backend = PHASE_BACKEND.get(phase, CHECK_BACKEND)
        rec = fns[phase](cfg, signals, arrays, backend,
                         rounds=PHASE_ROUNDS.get(phase, CHECK_REPEATS))
        rec["backend"] = backend
        rec["machine"] = hardware_key()
        gates[phase] = rec
    return gates


def check(path: pathlib.Path) -> int:
    """Regression gate on the chaining, cheap, serving, tiered-cache,
    fused-kernel AND multi-tenant fairness phases, machine-speed
    independent: compares the median interleaved pre/fast
    speedup ratio of each phase against the baseline's identically-measured
    ``<phase>_gate`` record.  A rise in any phase's normalized time beyond
    ``gate_tol()`` (default 20%; BENCH_GATE_PCT overrides) fails; a phase
    whose baseline record is absent skips cleanly."""
    if not path.exists():
        print(f"[bench_pipeline] no baseline at {path}; skipping "
              "regression check")
        return 0
    base = json.loads(path.read_text())
    prof = base.get("profiles", {}).get("quick", {})
    if not any(prof.get(f"{p}_gate") for p in GATE_PHASES):
        print("[bench_pipeline] baseline has no quick "
              f"{'/'.join(p + '_gate' for p in GATE_PHASES)} record; "
              "skipping")
        return 0
    base_machine = prof.get("machine")
    if base_machine and base_machine != hardware_key():
        print(f"[bench_pipeline] note: baseline measured on {base_machine}, "
              f"running on {hardware_key()} — ratio gate is machine-"
              "independent, absolute ms are not comparable")
    tol = gate_tol()
    gates = measure_gate()
    failed = 0
    for phase in GATE_PHASES:
        cur = gates[phase]
        gate = prof.get(f"{phase}_gate")
        if not gate:
            print(f"[bench_pipeline] baseline has no quick '{phase}_gate' "
                  "record; skipping that phase")
            continue
        baseline = gate[f"{phase}_speedup_median"]
        current = cur[f"{phase}_speedup_median"]
        ratio = baseline / current          # >1: normalized time grew
        print(f"[bench_pipeline] {phase} speedup ({cur['backend']}): "
              f"baseline {baseline:.2f}x, current {current:.2f}x "
              f"-> normalized {phase} time {ratio:.2f}x")
        if ratio > tol:
            print(f"[bench_pipeline] FAIL: {phase} phase regressed "
                  f">{(tol - 1) * 100:.0f}%")
            failed = 1
    if not failed:
        print("[bench_pipeline] OK")
    return failed


def measure_compiled(path: pathlib.Path) -> int:
    """Opt-in compiled-mode profile: re-measure the quick workload with the
    Pallas kernels actually compiled (Mosaic/Triton) rather than
    interpreted, and store it under a ``compiled_<backend>`` profile keyed
    by the machine's hardware fingerprint.  The regression gates only ever
    read ``profiles["quick"]``, so a committed compiled profile never
    perturbs --check.  On CPU-only hosts (where kernels run in interpret
    mode by construction) this prints a note and exits 0 so the flag is
    safe in CI."""
    import jax
    backend = jax.default_backend()
    if backend == "cpu":
        print("[bench_pipeline] --compiled: jax backend is 'cpu', where "
              "Pallas kernels only run in interpret mode; nothing to "
              "measure.  Run on an accelerator host to record a "
              "compiled_<backend> profile.")
        return 0
    key = f"compiled_{backend}"
    print(f"[bench_pipeline] measuring compiled-mode quick profile "
          f"under {key!r} ...", flush=True)
    # compiled kernels are fast: run the full read grid (no reduction)
    measured = measure(("quick",), pallas_serving=True,
                       pallas_reduced_reads=0)
    rec = measured["quick"]
    rec["kernel_mode"] = "compiled"
    rec["machine"] = hardware_key()
    write(path, {key: rec})
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="measure only the quick profile")
    ap.add_argument("--check", action="store_true",
                    help="compare a quick measurement against the committed "
                         "baseline instead of writing it")
    ap.add_argument("--compiled", action="store_true",
                    help="measure a compiled-mode (non-interpret) quick "
                         "profile under a hardware-keyed compiled_<backend> "
                         "key; no-op on CPU-only hosts")
    ap.add_argument("--support", action="store_true",
                    help="print the kernel-backend supports matrix and exit")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    if args.support:
        sys.path.insert(0, str(REPO / "scripts"))
        import kernel_support
        return kernel_support.main()
    if args.compiled:
        return measure_compiled(args.out)
    if args.check:
        return check(args.out)
    profiles = ("quick",) if args.quick else ("quick", "full")
    measured = measure(profiles, pallas_serving=not args.quick)
    # every write refreshes the gate baselines with the same interleaved
    # estimators --check uses, so the comparison is like-for-like
    for phase, rec in measure_gate().items():
        measured["quick"][f"{phase}_gate"] = rec
    write(args.out, measured)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
