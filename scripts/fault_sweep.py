#!/usr/bin/env python
"""Seeded fault-injection sweep: the degraded-mode CI gate.

Runs the three failure regimes the fault-tolerant storage path must
survive — tile corruption, drive loss, overload — on a tiny deterministic
dataset and asserts the PR's bit-parity oracles:

  1. tile faults (core/faults.FaultPlan at the HotTileCache page-in
     boundary): every injected corruption / read failure is either healed
     by the checksummed retry loop — in which case MapOutput and the
     CHUNK_COUNTER_SCHEMA counters are byte-identical to the fault-free
     baseline — or raises a loud TileReadError.  NO silent wrong answers.
  2. drive loss: ``repartition_index`` folding any failed drive out of an
     N-way partitioning is bit-identical to ``partition_index`` at N/2.
  3. overload: the closed-loop ServeDriver (shed=True) sheds only
     sheddable reads under saturation, never the protected SLO class, and
     every served read still matches the batch mapper bit for bit.

Everything derives from ONE seed (--seed), so a red run reproduces
exactly.  Exit 0 = all oracles hold; exit 1 = a violation (printed).

    PYTHONPATH=src python scripts/fault_sweep.py [--seed 0] [--plans 50]
"""
from __future__ import annotations

import argparse
import math
import sys

import numpy as np

from repro.core import (FaultPlan, Mapper, MarsConfig, SLOClass,
                        TileReadError, build_index, partition_index,
                        repartition_index, sample_fault_plans)
from repro.signal import simulate


def setup(seed: int):
    cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
    ref = simulate.make_reference(8_000, seed=5 + seed)
    reads = simulate.sample_reads(ref, 24, signal_len=cfg.signal_len,
                                  seed=6 + seed, junk_frac=0.25)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    return cfg, idx, reads.signals


def sweep_tile_faults(cfg, idx, sig, base, n_plans: int, seed: int) -> int:
    healed = raised = bad = 0
    for i, plan in enumerate(sample_fault_plans(n_plans, seed=seed)):
        m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
                   fault_plan=plan)
        try:
            out = m.map_signals(sig, chunk=8)
        except TileReadError:
            raised += 1
            continue
        ok = (np.array_equal(np.asarray(out.t_start), np.asarray(base.t_start))
              and np.array_equal(np.asarray(out.score), np.asarray(base.score))
              and np.array_equal(np.asarray(out.mapped), np.asarray(base.mapped))
              and out.counters == base.counters)
        if ok:
            healed += 1
        else:
            bad += 1
            print(f"VIOLATION: plan #{i} ({plan}) served a SILENT wrong "
                  f"answer — neither healed parity nor TileReadError")
    print(f"[tile faults] {n_plans} plans: healed={healed} raised={raised} "
          f"silent-wrong={bad}")
    return bad


def sweep_drive_loss(idx) -> int:
    bad = 0
    for n in (2, 4, 8):
        fresh = partition_index(idx, n // 2)
        for failed in range(n):
            parts, remap = repartition_index(idx, n, failed)
            for k in fresh:
                if not np.array_equal(parts[k], fresh[k]):
                    bad += 1
                    print(f"VIOLATION: repartition_index({n}, failed="
                          f"{failed})[{k}] != partition_index({n // 2})")
            if failed in remap or len(remap) != n // 2:
                bad += 1
                print(f"VIOLATION: remap {remap} for n={n} failed={failed}")
    print(f"[drive loss] N in (2,4,8) x every failed drive: "
          f"{'parity holds' if not bad else f'{bad} violations'}")
    return bad


def sweep_overload(cfg, idx, sig, base, seed: int) -> int:
    bad = 0
    classes = [SLOClass("gold", priority=1, deadline=64.0, sheddable=False),
               SLOClass("best_effort")]
    srv = Mapper(idx, cfg).serve(chunk=8, shed=True, shed_window=4.0,
                                 slo_classes=classes)
    rng = np.random.default_rng(seed)
    trace = []
    for w in range(6):                 # ~36 reads/unit >> 8 rows/unit
        t = w * 0.5 + float(rng.uniform(0, 0.01))
        trace.append((t, f"g{w}", sig[:12], None, None, "gold"))
        trace.append((t, f"b{w}", sig[12:], None, None, "best_effort"))
    srv.serve_trace(trace)
    cr = srv.class_report()
    if srv.n_shed == 0:
        bad += 1
        print("VIOLATION: saturating trace shed nothing")
    if cr["gold"].n_shed != 0:
        bad += 1
        print(f"VIOLATION: protected class shed {cr['gold'].n_shed} reads")
    # every SERVED read still matches the batch mapper bit for bit
    for w in range(6):
        out = srv.results(f"g{w}")
        want = np.asarray(base.mapped)[:12]
        got = np.asarray(out.mapped)
        adm = np.asarray(srv.stream(f"g{w}").admitted)
        if not np.array_equal(got[adm], want[adm]):
            bad += 1
            print(f"VIOLATION: stream g{w} served results diverge")
    print(f"[overload] shed={srv.n_shed} "
          f"(gold={cr['gold'].n_shed}, "
          f"best_effort={cr.get('best_effort').n_shed if 'best_effort' in cr else 0}); "
          f"{'oracles hold' if not bad else f'{bad} violations'}")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plans", type=int, default=50,
                    help="fault plans in the tile sweep (acceptance floor "
                         "is 50)")
    args = ap.parse_args(argv)

    cfg, idx, sig = setup(args.seed)
    base = Mapper(idx, cfg).map_signals(sig, chunk=8)
    bad = sweep_tile_faults(cfg, idx, sig, base, args.plans, args.seed)
    bad += sweep_drive_loss(idx)
    bad += sweep_overload(cfg, idx, sig, base, args.seed)
    if bad:
        print(f"FAULT SWEEP FAILED: {bad} oracle violations (seed "
              f"{args.seed} reproduces)")
        return 1
    print(f"fault sweep OK (seed {args.seed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
