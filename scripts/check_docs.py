#!/usr/bin/env python
"""Docs honesty check: every path the docs cite must exist.

Scans the front-door docs (README.md, ROADMAP.md, docs/*.md) for

  * markdown links ``[text](target)`` — the target (external URLs and
    pure #anchors excluded) must resolve relative to the repo root;
  * path-like tokens in inline code spans and fenced code blocks — a
    token that contains a ``/`` or ends in a source/doc suffix must name
    an existing file or directory (repo-root relative; bare file names
    like ``stages.py`` may live anywhere in the tree).

Two resolution idioms beyond repo-root-relative are honoured, because
the docs use them throughout: ``core/...`` / ``kernels/...`` style
cites are ``src/repro``-relative, and ``core/index.TieredIndex`` style
cites name an attribute of a module whose ``.py`` file must exist.

Tokens that are clearly not paths are skipped: CLI flags (leading
``-``), absolute paths (not claims about this tree), dotted python
identifiers (``pipeline.map_chunk``), prose alternations whose first
segment is no known directory (``Stage/Backend``), anything with
characters outside ``[A-Za-z0-9_.@/-]`` (shell operators, tuple
syntax, ``query:ring`` backend names, ...).

Exit 0 when every reference resolves; otherwise print one line per
broken reference and exit 1.  CI runs this so README / ARCHITECTURE /
COUNTERS can never drift from the tree they describe; locally it is
also exercised by tests/test_docs.py.
"""
from __future__ import annotations

import os
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

DOC_FILES = ["README.md", "ROADMAP.md"]

# a token with one of these suffixes is a path claim even without a "/"
PATH_SUFFIXES = (".py", ".md", ".sh", ".json", ".txt", ".yml", ".yaml",
                 ".ini", ".toml", ".jsonl")

LINK_RE = re.compile(r"\[[^\]^]*\]\(([^)\s]+)\)")
CODE_SPAN_RE = re.compile(r"`([^`]+)`")
TOKEN_CHARS_RE = re.compile(r"[A-Za-z0-9_.@/\-]+")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".venv", "node_modules"}


def tree_names() -> set:
    """Every file and directory basename in the repo (for bare-name cites)."""
    names = set()
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        names.update(dirnames)
        names.update(filenames)
    return names


def known_first_segments() -> set:
    """Directory names a path cite may start with: the repo's top-level
    dirs plus src/repro's (for the ``core/...`` shorthand)."""
    segs = {p.name for p in ROOT.iterdir() if p.is_dir()}
    repro = ROOT / "src" / "repro"
    if repro.is_dir():
        segs |= {p.name for p in repro.iterdir() if p.is_dir()}
    return segs - SKIP_DIRS


def path_like(token: str, first_segs: set) -> bool:
    if token.startswith(("-", "/", "~")):
        return False              # CLI flag / absolute path (not a tree claim)
    if not TOKEN_CHARS_RE.fullmatch(token):
        return False              # shell syntax, tuples, colons, ...
    if "/" in token.rstrip("/"):
        # a slash token is a path claim only when it starts in a known
        # directory — "Stage/Backend" prose alternations are not
        return token.split("/", 1)[0] in first_segs
    return token.endswith(PATH_SUFFIXES)


def resolves(token: str, names: set) -> bool:
    rel = token.rstrip("/")
    for base in (ROOT, ROOT / "src" / "repro"):
        if (base / rel).exists():
            return True
        # module-attribute cite: core/index.TieredIndex -> core/index.py
        stem = rel.rsplit(".", 1)[0]
        if stem != rel and (base / (stem + ".py")).exists():
            return True
    # bare file/dir name (no directory part): may live anywhere in the tree
    return "/" not in rel and rel in names


def candidate_tokens(line: str, in_fence: bool, first_segs: set):
    """Path-claim candidates on one line: fenced lines wholesale, inline
    code spans otherwise, plus markdown link targets."""
    spans = [line] if in_fence else CODE_SPAN_RE.findall(line)
    for span in spans:
        for raw in span.split():
            tok = raw.strip("`\"'()[]{},;:").rstrip(".")
            if tok and path_like(tok, first_segs):
                yield tok
    for target in LINK_RE.findall(line):
        if "://" in target or target.startswith(("mailto:", "#")):
            continue
        tok = target.split("#", 1)[0]
        if tok:
            yield tok


def check_file(path: Path, names: set, first_segs: set) -> list:
    failures = []
    in_fence = False
    for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        for tok in candidate_tokens(line, in_fence, first_segs):
            if not resolves(tok, names):
                failures.append((path.relative_to(ROOT), lineno, tok))
    return failures


def main(argv=None) -> int:
    docs = [ROOT / f for f in DOC_FILES]
    docs += sorted((ROOT / "docs").glob("*.md"))
    docs = [d for d in docs if d.exists()]
    names = tree_names()
    first_segs = known_first_segments()
    failures = []
    for doc in docs:
        failures.extend(check_file(doc, names, first_segs))
    for rel, lineno, tok in failures:
        print(f"check_docs: {rel}:{lineno}: cited path does not exist: "
              f"{tok!r}", file=sys.stderr)
    n_docs = len(docs)
    if failures:
        print(f"check_docs: {len(failures)} broken reference(s) across "
              f"{n_docs} doc(s)", file=sys.stderr)
        return 1
    print(f"check_docs: OK ({n_docs} docs, every cited path resolves)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
