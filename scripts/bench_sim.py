#!/usr/bin/env python
"""Pin sim-vs-analytic agreement of the two CostModel backends to
BENCH_sim.json at the repo root.

The discrete-event in-storage simulator (core/sim/) must agree with the
closed forms of core/ssd_model.py to <1% on degenerate no-contention
configs — that identity is the simulator's calibration contract (see
EXPERIMENTS.md "Simulator methodology").  This script evaluates both
backends over PINNED synthetic paper-scale workloads (pure constants from
signal/datasets.py Table-2 numbers — no pipeline runs, so the record is
machine-independent and CI-fast) and writes/checks:

  * ``degenerate``  — analytic vs sim total over a channels x dies sweep;
                      hard gate: relative error < 1% everywhere;
  * ``figures``     — the Fig. 11/12/13 MARS quantities under both
                      backends; drift gate: sim/analytic within 5%;
  * ``serving``     — the virtual-clock queueing twins' p50 below
                      saturation; gate: within 10% (a seeded measured
                      percentile vs an Erlang-C closed form);
  * ``contended``   — the per-component busy/idle/utilization breakdown
                      the simulator adds over the closed forms on a
                      narrow-channel config (reported, not gated).

    scripts/bench_sim.py            # regenerate BENCH_sim.json
    scripts/bench_sim.py --check    # recompute + validate the gates and
                                    # the committed values (exit 1 on any
                                    # gate breach or value drift)

Every quantity here is deterministic (pinned workloads, seeded arrival
traces), so --check also pins the committed values to 0.1% — a silent
change to either backend's math fails CI loudly.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

DEFAULT_OUT = REPO / "BENCH_sim.json"

DEGENERATE_GATE = 0.01      # sim vs closed form, no-contention configs
FIGURE_GATE = 0.05          # sim/analytic drift on the figure quantities
SERVING_GATE = 0.10         # measured-percentile twin vs Erlang-C p50
PIN_TOL = 1e-3              # committed-value regression pin

# channels x chips_per_channel sweep for the degenerate identity
SWEEP = ((1, 1), (1, 8), (2, 2), (4, 8), (8, 8))

# Pinned per-read stage counts for the synthetic paper-scale workloads: a
# representative raw-signal profile (one seed per detected event, paper
# frequency-filter survival, band-16 DP).  These are FIXTURE constants —
# the measured-counter extrapolation lives in benchmarks/common.workload_for
# and feeds the EXPERIMENTS.md tables; this file only needs a deterministic
# workload shape to pin backend agreement on.
PER_READ = dict(n_events=450, n_seeds=420, n_hits_raw=3400,
                n_hits_exact=3800, n_hits_postfreq=900, n_votes=900,
                n_anchors_postvote=260, n_sorted=260, n_dp_pairs=4160)
INDEX_BYTES_PER_BASE = 14


def pinned_workload(ds_key: str):
    from repro.core.workload import Workload
    from repro.signal import datasets

    spec = datasets.DATASETS[ds_key]
    r = int(spec.paper_reads)
    n_samples = int(spec.paper_bytes // 2)          # int16 DAC samples
    counts = {k: v * r for k, v in PER_READ.items()}
    return Workload(
        n_reads=r, n_samples=n_samples, n_lookups=counts["n_seeds"],
        bytes_raw=int(spec.paper_bytes),
        bytes_index=int(spec.paper_genome_len * INDEX_BYTES_PER_BASE),
        bytes_intermediate=(counts["n_events"] * 2 + counts["n_seeds"] * 4
                            + counts["n_hits_raw"] * 8
                            + counts["n_sorted"] * 4),
        fixed_point=True, **counts)


def measure():
    from repro.core import costmodel, ssd_model

    ana = costmodel.get_model("analytic")
    sim = costmodel.get_model("sim")
    datasets_used = ("D1", "D3", "D5")              # small / mid / large
    rec = {"schema": 1, "datasets": list(datasets_used),
           "per_read": dict(PER_READ)}

    # --- degenerate identity sweep ------------------------------------- #
    deg = {}
    for ds in datasets_used:
        w = pinned_workload(ds)
        row = {}
        for ch, chips in SWEEP:
            ssd = dataclasses.replace(ssd_model.SSDConfig(), channels=ch,
                                      chips_per_channel=chips)
            a = ana.latency(w, ssd)["total"]
            s = sim.latency(w, ssd)["total"]
            row[f"{ch}x{chips}"] = dict(
                analytic=a, sim=s, rel_err=abs(s - a) / a)
        deg[ds] = row
    rec["degenerate"] = deg

    # --- figure quantities under both backends ------------------------- #
    figs = {"fig11_mars_total": {}, "fig12_mars_energy": {}, "fig13": {}}
    for ds in datasets_used:
        w = pinned_workload(ds)
        a_t, s_t = ana.latency(w)["total"], sim.latency(w)["total"]
        a_e, s_e = ana.energy(w), sim.energy(w)
        figs["fig11_mars_total"][ds] = dict(analytic=a_t, sim=s_t,
                                            ratio=s_t / a_t)
        figs["fig12_mars_energy"][ds] = dict(analytic=a_e, sim=s_e,
                                             ratio=s_e / a_e)
        a_d = ana.dram_sensitivity(w)
        s_d = sim.dram_sensitivity(w)
        figs["fig13"][ds] = {
            f"{sz >> 30}GB": dict(analytic=a_d[sz], sim=s_d[sz],
                                  ratio=s_d[sz] / a_d[sz])
            for sz in sorted(a_d)}
    rec["figures"] = figs

    # --- serving queue twins ------------------------------------------- #
    sv_a = ana.serving_virtual(8, 4.0)
    sv_s = sim.serving_virtual(8, 4.0)
    w = pinned_workload("D3")
    arr_a = ana.serving(w, offered_load=1.0 / ana.array_latency(w)["total"]
                        * w.n_reads * 0.5)
    arr_s = sim.serving(w, offered_load=1.0 / ana.array_latency(w)["total"]
                        * w.n_reads * 0.5)
    rec["serving"] = dict(
        virtual=dict(analytic_p50=sv_a["p50"], sim_p50=sv_s["p50"],
                     ratio=sv_s["p50"] / sv_a["p50"]),
        array=dict(analytic_p50=arr_a["p50"], sim_p50=arr_s["p50"],
                   ratio=arr_s["p50"] / arr_a["p50"]))

    # --- contended breakdown (sim-only observability) ------------------ #
    w = pinned_workload("D5")
    ssd = dataclasses.replace(ssd_model.SSDConfig(), channels=2,
                              chips_per_channel=2)
    lat = sim.latency(w, ssd)
    rec["contended"] = dict(
        config="channels=2 chips=2 (flash-starved)",
        total=lat["total"], analytic=ana.latency(w, ssd)["total"],
        controller_stall_flash=lat["controller"]["stall_flash"],
        components={name: dict(utilization=c["utilization"],
                               busy_time=c["busy_time"],
                               queue_delay=c["queue_delay"])
                    for name, c in lat["components"].items()})
    return rec


# --------------------------------------------------------------------------- #
# Gates
# --------------------------------------------------------------------------- #
def validate(rec) -> list:
    """The hard agreement gates, on a (re)computed record."""
    bad = []
    for ds, row in rec["degenerate"].items():
        for cfg, r in row.items():
            if r["rel_err"] >= DEGENERATE_GATE:
                bad.append(f"degenerate {ds}/{cfg}: sim diverges "
                           f"{100 * r['rel_err']:.2f}% (gate "
                           f"{100 * DEGENERATE_GATE:.0f}%)")
    for fig, rows in rec["figures"].items():
        for ds, r in rows.items():
            entries = r if "ratio" not in r else {"": r}
            for sub, e in entries.items():
                if abs(e["ratio"] - 1.0) >= FIGURE_GATE:
                    bad.append(f"{fig}/{ds}{('/' + sub) if sub else ''}: "
                               f"sim/analytic {e['ratio']:.3f} outside "
                               f"+-{100 * FIGURE_GATE:.0f}%")
    for q, r in rec["serving"].items():
        if abs(r["ratio"] - 1.0) >= SERVING_GATE:
            bad.append(f"serving/{q}: p50 ratio {r['ratio']:.3f} outside "
                       f"+-{100 * SERVING_GATE:.0f}%")
    return bad


def _pin_drift(base, cur, path="") -> list:
    """Recursive committed-vs-recomputed comparison (floats to PIN_TOL)."""
    bad = []
    if isinstance(base, dict):
        if not isinstance(cur, dict) or set(base) != set(cur):
            return [f"{path}: structure changed"]
        for k in base:
            bad += _pin_drift(base[k], cur[k], f"{path}/{k}")
    elif isinstance(base, float) or isinstance(cur, float):
        b, c = float(base), float(cur)
        scale = max(abs(b), abs(c), 1e-30)
        if not (math.isfinite(b) and math.isfinite(c)) or \
                abs(b - c) / scale > PIN_TOL:
            bad.append(f"{path}: committed {b!r} != recomputed {c!r}")
    elif base != cur:
        bad.append(f"{path}: committed {base!r} != recomputed {cur!r}")
    return bad


def check(path: pathlib.Path) -> int:
    if not path.exists():
        print(f"[bench_sim] no baseline at {path}; run scripts/bench_sim.py "
              "to create it")
        return 1
    base = json.loads(path.read_text())
    cur = measure()
    problems = validate(cur) + _pin_drift(base, cur)
    for p in problems:
        print(f"[bench_sim] FAIL: {p}")
    if problems:
        return 1
    n_cfg = sum(len(r) for r in cur["degenerate"].values())
    worst = max(r["rel_err"] for row in cur["degenerate"].values()
                for r in row.values())
    print(f"[bench_sim] OK: {n_cfg} degenerate configs within "
          f"{100 * DEGENERATE_GATE:.0f}% (worst {100 * worst:.3f}%), "
          f"figure + serving twins agree, committed values reproduced")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="recompute and validate against the committed "
                         "baseline instead of writing it")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    if args.check:
        return check(args.out)
    rec = measure()
    problems = validate(rec)
    for p in problems:
        print(f"[bench_sim] FAIL: {p}")
    if problems:
        return 1
    args.out.write_text(json.dumps(rec, indent=2, sort_keys=True) + "\n")
    print(f"[bench_sim] wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
