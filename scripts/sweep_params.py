"""Dev sweep: event-detection + chaining params vs accuracy."""
import itertools
import numpy as np
from repro.core import MarsConfig, build_index, Mapper, score_accuracy
from repro.signal import simulate

ref = simulate.make_reference(100_000, seed=0)
for tau, mcs, pw in itertools.product((2.5, 3.0, 4.0), (4.0, 6.0), (2, 3)):
    cfg = MarsConfig(tstat_threshold=tau, min_chain_score=mcs,
                     peak_window=pw).with_mode("ms_fixed")
    reads = simulate.sample_reads(ref, 64, signal_len=cfg.signal_len, seed=1,
                                  junk_frac=0.1)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    out = Mapper(idx, cfg).map_signals(reads.signals, chunk=64)
    acc = score_accuracy(out, reads.true_pos, reads.true_strand,
                         reads.mappable, reads.n_bases, ref.n_events)
    ev = out.counters["n_events"] / 64
    hits = out.counters["n_hits_raw"] / 64
    print(f"tau={tau} mcs={mcs} pw={pw}: P={acc['precision']:.3f} "
          f"R={acc['recall']:.3f} F1={acc['f1']:.3f} ev/read={ev:.0f} hits/read={hits:.0f}")
