#!/usr/bin/env bash
# Tier-1 verify: the fast, full-collection test pass.
#
#   scripts/run_tier1.sh            # fast pass (skips @slow property sweeps)
#   scripts/run_tier1.sh --all      # everything, including @slow
#   scripts/run_tier1.sh --bench    # fast pass + chaining-phase perf gate:
#                                   # runs scripts/bench_pipeline.py --check
#                                   # (quick profile) and fails on a >20%
#                                   # regression vs the committed
#                                   # BENCH_pipeline.json (skips cleanly
#                                   # when no baseline exists)
#   scripts/run_tier1.sh tests/test_pipeline.py   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARKER=(-m "not slow")
BENCH=0
while [[ "${1:-}" == "--all" || "${1:-}" == "--bench" ]]; do
    case "$1" in
        --all)   MARKER=() ;;
        --bench) BENCH=1 ;;
    esac
    shift
done

python -m pytest -x -q "${MARKER[@]}" "$@"

if [[ "$BENCH" == 1 ]]; then
    python scripts/bench_pipeline.py --check
fi
