#!/usr/bin/env bash
# Tier-1 verify: the fast, full-collection test pass.
#
#   scripts/run_tier1.sh            # fast pass (skips @slow property sweeps)
#   scripts/run_tier1.sh --all      # everything, including @slow
#   scripts/run_tier1.sh --bench    # fast pass + chain+cheap phase perf
#                                   # gates: runs scripts/bench_pipeline.py
#                                   # --check (quick profile) and fails on a
#                                   # >20% regression of either phase vs the
#                                   # committed BENCH_pipeline.json (skips
#                                   # cleanly when no baseline exists)
#   scripts/run_tier1.sh tests/test_pipeline.py   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Repo hygiene: compiled bytecode must never be tracked (a stray tracked
# .pyc shadows source edits for anyone with a stale checkout).
if tracked_pyc=$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$'); then
    echo "ERROR: tracked __pycache__/*.pyc paths (git rm them):" >&2
    echo "$tracked_pyc" >&2
    exit 1
fi

MARKER=(-m "not slow")
BENCH=0
while [[ "${1:-}" == "--all" || "${1:-}" == "--bench" ]]; do
    case "$1" in
        --all)   MARKER=() ;;
        --bench) BENCH=1 ;;
    esac
    shift
done

python -m pytest -x -q "${MARKER[@]}" "$@"

# Distributed parity: the partitioned-index query backends must stay
# bit-identical to single-device map_chunk on a multi-device CPU mesh.
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m pytest -x -q tests/test_distributed_stages.py

if [[ "$BENCH" == 1 ]]; then
    python scripts/bench_pipeline.py --check
fi
