#!/usr/bin/env bash
# Tier-1 verify: the fast, full-collection test pass.
#
#   scripts/run_tier1.sh            # fast pass (skips @slow property sweeps)
#   scripts/run_tier1.sh --all      # everything, including @slow
#   scripts/run_tier1.sh --bench    # fast pass + chain/cheap/serving/cache/
#                                   # fused/fairness phase gates: runs scripts/bench_pipeline.py
#                                   # --check (quick profile) and fails on a
#                                   # >20% regression of any gated phase vs the
#                                   # committed BENCH_pipeline.json (skips
#                                   # cleanly when no baseline exists;
#                                   # BENCH_GATE_PCT overrides the tolerance)
#   scripts/run_tier1.sh --ci       # the CI entry point: non-interactive,
#                                   # forces JAX_PLATFORMS=cpu, and fails on
#                                   # uncommitted BENCH_pipeline.json drift
#                                   # (the committed baseline must match the
#                                   # tree being tested). Combinable with
#                                   # --bench / --all / --faults.
#   scripts/run_tier1.sh --faults   # + the seeded fault-injection sweep
#                                   # (scripts/fault_sweep.py): tile
#                                   # corruption x drive loss x overload,
#                                   # deterministic from its seed
#   scripts/run_tier1.sh --sim      # + the cost-model agreement gate
#                                   # (scripts/bench_sim.py --check): the
#                                   # discrete-event simulator must match
#                                   # the analytic closed forms <1% on
#                                   # degenerate configs and reproduce the
#                                   # committed BENCH_sim.json values
#   scripts/run_tier1.sh tests/test_pipeline.py   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Repo hygiene: compiled bytecode must never be tracked (a stray tracked
# .pyc shadows source edits for anyone with a stale checkout).
if tracked_pyc=$(git ls-files | grep -E '(^|/)__pycache__/|\.pyc$'); then
    echo "ERROR: tracked __pycache__/*.pyc paths (git rm them):" >&2
    echo "$tracked_pyc" >&2
    exit 1
fi

MARKER=(-m "not slow")
BENCH=0
CI=0
FAULTS=0
SIM=0
while [[ "${1:-}" == "--all" || "${1:-}" == "--bench" || "${1:-}" == "--ci" \
         || "${1:-}" == "--faults" || "${1:-}" == "--sim" ]]; do
    case "$1" in
        --all)    MARKER=() ;;
        --bench)  BENCH=1 ;;
        --ci)     CI=1 ;;
        --faults) FAULTS=1 ;;
        --sim)    SIM=1 ;;
    esac
    shift
done

if [[ "$CI" == 1 ]]; then
    # one entry point for the workflow and local runs: no TTY interaction,
    # CPU-only JAX (CI runners have no accelerator; local runs become
    # reproducible), and the committed bench baseline must match the tree.
    export JAX_PLATFORMS=cpu
    export PYTHONUNBUFFERED=1
    if ! git diff --quiet HEAD -- BENCH_pipeline.json BENCH_sim.json; then
        echo "ERROR: uncommitted BENCH_pipeline.json/BENCH_sim.json drift —" >&2
        echo "commit the re-measured baseline or restore the committed one:" >&2
        git --no-pager diff --stat HEAD -- BENCH_pipeline.json BENCH_sim.json >&2
        exit 1
    fi
fi

python -m pytest -x -q "${MARKER[@]}" "$@"

# Distributed parity: the partitioned-index query backends AND the serving
# driver over them must stay bit-identical to single-device map_chunk /
# map_realtime on a multi-device CPU mesh.
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m pytest -x -q tests/test_distributed_stages.py \
        tests/test_distributed_serve.py

if [[ "$BENCH" == 1 ]]; then
    python scripts/bench_pipeline.py --check
fi

if [[ "$FAULTS" == 1 ]]; then
    # degraded-mode gate: tile corruption x drive loss x overload, seeded
    # so a red run reproduces exactly (scripts/fault_sweep.py --seed N)
    python scripts/fault_sweep.py
fi

if [[ "$SIM" == 1 ]]; then
    # cost-model agreement gate: the discrete-event simulator must stay
    # within 1% of the analytic closed forms on degenerate configs and
    # reproduce the committed BENCH_sim.json record (pinned workloads +
    # seeded traces => fully deterministic, no tolerance for drift)
    python scripts/bench_sim.py --check
fi
