#!/usr/bin/env bash
# Tier-1 verify: the fast, full-collection test pass.
#
#   scripts/run_tier1.sh            # fast pass (skips @slow property sweeps)
#   scripts/run_tier1.sh --all      # everything, including @slow
#   scripts/run_tier1.sh tests/test_pipeline.py   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARKER=(-m "not slow")
if [[ "${1:-}" == "--all" ]]; then
    MARKER=()
    shift
fi
exec python -m pytest -x -q "${MARKER[@]}" "$@"
