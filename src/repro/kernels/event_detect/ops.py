"""Public wrapper for the event-detection kernel (MARS fixed-point path)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import events as ev
from repro.core import stages
from repro.core.config import MarsConfig
from repro.kernels.event_detect.event_detect import event_detect_fixed


def event_detect(signals: jnp.ndarray, cfg: MarsConfig):
    """signals: (R, S) f32 raw.  Normalize + early-quantize on the host
    graph, segment + reduce in the Pallas kernel.

    Returns (means (R, E) f32, n_events (R,) int32) — matching
    core.events.detect_events_batch under the ms_fixed config.
    """
    assert cfg.fixed_point and cfg.early_quantization, (
        "kernel implements the MARS fixed-point path")
    x = ev.robust_normalize(signals)
    xq = ev.quantize_signal_fixed(x, cfg.frac_bits)
    tau2 = int(round(cfg.tstat_threshold ** 2))
    eps = 1 << (2 * cfg.frac_bits - 8)
    return event_detect_fixed(
        xq, E=cfg.max_events, w=cfg.tstat_window, tau2=tau2, eps=eps,
        peak_r=cfg.peak_window, frac_bits=cfg.frac_bits)


def _detect_pallas(state, cfg, index):
    """Per-read stage backend (state-dict protocol): a unit batch dim is
    added per read and batched away by vmap.  The batched chunk program does
    NOT use this — it calls the batch-level ``primitive`` below, so the
    kernel runs once per chunk at its native grid (the per-read wrapper's
    unit-batch vmap was the pathological pre-fast-path configuration the
    cheap-phase microbenchmark still measures as its "pre" side)."""
    detector = lambda s: tuple(x[0] for x in event_detect(s[None], cfg))
    return stages.detect_with(state, cfg, index, detector=detector)


def _detect_supports(cfg):
    """The kernel evaluates the integer boundary test in int32 — reject
    configs whose static worst case overflows (events.fixed_tstat_bounds),
    exactly like the reference path's guard."""
    return (cfg.fixed_point and cfg.early_quantization
            and ev.fixed_tstat_in_range(cfg))


stages.register_backend(
    "detect", stages.PALLAS, _detect_pallas,
    supports=_detect_supports,
    primitive=event_detect)
