from repro.kernels.event_detect.ops import event_detect  # noqa: F401
