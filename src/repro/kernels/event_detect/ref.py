"""Pure-jnp oracle for event_detect: the core pipeline's own path."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import events as ev
from repro.core.config import MarsConfig


def event_detect_ref(signals: jnp.ndarray, cfg: MarsConfig):
    means, n_ev, _ = ev.detect_events_batch(signals, cfg)
    return means, n_ev
