"""Event detection (signal -> events) as a Pallas TPU kernel.

Implements MARS's fixed-point event-detection stage (paper Sections 5.2 +
6.2): the early-quantized int16 signal is segmented with the integer
(sqrt-free) t-statistic boundary test and reduced to per-segment means.

TPU mapping of the near-DRAM Arithmetic Unit:
  * word-serial window sums  -> lane-shifted adds on the VPU (w <= 8 shifts);
  * per-sample boundary test -> branch-free integer compare vector;
  * the peak-pick            -> shifted max-accumulation;
  * event-id assignment      -> Hillis-Steele prefix sum (log2 S shift-adds);
  * segment mean reduction   -> one-hot matmul on the MXU:
        sums = x (1,S) @ onehot(eid) (S,E).

Block layout: one read per program — signal (1, S) int32 Q-format in VMEM,
outputs (1, E) f32 means and (1, 1) int32 event count.  All arithmetic
matches core/events.py (the pure-jnp oracle) bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K

_NEG = -3.0e38  # python float: jnp scalars would be captured as constants


def _shift_left(x, d, fill):
    """x: (1, S); returns x[:, i+d] with `fill` past the end (static d)."""
    if d == 0:
        return x
    S = x.shape[1]
    pad = jnp.full((1, d), fill, x.dtype)
    return jnp.concatenate([x[:, d:], pad], axis=1)


def _shift_right(x, d, fill):
    if d == 0:
        return x
    S = x.shape[1]
    pad = jnp.full((1, d), fill, x.dtype)
    return jnp.concatenate([pad, x[:, : S - d]], axis=1)


def _kernel(xq_ref, means_ref, nev_ref, *, S: int, E: int, w: int,
            tau2: int, eps: int, peak_r: int, frac_bits: int):
    x = xq_ref[...].astype(jnp.int32)                   # (1, S)

    # ---- windowed sums (truncated windows at the borders == zero fill) ----
    zero = jnp.int32(0)
    sum_r = jnp.zeros_like(x)
    sq_r = jnp.zeros_like(x)
    sum_l = jnp.zeros_like(x)
    sq_l = jnp.zeros_like(x)
    for d in range(w):
        xr = _shift_left(x, d, zero)                    # x[i+d]
        sum_r = sum_r + xr
        sq_r = sq_r + xr * xr
        xl = _shift_right(x, d + 1, zero)               # x[i-1-d]
        sum_l = sum_l + xl
        sq_l = sq_l + xl * xl

    # ---- integer boundary test (events.boundary_mask_fixed) ----
    diff = (sum_r - sum_l) >> 2
    ssd_l = w * sq_l - sum_l * sum_l
    ssd_r = w * sq_r - sum_r * sum_r
    lhs = diff * diff * w
    rhs = tau2 * (((ssd_l + ssd_r) >> 4) + eps)
    above = lhs > rhs
    score = lhs.astype(jnp.float32) / (rhs.astype(jnp.float32) + 1.0)

    # ---- peak pick: windowed max via shifts ----
    wmax = score
    for d in range(1, peak_r + 1):
        wmax = jnp.maximum(wmax, _shift_left(score, d, _NEG))
        wmax = jnp.maximum(wmax, _shift_right(score, d, _NEG))
    lmax = score
    for d in range(1, peak_r + 1):
        lmax = jnp.maximum(lmax, _shift_right(score, d, _NEG))
    boundary = (score >= wmax) & (score >= lmax) & above

    # ---- event ids: inclusive prefix sum (Hillis-Steele) ----
    eid = boundary.astype(jnp.int32)
    d = 1
    while d < S:
        eid = eid + _shift_right(eid, d, zero)
        d *= 2
    n_events = jnp.minimum(eid[0, S - 1] + 1, E)
    eid = jnp.minimum(eid, E - 1)                       # (1, S)

    # ---- segment means: one-hot matmul on the MXU ----
    bins = jax.lax.broadcasted_iota(jnp.int32, (S, E), 1)
    onehot = (eid.reshape(S, 1) == bins).astype(jnp.float32)   # (S, E)
    xf = x.astype(jnp.float32)                          # exact: |x| < 2^12
    sums = jax.lax.dot(xf, onehot, precision=jax.lax.Precision.HIGHEST)
    ones = jnp.ones((1, S), jnp.float32)
    cnts = jax.lax.dot(ones, onehot, precision=jax.lax.Precision.HIGHEST)
    means = sums / jnp.maximum(cnts, 1.0) / float(1 << frac_bits)

    means_ref[...] = means                              # (1, E)
    nev_ref[...] = n_events.reshape(1, 1)


@functools.partial(jax.jit,
                   static_argnames=("E", "w", "tau2", "eps", "peak_r",
                                    "frac_bits", "interpret"))
def event_detect_fixed(xq: jnp.ndarray, *, E: int, w: int, tau2: int,
                       eps: int, peak_r: int, frac_bits: int,
                       interpret: bool | None = None):
    """xq: (R, S) int16/int32 Q-format quantized signal.

    Returns (means (R, E) f32 normalized units, n_events (R,) int32).
    """
    if interpret is None:
        interpret = K.INTERPRET
    R, S = xq.shape
    kern = functools.partial(_kernel, S=S, E=E, w=w, tau2=tau2, eps=eps,
                             peak_r=peak_r, frac_bits=frac_bits)
    means, nev = pl.pallas_call(
        kern,
        grid=(R,),
        in_specs=[pl.BlockSpec((1, S), lambda r: (r, 0))],
        out_specs=[
            pl.BlockSpec((1, E), lambda r: (r, 0)),
            pl.BlockSpec((1, 1), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, E), jnp.float32),
            jax.ShapeDtypeStruct((R, 1), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=K.CompilerParams(
            dimension_semantics=("parallel",)),
    )(xq.astype(jnp.int32))
    return means, nev.reshape(R)
