"""Public wrappers for the bitonic sort kernel + its stage-engine backend."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import stages
from repro.kernels.bitonic_sort.bitonic_sort import MAX_BLOCK, bitonic_sort

_PAD = jnp.int32(0x7FFFFFFF)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def sort_batch(keys: jnp.ndarray) -> jnp.ndarray:
    """keys: (B, L) int32 -> each row sorted ascending."""
    B, L = keys.shape
    Lp = max(128, _next_pow2(L))
    if Lp > MAX_BLOCK:
        # beyond one VMEM block: fall back to XLA sort (documented limit;
        # the distributed pipeline shards anchors well below this).
        return jnp.sort(keys, axis=-1)
    if Lp != L:
        pad = jnp.full((B, Lp - L), _PAD, jnp.int32)
        keys = jnp.concatenate([keys, pad], axis=1)
    out = bitonic_sort(keys.astype(jnp.int32))
    return out[:, :L]


def sort1d(keys: jnp.ndarray) -> jnp.ndarray:
    """keys: (L,) int32 ascending.  vmap-safe via expand/squeeze."""
    return sort_batch(keys.reshape(1, -1))[0]


def _sort_pallas(state, cfg, index):
    """Stage backend: anchor sort on the bitonic Sorter/Merger kernel."""
    return stages.sort_with(state, cfg, index, sorter=sort1d)


# ``sort1d`` doubles as the fast-path sorter primitive: under the
# select-then-sort ladder (core/pipeline.chain_phase) it receives the (W,)
# selected keys and sorts a 128/512-lane block instead of the padded full
# E*H block.
stages.register_backend("sort", stages.PALLAS, _sort_pallas,
                        primitive=sort1d)
