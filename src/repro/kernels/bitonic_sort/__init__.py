from repro.kernels.bitonic_sort.ops import sort1d, sort_batch  # noqa: F401
