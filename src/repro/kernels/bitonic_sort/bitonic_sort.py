"""Bitonic sort network as a Pallas TPU kernel.

MARS sorts anchors with an in-controller bitonic Sorter (<=128 elements)
feeding a streaming bitonic Merger (paper Section 6.4).  On TPU the same
network maps onto vector registers: the compare-exchange partner at XOR
distance j is obtained by reversing sub-vectors of length 2j —

    x[i ^ j]  ==  reshape(rev(reshape(x, (L/2j, 2, j)), axis=1), (L,))

a pure layout operation (no gather), and the min/max select runs on the VPU.
Stages with k <= 128 correspond to MARS's Sorter-128 blocks; the k > 128
stages are the Merger's merge passes — one kernel expresses both units.

Block layout: one read's anchor keys per program, (1, L) int32 in VMEM,
L a power of two (<= 8192 -> 32 KiB).  Ascending sort; pad with INT32_MAX.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K

MAX_BLOCK = 8192


def _xor_swap(x: jnp.ndarray, j: int) -> jnp.ndarray:
    """x: (1, L) -> x[i ^ j] via sub-vector reversal (j power of two)."""
    L = x.shape[1]
    y = x.reshape(L // (2 * j), 2, j)
    y = jnp.flip(y, axis=1)
    return y.reshape(1, L)


def _kernel(x_ref, out_ref, *, L: int):
    x = x_ref[...]                                   # (1, L) int32
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)
    k = 2
    while k <= L:
        j = k // 2
        while j >= 1:
            p = _xor_swap(x, j)
            up = (lane & k) == 0 if k < L else jnp.ones((1, L), jnp.bool_)
            is_lo = (lane & j) == 0
            take_min = up == is_lo
            x = jnp.where(take_min, jnp.minimum(x, p), jnp.maximum(x, p))
            j //= 2
        k *= 2
    out_ref[...] = x


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort(keys: jnp.ndarray, interpret: bool | None = None):
    """keys: (B, L) int32, L power of two <= MAX_BLOCK.  Sorts each row
    ascending (grid over rows; each row = one Sorter/Merger stream)."""
    if interpret is None:
        interpret = K.INTERPRET
    B, L = keys.shape
    assert L & (L - 1) == 0 and L <= MAX_BLOCK, L
    return pl.pallas_call(
        functools.partial(_kernel, L=L),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, L), lambda b: (b, 0))],
        out_specs=pl.BlockSpec((1, L), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L), jnp.int32),
        interpret=interpret,
        compiler_params=K.CompilerParams(
            dimension_semantics=("parallel",)),
    )(keys)
