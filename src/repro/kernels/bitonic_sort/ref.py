"""Pure-jnp oracle for bitonic_sort."""
import jax.numpy as jnp


def sort_ref(keys: jnp.ndarray) -> jnp.ndarray:
    return jnp.sort(keys, axis=-1)
