"""Banded chaining DP as a Pallas TPU kernel.

MARS runs the chaining dynamic program on word-serial Arithmetic Units next
to the anchors in SSD-DRAM (paper Section 6.4).  The TPU analogue keeps one
read's sorted anchors resident in VMEM and walks them with a fori_loop whose
inner band (B predecessors) is a vector op — the band is the VPU lane
dimension, the anchor walk is the sequential axis.

Band state is a RING BUFFER: the carried loop state is only the four (B,)
band vectors (f/diag/t/q of the last B anchors); anchor i occupies slot
i % B and each step overwrites that one fixed slot with a lane-mask select.
Scores stream straight to the output refs with a dynamic single-element
store — nothing of size A is carried through the loop (the old kernel
dynamic-sliced a full (A+B,) array every step).  argmax ties resolve to the
OLDEST band anchor via the explicit age rank k = (slot - i) mod B, matching
the age-ordered window of core/chaining.chain_dp{,_reference} bit for bit.

Block layout: one read per program; q/t/valid (1, A) int32 blocks.  The
arithmetic matches core/chaining.chain_dp exactly (same jnp ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K

NEG = -1e9
_SENT = -(1 << 30)


def _kernel(q_ref, t_ref, v_ref, f_ref, d_ref, *, A: int, B: int,
            max_gap: int, gap_cost: float, skip_cost: float,
            anchor_score: float):
    q = q_ref[...].reshape(A)
    t = t_ref[...].reshape(A)
    v = v_ref[...].reshape(A) != 0
    lane = jnp.arange(B)

    def step(i, carry):
        bf, bd, bt, bq = carry
        ti, qi, vi = t[i], q[i], v[i]
        dt = ti - bt
        dq = qi - bq
        ok = (dt > 0) & (dq > 0) & (dt <= max_gap) & (dq <= max_gap)
        gap = jnp.abs(dt - dq).astype(jnp.float32)
        skip = jnp.minimum(dt, dq).astype(jnp.float32)
        cand = bf - gap_cost * gap - skip_cost * skip
        cand = jnp.where(ok & (bf > NEG / 2), cand, NEG)
        best = jnp.max(cand)
        # oldest-first tie-break: age rank k=0 is the oldest band slot
        k = (lane - i) % B
        kbest = jnp.min(jnp.where(cand == best, k, B))
        dbest = jnp.sum(jnp.where((cand == best) & (k == kbest), bd, 0))
        fi = anchor_score + jnp.maximum(best, 0.0)
        fi = jnp.where(vi, fi, NEG)
        di = jnp.where(best > 0.0, dbest, ti - qi)
        f_ref[0, pl.ds(i, 1)] = fi[None]
        d_ref[0, pl.ds(i, 1)] = di[None]
        wr = lane == i % B
        return (jnp.where(wr, fi, bf), jnp.where(wr, di, bd),
                jnp.where(wr, ti, bt), jnp.where(wr, qi, bq))

    init = (jnp.full((B,), NEG, jnp.float32), jnp.zeros((B,), jnp.int32),
            jnp.full((B,), _SENT, jnp.int32), jnp.full((B,), _SENT, jnp.int32))
    jax.lax.fori_loop(0, A, step, init)


@functools.partial(jax.jit,
                   static_argnames=("B", "max_gap", "gap_cost", "skip_cost",
                                    "anchor_score", "interpret"))
def chain_dp_kernel(q: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray, *,
                    B: int, max_gap: int, gap_cost: float, skip_cost: float,
                    anchor_score: float, interpret: bool | None = None):
    """q, t: (R, A) int32 sorted anchors; valid: (R, A) bool.

    Returns (f (R, A) f32, diag0 (R, A) int32).
    """
    if interpret is None:
        interpret = K.INTERPRET
    R, A = q.shape
    kern = functools.partial(_kernel, A=A, B=B, max_gap=max_gap,
                             gap_cost=gap_cost, skip_cost=skip_cost,
                             anchor_score=anchor_score)
    f, d = pl.pallas_call(
        kern,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, A), lambda r: (r, 0)),
            pl.BlockSpec((1, A), lambda r: (r, 0)),
            pl.BlockSpec((1, A), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, A), lambda r: (r, 0)),
            pl.BlockSpec((1, A), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, A), jnp.float32),
            jax.ShapeDtypeStruct((R, A), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=K.CompilerParams(
            dimension_semantics=("parallel",)),
    )(q, t, valid.astype(jnp.int32))
    return f, d
