"""Banded chaining DP as a Pallas TPU kernel.

MARS runs the chaining dynamic program on word-serial Arithmetic Units next
to the anchors in SSD-DRAM (paper Section 6.4).  The TPU analogue keeps one
read's sorted anchors resident in VMEM and walks them with a fori_loop whose
inner band (B predecessors) is a vector op — the band is the VPU lane
dimension, the anchor walk is the sequential axis.

Block layout: one read per program; q/t/valid (1, A) int32 blocks, band
window B read with dynamic slices from the carried (1, A+B) state.  The
arithmetic matches core/chaining.chain_dp exactly (same jnp ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K

NEG = -1e9
_SENT = -(1 << 30)


def _kernel(q_ref, t_ref, v_ref, f_ref, d_ref, *, A: int, B: int,
            max_gap: int, gap_cost: float, skip_cost: float,
            anchor_score: float):
    q = q_ref[...].reshape(A)
    t = t_ref[...].reshape(A)
    v = v_ref[...].reshape(A) != 0

    f0 = jnp.full((A + B,), NEG, jnp.float32)
    d0 = jnp.zeros((A + B,), jnp.int32)
    tp = jnp.concatenate([jnp.full((B,), _SENT, jnp.int32), t])
    qp = jnp.concatenate([jnp.full((B,), _SENT, jnp.int32), q])

    def step(i, carry):
        f, d = carry
        ti, qi, vi = t[i], q[i], v[i]
        fw = jax.lax.dynamic_slice(f, (i,), (B,))
        dw = jax.lax.dynamic_slice(d, (i,), (B,))
        tw = jax.lax.dynamic_slice(tp, (i,), (B,))
        qw = jax.lax.dynamic_slice(qp, (i,), (B,))
        dt = ti - tw
        dq = qi - qw
        ok = (dt > 0) & (dq > 0) & (dt <= max_gap) & (dq <= max_gap)
        gap = jnp.abs(dt - dq).astype(jnp.float32)
        skip = jnp.minimum(dt, dq).astype(jnp.float32)
        cand = fw - gap_cost * gap - skip_cost * skip
        cand = jnp.where(ok & (fw > NEG / 2), cand, NEG)
        bj = jnp.argmax(cand)
        best = cand[bj]
        ext = best > 0.0
        fi = anchor_score + jnp.maximum(best, 0.0)
        fi = jnp.where(vi, fi, NEG)
        di = jnp.where(ext, dw[bj], ti - qi)
        f = jax.lax.dynamic_update_slice(f, fi[None], (i + B,))
        d = jax.lax.dynamic_update_slice(d, di[None], (i + B,))
        return f, d

    f, d = jax.lax.fori_loop(0, A, step, (f0, d0))
    f_ref[...] = f[B:].reshape(1, A)
    d_ref[...] = d[B:].reshape(1, A)


@functools.partial(jax.jit,
                   static_argnames=("B", "max_gap", "gap_cost", "skip_cost",
                                    "anchor_score", "interpret"))
def chain_dp_kernel(q: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray, *,
                    B: int, max_gap: int, gap_cost: float, skip_cost: float,
                    anchor_score: float, interpret: bool | None = None):
    """q, t: (R, A) int32 sorted anchors; valid: (R, A) bool.

    Returns (f (R, A) f32, diag0 (R, A) int32).
    """
    if interpret is None:
        interpret = K.INTERPRET
    R, A = q.shape
    kern = functools.partial(_kernel, A=A, B=B, max_gap=max_gap,
                             gap_cost=gap_cost, skip_cost=skip_cost,
                             anchor_score=anchor_score)
    f, d = pl.pallas_call(
        kern,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, A), lambda r: (r, 0)),
            pl.BlockSpec((1, A), lambda r: (r, 0)),
            pl.BlockSpec((1, A), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, A), lambda r: (r, 0)),
            pl.BlockSpec((1, A), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, A), jnp.float32),
            jax.ShapeDtypeStruct((R, A), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=K.CompilerParams(
            dimension_semantics=("parallel",)),
    )(q, t, valid.astype(jnp.int32))
    return f, d
