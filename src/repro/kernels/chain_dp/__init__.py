from repro.kernels.chain_dp.ops import chain_dp  # noqa: F401
