"""Public wrapper for the chaining-DP kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import MarsConfig
from repro.kernels.chain_dp.chain_dp import chain_dp_kernel


def chain_dp(q: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray,
             cfg: MarsConfig):
    """q, t: (R, A) int32 sorted by (t, q); valid: (R, A) bool.
    Returns (f (R, A) f32, diag0 (R, A) int32)."""
    return chain_dp_kernel(
        q.astype(jnp.int32), t.astype(jnp.int32), valid,
        B=cfg.chain_band, max_gap=cfg.max_gap, gap_cost=cfg.gap_cost,
        skip_cost=cfg.skip_cost, anchor_score=cfg.anchor_score)
