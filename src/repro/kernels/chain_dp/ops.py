"""Public wrapper for the chaining-DP kernel + its stage-engine backend."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import stages
from repro.core.config import MarsConfig
from repro.kernels.chain_dp.chain_dp import chain_dp_kernel


def chain_dp(q: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray,
             cfg: MarsConfig):
    """q, t: (R, A) int32 sorted by (t, q); valid: (R, A) bool.
    Returns (f (R, A) f32, diag0 (R, A) int32)."""
    return chain_dp_kernel(
        q.astype(jnp.int32), t.astype(jnp.int32), valid,
        B=cfg.chain_band, max_gap=cfg.max_gap, gap_cost=cfg.gap_cost,
        skip_cost=cfg.skip_cost, anchor_score=cfg.anchor_score)


def dp_read(q: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray,
            cfg: MarsConfig):
    """Per-read (vmap-safe) view of the kernel: (A,) in, (A,) out — the
    ``dp`` primitive the chaining fast path consumes at any anchor width."""
    return tuple(x[0] for x in chain_dp(q[None], t[None], valid[None], cfg))


def _dp_pallas(state, cfg, index):
    """Stage backend: banded chaining DP on the Pallas kernel (the kernel
    is batch-level; the per-read stage adds/strips a unit batch dim, which
    vmap batches away)."""
    dp = lambda q, t, v: dp_read(q, t, v, cfg)
    return stages.dp_with(state, cfg, index, dp=dp)


stages.register_backend("dp", stages.PALLAS, _dp_pallas, primitive=dp_read)
