"""Pure-jnp oracle for chain_dp: the core pipeline's own scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import chaining
from repro.core.config import MarsConfig


def chain_dp_ref(q: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray,
                 cfg: MarsConfig):
    fn = lambda qq, tt, vv: chaining.chain_dp(qq, tt, vv, cfg)
    return jax.vmap(fn)(q, t, valid)
