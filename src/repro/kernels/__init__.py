"""Pallas TPU kernels for MARS's compute hot-spots.

Each kernel package has:
    <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py     — jit'd public wrapper (padding, dtype plumbing, vmap rules)
    ref.py     — pure-jnp oracle the kernel is tested against

Kernels target TPU; on this CPU-only container they run (and are tested)
in interpret mode.  `INTERPRET` flips automatically.
"""
import jax
from jax.experimental.pallas import tpu as _pltpu

INTERPRET = jax.default_backend() == "cpu"

# jax renamed TPUCompilerParams -> CompilerParams; support both.
CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))
if CompilerParams is None:
    def CompilerParams(**_kw):  # noqa: F811 — clear failure over NoneType
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; unsupported jax version")
