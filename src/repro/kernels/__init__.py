"""Pallas TPU kernels for MARS's compute hot-spots.

Each kernel package has:
    <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py     — jit'd public wrapper (padding, dtype plumbing, vmap rules)
    ref.py     — pure-jnp oracle the kernel is tested against

Kernels target TPU; on this CPU-only container they run (and are tested)
in interpret mode.  `INTERPRET` flips automatically.
"""
import jax

INTERPRET = jax.default_backend() == "cpu"
