"""Public wrapper for the pLUTo lookup kernel + its stage-engine backend."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import stages
from repro.kernels.pluto_lookup.pluto_lookup import BQ, BT, pluto_lookup


def _pad_to(x: jnp.ndarray, m: int, value) -> jnp.ndarray:
    r = (-x.shape[-1]) % m
    if r == 0:
        return x
    return jnp.concatenate([x, jnp.full((r,), value, x.dtype)])


def lookup(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """out[i] = table[clip(idx[i], 0, N-1)] — drop-in for jnp.take(mode='clip').

    table: (N,) int32/uint32/int16, idx: (..., ) int — any shape.
    Routes through the Pallas pLUTo kernel (one-hot MXU sweep).
    """
    orig_dtype = table.dtype
    orig_shape = idx.shape
    n = table.shape[0]
    idx_flat = jnp.clip(idx.reshape(-1).astype(jnp.int32), 0, n - 1)
    table32 = table.astype(jnp.int32) if orig_dtype != jnp.int32 else table
    tp = _pad_to(table32, BT, 0)
    ip = _pad_to(idx_flat, BQ, 0)
    out = pluto_lookup(tp, ip)[: idx_flat.shape[0]]
    return out.reshape(orig_shape).astype(orig_dtype)


def _query_pallas(state, cfg, index):
    """Stage backend: hash-table query with pLUTo-kernel gathers."""
    return stages.query_with(state, cfg, index, gather=lookup)


stages.register_backend("query", stages.PALLAS, _query_pallas)
