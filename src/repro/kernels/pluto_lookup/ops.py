"""Public wrapper for the pLUTo lookup kernel + its stage-engine backend."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import stages
from repro.kernels.pluto_lookup.pluto_lookup import (BQ, BT, pluto_lookup,
                                                     pluto_lookup_rows)


def _pad_to(x: jnp.ndarray, m: int, value) -> jnp.ndarray:
    r = (-x.shape[-1]) % m
    if r == 0:
        return x
    pad = jnp.full(x.shape[:-1] + (r,), value, x.dtype)
    return jnp.concatenate([x, pad], axis=-1)


def lookup(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Pallas pLUTo gather (one-hot MXU sweep) — drop-in for the
    ``seeding`` gather contract:

    * table (N,): out[i] = table[clip(idx[i], 0, N-1)], idx any shape;
    * table (W, N) packed rows: returns (W, *idx.shape) — every word of
      each queried row from ONE table sweep (``pluto_lookup_rows``).
    """
    orig_dtype = table.dtype
    orig_shape = idx.shape
    n = table.shape[-1]
    idx_flat = jnp.clip(idx.reshape(-1).astype(jnp.int32), 0, n - 1)
    table32 = table.astype(jnp.int32) if orig_dtype != jnp.int32 else table
    tp = _pad_to(table32, BT, 0)
    ip = _pad_to(idx_flat, BQ, 0)
    if table.ndim == 2:
        out = pluto_lookup_rows(tp, ip)[:, : idx_flat.shape[0]]
        return out.reshape(table.shape[0], *orig_shape).astype(orig_dtype)
    out = pluto_lookup(tp, ip)[: idx_flat.shape[0]]
    return out.reshape(orig_shape).astype(orig_dtype)


def _query_pallas(state, cfg, index):
    """Stage backend: hash-table query with pLUTo-kernel gathers."""
    return stages.query_with(state, cfg, index, gather=lookup)


# ``primitive`` exposes the raw gather to the batch-level cheap phase
# (core/pipeline.cheap_phase): one whole-chunk (2, R, E, H) fused gather of
# the packed entry plane lowers to ONE pLUTo kernel sweep instead of
# per-read unit batches.
stages.register_backend("query", stages.PALLAS, _query_pallas,
                        primitive=lookup)
