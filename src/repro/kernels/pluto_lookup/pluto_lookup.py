"""pLUTo-style LUT lookup as an MXU one-hot matmul sweep.

MARS's Querying Unit (paper Section 6.3 / pLUTo) answers `out[i] =
table[idx[i]]` by sweeping DRAM rows: activate each candidate row, compare
its index against the keys latched in the source row buffer, and let gated
sense amplifiers copy matching values out.  The TPU-native analogue keeps
the table in VMEM tiles and expresses the same row sweep as a matmul:

    out = onehot(idx - tile_offset) @ table_tile            (MXU)

accumulated over table tiles (the grid's inner dimension).  Because f32
matmuls are only exact below 2^24, 32-bit table values are split into two
16-bit halves and recombined — two matmuls per tile, both exact.

Block layout: queries (1, BQ) int32, table tile (1, BT) int32,
output (1, BQ) int32 accumulated across the table-tile grid axis.

``pluto_lookup_rows`` is the packed-row variant (the cheap-phase fast
path): the table holds W-word rows ((W, N) int32) and ONE sweep answers
every query with its whole row — exactly pLUTo's row-wide activation,
where the gated sense amplifiers copy the full DRAM row, not one word.
The W x 2 16-bit half-planes fold into a single (BT, 2W) operand so each
tile still costs one one-hot matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K

BQ = 256          # queries per block (2 sublanes x 128 lanes)
BT = 512          # table entries per block


def _kernel(idx_ref, table_ref, out_ref):
    ti = pl.program_id(1)                      # table-tile index

    @pl.when(ti == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]                         # (1, BQ) int32
    tab = table_ref[...]                       # (1, BT) int32
    offset = ti * BT
    local = idx - offset                       # (1, BQ)
    # one-hot match matrix (BQ, BT): row-sweep compare of pLUTo
    lanes = jax.lax.broadcasted_iota(jnp.int32, (BQ, BT), 1)
    onehot = (local.reshape(BQ, 1) == lanes).astype(jnp.float32)
    # split 32-bit values into exact f32 halves (<= 2^16)
    hi = jnp.right_shift(tab, 16).astype(jnp.float32).reshape(BT, 1)
    lo = jnp.bitwise_and(tab, 0xFFFF).astype(jnp.float32).reshape(BT, 1)
    got_hi = jax.lax.dot(onehot, hi, precision=jax.lax.Precision.HIGHEST)
    got_lo = jax.lax.dot(onehot, lo, precision=jax.lax.Precision.HIGHEST)
    val = (got_hi.astype(jnp.int32) << 16) | got_lo.astype(jnp.int32)
    out_ref[...] += val.reshape(1, BQ)


def _kernel_rows(idx_ref, table_ref, out_ref, *, W: int):
    ti = pl.program_id(1)                      # table-tile index

    @pl.when(ti == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]                         # (1, BQ) int32
    tab = table_ref[...]                       # (W, BT) int32
    offset = ti * BT
    local = idx - offset                       # (1, BQ)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (BQ, BT), 1)
    onehot = (local.reshape(BQ, 1) == lanes).astype(jnp.float32)
    # all W rows' 16-bit halves as one (BT, 2W) operand: one matmul per tile
    hi = jnp.right_shift(tab, 16).astype(jnp.float32)          # (W, BT)
    lo = jnp.bitwise_and(tab, 0xFFFF).astype(jnp.float32)
    planes = jnp.concatenate([hi, lo], axis=0).T               # (BT, 2W)
    got = jax.lax.dot(onehot, planes, precision=jax.lax.Precision.HIGHEST)
    val = ((got[:, :W].astype(jnp.int32) << 16)
           | got[:, W:].astype(jnp.int32))                     # (BQ, W)
    out_ref[...] += val.T                                      # (W, BQ)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pluto_lookup_rows(table: jnp.ndarray, idx: jnp.ndarray,
                      interpret: bool | None = None) -> jnp.ndarray:
    """table: (W, N) int32 packed rows, idx: (Q,) int32 in [0, N).
    Returns (W, Q) int32 — every word of each queried row from ONE table
    sweep.  N and Q are padded to BT/BQ multiples by ops.lookup."""
    if interpret is None:
        interpret = K.INTERPRET
    Q, (W, N) = idx.shape[0], table.shape
    assert Q % BQ == 0 and N % BT == 0, (Q, N)
    grid = (Q // BQ, N // BT)
    out = pl.pallas_call(
        functools.partial(_kernel_rows, W=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ), lambda qi, ti: (0, qi)),
            pl.BlockSpec((W, BT), lambda qi, ti: (0, ti)),
        ],
        out_specs=pl.BlockSpec((W, BQ), lambda qi, ti: (0, qi)),
        out_shape=jax.ShapeDtypeStruct((W, Q), jnp.int32),
        interpret=interpret,
        compiler_params=K.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(idx.reshape(1, Q), table)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def pluto_lookup(table: jnp.ndarray, idx: jnp.ndarray,
                 interpret: bool | None = None) -> jnp.ndarray:
    """table: (N,) int32, idx: (Q,) int32 in [0, N). Returns (Q,) int32.

    N and Q are padded to BT/BQ multiples by ops.lookup; call through there.
    """
    if interpret is None:
        interpret = K.INTERPRET
    Q, N = idx.shape[0], table.shape[0]
    assert Q % BQ == 0 and N % BT == 0, (Q, N)
    grid = (Q // BQ, N // BT)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ), lambda qi, ti: (0, qi)),
            pl.BlockSpec((1, BT), lambda qi, ti: (0, ti)),
        ],
        out_specs=pl.BlockSpec((1, BQ), lambda qi, ti: (0, qi)),
        out_shape=jax.ShapeDtypeStruct((1, Q), jnp.int32),
        interpret=interpret,
        compiler_params=K.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(idx.reshape(1, Q), table.reshape(1, N))
    return out.reshape(Q)
