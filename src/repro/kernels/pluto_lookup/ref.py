"""Pure-jnp oracle for pluto_lookup."""
import jax.numpy as jnp


def lookup_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, idx, axis=0, mode="clip")
