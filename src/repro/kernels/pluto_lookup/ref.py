"""Pure-jnp oracle for pluto_lookup (1-D tables and (W, N) packed rows)."""
import jax.numpy as jnp


def lookup_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, idx, axis=table.ndim - 1, mode="clip")
