from repro.kernels.pluto_lookup.ops import lookup  # noqa: F401
