"""Fused cheap-phase mega-kernel: detect -> quantize -> seed -> query -> vote.

One `pl.pallas_call` executes the whole cheap phase for a block of reads
without leaving the kernel.  The quantized signal block is staged into VMEM
by the grid pipeline; event means, quantized symbols and seed keys live in
registers/scratch instead of round-tripping through HBM between stage
launches; and the packed 2-plane index (`bucket_start` + `entries_packed`)
stays in `pltpu.ANY` memory and is streamed tile-by-tile through VMEM
scratch with double-buffered `pltpu.make_async_copy` DMA — the
`emit_pipeline` idiom spelled out by hand: while tile t is being probed
(one-hot matmul gather, split into exact hi/lo 16-bit f32 planes), the DMA
for tile t+1 is already in flight.  This mirrors the HotTileCache's
host->device prefetch one level down, and MARS's flash-load/compute overlap
one level up.

The math is copied operation-for-operation from the per-stage path so the
fusion is bit-identical:

    detect     kernels/event_detect/event_detect.py::_kernel
    quantize   core/quantization.py::quantize_events_fixed
    seed       core/hashing.py::pack_seeds (+ mix32, minimizer_mask)
    query      core/seeding.py::query_index / unpack_entries / match_entries
    vote       core/vote.py::vote_filter

Tiling is chosen by `tune_tile` — a deliberately tiny grid in interpret
mode (CPU CI), MXU/warp-friendly blocks for Mosaic (TPU) and Triton (GPU).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import kernels as K
from repro.core import hashing
from repro.core.vote import DIAG_SHIFT

_NEG = -3.0e38  # ~f32 min; avoids jnp.finfo weak-type traps inside pallas
_HIGHEST = jax.lax.Precision.HIGHEST

# Column order of the fused kernel's per-read counter plane.
COUNTER_COLS = (
    "n_events", "n_seeds", "n_bucket_probes", "n_hits_raw",
    "n_hits_postfreq", "n_hits_exact", "n_votes_cast",
    "n_anchors_postvote", "n_votes_clipped",
)


@dataclasses.dataclass(frozen=True)
class FusedTile:
    """Grid/block-shape choice for the mega-kernel.

    r_blk — reads per kernel program (grid = n_reads_padded // r_blk)
    bt    — index-tile width in entries for the double-buffered DMA sweep
    """
    r_blk: int
    bt: int


def tune_tile(platform: str) -> FusedTile:
    """Autotuning hook: pick grid/block shapes per lowering target.

    `platform` is `jax.default_backend()` ("tpu" / "gpu" / "cpu") or the
    literal "interpret".  Interpret mode keeps the grid deliberately small
    so the CPU CI parity suite stays fast; the Mosaic and Triton entries
    are the seed points a real autotune sweep would refine on hardware.
    """
    if platform in ("cpu", "interpret"):
        return FusedTile(r_blk=1, bt=512)
    if platform == "tpu":
        # Mosaic: 8-row blocks keep the one-hot matmuls MXU-shaped; 2048-
        # entry tiles amortize DMA issue latency against VMEM pressure.
        return FusedTile(r_blk=8, bt=2048)
    # Triton (GPU): smaller tiles — gathers are shared-memory bound.
    return FusedTile(r_blk=4, bt=1024)


def _shift_left(x, d, fill):
    """x[:, i+d] with `fill` entering on the right (lanes-axis shift)."""
    if d == 0:
        return x
    rows = x.shape[0]
    pad = jnp.full((rows, d), fill, dtype=x.dtype)
    return jnp.concatenate([x[:, d:], pad], axis=1)


def _shift_right(x, d, fill):
    """x[:, i-d] with `fill` entering on the left."""
    if d == 0:
        return x
    rows = x.shape[0]
    pad = jnp.full((rows, d), fill, dtype=x.dtype)
    return jnp.concatenate([pad, x[:, :-d]], axis=1)


def _roll_left(x, d):
    """Circular jnp.roll(x, -d, axis=1) — wraparound must match pack_seeds
    exactly: raw t_pos is compared at *invalid* seed slots too, so the
    garbage keys there still have to be the same garbage."""
    if d == 0:
        return x
    return jnp.concatenate([x[:, d:], x[:, :d]], axis=1)


def _prefix_sum(x):
    """Inclusive Hillis-Steele prefix sum along the lanes axis (int32)."""
    span = x.shape[1]
    d = 1
    while d < span:
        x = x + _shift_right(x, d, 0)
        d *= 2
    return x


def _sweep_gather(src_ref, buf, sem, n_tiles, bt, qcol, nrows):
    """Double-buffered DMA sweep-gather over a (nrows, n_tiles*bt) table.

    Streams the table tile-by-tile from `pltpu.ANY` memory into the
    2-slot VMEM scratch `buf`, starting the copy of tile t+1 before
    probing tile t (hand-rolled `pltpu.emit_pipeline`).  Each tile is
    probed with a one-hot f32 matmul gather, exact because the int32
    values are split into hi/lo 16-bit planes (<= 2^16 in f32) and
    out-of-tile queries contribute zero rows.

    qcol: (Q, 1) int32 global column indices (pre-clipped in range).
    Returns (Q, nrows) int32 gathered values.
    """
    q = qcol.shape[0]

    def dma(slot, t):
        return pltpu.make_async_copy(
            src_ref.at[:, pl.ds(t * bt, bt)], buf.at[slot], sem.at[slot])

    dma(0, 0).start()
    lanes = jax.lax.broadcasted_iota(jnp.int32, (q, bt), 1)

    def body(t, acc):
        slot = jax.lax.rem(t, 2)

        @pl.when(t + 1 < n_tiles)
        def _():
            dma(1 - slot, t + 1).start()

        dma(slot, t).wait()
        tab = buf[slot]                                   # (nrows, bt) i32
        onehot = (qcol - t * bt == lanes).astype(jnp.float32)
        hi = jnp.right_shift(tab, 16).astype(jnp.float32)
        lo = jnp.bitwise_and(tab, 0xFFFF).astype(jnp.float32)
        planes = jnp.concatenate([hi, lo], axis=0).T      # (bt, 2*nrows)
        return acc + jax.lax.dot(onehot, planes, precision=_HIGHEST)

    acc = jax.lax.fori_loop(
        0, n_tiles, body, jnp.zeros((q, 2 * nrows), jnp.float32))
    return (jnp.left_shift(acc[:, :nrows].astype(jnp.int32), 16)
            | acc[:, nrows:].astype(jnp.int32))


def _kernel(xq_ref, bs_ref, ent_ref, tpos_ref, hit_ref, cnt_ref,
            bs_buf, ent_buf, bs_sem, ent_sem, *,
            n_ev_max, hits, tw, tau2, eps, peak_r, frac_bits,
            seed_w, seed_q, minimizer_r, levels, clip_q, step_q,
            n_buckets, n_entries, thresh_freq, use_freq, use_vote,
            vlog2, nbins, thresh_vote, bt, nt_bs, nt_ent):
    x = xq_ref[...]                                       # (RB, S) int32
    rb, s = x.shape
    e, h = n_ev_max, hits
    eh = e * h
    f32, i32 = jnp.float32, jnp.int32

    # ---- detect (event_detect._kernel, generalized to RB rows) ----------
    xx = x * x
    sum_l = jnp.zeros_like(x)
    sum_r = jnp.zeros_like(x)
    sq_l = jnp.zeros_like(x)
    sq_r = jnp.zeros_like(x)
    for d in range(tw):
        sum_l = sum_l + _shift_right(x, d + 1, 0)
        sq_l = sq_l + _shift_right(xx, d + 1, 0)
        sum_r = sum_r + _shift_left(x, d, 0)
        sq_r = sq_r + _shift_left(xx, d, 0)
    diff = (sum_r - sum_l) >> 2
    ssd_l = tw * sq_l - sum_l * sum_l
    ssd_r = tw * sq_r - sum_r * sum_r
    lhs = diff * diff * tw
    rhs = tau2 * (((ssd_l + ssd_r) >> 4) + eps)
    above = lhs > rhs
    score = lhs.astype(f32) / (rhs.astype(f32) + 1.0)

    wmax = score
    for d in range(1, peak_r + 1):
        wmax = jnp.maximum(wmax, _shift_left(score, d, _NEG))
        wmax = jnp.maximum(wmax, _shift_right(score, d, _NEG))
    lmax = score
    for d in range(1, peak_r + 1):
        lmax = jnp.maximum(lmax, _shift_right(score, d, _NEG))
    boundary = (score >= wmax) & (score >= lmax) & above

    eid = _prefix_sum(boundary.astype(i32))
    nev = jnp.minimum(eid[:, s - 1:s] + 1, e)             # (RB, 1)
    eid = jnp.minimum(eid, e - 1)

    xf = x.astype(f32)
    ones = jnp.ones((1, s), f32)
    bins_se = jax.lax.broadcasted_iota(i32, (s, e), 1)
    rows = []
    for r in range(rb):
        onehot = (eid[r:r + 1].reshape(s, 1) == bins_se).astype(f32)
        sums = jax.lax.dot(xf[r:r + 1], onehot, precision=_HIGHEST)
        cnts = jax.lax.dot(ones, onehot, precision=_HIGHEST)
        rows.append(sums / jnp.maximum(cnts, 1.0) / float(1 << frac_bits))
    means = rows[0] if rb == 1 else jnp.concatenate(rows, axis=0)

    # ---- quantize (quantization.quantize_events_fixed, row-vectorized) --
    eq = jnp.round(means * (1 << frac_bits)).astype(i32)  # (RB, E)
    iota_e = jax.lax.broadcasted_iota(i32, (rb, e), 1)
    ev_valid = iota_e < nev
    v = ev_valid.astype(i32)
    n = jnp.maximum(jnp.sum(v, axis=1, keepdims=True), 1)
    mean = jnp.sum(eq * v, axis=1, keepdims=True) // n
    dlt = eq - mean
    d2 = dlt >> 1
    var = (jnp.sum(d2 * d2 * v, axis=1, keepdims=True) // n) << 2
    std = jax.lax.fori_loop(
        0, 24, lambda _, g: (g + var // jnp.maximum(g, 1)) // 2,
        jnp.maximum(var, 1))
    std = jnp.maximum(std, 1)
    z_q = jnp.clip((dlt << frac_bits) // std, -clip_q, clip_q - 1)
    sym = jnp.clip((z_q + clip_q) // max(step_q, 1), 0, levels - 1)

    # ---- seed (hashing.pack_seeds + mix32 + minimizer_mask) -------------
    su = sym.astype(jnp.uint32)
    key = jnp.zeros((rb, e), jnp.uint32)
    for j in range(seed_w):
        key = (key << seed_q) | _roll_left(su, j)
    key = hashing.mix32(key)
    seed_valid = (iota_e + seed_w) <= nev
    if minimizer_r > 0:
        big = jnp.uint32(0xFFFFFFFF)
        kv = jnp.where(seed_valid, key, big)
        wmin = kv
        for d in range(1, minimizer_r + 1):
            wmin = jnp.minimum(wmin, _shift_left(kv, d, big))
            wmin = jnp.minimum(wmin, _shift_right(kv, d, big))
        seed_valid = seed_valid & (kv == wmin)

    # ---- query (seeding.query_index on the streamed 2-plane index) ------
    mask_u = jnp.uint32(n_buckets - 1)
    bucket = (key & mask_u).astype(i32)                   # (RB, E)
    qb = jnp.concatenate([bucket, bucket + 1], axis=1)    # (RB, 2E)
    se = _sweep_gather(bs_ref, bs_buf, bs_sem, nt_bs, bt,
                       qb.reshape(rb * 2 * e, 1), nrows=1)
    se = se.reshape(rb, 2 * e)
    start, end = se[:, :e], se[:, e:]
    cnt_bucket = end - start

    idx = (jnp.broadcast_to(start.reshape(rb, e, 1), (rb, e, h))
           + jax.lax.broadcasted_iota(i32, (rb, e, h), 2)).reshape(rb, eh)
    idx_c = jnp.minimum(idx, n_entries - 1)
    ent = _sweep_gather(ent_ref, ent_buf, ent_sem, nt_ent, bt,
                        idx_c.reshape(rb * eh, 1), nrows=2)
    word0 = ent[:, 0:1].reshape(rb, eh)
    t_pos = ent[:, 1:2].reshape(rb, eh)

    # unpack_entries + match_entries, flattened to (RB, E*H)
    pu = jax.lax.bitcast_convert_type(word0, jnp.uint32)
    key_rep = jnp.broadcast_to(
        key.reshape(rb, e, 1), (rb, e, h)).reshape(rb, eh)
    got_key = (pu & ~mask_u) | (key_rep & mask_u)
    key_cnt = (pu & mask_u).astype(i32)
    cnt_rep = jnp.broadcast_to(
        cnt_bucket.reshape(rb, e, 1), (rb, e, h)).reshape(rb, eh)
    jh = jax.lax.broadcasted_iota(i32, (rb, e, h), 2).reshape(rb, eh)
    valid_rep = jnp.broadcast_to(
        seed_valid.reshape(rb, e, 1), (rb, e, h)).reshape(rb, eh)
    in_bucket = jh < cnt_rep
    key_match = got_key == key_rep
    raw_hit = in_bucket & key_match & valid_rep
    hit_v = raw_hit & (key_cnt <= thresh_freq) if use_freq else raw_hit

    fm = (key_match & in_bucket).reshape(rb * e, h)
    first_match = (fm & (_prefix_sum(fm.astype(i32)) == 1)).reshape(rb, eh)

    n_seeds = jnp.sum(seed_valid, axis=1, keepdims=True)
    probes = jnp.sum(jnp.minimum(cnt_bucket, h) * seed_valid,
                     axis=1, keepdims=True)
    raw = jnp.sum(raw_hit, axis=1, keepdims=True)
    postfreq = jnp.sum(hit_v, axis=1, keepdims=True)
    exact = jnp.sum(jnp.where(first_match & valid_rep, key_cnt, 0),
                    axis=1, keepdims=True)

    # ---- vote (vote.vote_filter, per-read histogram partials) -----------
    if use_vote:
        q_pos = jax.lax.broadcasted_iota(i32, (rb, e, h), 1).reshape(rb, eh)
        shifted = (t_pos - q_pos) + DIAG_SHIFT
        clipped = jnp.maximum(shifted, 0)
        n_clip = jnp.sum(hit_v & (shifted < 0), axis=1, keepdims=True)
        w1 = (clipped >> vlog2) % nbins
        w2 = ((clipped >> vlog2) + 1) % nbins
        bins_hn = jax.lax.broadcasted_iota(i32, (eh, nbins), 1)
        keep_rows = []
        for r in range(rb):
            oh1 = (w1[r:r + 1].T == bins_hn).astype(f32)  # (EH, nbins)
            oh2 = (w2[r:r + 1].T == bins_hn).astype(f32)
            vf = hit_v[r:r + 1].astype(f32)               # (1, EH)
            votes = (jax.lax.dot(vf, oh1, precision=_HIGHEST)
                     + jax.lax.dot(vf, oh2, precision=_HIGHEST)).T
            v1 = jax.lax.dot(oh1, votes, precision=_HIGHEST)  # (EH, 1)
            v2 = jax.lax.dot(oh2, votes, precision=_HIGHEST)
            vmax = jnp.maximum(v1, v2).astype(i32).T      # (1, EH)
            keep_rows.append(hit_v[r:r + 1] & (vmax >= thresh_vote))
        keep = keep_rows[0] if rb == 1 else jnp.concatenate(keep_rows, 0)
        n_cast = 2 * jnp.sum(hit_v, axis=1, keepdims=True)
    else:
        keep = hit_v
        n_cast = jnp.zeros((rb, 1), i32)
        n_clip = jnp.zeros((rb, 1), i32)
    n_anchors = jnp.sum(keep, axis=1, keepdims=True)

    tpos_ref[...] = t_pos
    hit_ref[...] = keep.astype(i32)
    cnt_ref[...] = jnp.concatenate(
        [c.astype(i32) for c in
         (nev, n_seeds, probes, raw, postfreq, exact,
          n_cast, n_anchors, n_clip)], axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("n_ev_max", "hits", "tw", "tau2", "eps", "peak_r",
                     "frac_bits", "seed_w", "seed_q", "minimizer_r",
                     "levels", "clip_q", "step_q", "n_buckets", "n_entries",
                     "thresh_freq", "use_freq", "use_vote", "vlog2", "nbins",
                     "thresh_vote", "tile", "interpret"))
def cheap_fused_fixed(xq, bucket_start, entries_packed, *,
                      n_ev_max, hits, tw, tau2, eps, peak_r, frac_bits,
                      seed_w, seed_q, minimizer_r, levels, clip_q, step_q,
                      n_buckets, n_entries, thresh_freq, use_freq, use_vote,
                      vlog2, nbins, thresh_vote, tile, interpret=None):
    """Launch the mega-kernel over a padded read block.

    xq             (Rp, S)     int32, Rp % tile.r_blk == 0
    bucket_start   (1, NBpad)  int32, NBpad % tile.bt == 0
    entries_packed (2, Npad)   int32, Npad % tile.bt == 0
    Returns t_pos (Rp, E*H) i32, hit (Rp, E*H) i32, counters (Rp, 9) i32.
    """
    if interpret is None:
        interpret = K.INTERPRET
    rp, s = xq.shape
    rb, bt = tile.r_blk, tile.bt
    assert rp % rb == 0 and bucket_start.shape[1] % bt == 0 \
        and entries_packed.shape[1] % bt == 0
    eh = n_ev_max * hits
    grid = (rp // rb,)
    kern = functools.partial(
        _kernel, n_ev_max=n_ev_max, hits=hits, tw=tw, tau2=tau2, eps=eps,
        peak_r=peak_r, frac_bits=frac_bits, seed_w=seed_w, seed_q=seed_q,
        minimizer_r=minimizer_r, levels=levels, clip_q=clip_q,
        step_q=step_q, n_buckets=n_buckets, n_entries=n_entries,
        thresh_freq=thresh_freq, use_freq=use_freq, use_vote=use_vote,
        vlog2=vlog2, nbins=nbins, thresh_vote=thresh_vote, bt=bt,
        nt_bs=bucket_start.shape[1] // bt,
        nt_ent=entries_packed.shape[1] // bt)
    call = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, s), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((rb, eh), lambda i: (i, 0)),
            pl.BlockSpec((rb, eh), lambda i: (i, 0)),
            pl.BlockSpec((rb, len(COUNTER_COLS)), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, eh), jnp.int32),
            jax.ShapeDtypeStruct((rp, eh), jnp.int32),
            jax.ShapeDtypeStruct((rp, len(COUNTER_COLS)), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, 1, bt), jnp.int32),
            pltpu.VMEM((2, 2, bt), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=K.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )
    return call(xq, bucket_start, entries_packed)
