"""Public wrapper for the fused cheap-phase mega-kernel.

Host graph: normalize + early-quantize the signals (same split as the
event_detect wrapper), pad reads to the block grid and the 2-plane packed
index to the DMA tile width, launch the mega-kernel once, then slice the
padding back off and rebuild the cheap-phase (q_pos, t_pos, hit_valid,
counters) contract.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import kernels as K
from repro.core import events as ev
from repro.core import stages
from repro.core.config import MarsConfig
from repro.kernels.cheap_fused.cheap_fused import (
    COUNTER_COLS, FusedTile, cheap_fused_fixed, tune_tile)


def _pad_axis(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    rem = -n % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


def cheap_fused(signals: jnp.ndarray, index: Dict[str, jnp.ndarray],
                cfg: MarsConfig, tile: Optional[FusedTile] = None):
    """signals: (R, S) f32 raw; index: the packed online index view.

    Returns (q_pos, t_pos, hit_valid, counters) — the exact
    ``pipeline.cheap_phase`` contract, bit-identical to the per-stage
    pallas program for every config the `supports` gate admits.
    """
    assert cfg.fixed_point and cfg.early_quantization, (
        "mega-kernel implements the MARS fixed-point path")
    if tile is None:
        tile = tune_tile("interpret" if K.INTERPRET
                         else jax.default_backend())
    x = ev.robust_normalize(signals)
    xq = ev.quantize_signal_fixed(x, cfg.frac_bits).astype(jnp.int32)
    r = xq.shape[0]
    e, h = cfg.max_events, cfg.max_hits_per_seed

    n_entries = index["entries_packed"].shape[-1]
    bs = _pad_axis(index["bucket_start"].reshape(1, -1), 1, tile.bt)
    ent = _pad_axis(index["entries_packed"], 1, tile.bt)
    xq = _pad_axis(xq, 0, tile.r_blk)

    clip_q = int(round(cfg.quant_clip_sigma * (1 << cfg.frac_bits)))
    t_pos, hit, cnt = cheap_fused_fixed(
        xq, bs, ent,
        n_ev_max=e, hits=h, tw=cfg.tstat_window,
        tau2=int(round(cfg.tstat_threshold ** 2)),
        eps=1 << (2 * cfg.frac_bits - 8),
        peak_r=cfg.peak_window, frac_bits=cfg.frac_bits,
        seed_w=cfg.seed_width, seed_q=cfg.quant_bits,
        minimizer_r=cfg.minimizer_radius, levels=cfg.quant_levels,
        clip_q=clip_q, step_q=(2 * clip_q) // cfg.quant_levels,
        n_buckets=cfg.n_buckets, n_entries=n_entries,
        thresh_freq=cfg.thresh_freq, use_freq=cfg.use_freq_filter,
        use_vote=cfg.use_vote_filter, vlog2=cfg.voting_window_log2,
        nbins=cfg.vote_bins, thresh_vote=cfg.thresh_voting, tile=tile)

    t_pos = t_pos[:r].reshape(r, e, h)
    hit_valid = hit[:r].reshape(r, e, h).astype(bool)
    counters = {name: cnt[:r, i] for i, name in enumerate(COUNTER_COLS)}
    q_pos = jnp.broadcast_to(
        jnp.arange(e, dtype=jnp.int32)[None, :, None], t_pos.shape)
    return q_pos, t_pos, hit_valid, counters


def _fused_supports(cfg: MarsConfig) -> bool:
    """Same admission rule as the event_detect kernel it subsumes: the
    integer boundary test must fit int32 for this config."""
    return (cfg.fixed_point and cfg.early_quantization
            and ev.fixed_tstat_in_range(cfg))


stages.register_fused_cheap(stages.PALLAS, cheap_fused,
                            supports=_fused_supports)
