"""Pure-jnp oracle for cheap_fused: the core pipeline's own per-read path.

The mega-kernel's primary parity comparand is the per-stage program of its
OWN plan (``pipeline.cheap_phase(..., use_fused=False)``); this oracle pins
the reference-backend math the whole ladder bottoms out in.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import pipeline, stages
from repro.core.config import MarsConfig


def cheap_fused_ref(signals: jnp.ndarray, index, cfg: MarsConfig):
    plan = stages.resolve_plan(cfg, stages.REFERENCE)
    return pipeline.cheap_phase_vmap(signals, index, cfg, plan)
