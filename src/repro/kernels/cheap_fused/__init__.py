from repro.kernels.cheap_fused.ops import cheap_fused  # noqa: F401
from repro.kernels.cheap_fused.cheap_fused import FusedTile, tune_tile  # noqa: F401
