"""Mamba-2 SSD (state-space duality) block — chunked matmul form.

The SSD recurrence  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T,
y_t = C_t h_t + D x_t  (scalar A per head) is evaluated chunk-wise
(arXiv:2405.21060 Alg. 1): within a chunk the quadratic "attention-like"
matmul form runs on the MXU; across chunks a small state (B,H,N,P) is
carried by a scan — O(S) total, MXU-dominated.

TPU note: this shares its core building block (decay-masked segment
reduction) with MARS's event detection — both are segmented scans evaluated
as matmuls; see DESIGN.md Arch-applicability.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

F32 = jnp.float32
CHUNK = 512


def _split_proj(zxbcdt: jnp.ndarray, cfg: ArchConfig):
    d_in = cfg.d_inner
    H, N = cfg.n_ssm_heads, cfg.ssm_state
    z, xs, B_, C_, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, xs, B_, C_, dt  # dt: (..., H)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, width W.  x: (B,S,d), w: (W,d).
    With `state` (B,W-1,d): single-step decode, returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
        y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
        return jax.nn.silu(y.astype(F32)).astype(x.dtype), None
    full = jnp.concatenate([state, x], axis=1)            # (B, W, d)
    y = sum(full[:, i:i + 1, :] * w[i] for i in range(W))
    return (jax.nn.silu(y.astype(F32)).astype(x.dtype),
            full[:, 1:, :].astype(state.dtype))


def ssd_chunked(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                B_: jnp.ndarray, C_: jnp.ndarray,
                state0: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    xh: (B,S,H,P), dt: (B,S,H) (post-softplus), A: (H,) negative,
    B_, C_: (B,S,N) (single group).  Returns (y (B,S,H,P), state (B,H,N,P)).
    """
    Bb, S, H, P = xh.shape
    N = B_.shape[-1]
    Q = min(CHUNK, S)
    nc = S // Q
    assert nc * Q == S, (S, Q)

    dA = dt * A[None, None, :]                       # (B,S,H) <= 0
    x_dt = xh * dt[..., None]                        # dt-weighted input
    # reshape into chunks: (nc, B, Q, ...)
    def ck(t):
        return t.reshape(Bb, nc, Q, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))
    dA_c, x_c, B_c, C_c = ck(dA), ck(x_dt), ck(B_), ck(C_)

    cum = jnp.cumsum(dA_c, axis=2)                   # (nc,B,Q,H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (nc,B,Qi,Qj,H)
    iq = jnp.arange(Q)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    # §Perf iteration (mamba2 cell): decay mask in bf16 — it multiplies
    # bf16 operands of an MXU dot; keeping it f32 doubled the dominant
    # (nc,B,Q,Q,H) HBM traffic of the memory-bound train_4k cell.
    L = jnp.where(causal, jnp.exp(seg), 0.0).astype(jnp.bfloat16)

    # intra-chunk: y_intra[i] = sum_j (C_i . B_j) L_ij x_dt[j]
    G = jnp.einsum("cbin,cbjn->cbij", C_c, B_c,
                   preferred_element_type=F32).astype(jnp.bfloat16)
    M = G[..., None] * L                             # (nc,B,Qi,Qj,H) bf16
    y_intra = jnp.einsum("cbijh,cbjhp->cbihp", M, x_c.astype(jnp.bfloat16),
                         preferred_element_type=F32)

    # inter-chunk: carried state
    decay_out = jnp.exp(cum)                         # (nc,B,Q,H)
    decay_last = jnp.exp(cum[:, :, -1:, :] - cum)    # exp(cum_Q - cum_j)
    if state0 is None:
        state0 = jnp.zeros((Bb, H, N, P), F32)

    def step(state, inp):
        dA_l, x_l, B_l, C_l, d_out, d_last = inp
        # y_inter[i] = C_i . state * exp(cum_i)
        y_int = jnp.einsum("bin,bhnp->bihp", C_l.astype(F32), state) \
            * d_out[..., None]
        chunk_decay = jnp.exp(dA_l.sum(axis=1))      # (B,H)
        upd = jnp.einsum("bjn,bjhp->bhnp", B_l.astype(F32),
                         x_l.astype(F32) * d_last[..., None])
        state = state * chunk_decay[:, :, None, None] + upd
        return state, y_int

    state, y_inter = jax.lax.scan(
        step, state0.astype(F32), (dA_c, x_c, B_c, C_c, decay_out, decay_last))
    y = y_intra + y_inter                            # (nc,B,Q,H,P)
    y = y.transpose(1, 0, 2, 3, 4).reshape(Bb, S, H, P)
    return y.astype(xh.dtype), state


def ssd_block(x: jnp.ndarray, p: dict, cfg: ArchConfig,
              cache: Optional[dict] = None, mesh=None):
    """Full Mamba-2 block.  x: (B,S,d).

    p: {'in_proj' (d, 2*d_in+2N+H), 'conv_w' (W, d_in), 'A_log' (H,),
        'D' (H,), 'dt_bias' (H,), 'gate_norm' (d_in,), 'out_proj' (d_in,d)}.
    cache: {'conv' (B,W-1,d_in), 'state' (B,H,N,P)} for decode.
    """
    from repro.models.part import constrain
    Bb, S, d = x.shape
    H, N, P = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    zxbcdt = constrain(zxbcdt, mesh, ("dp", None, None))
    z, xs, B_, C_, dt_raw = _split_proj(zxbcdt, cfg)
    dt = jax.nn.softplus(dt_raw.astype(F32) + p["dt_bias"].astype(F32))
    A = -jnp.exp(p["A_log"].astype(F32))

    new_cache = cache
    if cache is None:
        xc, _ = _causal_conv(xs, p["conv_w"])
        xh = xc.reshape(Bb, S, H, P)
        y, _ = ssd_chunked(xh, dt, A, B_, C_)
        y = y.astype(F32)
    elif S > 1:
        # prefill: run the chunked scan from the empty state, then stash the
        # final SSD state and the conv tail into the cache.
        W = p["conv_w"].shape[0]
        xc, _ = _causal_conv(xs, p["conv_w"])
        xh = xc.reshape(Bb, S, H, P)
        y, state = ssd_chunked(xh, dt, A, B_, C_)
        y = y.astype(F32)
        conv_state = xs[:, S - (W - 1):, :].astype(cache["conv"].dtype)
        new_cache = dict(conv=conv_state,
                         state=state.astype(cache["state"].dtype))
    else:
        xc, conv_state = _causal_conv(xs, p["conv_w"], cache["conv"])
        xh = xc.reshape(Bb, S, H, P)
        # single-step recurrence (S == 1 in decode)
        decay = jnp.exp(dt * A[None, None, :])[:, 0]          # (B,H)
        upd = jnp.einsum("bn,bhp->bhnp", B_[:, 0].astype(F32),
                         xh[:, 0].astype(F32) * dt[:, 0, :, None])
        state = cache["state"].astype(F32) * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", C_[:, 0].astype(F32), state)[:, None]
        new_cache = dict(conv=conv_state,
                         state=state.astype(cache["state"].dtype))

    # D skip connection on the (conv'd) input heads
    y = y + xh.astype(F32) * p["D"].astype(F32)[None, None, :, None]
    y = y.reshape(Bb, S, H * P).astype(x.dtype)
    # gated RMSNorm (Mamba-2): norm(y * silu(z))
    from repro.models.layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype),
                 p["gate_norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, new_cache
