"""Mixture-of-Experts FFN: token-choice top-k routing with static capacity
(GShard-style dense dispatch) + optional shared expert.

Tokens are processed in small groups (GROUP tokens) so the dispatch/combine
einsums stay a tiny fraction of expert FLOPs (dispatch cost per token is
2*E*C*d with C ~= GROUP*top_k*cf/E, i.e. ~GROUP*top_k*cf*2d — a few percent
of 6*top_k*d*d_ff_expert for GROUP=128).  The expert axis E is sharded over
the `model` mesh axis: GSPMD turns the dispatch/combine einsums into
all-to-alls — classic expert parallelism.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

F32 = jnp.float32
GROUP = 64           # tokens per dispatch group
CAPACITY_FACTOR = 1.0
# GROUP/capacity sizing: dispatch+combine are (nG, GROUP, E, C) tensors; at
# GROUP=128/cf=1.25 the dry-run measured 40 GiB/device temps on
# qwen3-moe train_4k.  GROUP=64/cf=1.0 keeps the dispatch footprint ~6x
# smaller at ~2 tokens/expert/group average occupancy (drop-rate trade
# documented in EXPERIMENTS Perf).


def capacity(cfg: ArchConfig, group: int = GROUP) -> int:
    c = math.ceil(group * cfg.top_k * CAPACITY_FACTOR / cfg.n_experts)
    return max(4, -(-c // 4) * 4)      # round up to a multiple of 4


def moe_ffn(x: jnp.ndarray, p: dict, cfg: ArchConfig,
            mesh=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d).  p: {'router' (d,E), 'w_gate','w_up' (E,d,f),
    'w_down' (E,f,d)[, shared expert 'sh_gate','sh_up','sh_down']}.

    Returns (y (B,S,d), aux_loss scalar) — aux is the standard load-balance
    loss (mean fraction * mean prob * E)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg)
    T = B * S
    Tp = -(-T // GROUP) * GROUP                # pad to a group multiple
    xf = x.reshape(T, d)
    if Tp != T:
        xf = jnp.concatenate(
            [xf, jnp.zeros((Tp - T, d), x.dtype)], axis=0)
    nG = Tp // GROUP
    xg = xf.reshape(nG, GROUP, d)
    t_valid = (jnp.arange(Tp) < T).reshape(nG, GROUP)     # padded tokens

    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (nG, T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * mean(fraction_e) * mean(prob_e)
    top1 = jax.nn.one_hot(gate_idx[..., 0], E, dtype=F32)
    aux = E * jnp.mean(jnp.mean(top1, axis=(0, 1)) *
                       jnp.mean(probs, axis=(0, 1)))

    # --- capacity-constrained dispatch/combine masks -----------------------
    # §Perf iteration (qwen3-moe cell): build every routing tensor with its
    # expert axis ALREADY sharded over 'model' — without the constraints the
    # (nG,T,E)/(nG,T,E,C) cumsum/one-hot intermediates are resharded through
    # EP all-to-alls far larger than the token payload itself.
    from repro.models.part import constrain
    dispatch = jnp.zeros((nG, GROUP, E, C), jnp.bfloat16)
    combine = jnp.zeros((nG, GROUP, E, C), jnp.bfloat16)
    pos_base = jnp.zeros((nG, 1, E), jnp.int32)
    for s in range(k):
        oh = jax.nn.one_hot(gate_idx[..., s], E, dtype=jnp.int32)  # (nG,T,E)
        oh = constrain(oh, mesh, ("dp", None, "tp"))
        oh = oh * t_valid[..., None]           # padded tokens route nowhere
        pos = jnp.cumsum(oh, axis=1) - oh + pos_base               # (nG,T,E)
        pos_base = pos_base + oh.sum(axis=1, keepdims=True)
        keep = (pos < C) & (oh > 0)
        pc = jax.nn.one_hot(pos, C, dtype=jnp.bfloat16) * \
            keep[..., None].astype(jnp.bfloat16)                   # (nG,T,E,C)
        pc = constrain(pc, mesh, ("dp", None, "tp", None))
        dispatch = dispatch + pc
        combine = combine + pc * gate_vals[..., s][..., None, None].astype(jnp.bfloat16)
    dispatch = constrain(dispatch, mesh, ("dp", None, "tp", None))
    combine = constrain(combine, mesh, ("dp", None, "tp", None))

    # --- expert compute (E over 'model' = expert parallelism; the dispatch
    # einsum becomes the all-to-all under GSPMD) ------------------------------
    from repro.models.part import constrain
    xg = constrain(xg, mesh, ("dp", None, None))
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)                # (nG,E,C,d)
    xe = constrain(xe, mesh, ("dp", "tp", None, None))
    h_g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    h_u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = jax.nn.silu(h_g.astype(F32)).astype(xe.dtype) * h_u
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = constrain(ye, mesh, ("dp", "tp", None, None))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(ye.dtype), ye)
    y = constrain(y, mesh, ("dp", None, None))

    if cfg.n_shared_experts:
        g = jnp.einsum("gtd,df->gtf", xg, p["sh_gate"])
        u = jnp.einsum("gtd,df->gtf", xg, p["sh_up"])
        sh = jax.nn.silu(g.astype(F32)).astype(xg.dtype) * u
        y = y + jnp.einsum("gtf,fd->gtd", sh, p["sh_down"])

    y = y.reshape(Tp, d)[:T]
    return y.reshape(B, S, d), aux
