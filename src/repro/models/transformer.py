"""Unified decoder stack for all assigned architectures.

Layers are organized in GROUPS so heterogeneous stacks scan cleanly:
the layer pattern (e.g. Llama-4's [dense, moe], Llama-3.2-Vision's
[self x4, cross]) repeats n_layers/len(pattern) times; parameters are
stacked per pattern slot and the stack runs as one lax.scan over groups
(compact HLO, fast compiles, remat per group).

Families:
    dense   — pre-norm GQA attention + SwiGLU (SWA / qk-norm variants)
    moe     — attention + routed experts (moe.py), optional dense interleave
    hybrid  — Hymba: parallel attention & SSM branches + SwiGLU
    vlm     — decoder with cross-attention layers every k-th layer
    audio   — Whisper: bidirectional encoder + causal decoder w/ cross-attn
    ssm     — Mamba-2 (SSD), attention-free
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import AttnSpec, attention, rms_norm, swiglu
from repro.models.part import constrain

F32 = jnp.float32
BF16 = jnp.bfloat16


# --------------------------------------------------------------------------- #
# Layer patterns
# --------------------------------------------------------------------------- #
def layer_pattern(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.family == "dense":
        return ("self",)
    if cfg.family == "moe":
        if cfg.moe_every == 2:
            return ("self", "self_moe")
        return ("self_moe",)
    if cfg.family == "hybrid":
        return ("hybrid",)
    if cfg.family == "vlm":
        k = cfg.cross_attn_every
        return tuple(["self"] * (k - 1) + ["cross"])
    if cfg.family == "audio":
        return ("dec",)
    if cfg.family == "ssm":
        return ("ssd",)
    raise ValueError(cfg.family)


def n_groups(cfg: ArchConfig) -> int:
    p = layer_pattern(cfg)
    assert cfg.n_layers % len(p) == 0, (cfg.name, cfg.n_layers, p)
    return cfg.n_layers // len(p)


def attn_spec(cfg: ArchConfig, *, causal=True, window=None) -> AttnSpec:
    return AttnSpec(n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.d_head,
                    causal=causal, window=window, qk_norm=cfg.qk_norm,
                    rope_theta=cfg.rope_theta)


# --------------------------------------------------------------------------- #
# Parameter init (pure; run under jax.eval_shape for the dry-run)
# --------------------------------------------------------------------------- #
def _lin(rng, shape, scale, dtype=BF16):
    return (jax.random.normal(rng, shape, F32) * scale).astype(dtype)


def _init_attn(rng, cfg: ArchConfig, G: int, cross=False) -> Dict:
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(rng, 6)
    s_in = 0.02
    s_out = 0.02 / (2 * cfg.n_layers) ** 0.5
    p = dict(
        wq=_lin(ks[0], (G, d, H * Dh), s_in),
        wk=_lin(ks[1], (G, d, K * Dh), s_in),
        wv=_lin(ks[2], (G, d, K * Dh), s_in),
        wo=_lin(ks[3], (G, H * Dh, d), s_out),
    )
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((G, Dh), BF16)
        p["k_norm"] = jnp.ones((G, Dh), BF16)
    return p


def _init_mlp(rng, cfg: ArchConfig, G: int, d_ff: int) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    s_in = 0.02
    s_out = 0.02 / (2 * cfg.n_layers) ** 0.5
    return dict(w_gate=_lin(ks[0], (G, d, d_ff), s_in),
                w_up=_lin(ks[1], (G, d, d_ff), s_in),
                w_down=_lin(ks[2], (G, d_ff, d), s_out))


def _init_moe(rng, cfg: ArchConfig, G: int) -> Dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(rng, 7)
    s_in, s_out = 0.02, 0.02 / (2 * cfg.n_layers) ** 0.5
    p = dict(router=_lin(ks[0], (G, d, E), s_in, F32),
             w_gate=_lin(ks[1], (G, E, d, f), s_in),
             w_up=_lin(ks[2], (G, E, d, f), s_in),
             w_down=_lin(ks[3], (G, E, f, d), s_out))
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p.update(sh_gate=_lin(ks[4], (G, d, fs), s_in),
                 sh_up=_lin(ks[5], (G, d, fs), s_in),
                 sh_down=_lin(ks[6], (G, fs, d), s_out))
    return p


def _init_ssm(rng, cfg: ArchConfig, G: int) -> Dict:
    d, d_in = cfg.d_model, cfg.d_inner
    H, N, W = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_conv
    e = 2 * d_in + 2 * N + H
    ks = jax.random.split(rng, 4)
    s_in, s_out = 0.02, 0.02 / (2 * cfg.n_layers) ** 0.5
    return dict(
        in_proj=_lin(ks[0], (G, d, e), s_in),
        conv_w=_lin(ks[1], (G, W, d_in), 0.2),
        A_log=jnp.zeros((G, H), F32),
        D=jnp.ones((G, H), F32),
        dt_bias=jnp.zeros((G, H), F32),
        gate_norm=jnp.ones((G, d_in), BF16),
        out_proj=_lin(ks[2], (G, d_in, d), s_out),
    )


def _init_block(rng, cfg: ArchConfig, kind: str, G: int) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(rng, 4)
    ones = lambda: jnp.ones((G, d), BF16)
    if kind == "self":
        return dict(ln1=ones(), attn=_init_attn(ks[0], cfg, G),
                    ln2=ones(), mlp=_init_mlp(ks[1], cfg, G, cfg.d_ff))
    if kind == "self_moe":
        return dict(ln1=ones(), attn=_init_attn(ks[0], cfg, G),
                    ln2=ones(), moe=_init_moe(ks[1], cfg, G))
    if kind == "cross":
        return dict(ln1=ones(), xattn=_init_attn(ks[0], cfg, G, cross=True),
                    ln2=ones(), mlp=_init_mlp(ks[1], cfg, G, cfg.d_ff))
    if kind == "hybrid":
        return dict(ln1=ones(), attn=_init_attn(ks[0], cfg, G),
                    ssm=_init_ssm(ks[1], cfg, G),
                    norm_attn=ones(), norm_ssm=ones(),
                    ln2=ones(), mlp=_init_mlp(ks[2], cfg, G, cfg.d_ff))
    if kind == "dec":
        return dict(ln1=ones(), attn=_init_attn(ks[0], cfg, G),
                    ln_x=ones(), xattn=_init_attn(ks[1], cfg, G, cross=True),
                    ln2=ones(), mlp=_init_mlp(ks[2], cfg, G, cfg.d_ff))
    if kind == "enc":
        return dict(ln1=ones(), attn=_init_attn(ks[0], cfg, G),
                    ln2=ones(), mlp=_init_mlp(ks[1], cfg, G, cfg.d_ff))
    if kind == "ssd":
        return dict(ln1=ones(), ssm=_init_ssm(ks[0], cfg, G))
    raise ValueError(kind)


def init_params(cfg: ArchConfig, rng) -> Dict:
    pattern = layer_pattern(cfg)
    G = n_groups(cfg)
    ks = jax.random.split(rng, len(pattern) + 4)
    params: Dict = dict(
        embed=_lin(ks[0], (cfg.vocab, cfg.d_model), 0.02),
        final_norm=jnp.ones((cfg.d_model,), BF16),
        blocks={f"slot{j}": _init_block(ks[j + 1], cfg, kind, G)
                for j, kind in enumerate(pattern)},
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = _lin(ks[len(pattern) + 1],
                                 (cfg.d_model, cfg.vocab), 0.02)
    if cfg.family == "audio":
        Ge = cfg.n_enc_layers
        params["enc_blocks"] = {"slot0": _init_block(
            ks[len(pattern) + 2], cfg.replace(n_layers=Ge), "enc", Ge)}
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), BF16)
        params["enc_pos"] = _lin(ks[len(pattern) + 3],
                                 (cfg.n_ctx_tokens, cfg.d_model), 0.02)
    return params


# --------------------------------------------------------------------------- #
# Block application
# --------------------------------------------------------------------------- #
def _apply_block(x, bp, kind: str, cfg: ArchConfig, *, pos, is_global=None,
                 cache=None, cache_index=None, ctx=None, mesh=None):
    """One layer.  Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), F32)
    new_cache = cache
    # Megatron-style sequence parallelism on the residual stream: tokens
    # sharded over BOTH dp and 'model' between blocks (the scan-carried
    # residuals are what remat saves per layer — measured 36 GiB/device
    # without SP on qwen3-moe train_4k).  Attention/MLP internally
    # re-gather S and shard heads/hidden instead (TP).
    x = constrain(x, mesh, ("dp", "tp", None))

    if kind == "ssd":
        h, new_cache = ssm_lib.ssd_block(rms_norm(x, bp["ln1"]), bp["ssm"],
                                         cfg, cache, mesh=mesh)
        return x + constrain(h, mesh, ("dp", "tp", None)), new_cache, aux

    if kind == "hybrid":
        xin = rms_norm(x, bp["ln1"])
        window = jnp.where(is_global, jnp.int32(1 << 30),
                           jnp.int32(cfg.swa_window))
        spec = attn_spec(cfg, window=None)  # window applied via valid mask
        a_cache = None if cache is None else cache.get("attn")
        s_cache = None if cache is None else cache.get("ssm")
        # dynamic window: pass the per-layer window as a traced bound
        a_out, a_cache = _windowed_attention(xin, bp["attn"], spec, window,
                                             pos, a_cache, cache_index,
                                             mesh=mesh)
        s_out, s_cache = ssm_lib.ssd_block(xin, bp["ssm"], cfg, s_cache,
                                           mesh=mesh)
        h = 0.5 * (rms_norm(a_out, bp["norm_attn"]) +
                   rms_norm(s_out, bp["norm_ssm"]))
        x = x + h.astype(x.dtype)
        x = x + swiglu(rms_norm(x, bp["ln2"]), **bp["mlp"])
        if cache is not None:
            new_cache = dict(attn=a_cache, ssm=s_cache)
        return x, new_cache, aux

    # attention part (self / cross / dec)
    if kind in ("self", "self_moe", "enc"):
        spec = attn_spec(cfg, causal=kind != "enc", window=cfg.swa_window)
        h, new_cache = attention(rms_norm(x, bp["ln1"]), bp["attn"], spec,
                                 pos=pos, cache=cache,
                                 cache_index=cache_index, mesh=mesh)
        x = x + constrain(h, mesh, ("dp", "tp", None))
    elif kind == "cross":
        spec = attn_spec(cfg, causal=False)
        kx = jnp.einsum("btd,dhx->bthx", ctx, bp["xattn"]["wk"].reshape(
            cfg.d_model, cfg.n_kv, cfg.d_head))
        vx = jnp.einsum("btd,dhx->bthx", ctx, bp["xattn"]["wv"].reshape(
            cfg.d_model, cfg.n_kv, cfg.d_head))
        h, _ = attention(rms_norm(x, bp["ln1"]), bp["xattn"], spec, pos=pos,
                         ctx_kv=(kx, vx), mesh=mesh)
        x = x + constrain(h, mesh, ("dp", "tp", None))
    elif kind == "dec":
        spec = attn_spec(cfg, causal=True)
        h, new_cache = attention(rms_norm(x, bp["ln1"]), bp["attn"], spec,
                                 pos=pos, cache=cache,
                                 cache_index=cache_index, mesh=mesh)
        x = x + constrain(h, mesh, ("dp", "tp", None))
        kx = jnp.einsum("btd,dhx->bthx", ctx, bp["xattn"]["wk"].reshape(
            cfg.d_model, cfg.n_kv, cfg.d_head))
        vx = jnp.einsum("btd,dhx->bthx", ctx, bp["xattn"]["wv"].reshape(
            cfg.d_model, cfg.n_kv, cfg.d_head))
        hx, _ = attention(rms_norm(x, bp["ln_x"]), bp["xattn"],
                          attn_spec(cfg, causal=False), pos=pos,
                          ctx_kv=(kx, vx), mesh=mesh)
        x = x + constrain(hx, mesh, ("dp", "tp", None))
    else:
        raise ValueError(kind)

    # FFN part
    if kind == "self_moe":
        h, aux = moe_lib.moe_ffn(rms_norm(x, bp["ln2"]), bp["moe"], cfg,
                                 mesh=mesh)
        x = x + constrain(h, mesh, ("dp", "tp", None))
    else:
        h = swiglu(rms_norm(x, bp["ln2"]), **bp["mlp"])
        x = x + constrain(h, mesh, ("dp", "tp", None))
    return x, new_cache, aux


def _windowed_attention(x, p, spec: AttnSpec, window, pos, cache,
                        cache_index, mesh=None):
    """Attention with a *traced* per-layer window bound (hybrid stacks mix
    SWA and global layers inside one scan).  Implemented by passing the
    window as a dynamic clip on key positions inside the online-softmax."""
    from repro.models import layers as L
    B, S, d = x.shape
    H, K, D = spec.n_heads, spec.n_kv, spec.d_head
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"].reshape(d, H, D))
    k = jnp.einsum("bsd,dhx->bshx", x, p["wk"].reshape(d, K, D))
    v = jnp.einsum("bsd,dhx->bshx", x, p["wv"].reshape(d, K, D))
    q = constrain(q, mesh, ("dp", None, "tp", None))
    k = constrain(k, mesh, ("dp", None, "tp", None))
    v = constrain(v, mesh, ("dp", None, "tp", None))
    q = L.apply_rope(q, pos, spec.rope_theta)
    k = L.apply_rope(k, pos, spec.rope_theta)
    new_cache = cache
    if cache is None:
        out = _mha_dyn_window(q, k, v, window, q_offset=0, valid_len=S,
                              chunk=spec.kv_chunk)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = dict(k=ck, v=cv)
        out = _mha_dyn_window(q, ck.astype(q.dtype), cv.astype(q.dtype),
                              window, q_offset=cache_index,
                              valid_len=cache_index + S, chunk=spec.kv_chunk)
    y = jnp.einsum("bshx,hxd->bsd", out, p["wo"].reshape(H, D, d))
    return y, new_cache


def _mha_dyn_window(q, k, v, window, *, q_offset, valid_len, chunk):
    """mha_online with a traced (dynamic) window size."""
    from repro.models.layers import NEG_INF
    import math as _m
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    chunk = min(chunk, T)
    n_chunks = -(-T // chunk)
    Tp = n_chunks * chunk
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    scale = 1.0 / _m.sqrt(D)
    qg = (q.reshape(B, S, K, G, D).astype(F32) * scale).astype(q.dtype)
    kc = k.reshape(B, n_chunks, chunk, K, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, D).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(S)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, t0 = inp
        s = jnp.einsum("bskgd,btkd->bskgt", qg, kb,
                       preferred_element_type=F32)
        k_pos = t0 + jnp.arange(chunk)
        ok = (k_pos[None, :] < valid_len) & \
             (q_pos[:, None] >= k_pos[None, :]) & \
             (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p.astype(vb.dtype), vb,
            preferred_element_type=F32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, K, G), NEG_INF, F32)
    l0 = jnp.zeros((B, S, K, G), F32)
    a0 = jnp.zeros((B, S, K, G, D), F32)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kc, vc, jnp.arange(n_chunks) * chunk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, D).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Stack forward (scan over groups)
# --------------------------------------------------------------------------- #
def _group_extras(cfg: ArchConfig):
    """Per-group scanned extras (e.g. hybrid global-layer flags)."""
    pattern = layer_pattern(cfg)
    G = n_groups(cfg)
    if cfg.family == "hybrid":
        flags = jnp.zeros((G, len(pattern)), bool)
        for g in cfg.global_layers:
            gi, si = divmod(g, len(pattern))
            flags = flags.at[gi, si].set(True)
        return dict(is_global=flags)
    return {}


def run_stack(blocks: Dict, x, cfg: ArchConfig, *, pos, cache=None,
              cache_index=None, ctx=None, remat=True,
              blocks_key="blocks", mesh=None):
    """Scan the layer groups.  Returns (x, new_cache, aux_sum)."""
    pattern = (("enc",) if blocks_key == "enc_blocks"
               else layer_pattern(cfg))
    extras = _group_extras(cfg) if blocks_key == "blocks" else {}

    def group_fn(carry, scanned):
        x, aux = carry
        gp = scanned["params"]
        gc = scanned.get("cache")
        new_gc = {} if gc is not None else None
        for j, kind in enumerate(pattern):
            slot = f"slot{j}"
            c_j = None if gc is None else gc.get(slot)
            ig = scanned["extras"]["is_global"][j] if extras else None
            x, c_out, a = _apply_block(
                x, gp[slot], kind, cfg, pos=pos, is_global=ig, cache=c_j,
                cache_index=cache_index, ctx=ctx, mesh=mesh)
            if new_gc is not None:
                new_gc[slot] = c_out if c_out is not None else {}
            aux = aux + a
        out = {"cache": new_gc} if new_gc is not None else {}
        return (x, aux), out

    fn = jax.checkpoint(group_fn) if remat else group_fn
    scanned = {"params": blocks, "extras": extras} if extras else \
        {"params": blocks}
    if not extras:
        scanned["extras"] = {}
    if cache is not None:
        scanned["cache"] = cache
    (x, aux), ys = jax.lax.scan(fn, (x, jnp.zeros((), F32)), scanned)
    new_cache = ys.get("cache") if isinstance(ys, dict) else None
    return x, new_cache, aux
