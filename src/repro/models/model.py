"""Model entry points: init / forward / loss / cache management.

These are pure functions of (params, inputs) so the dry-run can lower them
with ShapeDtypeStruct stand-ins, and the launcher can jit them with
NamedSharding in/out specs.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.models.layers import rms_norm

F32 = jnp.float32
BF16 = jnp.bfloat16


def init_params(cfg: ArchConfig, rng) -> Dict:
    return T.init_params(cfg, rng)


def abstract_params(cfg: ArchConfig) -> Dict:
    """ShapeDtypeStruct pytree (no allocation) for the dry-run."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# --------------------------------------------------------------------------- #
# KV / SSM cache
# --------------------------------------------------------------------------- #
def _slot_cache(cfg: ArchConfig, kind: str, G: int, B: int, T_max: int,
                kv_dtype=BF16) -> Dict:
    K, Dh = cfg.n_kv, cfg.d_head
    if kv_dtype == jnp.int8:
        # quantized cache: int8 values + per-(token, head) bf16 scales
        kv = lambda: dict(
            k=jnp.zeros((G, B, T_max, K, Dh), jnp.int8),
            k_scale=jnp.zeros((G, B, T_max, K, 1), BF16),
            v=jnp.zeros((G, B, T_max, K, Dh), jnp.int8),
            v_scale=jnp.zeros((G, B, T_max, K, 1), BF16))
    else:
        kv = lambda: dict(k=jnp.zeros((G, B, T_max, K, Dh), kv_dtype),
                          v=jnp.zeros((G, B, T_max, K, Dh), kv_dtype))
    ssm = lambda: dict(
        conv=jnp.zeros((G, B, cfg.ssm_conv - 1, cfg.d_inner), BF16),
        state=jnp.zeros((G, B, cfg.n_ssm_heads, cfg.ssm_state,
                         cfg.ssm_head_dim), F32))
    if kind in ("self", "self_moe", "dec"):
        return kv()
    if kind == "hybrid":
        return dict(attn=kv(), ssm=ssm())
    if kind == "ssd":
        return ssm()
    if kind == "cross":
        return {}
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               kv_dtype=BF16) -> Dict:
    pattern = T.layer_pattern(cfg)
    G = T.n_groups(cfg)
    return {f"slot{j}": _slot_cache(cfg, kind, G, batch, max_len, kv_dtype)
            for j, kind in enumerate(pattern)}


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int,
                   kv_dtype=BF16) -> Dict:
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, kv_dtype))


# --------------------------------------------------------------------------- #
# Forward passes
# --------------------------------------------------------------------------- #
def _encode_ctx(params: Dict, cfg: ArchConfig, ctx: jnp.ndarray,
                mesh=None):
    """Audio: run the stub frame embeddings through the encoder stack."""
    if cfg.family != "audio":
        return ctx
    Tc = ctx.shape[1]
    x = ctx.astype(BF16) + params["enc_pos"][None, :Tc, :]
    pos = jnp.arange(Tc)
    x, _, _ = T.run_stack(params["enc_blocks"], x, cfg, pos=pos,
                          blocks_key="enc_blocks", mesh=mesh)
    return rms_norm(x, params["enc_final_norm"])


def forward(params: Dict, tokens: jnp.ndarray, cfg: ArchConfig, *,
            ctx: Optional[jnp.ndarray] = None,
            cache: Optional[Dict] = None, cache_index=0, remat: bool = True,
            mesh=None):
    """tokens: (B, S) int32.  ctx: (B, Tc, d_model) stub embeddings for
    vlm/audio.  Returns (logits (B,S,V) f32, new_cache, aux)."""
    from repro.models.part import constrain
    B, S = tokens.shape
    if mesh is not None:
        # §Perf iteration 2: vocab-sharded embedding lookup as a one-hot
        # matmul.  jnp.take over the model-sharded vocab axis makes GSPMD
        # replicate the table (and its scatter-add gradient) in f32 —
        # measured 7.8 GiB x15 buffers on llama3-405b; the contraction
        # keeps table + gradient sharded, at ~0.4% extra (MXU) flops.
        onehot = jax.nn.one_hot(tokens, cfg.vocab,
                                dtype=params["embed"].dtype)
        x = jnp.einsum("bsv,vd->bsd", onehot, params["embed"])
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, mesh, ("dp", None, None))
    if cache is None:
        pos = jnp.arange(S)
    else:
        pos = cache_index + jnp.arange(S)
    enc = _encode_ctx(params, cfg, ctx, mesh=mesh) if ctx is not None else None
    x, new_cache, aux = T.run_stack(params["blocks"], x, cfg, pos=pos,
                                    cache=cache, cache_index=cache_index,
                                    ctx=enc, remat=remat, mesh=mesh)
    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(F32)
    return logits, new_cache, aux


def loss_fn(params: Dict, batch: Dict, cfg: ArchConfig,
            aux_weight: float = 0.01, mesh=None) -> Tuple[jnp.ndarray, Dict]:
    """batch: {'tokens' (B,S), 'labels' (B,S)[, 'ctx' (B,Tc,d)]}"""
    logits, _, aux = forward(params, batch["tokens"], cfg,
                             ctx=batch.get("ctx"), mesh=mesh)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: gathers over a
    # vocab-sharded axis force XLA to replicate the full logits tensor
    # (measured: 120 GiB/device on the dry-run); the contraction keeps the
    # vocab axis sharded end-to-end.
    onehot = jax.nn.one_hot(labels, cfg.vocab, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = (logz - gold).mean()
    loss = nll + aux_weight * aux
    return loss, dict(nll=nll, aux=aux)


def prefill(params: Dict, tokens: jnp.ndarray, cfg: ArchConfig, *,
            cache: Dict, ctx: Optional[jnp.ndarray] = None, mesh=None):
    """Write the prompt into the cache; return last-position logits."""
    logits, new_cache, _ = forward(params, tokens, cfg, ctx=ctx, cache=cache,
                                   cache_index=0, mesh=mesh)
    return logits[:, -1, :], new_cache


def decode_step(params: Dict, tokens: jnp.ndarray, cfg: ArchConfig, *,
                cache: Dict, cache_index, ctx: Optional[jnp.ndarray] = None,
                mesh=None):
    """tokens: (B, 1) — one decode step at position cache_index."""
    logits, new_cache, _ = forward(params, tokens, cfg, ctx=ctx, cache=cache,
                                   cache_index=cache_index, remat=False,
                                   mesh=mesh)
    return logits[:, -1, :], new_cache


def param_count(cfg: ArchConfig) -> int:
    import math
    tree = abstract_params(cfg)
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(tree))


def active_param_count(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    G = T.n_groups(cfg)
    n_moe_layers = G  # one moe slot per group
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive
