"""LM substrate: one flexible stack covering all 10 assigned architectures."""
