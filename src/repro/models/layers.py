"""Shared transformer layers: RMSNorm, RoPE, GQA attention (full / sliding
window / bidirectional / cross / decode-with-cache), SwiGLU MLP.

Attention is memory-efficient by construction: an online-softmax scan over
KV chunks (never materializing the full (S, T) score matrix) — required for
the 32k prefill and 500k decode shapes, and remat-friendly for train_4k.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # statistics in f32, but never materialize a full f32 copy of x: the
    # f32 tensor feeds ONLY the mean-reduction (fuses to a small (...,1)
    # result).  §Perf iteration 1: the f32 copy was XLA-hoisted out of the
    # backward scan as a full (L, B, S, d) stack — 31.5 GiB/device on
    # llama3-405b train_4k.
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D), pos: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=F32) / half))
    if pos.ndim == 1:
        ang = pos[None, :, None].astype(F32) * freqs[None, None, :]
    else:
        ang = pos[..., None].astype(F32) * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, w_down)


class AttnSpec(NamedTuple):
    n_heads: int
    n_kv: int
    d_head: int
    causal: bool = True
    window: Optional[int] = None     # sliding-window size (None = full)
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    kv_chunk: int = 2048


def mha_online(q: jnp.ndarray, k, v, *,
               causal: bool, window: Optional[int], q_offset,
               valid_len, chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax attention over KV chunks.

    q: (B, S, H, D); k, v: (B, T, K, D) with H a multiple of K (GQA) —
    OR (values int8, scales) tuples for a quantized KV cache (MARS's
    arithmetic conversion applied to serving): chunks are dequantized
    inside the scan so only int8 + per-token scales stream from HBM.
    q_offset: scalar position of q[0] (decode: the cache index).
    valid_len: number of valid KV positions (scalar).
    Returns (B, S, H, D) in q.dtype; accumulation in f32.
    """
    k, k_sc = k if isinstance(k, tuple) else (k, None)
    v, v_sc = v if isinstance(v, tuple) else (v, None)
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    chunk = min(chunk, T)
    n_chunks = -(-T // chunk)
    Tp = n_chunks * chunk
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        if k_sc is not None:
            k_sc = jnp.pad(k_sc, pad)
            v_sc = jnp.pad(v_sc, pad)
    scale = 1.0 / math.sqrt(D)
    # §Perf iteration 1: keep QK/PV dot OPERANDS in bf16 (MXU-native) with
    # f32 accumulation via preferred_element_type — halves score-tensor
    # HBM traffic and restores bf16 matmul peak in the compute term.
    qg = (q.reshape(B, S, K, G, D).astype(F32) * scale).astype(q.dtype)

    def _chunked(t):
        return t.reshape(B, n_chunks, chunk, K, -1).transpose(1, 0, 2, 3, 4)
    kc, vc = _chunked(k), _chunked(v)
    scs = ((_chunked(k_sc), _chunked(v_sc)) if k_sc is not None
           else (jnp.zeros((n_chunks,)), jnp.zeros((n_chunks,))))
    q_pos = q_offset + jnp.arange(S)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, t0, ksb, vsb = inp
        if k_sc is not None:           # dequantize int8 chunk in-register
            kb = (kb.astype(F32) * ksb.astype(F32)).astype(q.dtype)
            vb = (vb.astype(F32) * vsb.astype(F32)).astype(q.dtype)
        s = jnp.einsum("bskgd,btkd->bskgt", qg, kb,
                       preferred_element_type=F32)
        k_pos = t0 + jnp.arange(chunk)
        ok = k_pos[None, :] < valid_len
        if causal:
            ok = ok & (q_pos[:, None] >= k_pos[None, :])
        if window is not None:
            ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p.astype(vb.dtype), vb,
            preferred_element_type=F32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, K, G), NEG_INF, F32)
    l0 = jnp.zeros((B, S, K, G), F32)
    a0 = jnp.zeros((B, S, K, G, D), F32)
    t0s = jnp.arange(n_chunks) * chunk
    # remat the chunk step: without it the backward pass stacks every
    # chunk's (B,S,K,G,chunk) f32 probabilities (measured ~1 GiB/layer/dev
    # on the dry-run) — this is the flash-attention backward trade.
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kc, vc, t0s, scs[0], scs[1]))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, D).astype(q.dtype)


def attention(x: jnp.ndarray, p: dict, spec: AttnSpec, *,
              pos: jnp.ndarray, cache: Optional[dict] = None,
              cache_index=None, ctx_kv: Optional[tuple] = None, mesh=None):
    """Self- or cross-attention with optional KV cache.

    x: (B, S, d).  p: {'wq','wk','wv','wo'[, 'q_norm','k_norm']}.
    pos: (S,) absolute positions of x.
    cache: {'k','v'} (B, T_max, K, D) -> returns updated cache.
    ctx_kv: (k, v) precomputed cross-attention KV (overrides x-derived kv).
    """
    from repro.models.part import constrain
    B, S, d = x.shape
    H, K, D = spec.n_heads, spec.n_kv, spec.d_head
    q = jnp.einsum("bsd,dhx->bshx", x,
                   p["wq"].reshape(d, H, D))
    q = constrain(q, mesh, ("dp", None, "tp", None))
    if ctx_kv is None:
        k = jnp.einsum("bsd,dhx->bshx", x, p["wk"].reshape(d, K, D))
        v = jnp.einsum("bsd,dhx->bshx", x, p["wv"].reshape(d, K, D))
        k = constrain(k, mesh, ("dp", None, "tp", None))
        v = constrain(v, mesh, ("dp", None, "tp", None))
    else:
        k, v = ctx_kv
    if spec.qk_norm:
        q = rms_norm(q, p["q_norm"])
        if ctx_kv is None:
            k = rms_norm(k, p["k_norm"])
    if ctx_kv is None:
        q = apply_rope(q, pos, spec.rope_theta)
        k = apply_rope(k, pos, spec.rope_theta)

    new_cache = cache
    if ctx_kv is not None:
        # cross-attention: full-context bidirectional over ctx
        out = mha_online(q, k, v, causal=False, window=None, q_offset=0,
                         valid_len=k.shape[1], chunk=spec.kv_chunk)
    elif cache is None:
        out = mha_online(q, k, v, causal=spec.causal, window=spec.window,
                         q_offset=0, valid_len=S, chunk=spec.kv_chunk)
    elif "k_scale" in cache:
        # int8 KV cache (MARS arithmetic conversion applied to serving):
        # per-(token, head) block scales; dequantization happens per chunk
        # inside the online-softmax scan.
        from repro.distributed.collectives import quantize_kv_int8
        kq, ks = quantize_kv_int8(k)
        vq, vs = quantize_kv_int8(v)
        upd = lambda buf, val: jax.lax.dynamic_update_slice(
            buf, val.astype(buf.dtype), (0, cache_index, 0, 0))
        new_cache = dict(k=upd(cache["k"], kq),
                         k_scale=upd(cache["k_scale"], ks),
                         v=upd(cache["v"], vq),
                         v_scale=upd(cache["v_scale"], vs))
        out = mha_online(q, (new_cache["k"], new_cache["k_scale"]),
                         (new_cache["v"], new_cache["v_scale"]),
                         causal=spec.causal, window=spec.window,
                         q_offset=cache_index, valid_len=cache_index + S,
                         chunk=spec.kv_chunk)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = dict(k=ck, v=cv)
        out = mha_online(q, ck.astype(q.dtype), cv.astype(q.dtype),
                         causal=spec.causal, window=spec.window,
                         q_offset=cache_index, valid_len=cache_index + S,
                         chunk=spec.kv_chunk)
    y = jnp.einsum("bshx,hxd->bsd", out, p["wo"].reshape(H, D, d))
    return y, new_cache
