"""Activation-sharding constraints (GSPMD hints).

Without in-graph constraints XLA is free to replicate scan-carried
activations across the data axes — measured on the dry-run: 16x redundant
matmul flops and 120 GiB/device temps.  `constrain` pins the standard
layouts: batch/tokens over the DP axes, heads/experts/hidden over 'model'.

Template entries: "dp" -> all non-'model' axes, "tp" -> 'model', None ->
replicated.  An axis is applied only if the dim is divisible (mirrors
distributed/sharding.py) so the same model code runs on any mesh — or with
mesh=None (single-device tests) as a no-op.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _resolve(mesh: Mesh, dim: int, tmpl):
    if tmpl is None:
        return None
    if tmpl == "dp":
        axes = tuple(a for a in mesh.axis_names if a != "model")
    elif tmpl == "tp":
        axes = ("model",) if "model" in mesh.axis_names else ()
        axes = axes[0] if axes else None
    else:
        axes = tmpl
        if isinstance(axes, str) and axes not in mesh.axis_names:
            return None
        if isinstance(axes, tuple):
            axes = tuple(a for a in axes if a in mesh.axis_names) or None
    if axes is None:
        return None
    if dim % _axes_size(mesh, axes) == 0:
        return axes
    if isinstance(axes, tuple) and len(axes) > 1:
        # drop leading axes until divisible
        for i in range(1, len(axes)):
            if dim % _axes_size(mesh, axes[i:]) == 0:
                return axes[i:]
    return None


def constrain(x: jax.Array, mesh: Optional[Mesh],
              tmpl: Sequence) -> jax.Array:
    """x with sharding constraint from the template; no-op if mesh None."""
    if mesh is None:
        return x
    assert len(tmpl) == x.ndim, (tmpl, x.shape)
    spec = P(*[_resolve(mesh, d, t) for d, t in zip(x.shape, tmpl)])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
