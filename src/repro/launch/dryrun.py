import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
cell on the production mesh with ShapeDtypeStruct stand-ins (no data is
allocated), then record memory/cost/collective analyses for the roofline.

MUST be run as its own process (the two lines above must execute before
any jax import — device count locks at first init):

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out results/dryrun

Filters: --arch, --shape, --mesh {single,multi,both}, --skip-existing.
The MARS pipeline itself is dry-run as the extra arch 'mars-rsga'.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo as hlo_lib
from repro.analysis import roofline as rl
from repro.configs import ARCHS, SHAPES, SHAPE_ORDER, cell_applicable, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.train import optimizer as opt
from repro.train import steps as steps_lib

SDS = jax.ShapeDtypeStruct


def _cost_items(compiled):
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c) if c else {}


def _memory_stats(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None, "memory_analysis unavailable"
    if ma is None:
        return None, "memory_analysis None"
    try:
        stats = dict(
            argument_size=getattr(ma, "argument_size_in_bytes", None),
            output_size=getattr(ma, "output_size_in_bytes", None),
            temp_size=getattr(ma, "temp_size_in_bytes", None),
            generated_code_size=getattr(ma, "generated_code_size_in_bytes",
                                        None),
        )
        peak = sum(v for k, v in stats.items()
                   if v and k in ("argument_size", "output_size",
                                  "temp_size"))
        return peak, json.dumps(stats)
    except Exception as e:                                   # pragma: no cover
        return None, f"memory_analysis parse error: {e}"


def lower_cell(arch: str, shape_key: str, multi_pod: bool,
               microbatches: int = 1, layout: str = "2d"):
    """Build + lower + compile one cell.  Returns (CellResult, compiled)."""
    mesh = make_production_mesh(multi_pod=multi_pod, layout=layout)
    mesh_name = "multi" if multi_pod else "single"
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))

    if arch == "mars-rsga":
        return _lower_mars_cell(shape_key, mesh, mesh_name, chips,
                                schedule=os.environ.get("MARS_SCHEDULE",
                                                        "a2a"))

    cfg = get_config(arch)
    shape = SHAPES[shape_key]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return rl.CellResult(
            arch=arch, shape=shape_key, mesh=mesh_name, chips=chips,
            flops_per_device=0, bytes_per_device=0, wire_bytes_per_device=0,
            collective_detail={}, peak_memory_per_device=None, model_flops=0,
            model_flops_basis="-", tokens=0, status="skip", note=why), None

    params_abs = M.abstract_params(cfg)
    batch_abs = steps_lib.make_batch_abstract(cfg, shape)
    n_params = M.param_count(cfg)
    n_active = M.active_param_count(cfg)

    if shape.kind == "train":
        adamw = opt.AdamWConfig()
        _, jit_for, sh = steps_lib.make_train_step(
            cfg, mesh, adamw, microbatches=microbatches)
        fn = jit_for(batch_abs)
        opt_abs = opt.abstract_state(params_abs)
        lowered = fn.lower(params_abs, opt_abs, batch_abs)
        tokens = shape.global_batch * shape.seq_len
        model_flops, basis = 6.0 * n_active * tokens, "6ND"
    elif shape.kind == "prefill":
        _, jit_for, sh = steps_lib.make_prefill_step(
            cfg, mesh, shape.seq_len, shape.global_batch)
        fn = jit_for(batch_abs)
        cache_abs = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        args = [params_abs, batch_abs["tokens"], cache_abs]
        if "ctx" in batch_abs:
            args.append(batch_abs["ctx"])
        lowered = fn.lower(*args)
        tokens = shape.global_batch * shape.seq_len
        model_flops, basis = 2.0 * n_active * tokens, "2ND"
    else:  # decode
        kv_dtype = (jnp.int8 if os.environ.get("KV_INT8") == "1"
                    else jnp.bfloat16)
        _, jit_for, sh = steps_lib.make_decode_step(
            cfg, mesh, shape.seq_len, shape.global_batch, kv_dtype)
        fn = jit_for(batch_abs)
        cache_abs = M.abstract_cache(cfg, shape.global_batch, shape.seq_len,
                                     kv_dtype)
        args = [params_abs, batch_abs["tokens"], cache_abs,
                SDS((), jnp.int32)]
        if "ctx" in batch_abs:
            args.append(batch_abs["ctx"])
        lowered = fn.lower(*args)
        tokens = shape.global_batch
        model_flops, basis = 2.0 * n_active * tokens, "2ND"

    compiled = lowered.compile()
    cost = _cost_items(compiled)
    text = compiled.as_text()
    hl = hlo_lib.analyze(text)          # loop-aware flops/bytes/collectives
    peak_mem, mem_note = _memory_stats(compiled)
    note = (f"{mem_note}; cost_analysis(body-once): "
            f"flops={cost.get('flops', 0):.3e} "
            f"bytes={cost.get('bytes accessed', 0):.3e}; "
            f"unknown_trip={hl.get('unknown_trip', 0):.0f}")
    res = rl.CellResult(
        arch=arch, shape=shape_key, mesh=mesh_name, chips=chips,
        flops_per_device=float(hl["flops"]),
        bytes_per_device=float(hl["bytes"]),
        wire_bytes_per_device=float(hl["total"]),
        collective_detail={k: v for k, v in hl.items()
                           if k.startswith(("bytes_", "count_"))},
        peak_memory_per_device=peak_mem,
        model_flops=model_flops, model_flops_basis=basis, tokens=tokens,
        note=note)
    return res, compiled


def _lower_mars_cell(shape_key: str, mesh, mesh_name: str, chips: int,
                     schedule: str = "a2a"):
    """Dry-run the distributed MARS mapper at production scale."""
    from repro.core import pipeline, stages
    from repro.core.config import MarsConfig

    cfg = MarsConfig(hash_bits=18).with_mode("ms_fixed")
    reads = {"map_8k": 8192, "map_32k": 32768}[shape_key]
    n_model = mesh.shape["model"]
    # D5-scale scaled index: ~4M entries over 2^18 buckets
    emax = (4_000_000 // n_model) + 64
    bl = cfg.n_buckets // n_model
    # packed entry rows: [keycnt; t_pos] (core/index.partition_index)
    parts_abs = dict(
        p_bucket_start=SDS((n_model, bl + 1), jnp.int32),
        p_entries_packed=SDS((n_model, 2, emax), jnp.int32),
    )
    signals_abs = SDS((reads, cfg.signal_len), jnp.float32)
    # the stage-engine path (resolve_plan + the sharded chunk program) —
    # the query schedule ("ring"/"a2a") is just a registered backend
    plan = stages.resolve_plan(cfg, schedule)
    fn = pipeline.sharded_chunk_fn(cfg, mesh, plan)
    lowered = fn.lower(signals_abs, parts_abs, SDS((), jnp.int32))
    compiled = lowered.compile()
    text = compiled.as_text()
    hl = hlo_lib.analyze(text)
    coll = {k: v for k, v in hl.items() if k.startswith(("bytes_", "count_"))}
    coll["total"] = hl["total"]
    peak_mem, mem_note = _memory_stats(compiled)
    # "useful work" for the mapper: AU-op count per read chunk (ssd_model
    # op inventory), converted to flops-equivalent.
    from repro.core.ssd_model import OPS
    useful = reads * (cfg.signal_len * OPS["ed_per_sample"] +
                      cfg.max_events * OPS["quant_per_event"] +
                      cfg.max_events * OPS["hash_per_seed"] +
                      cfg.max_anchors * cfg.chain_band * OPS["dp_per_pair"])
    res = rl.CellResult(
        arch="mars-rsga", shape=shape_key, mesh=mesh_name, chips=chips,
        flops_per_device=float(hl["flops"]),
        bytes_per_device=float(hl["bytes"]),
        wire_bytes_per_device=float(hl["total"]),
        collective_detail=coll, peak_memory_per_device=peak_mem,
        model_flops=float(useful), model_flops_basis="AU-ops", tokens=reads,
        note=mem_note)
    return res, compiled


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--include-mars", action="store_true")
    ap.add_argument("--layout", default="2d", choices=("2d", "fsdp"),
                    help="axis semantics: 2d = TP+FSDP ('data','model'); "
                         "fsdp = pure data/FSDP (Perf hillclimb variant)")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    assert jax.device_count() == 512, (
        f"dry-run needs 512 placeholder devices, got {jax.device_count()}")

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    if args.include_mars and args.arch == "all":
        archs.append("mars-rsga")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    for arch in archs:
        shape_keys = (["map_8k"] if arch == "mars-rsga" else
                      list(SHAPE_ORDER))
        if args.shape != "all":
            shape_keys = [args.shape]
        for sk in shape_keys:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                fname = out_dir / f"{arch}__{sk}__{mesh_name}.json"
                if args.skip_existing and fname.exists():
                    print(f"[skip-existing] {fname.name}")
                    continue
                t0 = time.time()
                try:
                    res, compiled = lower_cell(
                        arch, sk, mp, microbatches=args.microbatches,
                        layout=args.layout)
                    dt = time.time() - t0
                    rl.save_cell(res, out_dir)
                    if res.status == "ok":
                        print(f"[ok] {arch} {sk} {mesh_name}: "
                              f"flops/dev={res.flops_per_device:.3e} "
                              f"wire/dev={res.wire_bytes_per_device:.3e} "
                              f"bound={res.bottleneck} "
                              f"roofline={res.roofline_fraction:.2%} "
                              f"({dt:.0f}s)")
                        if res.peak_memory_per_device:
                            print(f"     mem/dev={res.peak_memory_per_device/2**30:.2f} GiB")
                    else:
                        print(f"[{res.status}] {arch} {sk} {mesh_name}: "
                              f"{res.note}")
                except Exception as e:
                    dt = time.time() - t0
                    print(f"[FAIL] {arch} {sk} {mesh_name} ({dt:.0f}s): {e}")
                    traceback.print_exc()
                    res = rl.CellResult(
                        arch=arch, shape=sk, mesh=mesh_name, chips=0,
                        flops_per_device=0, bytes_per_device=0,
                        wire_bytes_per_device=0, collective_detail={},
                        peak_memory_per_device=None, model_flops=0,
                        model_flops_basis="-", tokens=0, status="error",
                        note=str(e)[:500])
                    rl.save_cell(res, out_dir)

    cells = rl.load_cells(out_dir)
    print("\n" + rl.format_table(cells))


if __name__ == "__main__":
    main()
