"""LLM token-serving launcher: batched prefill + decode loop with KV cache.

This drives the *language-model* side of the repo (repro.models /
repro.train) — it has nothing to do with raw-signal read mapping.  The
RSGA serving launcher — continuous-batching multi-stream read mapping
through ``core/server.ServeDriver`` — is ``repro.launch.serve_rsga``.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_mesh
from repro.launch.train import parse_mesh
from repro.models import model as M
from repro.train import steps as steps_lib


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="LLM token-serving launcher (batched prefill + decode "
                    "with KV cache). For RSGA read-mapping serving, see "
                    "`python -m repro.launch.serve_rsga --help`.")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="auto")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache (MARS arithmetic-conversion analogue)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = parse_mesh(args.mesh, jax.device_count())
    max_len = args.prompt_len + args.gen
    kv_dtype = jnp.int8 if args.kv_int8 else jnp.bfloat16
    if args.kv_int8:
        # int8 cache stores pre-scaled values; for the demo we keep bf16
        # math and quantize at rest via the collectives helpers.
        kv_dtype = jnp.bfloat16

    _, jit_prefill, sh = steps_lib.make_prefill_step(cfg, mesh, max_len,
                                                     args.batch, kv_dtype)
    _, jit_decode, _ = steps_lib.make_decode_step(cfg, mesh, max_len,
                                                  args.batch, kv_dtype)
    from repro.configs.base import ShapeSpec
    b_abs_p = steps_lib.make_batch_abstract(
        cfg, ShapeSpec("p", args.prompt_len, args.batch, "prefill"))
    b_abs_d = steps_lib.make_batch_abstract(
        cfg, ShapeSpec("d", max_len, args.batch, "decode"))
    prefill_fn = jit_prefill(b_abs_p)
    decode_fn = jit_decode(b_abs_d)

    params = jax.device_put(M.init_params(cfg, jax.random.key(0)),
                            sh["params"])
    cache = jax.device_put(
        M.init_cache(cfg, args.batch, max_len, kv_dtype),
        shlib.cache_shardings(
            M.abstract_cache(cfg, args.batch, max_len, kv_dtype), mesh))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len), np.int64),
                         jnp.int32)
    ctx = (jnp.asarray(rng.normal(0, 1, (args.batch, cfg.n_ctx_tokens,
                                         cfg.d_model)), jnp.bfloat16)
           if cfg.n_ctx_tokens else None)

    t0 = time.time()
    pf_args = (params, tokens, cache) + ((ctx,) if ctx is not None else ())
    logits, cache = prefill_fn(*pf_args)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        d_args = (params, tok, cache, jnp.int32(args.prompt_len + i)) + \
            ((ctx,) if ctx is not None else ())
        logits, cache = decode_fn(*d_args)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    t_decode = time.time() - t0
    toks = np.stack(out, 1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.0f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.0f} ms "
          f"({args.batch*args.gen/t_decode:.1f} tok/s)")
    print("sample tokens:", toks[0][:16])
    return toks


if __name__ == "__main__":
    main()
