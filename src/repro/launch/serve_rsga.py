"""RSGA serving launcher: multi-stream read mapping at an offered load.

Simulates K concurrent client streams (sequencer channels / tenants)
submitting reads as a Poisson arrival trace, serves them through the
continuous-batching ``ServeDriver`` (core/server.py) over the stage
engine, and reports per-stream latency percentiles, aggregate
streams/sec + reads/sec, and — for context — the analytic multi-SSD
serving percentiles from ``ssd_model.serving_latency`` at the same
offered load.

    PYTHONPATH=src python -m repro.launch.serve_rsga --dataset D1 \
        --streams 8 --reads-per-stream 16 --load 0.7

(`--load` is the offered load as a fraction of the measured service
capacity; >1 exercises the bounded-queue backpressure path.)

The LLM token-serving twin of this launcher — batched prefill + decode
with a KV cache — is ``repro.launch.serve``.
"""
from __future__ import annotations

import argparse
import math
import time

import numpy as np

from repro.core import (MarsConfig, Mapper, ServeDriver, build_index,
                        ssd_model, workload)
from repro.signal import datasets, simulate


def build_trace(signals: np.ndarray, n_streams: int, reads_per_stream: int,
                arrival_rate: float, seed: int = 0,
                priorities=(0,)) -> list:
    """A Poisson arrival trace over ``n_streams`` streams: each stream
    submits ``reads_per_stream`` single-read requests; inter-arrival
    times are exponential with the given aggregate rate (virtual-time
    units = chunk services)."""
    rng = np.random.default_rng(seed)
    n = n_streams * reads_per_stream
    gaps = rng.exponential(1.0 / max(arrival_rate, 1e-9), n)
    times = np.cumsum(gaps)
    owners = rng.permutation(np.repeat(np.arange(n_streams),
                                       reads_per_stream))
    trace = []
    for k in range(n):
        sid = f"s{owners[k]}"
        trace.append((float(times[k]), sid, signals[k % signals.shape[0]],
                      int(priorities[owners[k] % len(priorities)])))
    return trace


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="MARS RSGA serving launcher: continuous-batching "
                    "multi-stream read mapping (ServeDriver). For LLM "
                    "token serving (prefill+decode), see "
                    "`python -m repro.launch.serve --help`.")
    ap.add_argument("--dataset", default="D1",
                    choices=sorted(datasets.DATASETS))
    ap.add_argument("--mode", default="ms_fixed",
                    choices=("rh2", "ms_float", "ms_fixed"))
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--reads-per-stream", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--load", type=float, default=0.7,
                    help="offered load as a fraction of service capacity "
                         "(1 chunk per virtual time unit)")
    ap.add_argument("--max-queue", type=int, default=4096,
                    help="bounded ready queue (reads); overload beyond it "
                         "is rejected by priority")
    ap.add_argument("--early-term", action="store_true",
                    help="realtime prefix ladder: confident early reads "
                         "free their slot before full length")
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--n-ssds", type=int, default=4,
                    help="drives in the analytic multi-SSD array report")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = datasets.DATASETS[args.dataset]
    cfg = datasets.config_for(spec).with_mode(args.mode)
    t0 = time.time()
    ref = simulate.make_reference(spec.genome_len, seed=spec.seed)
    n_reads = args.streams * args.reads_per_stream
    rs = simulate.sample_reads(ref, n_reads, signal_len=cfg.signal_len,
                               seed=spec.seed + 1, junk_frac=0.08)
    index = build_index(ref.events_concat, ref.n_events, cfg)
    print(f"[setup] genome={spec.genome_len}bp streams={args.streams} "
          f"reads/stream={args.reads_per_stream} "
          f"index={index.n_entries} entries {time.time()-t0:.1f}s")

    mapper = Mapper(index, cfg, use_kernels=args.use_kernels)
    # offered load in reads per virtual time unit: one unit serves one
    # chunk, i.e. `chunk` reads at capacity
    rate = args.load * args.chunk
    trace = build_trace(rs.signals, args.streams, args.reads_per_stream,
                        arrival_rate=rate, seed=args.seed)
    sd = ServeDriver(mapper, chunk=args.chunk, max_queue=args.max_queue,
                     early_term=args.early_term)
    t0 = time.time()
    reports = sd.serve_trace(trace)
    wall = time.time() - t0

    print(f"[serve] {n_reads} reads over {args.streams} streams in "
          f"{wall:.2f}s wall ({n_reads/max(wall, 1e-9):.1f} reads/s, "
          f"{args.streams/max(wall, 1e-9):.2f} streams/s); "
          f"{sd.n_chunks} chunks, {sd.n_pad_rows} pad rows, "
          f"virtual makespan {sd.clock:.1f}")
    for sid in sorted(reports, key=lambda s: int(s[1:])):
        r = reports[sid]
        print(f"  {sid}: reads={r.n_reads} mapped={r.n_mapped} "
              f"rejected={r.n_rejected} latency p50={r.p50_latency:.2f} "
              f"p99={r.p99_latency:.2f} mean={r.mean_latency:.2f} "
              f"(virtual units)")

    # analytic multi-SSD serving percentiles at the matching offered load
    w = workload.from_counters(sd.counters, cfg, index_bytes=index.nbytes)
    if w.n_reads:
        arr = ssd_model.SSDArrayConfig(n_ssds=args.n_ssds)
        batch = ssd_model.mars_array_latency(w, arr)
        cap = w.n_reads / batch["total"]          # reads/s at saturation
        sv = ssd_model.serving_latency(w, offered_load=args.load * cap,
                                       arr=arr)
        print(f"[model] {args.n_ssds}-SSD array: batch={batch['total']*1e3:.2f}ms "
              f"service={sv['service']*1e6:.1f}us/read rho={sv['utilization']:.2f} "
              f"p50={sv['p50']*1e6:.1f}us p99={sv['p99']*1e6:.1f}us"
              + (" SATURATED" if sv["saturated"] else ""))
    return reports


if __name__ == "__main__":
    main()
