"""RSGA serving launcher: multi-stream read mapping at an offered load.

Simulates K concurrent client streams (sequencer channels / tenants)
submitting reads as a Poisson arrival trace, serves them through the
continuous-batching ``ServeDriver`` (core/server.py) over the stage
engine, and reports per-stream latency percentiles, aggregate
streams/sec + reads/sec, and — for context — the analytic multi-SSD
serving percentiles from ``ssd_model.serving_latency`` at the same
offered load.

    PYTHONPATH=src python -m repro.launch.serve_rsga --dataset D1 \
        --streams 8 --reads-per-stream 16 --load 0.7

(`--load` is the offered load as a fraction of the measured service
capacity; >1 exercises the bounded-queue backpressure path.)

Degraded-mode serving (EXPERIMENTS.md "Degraded-mode methodology"):
``--fault-plan SEED`` routes the index through the tiered storage path
with a seeded ``core/faults.FaultPlan`` injected at tile page-in
(checksummed retry/backoff, virtual-time accounted); ``--shed`` closes
the admission loop (SLO classes + saturation-aware shedding); and
``--load-sweep 0.5,0.9,1.3,1.8`` serves the same trace shape at several
offered loads, printing the shed-rate vs p50/p99 curve.

The LLM token-serving twin of this launcher — batched prefill + decode
with a KV cache — is ``repro.launch.serve``.
"""
from __future__ import annotations

import argparse
import math
import time

import numpy as np

from repro.core import (FaultPlan, MarsConfig, Mapper, ServeDriver, SLOClass,
                        TenantBudget, build_index, costmodel, ssd_model,
                        workload)
from repro.signal import datasets, simulate


def build_trace(signals: np.ndarray, n_streams: int, reads_per_stream: int,
                arrival_rate: float, seed: int = 0,
                priorities=(0,), slos=None, tenants: int = 0,
                skew: float = 0.0) -> list:
    """A Poisson arrival trace over ``n_streams`` streams: each stream
    submits ``reads_per_stream`` single-read requests; inter-arrival
    times are exponential with the given aggregate rate (virtual-time
    units = chunk services).  With ``slos`` each stream is tagged with
    the SLO class name ``slos[stream % len(slos)]`` (priority/deadline
    come from the class).

    ``tenants`` > 0 assigns stream k to tenant ``t{k % tenants}`` (rows
    grow the tenant column ``ServeDriver.serve_trace`` binds on).
    ``skew`` > 0 draws each read's owning stream from a Zipf-like
    distribution (stream k weighted ``(k+1)**-skew``) instead of the
    balanced split, so low-numbered streams — and their tenants — hog
    the trace; 0 keeps the legacy balanced trace bit-exactly."""
    rng = np.random.default_rng(seed)
    n = n_streams * reads_per_stream
    gaps = rng.exponential(1.0 / max(arrival_rate, 1e-9), n)
    times = np.cumsum(gaps)
    if skew > 0:
        p = (1.0 + np.arange(n_streams)) ** -float(skew)
        owners = rng.choice(n_streams, size=n, p=p / p.sum())
    else:
        owners = rng.permutation(np.repeat(np.arange(n_streams),
                                           reads_per_stream))
    trace = []
    for k in range(n):
        sid = f"s{owners[k]}"
        sig = signals[k % signals.shape[0]]
        tenant = f"t{int(owners[k]) % tenants}" if tenants else None
        if tenants:
            prio = (None if slos is not None
                    else int(priorities[owners[k] % len(priorities)]))
            slo = None if slos is None else slos[int(owners[k]) % len(slos)]
            trace.append((float(times[k]), sid, sig, prio, None, slo,
                          tenant))
        elif slos is None:
            trace.append((float(times[k]), sid, sig,
                          int(priorities[owners[k] % len(priorities)])))
        else:
            trace.append((float(times[k]), sid, sig, None, None,
                          slos[int(owners[k]) % len(slos)]))
    return trace


# The two-tier serving contract the --shed path demonstrates: latency-
# sensitive streams are never shed; bulk streams absorb the overload.
SHED_CLASSES = (SLOClass("gold", priority=1, deadline=64.0, sheddable=False),
                SLOClass("best_effort", priority=0))


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="MARS RSGA serving launcher: continuous-batching "
                    "multi-stream read mapping (ServeDriver). For LLM "
                    "token serving (prefill+decode), see "
                    "`python -m repro.launch.serve --help`.")
    ap.add_argument("--dataset", default="D1",
                    choices=sorted(datasets.DATASETS))
    ap.add_argument("--mode", default="ms_fixed",
                    choices=("rh2", "ms_float", "ms_fixed"))
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--reads-per-stream", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--load", type=float, default=0.7,
                    help="offered load as a fraction of service capacity "
                         "(1 chunk per virtual time unit)")
    ap.add_argument("--max-queue", type=int, default=4096,
                    help="bounded ready queue (reads); overload beyond it "
                         "is rejected by priority")
    ap.add_argument("--early-term", action="store_true",
                    help="realtime prefix ladder: confident early reads "
                         "free their slot before full length")
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--model", default="analytic",
                    choices=sorted(costmodel.MODELS),
                    help="performance backend for the array report and the "
                         "shed controller (core/costmodel.py): closed "
                         "forms or the discrete-event in-storage simulator")
    ap.add_argument("--n-ssds", type=int, default=4,
                    help="drives in the multi-SSD array report")
    ap.add_argument("--n-failed", type=int, default=0, choices=(0, 1),
                    help="degraded analytic array: one drive lost, index "
                         "rebalanced N -> N/2 (repartition_index)")
    ap.add_argument("--fault-plan", type=int, default=None, metavar="SEED",
                    help="serve through the tiered storage path with a "
                         "seeded FaultPlan (read errors + corruption + "
                         "latency spikes) injected at tile page-in")
    ap.add_argument("--tiles", type=int, default=8,
                    help="host-resident index tiles (with --fault-plan)")
    ap.add_argument("--cache-slots", type=int, default=4,
                    help="device tile-cache slots (with --fault-plan)")
    ap.add_argument("--cache-replicas", type=int, default=0,
                    help="pinned replica slots for the hottest tiles "
                         "(with --fault-plan): traffic-driven, result-"
                         "invisible; the [model] line prices the win")
    ap.add_argument("--tenants", type=int, default=0, metavar="N",
                    help="assign streams round-robin to N tenants with "
                         "fair-share shed budgets (capacity/N reads per "
                         "virtual unit each) and print the per-tenant "
                         "report; 0 = tenant-free legacy driver")
    ap.add_argument("--skew", type=float, default=0.0, metavar="ALPHA",
                    help="Zipf exponent skewing trace volume toward low-"
                         "numbered streams/tenants (0 = balanced); with "
                         "--tenants the hot tenant overruns its budget "
                         "and is shed first")
    ap.add_argument("--shed", action="store_true",
                    help="closed-loop admission: SLO classes (gold / "
                         "best_effort) + saturation-aware load shedding")
    ap.add_argument("--shed-window", type=float, default=8.0)
    ap.add_argument("--load-sweep", default=None, metavar="L1,L2,...",
                    help="serve the trace shape at several offered loads "
                         "and print the shed-rate vs p50/p99 curve")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = datasets.DATASETS[args.dataset]
    cfg = datasets.config_for(spec).with_mode(args.mode)
    t0 = time.time()
    ref = simulate.make_reference(spec.genome_len, seed=spec.seed)
    n_reads = args.streams * args.reads_per_stream
    rs = simulate.sample_reads(ref, n_reads, signal_len=cfg.signal_len,
                               seed=spec.seed + 1, junk_frac=0.08)
    index = build_index(ref.events_concat, ref.n_events, cfg)
    print(f"[setup] genome={spec.genome_len}bp streams={args.streams} "
          f"reads/stream={args.reads_per_stream} "
          f"index={index.n_entries} entries {time.time()-t0:.1f}s")

    def make_mapper():
        if args.fault_plan is None:
            return Mapper(index, cfg, use_kernels=args.use_kernels)
        plan = FaultPlan(seed=args.fault_plan, p_read_error=0.02,
                         p_corrupt=0.02, p_latency=0.05, latency_units=2.0)
        return Mapper(index, cfg, backend="tiered", tiles=args.tiles,
                      cache_slots=args.cache_slots,
                      cache_replicas=args.cache_replicas, fault_plan=plan)

    slos = None
    serve_kw = dict(chunk=args.chunk, max_queue=args.max_queue,
                    early_term=args.early_term, cost_model=args.model)
    if args.shed:
        serve_kw.update(shed=True, shed_window=args.shed_window,
                        slo_classes=SHED_CLASSES)
        slos = [c.name for c in SHED_CLASSES]
    if args.tenants:
        # fair share of service capacity (`chunk` reads per virtual unit)
        serve_kw.update(tenant_budgets=tuple(
            TenantBudget(f"t{i}", rate=args.chunk / args.tenants)
            for i in range(args.tenants)))

    def run_once(load, verbose=True):
        # offered load in reads per virtual time unit: one unit serves one
        # chunk, i.e. `chunk` reads at capacity
        mapper = make_mapper()
        trace = build_trace(rs.signals, args.streams, args.reads_per_stream,
                            arrival_rate=load * args.chunk, seed=args.seed,
                            slos=slos, tenants=args.tenants, skew=args.skew)
        sd = ServeDriver(mapper, **serve_kw)
        t0 = time.time()
        reports = sd.serve_trace(trace)
        wall = time.time() - t0
        if verbose:
            print(f"[serve] {n_reads} reads over {args.streams} streams in "
                  f"{wall:.2f}s wall ({n_reads/max(wall, 1e-9):.1f} reads/s, "
                  f"{args.streams/max(wall, 1e-9):.2f} streams/s); "
                  f"{sd.n_chunks} chunks, {sd.n_pad_rows} pad rows, "
                  f"virtual makespan {sd.clock:.1f}")
            for sid in sorted(reports, key=lambda s: int(s[1:])):
                r = reports[sid]
                print(f"  {sid}: reads={r.n_reads} mapped={r.n_mapped} "
                      f"rejected={r.n_rejected} shed={r.n_shed} "
                      f"latency p50={r.p50_latency:.2f} "
                      f"p99={r.p99_latency:.2f} mean={r.mean_latency:.2f} "
                      f"(virtual units)")
            if args.shed:
                for name, c in sorted(sd.class_report().items(),
                                      key=lambda kv: str(kv[0])):
                    print(f"  [class {name}] reads={c.n_reads} "
                          f"mapped={c.n_mapped} shed={c.n_shed} "
                          f"p50={c.p50_latency:.2f} p99={c.p99_latency:.2f}")
            if args.tenants:
                for name, r in sorted(sd.tenant_report().items(),
                                      key=lambda kv: str(kv[0])):
                    tokens = (sd.tenant_tokens(name)
                              if name in sd.tenant_budgets else math.nan)
                    print(f"  [tenant {name}] reads={r.n_reads} "
                          f"mapped={r.n_mapped} shed={r.n_shed} "
                          f"over_budget={r.n_over_budget} "
                          f"p50={r.p50_latency:.2f} p99={r.p99_latency:.2f} "
                          f"tokens_left={tokens:.1f}")
            if mapper.cache is not None:
                c = mapper.cache
                print(f"[storage] tiles paged={c.misses} retries={c.retries} "
                      f"corruptions healed={c.corruptions} "
                      f"vtime lost to backoff={c.vtime_penalty:.1f}")
        return sd, reports

    if args.load_sweep:
        loads = [float(x) for x in args.load_sweep.split(",") if x]
        print(f"[sweep] shed-rate vs latency over loads {loads}")
        print("  load   shed%   rejected%   p50     p99")
        for load in loads:
            sd, reports = run_once(load, verbose=False)
            lat = np.asarray([l for st in sd._streams.values()
                              for l, a in zip(st.latency, st.admitted)
                              if a and math.isfinite(l)])
            total = sum(r.n_reads for r in reports.values())
            shed = sum(r.n_shed for r in reports.values())
            rej = sum(r.n_rejected for r in reports.values())
            p50 = float(np.percentile(lat, 50)) if lat.size else math.nan
            p99 = float(np.percentile(lat, 99)) if lat.size else math.nan
            print(f"  {load:5.2f}  {100*shed/max(total,1):5.1f}  "
                  f"{100*rej/max(total,1):9.1f}  {p50:6.2f}  {p99:6.2f}")
        return None

    sd, reports = run_once(args.load)

    # modeled multi-SSD serving percentiles at the matching offered load,
    # through the selected costmodel backend (--model)
    w = workload.from_counters(sd.counters, cfg, index_bytes=index.nbytes)
    if w.n_reads:
        cm = costmodel.get_model(args.model)
        arr = ssd_model.SSDArrayConfig(n_ssds=args.n_ssds,
                                       n_failed=args.n_failed)
        batch = cm.array_latency(w, arr)
        cap = w.n_reads / batch["total"]          # reads/s at saturation
        sv = cm.serving(w, offered_load=args.load * cap, arr=arr)
        tag = f"{args.n_ssds}-SSD array [{cm.name}]"
        if args.n_failed:
            tag += f" (DEGRADED: {arr.n_serving} serving)"
        print(f"[model] {tag}: batch={batch['total']*1e3:.2f}ms "
              f"service={sv['service']*1e6:.1f}us/read rho={sv['utilization']:.2f} "
              f"p50={sv['p50']*1e6:.1f}us p99={sv['p99']*1e6:.1f}us"
              + (" SATURATED" if sv["saturated"] else ""))
        cache = sd.mapper.cache
        if cache is not None:
            # price the measured tile-traffic skew + the replication win
            sk = cm.skewed_serving(w, cache.tile_traffic(),
                                   replicas=cache.n_replicas)
            print(f"[skew] tile-traffic imbalance x{sk['factor']:.2f}; "
                  f"{cache.n_replicas} replica(s) -> "
                  f"x{sk['factor_replicated']:.2f}; modeled replication "
                  f"speedup {sk['replication_speedup']:.2f}x "
                  f"(replica loads={cache.replica_loads})")
    return reports


if __name__ == "__main__":
    main()
