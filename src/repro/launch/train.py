"""Training launcher: fault-tolerant loop with checkpoint/resume, straggler
monitoring and elastic restarts.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container use --reduced; the full configs are exercised by the
dry-run (launch/dryrun.py).  Restarting the same command resumes from the
latest valid checkpoint — including on a different device count
(reshard-on-restore).
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.tokens import TokenStream, TokenStreamState
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train import steps as steps_lib
from repro.train.monitor import StepMonitor


def parse_mesh(spec: str, n_devices: int):
    if spec == "auto":
        if n_devices == 1:
            return make_mesh((1, 1), ("data", "model"))
        d = n_devices // 2
        return make_mesh((d, 2), ("data", "model"))
    dims = tuple(int(x) for x in spec.split("x"))
    names = ("pod", "data", "model")[-len(dims):]
    return make_mesh(dims, names)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--mesh", default="auto")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = parse_mesh(args.mesh, jax.device_count())
    print(f"arch={cfg.name} devices={jax.device_count()} "
          f"mesh={dict(mesh.shape)}")

    adamw = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                            total_steps=args.steps)
    _, jit_for, sh = steps_lib.make_train_step(cfg, mesh, adamw)
    shape = ShapeSpec("train", args.seq, args.batch, "train")
    fn = jit_for(steps_lib.make_batch_abstract(cfg, shape))

    # init or resume
    start_step = 0
    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=args.seed,
                         n_ctx=cfg.n_ctx_tokens, d_model=cfg.d_model)
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state_abs = (M.abstract_params(cfg),
                     opt.abstract_state(M.abstract_params(cfg)))
        (params, opt_state), start_step, ds, _ = ckpt.restore(
            args.ckpt_dir, state_abs, shardings=(sh["params"], sh["opt"]))
        stream.state = TokenStreamState.from_dict(ds)
        print(f"resumed from step {start_step}")
    else:
        params = jax.device_put(M.init_params(cfg, jax.random.key(args.seed)),
                                sh["params"])
        opt_state = jax.jit(opt.init_state, out_shardings=sh["opt"])(params)

    mon = StepMonitor(on_straggler=lambda ev: print(
        f"[straggler] step={ev.step} {ev.step_time:.2f}s = {ev.ratio:.1f}x ema"))
    tokens_per_step = args.batch * args.seq
    for step in range(start_step, args.steps):
        batch = stream.next_batch()
        batch = {k: jnp.asarray(v, jnp.bfloat16 if k == "ctx" else None)
                 for k, v in batch.items()}
        mon.start()
        params, opt_state, metrics = fn(params, opt_state, batch)
        metrics = jax.device_get(metrics)
        dt = mon.stop()
        if (step + 1) % args.log_every == 0 or step == start_step:
            print(f"step {step+1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"{dt:.2f}s {mon.tokens_per_sec(tokens_per_step):.0f} tok/s")
        if args.ckpt_dir and (step + 1) % args.save_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state),
                      data_state=stream.state.as_dict())
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, (params, opt_state),
                  data_state=stream.state.as_dict())
    print(f"done: {args.steps} steps, final loss "
          f"{float(metrics['loss']):.4f}, stragglers={len(mon.events)}")
    return params


if __name__ == "__main__":
    main()
