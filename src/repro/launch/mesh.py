"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The single-pod mesh is 16x16 = 256 chips
('data', 'model'); the multi-pod mesh adds a leading 'pod' axis
(2 x 16 x 16 = 512 chips).  `pod` x `data` together form the DP/FSDP
domain; `model` carries TP / EP / MARS index partitions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False, layout: str = "2d"):
    """layout='2d' (default): ('data','model') TP+FSDP.  layout='fsdp':
    pure data/FSDP parallelism — the 'model' axis is renamed 'data2' so the
    sharding rules treat every axis as a DP/FSDP axis (dense-model
    hillclimb variant, EXPERIMENTS.md §Perf)."""
    if layout == "fsdp":
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = (("pod", "data", "data2") if multi_pod
                else ("data", "data2"))
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh for tests / elastic restarts (e.g. (4,2) on 8 CPU
    devices)."""
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """Data-parallel (FSDP) axes of a mesh: everything except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def tp_axis(mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
