"""MARS read-mapping launcher — the paper-kind end-to-end driver.

Streams raw-signal chunks from a container file through the unified
double-buffered driver (core/driver.py — reader prefetch + async device
dispatch = the flash/compute overlap), checkpoints progress to an
append-only JSONL log so a killed job resumes where it stopped, and
writes PAF-like output.

    PYTHONPATH=src python -m repro.launch.map_reads --dataset D1 \
        --out /tmp/mars.paf --workdir /tmp/mars
"""
from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

from repro.core import MarsConfig, Mapper, build_index, driver, score_accuracy
from repro.signal import datasets, reader, simulate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="D1", choices=sorted(datasets.DATASETS))
    ap.add_argument("--mode", default="ms_fixed",
                    choices=("rh2", "ms_float", "ms_fixed"))
    ap.add_argument("--workdir", default="/tmp/mars_run")
    ap.add_argument("--out", default=None)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--reads", type=int, default=None)
    ap.add_argument("--use-kernels", action="store_true")
    args = ap.parse_args(argv)

    spec = datasets.DATASETS[args.dataset]
    cfg = datasets.config_for(spec).with_mode(args.mode)
    wd = pathlib.Path(args.workdir)
    wd.mkdir(parents=True, exist_ok=True)

    # ---- build (or reuse) reference/index/reads --------------------------- #
    sig_file = wd / f"{spec.key}_signals.mars"
    t0 = time.time()
    ref = simulate.make_reference(spec.genome_len, seed=spec.seed)
    n_reads = args.reads or spec.bench_reads
    rs = simulate.sample_reads(ref, n_reads, signal_len=cfg.signal_len,
                               seed=spec.seed + 1, junk_frac=0.08)
    reader.write_signals(sig_file, rs.signals)
    index = build_index(ref.events_concat, ref.n_events, cfg)
    print(f"[setup] genome={spec.genome_len}bp reads={n_reads} "
          f"index={index.n_entries} entries ({index.nbytes/1e6:.1f} MB) "
          f"{time.time()-t0:.1f}s")

    # ---- resume state (append-only JSONL, periodic compaction) ------------- #
    progress = driver.ProgressLog(wd / f"progress_{args.mode}.jsonl")
    start_chunk, results = progress.load()
    if start_chunk:
        print(f"[resume] continuing at chunk {start_chunk}")

    mapper = Mapper(index, cfg, use_kernels=args.use_kernels)
    rdr = reader.SignalReader(sig_file, chunk=args.chunk,
                              start_chunk=start_chunk)
    t0 = time.time()
    n_done = len(results)
    stream = driver.stream_map(mapper.chunk_fn(), rdr)
    for ci, n_valid, out in stream:
        rows = [(int(out.t_start[i]), float(out.score[i]),
                 bool(out.mapped[i])) for i in range(n_valid)]
        progress.append(ci + 1, rows)      # also accumulates progress.rows
        n_done += n_valid
    results = progress.rows
    dt = time.time() - t0
    print(f"[map] {n_done} reads in {dt:.1f}s "
          f"({n_done/max(dt,1e-9):.1f} reads/s)")

    # ---- score + write PAF -------------------------------------------------- #
    t_start = np.array([r[0] for r in results], np.int64)
    score = np.array([r[1] for r in results], np.float32)
    mapped = np.array([r[2] for r in results])
    from repro.core.pipeline import MapOutput
    out = MapOutput(t_start=t_start, score=score, mapped=mapped,
                    n_events=np.zeros_like(t_start), counters={})
    acc = score_accuracy(out, rs.true_pos[:len(results)],
                         rs.true_strand[:len(results)],
                         rs.mappable[:len(results)],
                         rs.n_bases[:len(results)], ref.n_events)
    print(f"[accuracy] P={acc['precision']:.3f} R={acc['recall']:.3f} "
          f"F1={acc['f1']:.3f}")

    if args.out:
        Le = ref.n_events
        with open(args.out, "w") as f:
            for i, (t, s, m) in enumerate(results):
                if not m:
                    continue
                strand = "-" if t >= Le else "+"
                fwd = t if t < Le else Le - 1 - ((t - Le) + int(rs.n_bases[i]) - 1)
                f.write(f"read{i}\t{cfg.signal_len}\t0\t{cfg.signal_len}\t"
                        f"{strand}\tref\t{Le}\t{fwd}\t"
                        f"{fwd + int(rs.n_bases[i])}\t{s:.1f}\t255\n")
        print(f"[out] PAF written to {args.out}")
    progress.clear()
    return acc


if __name__ == "__main__":
    main()
