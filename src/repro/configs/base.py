"""Architecture configuration schema + input-shape registry.

Every assigned architecture is one `ArchConfig` instance (its own file in
this package).  `reduced()` derives the CPU smoke-test variant (same family
and code paths, tiny dims).  `shapes.py`-style shape specs live here too so
(arch x shape) cells are fully defined in one place.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "hybrid", "vlm", "audio", "ssm", "rsga")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int                    # query heads (0 for attn-free)
    n_kv: int
    d_head: int
    d_ff: int                       # dense-layer FFN width (0 = no MLP)
    vocab: int

    # attention details
    qk_norm: bool = False
    swa_window: Optional[int] = None        # sliding-window size (None=full)
    global_layers: Tuple[int, ...] = ()     # full-attn layers in a SWA stack
    rope_theta: float = 500_000.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1              # 2 -> alternate dense/MoE (Llama-4)

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64

    # encoder-decoder (audio) / cross-attention (vlm)
    n_enc_layers: int = 0
    cross_attn_every: int = 0       # every k-th layer cross-attends
    n_ctx_tokens: int = 0           # image patches / encoder frames (stub)

    tie_embeddings: bool = False
    source: str = ""                # provenance note

    # ------------------------------------------------------------------ #
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run the 500k-context decode shape: SSM,
        hybrid, or sliding-window attention stacks."""
        return (self.family in ("ssm", "hybrid")
                or self.swa_window is not None)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        r = dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4) if self.moe_every == 1 else 4,
            d_model=128,
            n_heads=4 if self.n_heads else 0,
            n_kv=min(self.n_kv, 2) if self.n_kv else 0,
            d_head=32 if self.n_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            n_experts=8 if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_ff_expert=64 if self.d_ff_expert else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            n_enc_layers=2 if self.n_enc_layers else 0,
            cross_attn_every=(2 if self.cross_attn_every else 0),
            n_ctx_tokens=32 if self.n_ctx_tokens else 0,
            swa_window=(64 if self.swa_window is not None else None),
            global_layers=tuple(g for g in self.global_layers if g < 4),
        )
        return r

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    key: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Per assignment rules: long_500k only for sub-quadratic archs."""
    if shape.key == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attention)"
    return True, ""
