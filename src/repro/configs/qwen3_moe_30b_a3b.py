"""Qwen3-MoE 30B-A3B [hf:Qwen/Qwen3-30B-A3B; hf] — 128 experts, top-8,
fine-grained experts (d_ff_expert=768), qk-norm.  48L d_model=2048 32H
(GQA kv=4) vocab=151936."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    d_head=128,
    d_ff=0,                # every layer is MoE (no dense FFN layers)
    vocab=151936,
    qk_norm=True,
    n_experts=128,
    top_k=8,
    d_ff_expert=768,
    n_shared_experts=0,
    moe_every=1,
    rope_theta=1_000_000.0,
    source="hf: Qwen/Qwen3-30B-A3B",
)
