"""The paper's own workload as a selectable config: the MARS RSGA
read-mapping pipeline (distributed: reads over data axes, reference index
sharded over the model axis).  Not an LM — `family="rsga"`; its shapes are
(reads_per_chunk x signal_len) rather than (batch x seq)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mars-rsga",
    family="rsga",
    n_layers=0, d_model=0, n_heads=0, n_kv=0, d_head=0, d_ff=0, vocab=0,
    source="this paper (MARS, Sections 5-6)",
)
