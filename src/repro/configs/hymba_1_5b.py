"""Hymba 1.5B [arXiv:2411.13676; hf] — hybrid heads: attention and Mamba
(SSM) branches run in PARALLEL inside every layer; SWA everywhere except
three full-attention layers (first / middle / last).
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.

Simplifications recorded in DESIGN.md: meta-tokens (128 learned prefix
tokens) and cross-layer KV sharing are omitted — backbone only."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    swa_window=1024,
    global_layers=(0, 15, 31),
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    rope_theta=10_000.0,
    source="arXiv:2411.13676 (hf: nvidia/Hymba-1.5B-Base)",
)
