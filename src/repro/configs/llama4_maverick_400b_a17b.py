"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-*; unverified] — MoE
with 128 routed experts (top-1) + 1 shared expert, MoE layers interleaved
with dense layers.  48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.

The early-fusion vision pathway is out of scope for the LM backbone cells
(text-only shapes assigned)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_head=128,
    d_ff=16384,            # dense (non-MoE) interleaved layers
    vocab=202048,
    n_experts=128,
    top_k=1,
    d_ff_expert=8192,
    n_shared_experts=1,
    moe_every=2,           # alternate dense / MoE
    rope_theta=500_000.0,
    source="hf: meta-llama/Llama-4-Maverick-17B-128E (dims per assignment)",
)
