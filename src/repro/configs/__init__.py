"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (ArchConfig, ShapeSpec, SHAPES, SHAPE_ORDER,
                                cell_applicable)

from repro.configs.h2o_danube_1_8b import CONFIG as _danube
from repro.configs.llama3_405b import CONFIG as _llama3
from repro.configs.granite_20b import CONFIG as _granite
from repro.configs.qwen3_4b import CONFIG as _qwen3
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.llama4_maverick_400b_a17b import CONFIG as _llama4
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from repro.configs.llama32_vision_11b import CONFIG as _llamav
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.mars_rsga import CONFIG as _mars

ARCHS: Dict[str, ArchConfig] = {c.name: c for c in (
    _danube, _llama3, _granite, _qwen3, _hymba, _llama4, _qwen3moe,
    _llamav, _whisper, _mamba2,
)}

# the paper's own pipeline is selectable but not part of the 40 LM cells
EXTRA_ARCHS: Dict[str, ArchConfig] = {_mars.name: _mars}


def get_config(name: str) -> ArchConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in EXTRA_ARCHS:
        return EXTRA_ARCHS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def list_archs() -> List[str]:
    return list(ARCHS)


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "SHAPE_ORDER", "ARCHS",
           "EXTRA_ARCHS", "get_config", "list_archs", "cell_applicable"]
