"""H2O-Danube 1.8B [arXiv:2401.16818; hf] — llama+mistral mix with
sliding-window attention.  24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, SWA window 4096."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv=8,
    d_head=80,
    d_ff=6912,
    vocab=32000,
    swa_window=4096,
    rope_theta=10_000.0,
    source="arXiv:2401.16818 (hf: h2oai/h2o-danube-1.8b)",
)
