"""Granite 20B (code) [arXiv:2405.04324; hf] — llama-arch with MQA (kv=1).
52L d_model=6144 48H d_ff=24576 vocab=49152.

Note: the released granite-20b-code uses GPT-BigCode-style learned absolute
positions; we use RoPE uniformly across the stack (recorded deviation —
the assignment pins layer/width/head/vocab dims, which match exactly)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv=1,
    d_head=128,
    d_ff=24576,
    vocab=49152,
    rope_theta=10_000.0,
    source="arXiv:2405.04324 (hf: ibm-granite/granite-20b-code-base)",
)
