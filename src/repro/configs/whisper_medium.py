"""Whisper medium [arXiv:2212.04356; unverified] — encoder-decoder; the
conv frontend is a STUB (input_specs() provides precomputed frame
embeddings).  24L enc + 24L dec, d_model=1024 16H (kv=16 -> MHA) d_ff=4096
vocab=51865."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,            # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_head=64,
    d_ff=4096,
    vocab=51865,
    n_ctx_tokens=1500,      # encoder frames (30 s / 20 ms hop, stub)
    rope_theta=10_000.0,    # (whisper uses sinusoidal; rope noted deviation)
    source="arXiv:2212.04356",
)
