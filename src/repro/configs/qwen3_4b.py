"""Qwen3 4B [hf:Qwen/Qwen3-8B family; hf] — qk-norm, GQA.
36L d_model=2560 32H (GQA kv=8, head_dim 128) d_ff=9728 vocab=151936."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv=8,
    d_head=128,          # head_dim decoupled from d_model/n_heads (Qwen3)
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf: Qwen/Qwen3-4B",
)
