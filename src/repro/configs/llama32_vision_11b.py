"""Llama-3.2 Vision 11B [hf:meta-llama/Llama-3.2-11B-Vision; unverified] —
decoder with cross-attention image layers every 5th layer.  40L
d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

The vision encoder is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (n_ctx_tokens x d_model)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_head=128,
    d_ff=14336,
    vocab=128256,
    cross_attn_every=5,
    n_ctx_tokens=1600,      # image patch tokens (stub embeddings)
    rope_theta=500_000.0,
    source="hf: meta-llama/Llama-3.2-11B-Vision",
)
