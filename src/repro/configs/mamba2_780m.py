"""Mamba-2 780M [arXiv:2405.21060; unverified] — SSD (state-space duality),
attention-free.  48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128,
expand=2 (d_inner=3072), head_dim=64 -> 48 SSD heads, conv width 4."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv=0,
    d_head=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    source="arXiv:2405.21060",
)
