"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs_global   / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes_global   / (chips * HBM_BW)
    collective term = wire_bytes_per_dev / LINK_BW
                      (== collective_bytes_global / (chips * LINK_BW))

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).

`cost_analysis()` of the SPMD-partitioned executable reports PER-DEVICE
flops/bytes; we scale by chips for the global numbers.  MODEL_FLOPS uses
6*N*D for training and 2*N*D for forward-only serving shapes (documented
next to the ratio).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / ICI link


def suggest(arch: str, bottleneck: str, basis: str) -> str:
    """One sentence: what would move the dominant term down."""
    serve = basis != "6ND"
    if arch == "mars-rsga":
        return ("fuse the integer pipeline into the Pallas kernels "
                "(VMEM-resident intermediates); the jnp fallback "
                "materializes every stage")
    if bottleneck == "collective":
        if "moe" in arch or "maverick" in arch:
            return ("shrink EP all-to-all payloads: larger token GROUP, "
                    "int8 dispatch masks, fewer expert shards per group")
        if serve:
            return ("shard the KV cache over more axes; batch decode "
                    "requests to amortize weight gathers")
        return ("reduce TP degree / FSDP layout: activation collectives "
                "dominate, weights-only gathers are ~3x params")
    if bottleneck == "memory":
        if serve:
            return ("int8 KV cache + larger decode batch (cache and "
                    "weight reads amortize over tokens)")
        return ("fused attention/SSD kernel keeping score/decay tensors "
                "in VMEM; bf16 intermediates; tuned kv_chunk")
    return ("raise per-chip arithmetic intensity: larger microbatch or "
            "wider per-shard layers")


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collective_detail: Dict[str, float]
    peak_memory_per_device: Optional[float]
    model_flops: float
    model_flops_basis: str        # "6ND" or "2ND"
    tokens: int
    status: str = "ok"
    note: str = ""

    # ---- derived ----------------------------------------------------------
    @property
    def flops_global(self) -> float:
        return self.flops_per_device * self.chips

    @property
    def bytes_global(self) -> float:
        return self.bytes_per_device * self.chips

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.t_compute, memory=self.t_memory,
                     collective=self.t_collective)
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        if self.flops_global <= 0:
            return 0.0
        return self.model_flops / self.flops_global

    @property
    def suggestion(self) -> str:
        return suggest(self.arch, self.bottleneck, self.model_flops_basis)

    @property
    def roofline_fraction(self) -> float:
        """useful work / time-at-bottleneck: MODEL_FLOPS/(chips*peak) over
        the dominant term — the MFU-analogue the perf loop maximizes."""
        t_dom = max(self.t_compute, self.t_memory, self.t_collective)
        if t_dom <= 0:
            return 0.0
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_useful / t_dom

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction,
                 flops_global=self.flops_global,
                 bytes_global=self.bytes_global,
                 suggestion=self.suggestion)
        return d


def save_cell(result: CellResult, out_dir) -> pathlib.Path:
    out_dir = pathlib.Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    f = out_dir / f"{result.arch}__{result.shape}__{result.mesh}.json"
    f.write_text(json.dumps(result.to_dict(), indent=1))
    return f


def load_cells(out_dir) -> Dict[str, Dict]:
    out = {}
    for f in sorted(pathlib.Path(out_dir).glob("*.json")):
        out[f.stem] = json.loads(f.read_text())
    return out


def format_table(cells: Dict[str, Dict]) -> str:
    hdr = (f"{'arch':28s} {'shape':12s} {'mesh':9s} "
           f"{'t_comp(s)':>10s} {'t_mem(s)':>10s} {'t_coll(s)':>10s} "
           f"{'bound':>7s} {'useful':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for key in sorted(cells):
        c = cells[key]
        if c.get("status") != "ok":
            lines.append(f"{c['arch']:28s} {c['shape']:12s} {c['mesh']:9s} "
                         f"{c.get('note', c['status'])}")
            continue
        lines.append(
            f"{c['arch']:28s} {c['shape']:12s} {c['mesh']:9s} "
            f"{c['t_compute']:10.3e} {c['t_memory']:10.3e} "
            f"{c['t_collective']:10.3e} {c['bottleneck']:>7s} "
            f"{c['useful_flops_ratio']:7.2%} {c['roofline_fraction']:9.2%}")
    return "\n".join(lines)


def format_suggestions(cells: Dict[str, Dict]) -> str:
    """Per-cell 'what moves the dominant term down' (deliverable g)."""
    seen, lines = set(), []
    for key in sorted(cells):
        c = cells[key]
        if c.get("status") != "ok":
            continue
        s = c.get("suggestion") or suggest(c["arch"], c["bottleneck"],
                                           c.get("model_flops_basis", "6ND"))
        tag = (c["arch"], c["shape"], c["bottleneck"])
        if tag in seen:
            continue
        seen.add(tag)
        lines.append(f"{c['arch']:28s} {c['shape']:12s} "
                     f"[{c['bottleneck']:>10s}] {s}")
    return "\n".join(lines)
