"""Loop-aware HLO analysis: flops / bytes / collective wire bytes.

Why not `compiled.cost_analysis()` alone: XLA's cost analysis counts each
`while` body ONCE, not x trip-count (verified experimentally — a 10-step
scan of a matmul reports the flops of one matmul).  Our stacks scan over
layer groups, so everything interesting lives inside whiles.  This module
walks the computation call graph from ENTRY, multiplying by loop trip
counts (parsed from each while's condition), and accumulates:

  * flops      — 2 * prod(result dims) * prod(contracting dims) per dot
                 (operand shapes resolved through the computation's SSA
                 table — optimized HLO does not print them inline);
  * bytes      — operand + result bytes at fusion/call boundaries (ops
                 inside a fusion body touch registers/VMEM, not HBM);
  * wire bytes — collective results weighted by ring-algorithm cost:
                 all-reduce 2x, all-gather / reduce-scatter / all-to-all /
                 collective-permute 1x.  Shapes in the partitioned module
                 are PER-DEVICE, so totals are per-device.

Structural estimates (no fabric model), but consistent across cells and
optimizations — which is what the roofline iteration needs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->")
_OPLINE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\(")
_NAME_RE = re.compile(r"%[\w\.\-]+")
_CALLED = re.compile(
    r"(?:calls=|body=|condition=|to_apply=|true_computation=|"
    r"false_computation=|branch_computations=\{)\s*([%\w\.\-, ]+)\}?")
_CONST_S32 = re.compile(r"constant\((\d+)\)")
_COMPARE = re.compile(
    r"compare\(([^)]*)\),?.*direction=(LT|LE|GT|GE)")

_NO_DATA = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "partition-id", "replica-id", "iota"}


def _nelem(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    result_bytes: int
    result_elems: int
    dims: List[List[int]]        # dims of each shape in the result
    operands: List[str]
    called: List[str]
    line: str
    const_val: Optional[int] = None


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]
    table: Dict[str, OpInfo]


def _parse_result(result_part: str) -> Tuple[int, int, List[List[int]]]:
    nbytes, nelems, dims = 0, 0, []
    for dt, dd in _SHAPE_RE.findall(result_part):
        e = _nelem(dd)
        nbytes += e * _DTYPE_BYTES.get(dt, 4)
        nelems += e
        dims.append([int(x) for x in dd.split(",")] if dd else [])
    return nbytes, nelems, dims


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and ("{" in line):
            cur = Computation(name=hdr.group(1), ops=[], table={})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OPLINE.match(line)
        if not om:
            continue
        name, result_part, kind = om.groups()
        nbytes, nelems, dims = _parse_result(result_part)
        # operand names: everything inside the first paren group
        after = line[om.end():]
        depth, i = 1, 0
        while i < len(after) and depth:
            if after[i] == "(":
                depth += 1
            elif after[i] == ")":
                depth -= 1
            i += 1
        args = after[:i - 1] if depth == 0 else after
        operands = _NAME_RE.findall(args)
        called = []
        for cg in _CALLED.finditer(line):
            for c in cg.group(1).split(","):
                c = c.strip()
                if c.startswith("%"):
                    called.append(c)
        operands = [o for o in operands if o not in called]
        const_val = None
        if kind == "constant":
            cv = _CONST_S32.search(line)
            if cv:
                const_val = int(cv.group(1))
        op = OpInfo(name=name, kind=kind, result_bytes=nbytes,
                    result_elems=nelems, dims=dims, operands=operands,
                    called=called, line=line, const_val=const_val)
        cur.ops.append(op)
        cur.table[name] = op
    return comps, entry


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    k = 1
    if cd and op.operands:
        lhs = comp.table.get(op.operands[0])
        if lhs and lhs.dims:
            ld = lhs.dims[0]
            for i in (cd.group(1).split(",") if cd.group(1) else []):
                idx = int(i)
                if idx < len(ld):
                    k *= ld[idx]
    return 2.0 * op.result_elems * k


def _conv_flops(op: OpInfo, comp: Computation) -> float:
    k = 1
    if len(op.operands) >= 2:
        ker = comp.table.get(op.operands[1])
        if ker and ker.dims:
            k = _nelem(",".join(map(str, ker.dims[0])))
    return 2.0 * op.result_elems * k


def _operand_bytes(op: OpInfo, comp: Computation) -> int:
    return sum(comp.table[o].result_bytes for o in op.operands
               if o in comp.table)


def _op_traffic(op: OpInfo, comp: Computation) -> float:
    """HBM traffic estimate for one op.

    Slicing ops read/write only the slice, not the whole operand buffer
    (charging full operands made scan-over-stacked-params look like it
    re-reads all layers' weights every layer).  Loop fusions are capped the
    same way: each operand contributes at most the fusion's result size,
    except kInput (reduction) fusions which legitimately read operands
    larger than their result.
    """
    k = op.kind
    if k == "dynamic-slice" or k == "gather" or k == "copy" or k == "slice":
        return 2.0 * op.result_bytes
    if k == "dynamic-update-slice":
        upd = (comp.table[op.operands[1]].result_bytes
               if len(op.operands) > 1 and op.operands[1] in comp.table
               else op.result_bytes)
        return 2.0 * upd
    if k == "scatter":
        upd = (comp.table[op.operands[2]].result_bytes
               if len(op.operands) > 2 and op.operands[2] in comp.table
               else op.result_bytes)
        return 2.0 * upd
    if k == "fusion":
        cap = "kind=kInput" not in op.line
        total = op.result_bytes
        for o in op.operands:
            ob = comp.table[o].result_bytes if o in comp.table else 0
            total += min(ob, op.result_bytes) if cap else ob
        return float(total)
    return float(op.result_bytes + _operand_bytes(op, comp))


def _trip_count(cond: Computation) -> Optional[int]:
    for op in cond.ops:
        m = _COMPARE.search(op.line)
        if m:
            names = _NAME_RE.findall(m.group(1))
            d = m.group(2)
            for n in names:
                src = cond.table.get(n)
                if src is not None and src.const_val is not None:
                    return src.const_val + (1 if d in ("LE", "GE") else 0)
            # inline constant in the compare args
            cv = _CONST_S32.search(m.group(1))
            if cv:
                return int(cv.group(1)) + (1 if d in ("LE", "GE") else 0)
    consts = [o.const_val for o in cond.ops if o.const_val is not None]
    return max(consts) if consts else None


def analyze(text: str) -> Dict[str, float]:
    comps, entry = parse_module(text)
    if entry is None:
        return dict(flops=0.0, bytes=0.0, total=0.0, parse_error=1.0)

    totals = dict(flops=0.0, bytes=0.0, unknown_trip=0.0)
    coll = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0.0 for k in _COLLECTIVES}

    def walk(comp_name: str, mult: float, in_fusion: bool, stack):
        if comp_name not in comps or comp_name in stack:
            return
        comp = comps[comp_name]
        stack = stack | {comp_name}
        for op in comp.ops:
            if op.kind == "while":
                bm = re.search(r"body=([%\w\.\-]+)", op.line)
                cm = re.search(r"condition=([%\w\.\-]+)", op.line)
                trip = None
                if cm and cm.group(1) in comps:
                    trip = _trip_count(comps[cm.group(1)])
                if trip is None:
                    trip = 1
                    totals["unknown_trip"] += 1
                if bm:
                    walk(bm.group(1), mult * trip, in_fusion, stack)
                continue
            base = op.kind.replace("-start", "")
            if base in _COLLECTIVES and not op.kind.endswith("-done"):
                nbytes = op.result_bytes
                # bf16-legalization correction: the XLA CPU backend upcasts
                # every bf16 dot to f32, so weight/activation gathers feeding
                # dots appear as f32 collectives (verified: 0 bf16 dots in
                # the llama3-405b module).  A collective whose operand is a
                # convert-from-bf16 fusion is bf16 on the TPU target —
                # count it at half width.
                if "f32[" in op.line:
                    src = comp.table.get(op.operands[0]) if op.operands else None
                    if src is not None and ("convert" in src.name
                                            or "convert" in src.kind):
                        nbytes //= 2
                coll[base] += mult * nbytes * _WIRE_FACTOR[base]
                counts[base] += mult
                for c in op.called:          # all-reduce reducer (tiny)
                    walk(c, mult, True, stack)
                continue
            if op.kind == "fusion":
                if not in_fusion:
                    totals["bytes"] += mult * _op_traffic(op, comp)
                for c in op.called:
                    walk(c, mult, True, stack)
                continue
            if op.called:
                for c in op.called:
                    walk(c, mult, True, stack)
            if op.kind == "dot":
                totals["flops"] += mult * _dot_flops(op, comp)
            elif op.kind == "convolution":
                totals["flops"] += mult * _conv_flops(op, comp)
            if not in_fusion and op.kind not in _NO_DATA:
                totals["bytes"] += mult * _op_traffic(op, comp)
        return

    walk(entry, 1.0, False, frozenset())
    out = dict(flops=totals["flops"], bytes=totals["bytes"],
               unknown_trip=totals["unknown_trip"])
    out.update({f"bytes_{k}": v for k, v in coll.items()})
    out.update({f"count_{k}": v for k, v in counts.items()})
    out["total"] = sum(coll.values())
    return out


def collective_bytes(text: str) -> Dict[str, float]:
    """Back-compat wrapper: collective wire bytes (loop-aware)."""
    a = analyze(text)
    return {k: v for k, v in a.items()
            if k.startswith(("bytes_", "count_", "total"))}


def op_histogram(hlo_text: str, top: int = 15) -> Dict[str, int]:
    ops = re.findall(r"=\s*[a-z0-9]+\[[^\]]*\][^ ]*\s+([a-z\-]+)\(",
                     hlo_text)
    hist: Dict[str, int] = {}
    for o in ops:
        hist[o] = hist.get(o, 0) + 1
    return dict(sorted(hist.items(), key=lambda kv: -kv[1])[:top])
