"""Step-time monitoring: straggler detection + throughput accounting.

At 1000+ node scale, slow hosts (failing NICs, thermal throttling,
preemption warnings) surface as step-time outliers long before they surface
as errors.  The monitor keeps an EMA of step time; a step slower than
`threshold` x EMA raises a straggler event, which the launcher logs and —
on real deployments — feeds the scheduler (drain + replace the host; with
our elastic checkpoints a replacement joins at the next restore).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ema: float
    ratio: float


class StepMonitor:
    def __init__(self, ema_alpha: float = 0.2, threshold: float = 2.0,
                 warmup_steps: int = 3,
                 on_straggler: Optional[Callable] = None):
        self.ema_alpha = ema_alpha
        self.threshold = threshold
        self.warmup_steps = warmup_steps
        self.on_straggler = on_straggler
        self.ema: Optional[float] = None
        self.events: List[StragglerEvent] = []
        self.history: List[float] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self._step += 1
        self.history.append(dt)
        if self._step <= self.warmup_steps:
            return dt                         # ignore compile steps
        if self.ema is None:
            self.ema = dt
            return dt
        if dt > self.threshold * self.ema:
            ev = StragglerEvent(step=self._step, step_time=dt, ema=self.ema,
                                ratio=dt / self.ema)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
        self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * dt
        return dt

    def tokens_per_sec(self, tokens_per_step: int) -> float:
        if self.ema is None:
            return 0.0
        return tokens_per_step / self.ema
