"""Step factories: sharded train / prefill / decode steps for any arch.

Each factory returns (jitted_fn, in_shardings_info) with NamedSharding
in/out specs derived from distributed/sharding.py rules — the same
functions the dry-run lowers with ShapeDtypeStructs and the launcher runs
with real arrays.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.distributed import sharding as shlib
from repro.models import model as M
from repro.train import optimizer as opt


def make_batch_abstract(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = dict(tokens=sds((B, S), jnp.int32),
                     labels=sds((B, S), jnp.int32))
    elif shape.kind == "prefill":
        batch = dict(tokens=sds((B, S), jnp.int32))
    else:  # decode: one new token against a seq_len cache
        batch = dict(tokens=sds((B, 1), jnp.int32))
    if cfg.n_ctx_tokens:
        batch["ctx"] = sds((B, cfg.n_ctx_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def make_train_step(cfg: ArchConfig, mesh, adamw: opt.AdamWConfig,
                    donate: bool = True, microbatches: int = 1):
    """Returns (step_fn, shardings dict).  step(params, opt_state, batch)
    -> (params, opt_state, metrics).

    microbatches > 1 enables gradient accumulation: the batch is split into
    M sequential microbatches and grads are averaged in a scan — the saved
    residual stack (the dominant training activation memory: 15.75
    GiB/device for llama3-405b train_4k) shrinks by M at the cost of M
    smaller collectives (§Perf iteration 3)."""
    params_abs = M.abstract_params(cfg)
    p_sh = shlib.param_shardings(params_abs, mesh)
    o_sh = opt.AdamWState(
        step=NamedSharding(mesh, P()),
        m=p_sh, v=p_sh)

    grad_fn = jax.value_and_grad(
        lambda p, b: M.loss_fn(p, b, cfg, mesh=mesh), has_aux=True)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            grads = jax.tree_util.tree_map(
                lambda g: (g / microbatches), grads)
            loss = loss_sum / microbatches
            parts = dict(nll=loss, aux=jnp.zeros((), jnp.float32))
        params, opt_state, om = opt.update(adamw, params, grads, opt_state)
        metrics = dict(loss=loss, **parts, **om)
        return params, opt_state, metrics

    def jit_for(batch_abstract):
        b_sh = shlib.batch_specs(cfg, mesh, batch_abstract)
        return jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1) if donate else (),
        )
    return step, jit_for, dict(params=p_sh, opt=o_sh)


def make_prefill_step(cfg: ArchConfig, mesh, max_len: int, batch: int,
                      kv_dtype=jnp.bfloat16):
    params_abs = M.abstract_params(cfg)
    p_sh = shlib.param_shardings(params_abs, mesh)
    cache_abs = M.abstract_cache(cfg, batch, max_len, kv_dtype)
    c_sh = shlib.cache_shardings(cache_abs, mesh)

    def step(params, tokens, cache, ctx=None):
        logits, new_cache = M.prefill(params, tokens, cfg, cache=cache,
                                      ctx=ctx, mesh=mesh)
        return logits, new_cache

    def jit_for(batch_abstract):
        b_sh = shlib.batch_specs(cfg, mesh, batch_abstract)
        ctx_sh = b_sh.get("ctx")
        args = (p_sh, b_sh["tokens"], c_sh) + ((ctx_sh,) if ctx_sh else ())
        return jax.jit(step, in_shardings=args,
                       out_shardings=(None, c_sh), donate_argnums=(2,))
    return step, jit_for, dict(params=p_sh, cache=c_sh)


def make_decode_step(cfg: ArchConfig, mesh, max_len: int, batch: int,
                     kv_dtype=jnp.bfloat16):
    params_abs = M.abstract_params(cfg)
    p_sh = shlib.param_shardings(params_abs, mesh)
    cache_abs = M.abstract_cache(cfg, batch, max_len, kv_dtype)
    c_sh = shlib.cache_shardings(cache_abs, mesh)

    def step(params, tokens, cache, cache_index, ctx=None):
        logits, new_cache = M.decode_step(params, tokens, cfg, cache=cache,
                                          cache_index=cache_index, ctx=ctx,
                                          mesh=mesh)
        return logits, new_cache

    def jit_for(batch_abstract):
        b_sh = shlib.batch_specs(cfg, mesh, batch_abstract)
        ctx_sh = b_sh.get("ctx")
        args = (p_sh, b_sh["tokens"], c_sh, NamedSharding(mesh, P())) + \
            ((ctx_sh,) if ctx_sh else ())
        return jax.jit(step, in_shardings=args,
                       out_shardings=(None, c_sh), donate_argnums=(2,))
    return step, jit_for, dict(params=p_sh, cache=c_sh)
