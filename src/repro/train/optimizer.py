"""AdamW with decoupled weight decay + global-norm clipping.

Self-contained (no optax in this environment).  Optimizer state (m, v in
f32) is a pytree congruent with params, so it inherits the parameter
shardings — ZeRO-3-equivalent partitioning under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init_state(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def abstract_state(params_abstract) -> AdamWState:
    return jax.eval_shape(init_state, params_abstract)


def _schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(F32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), norm


def update(cfg: AdamWConfig, params, grads,
           state: AdamWState) -> Tuple[Any, AdamWState, Dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = _schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(F32)
    bc2 = 1 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g32 = g.astype(F32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        p_new = (p.astype(F32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    params_new = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    m_new = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    v_new = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = dict(grad_norm=gnorm, lr=lr)
    return params_new, AdamWState(step=step, m=m_new, v=v_new), metrics
