"""Fault-tolerant checkpointing: sharded save / latest-valid restore /
reshard-on-restore (elastic restarts).

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json      {step, leaves: [{path, shape, dtype, file,
                            sha256}], data_state, extra}
        arr_00000.npy ...  one .npy per leaf (host-gathered)
        COMMIT             written last; a checkpoint without COMMIT is
                           ignored (atomicity against mid-write failures)

Restore validates hashes, rebuilds the pytree, and `device_put`s with the
CURRENT mesh's shardings — so a job checkpointed on 512 chips restarts on
any other device count (elastic scaling).  At 1000+ node scale the same
manifest format extends to per-shard files; host-gather is the CPU-sim
compromise (documented).
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1]), name
    return arr, name


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name][0])
    return arr


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in kp) for kp, _ in flat]
    return paths, [l for _, l in flat], treedef


def save(ckpt_dir, step: int, tree, data_state: Optional[Dict] = None,
         extra: Optional[Dict] = None, keep: int = 3) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step:09d}_{int(time.time()*1e6)}"
    final = ckpt_dir / f"step_{step:09d}"
    tmp.mkdir(parents=True, exist_ok=True)

    paths, leaves, _ = _leaf_paths(tree)
    manifest = dict(step=step, leaves=[], data_state=data_state or {},
                    extra=extra or {})
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        store, dtype_name = _to_savable(arr)
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, store)
        digest = hashlib.sha256((tmp / fname).read_bytes()).hexdigest()
        manifest["leaves"].append(dict(path=p, shape=list(arr.shape),
                                       dtype=dtype_name, file=fname,
                                       sha256=digest))
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(d for d in ckpt_dir.glob("step_*") if d.is_dir())
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)
    for d in ckpt_dir.glob(".tmp_step_*"):
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    valid = [d for d in sorted(ckpt_dir.glob("step_*"))
             if (d / "COMMIT").exists()]
    if not valid:
        return None
    return int(valid[-1].name.split("_")[1])


def restore(ckpt_dir, tree_abstract, step: Optional[int] = None,
            shardings=None, validate: bool = True
            ) -> Tuple[Any, int, Dict, Dict]:
    """Restore into the CURRENT mesh: leaves are device_put with
    `shardings` (congruent pytree) if given — reshard-on-restore."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())

    paths, leaves_abs, treedef = _leaf_paths(tree_abstract)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    sh_flat = None
    if shardings is not None:
        _, sh_flat, _ = _leaf_paths(shardings)
    for i, (p, ab) in enumerate(zip(paths, leaves_abs)):
        e = by_path[p]
        f = d / e["file"]
        if validate:
            digest = hashlib.sha256(f.read_bytes()).hexdigest()
            if digest != e["sha256"]:
                raise IOError(f"checkpoint corruption in {f}")
        arr = _from_savable(np.load(f), e["dtype"])
        if tuple(arr.shape) != tuple(ab.shape):
            raise ValueError(f"{p}: shape {arr.shape} != expected {ab.shape}")
        if arr.dtype != ab.dtype:
            arr = arr.astype(ab.dtype)
        if sh_flat is not None:
            out.append(jax.device_put(arr, sh_flat[i]))
        else:
            out.append(jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, step, manifest.get("data_state", {}), manifest.get("extra", {})
