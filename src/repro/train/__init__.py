"""Training substrate: optimizer, steps, checkpointing, monitoring."""
