"""Analytic MARS hardware performance / energy / area model.

The paper evaluates MARS with MQSim (SSD timing), CACTI7 (DRAM/PIM timing +
energy) and Synopsys DC synthesis (sorter/merger timing + area), combining
component latencies with data-movement transfer times (Section 7).  This
module is the equivalent analytic model: it converts Workload counts
(workload.py, measured on the real JAX pipeline and scaled to paper-size
datasets) into per-stage latencies and energies for MARS and every baseline
system of Section 7.

Two calibration domains:
  * in-storage units — first-principles from Table 1 (+FULCRUM/pLUTo/DC
    numbers): 256 AUs @164 MHz, 512 QUs (4*tRC pLUTo query), 8 sorter/
    merger pairs @1 GHz, 8x1 GB/s flash channels;
  * host software (RH2 / MS-CPU / minimap2 side) — component rates fitted
    against the paper's own totals (Table 4 + Fig. 11 profile) and Fig. 5
    stage fractions; see benchmarks/common.calibrated_host().

This module is the ANALYTIC backend of the ``core/costmodel.py``
Workload->cost interface.  The closed forms here stay the calibration
oracle; the event-driven twin (``core/sim/``) plays the same Workload
through an explicit machine (channels x dies, PNM units, internal DRAM)
and must agree with these formulas to <1% on degenerate no-contention
configs (tests/test_sim.py, scripts/bench_sim.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence

from repro.core.workload import Workload


# --------------------------------------------------------------------------- #
# Hardware constants (paper Table 1 + cited parts)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SSDConfig:
    channels: int = 8
    chips_per_channel: int = 8
    channel_bw: float = 1.0e9          # B/s per flash channel (Table 1)
    t_dma: float = 16e-6               # s
    t_read: float = 22.5e-6            # s (TLC page read)
    page_bytes: int = 16 * 1024
    pcie_bw: float = 7.0e9             # B/s external (PM1735)

    dram_bytes: int = 4 << 30          # 4 GB LPDDR4
    dram_subarrays: int = 512
    dram_row_bytes: int = 2048
    dram_trc: float = 60e-9            # row cycle
    dram_bw: float = 8.5e9             # B/s streaming

    n_arith_units: int = 256           # Section 6.1.1
    arith_freq: float = 164e6
    n_query_units: int = 512
    n_sorters: int = 8
    sorter_freq: float = 1.0e9
    sorter_width: int = 128


@dataclasses.dataclass(frozen=True)
class HostConfig:
    cpu_threads: int = 128             # 2x EPYC 7742
    cpu_watts: float = 450.0
    dram_watts: float = 40.0
    gpu_watts: float = 300.0
    gpu_basecall_samples_per_sec: float = 2.5e6   # Dorado hac on A6000-class
    minimap_ops_per_base: float = 1.2e3
    samples_per_base: float = 9.0


@dataclasses.dataclass(frozen=True)
class HostRates:
    """Inverse rates (seconds per unit) for the host software pipeline.
    Units: io -> bytes ingested, event -> raw samples, seed -> seed
    lookups, chain -> anchors entering chaining.  Fitted by
    benchmarks/common.calibrated_host()."""
    inv_io: float = 1.0 / 150e6        # ~150 MB/s fast5 ingest default
    inv_event: float = 1.0 / 500e6     # samples/s aggregate
    inv_seed: float = 1.0 / 50e6       # probes/s aggregate
    inv_chain: float = 1.0 / 20e6      # anchors/s aggregate


# Per-primitive op counts of OUR pipeline (word-serial AU ops per item;
# from the events/quantization/hashing/vote/chaining op inventories).
OPS = dict(
    ed_per_sample=14, quant_per_event=12, hash_per_seed=13,
    freq_per_hit=2, vote_per_anchor=6, dp_per_pair=10,
)

# Energy constants (J) — 65nm logic + LPDDR4 DRAM, CACTI7-class.
# qu_lookup is dominated by the pLUTo row activations of the sweep
# (amortized ~2 nJ/lookup); au_op includes instruction-buffer control.
ENERGY = dict(
    au_op=5.0e-12, qu_lookup=2.0e-9, sort_elem=10e-12, dram_byte=50e-12,
    flash_byte=150e-12, pcie_byte=120e-12, host_io_byte=900e-12,
)
# In-storage static power: SSD controller + DRAM refresh while mapping.
# (Component-level accounting like the paper's CACTI+DC methodology; host
# idle power is EXCLUDED for in-storage systems — see EXPERIMENTS.md
# Energy-calibration notes for the reconciliation discussion.)
SSD_ACTIVE_W = 8.0

# Area (mm^2) — paper Table 5 (as published; we do not re-synthesize).
AREA = dict(arith_unit=0.0295, n_arith=256, query_unit=0.018, n_query=512,
            sorter=0.78, n_sorter=8, merger=0.14, n_merger=8,
            control=0.002, n_control=1)


def area_table() -> Dict[str, Dict[str, float]]:
    return {
        "Arithmetic": dict(instances=AREA["n_arith"],
                           per_unit=AREA["arith_unit"],
                           total=AREA["n_arith"] * AREA["arith_unit"]),
        "Querying": dict(instances=AREA["n_query"],
                         per_unit=AREA["query_unit"],
                         total=AREA["n_query"] * AREA["query_unit"]),
        "Sorter": dict(instances=AREA["n_sorter"], per_unit=AREA["sorter"],
                       total=AREA["n_sorter"] * AREA["sorter"]),
        "Merger": dict(instances=AREA["n_merger"], per_unit=AREA["merger"],
                       total=AREA["n_merger"] * AREA["merger"]),
        "Control": dict(instances=AREA["n_control"],
                        per_unit=AREA["control"],
                        total=AREA["n_control"] * AREA["control"]),
    }


# --------------------------------------------------------------------------- #
# Host (CPU software) model
# --------------------------------------------------------------------------- #
def host_components(w: Workload) -> Dict[str, float]:
    """Natural units per stage for the host pipeline.  Chaining scales with
    the anchors that actually enter the DP (post-vote when the vote filter
    runs — that is where MS-CPU's speedup over RH2 comes from, Section 8.2)."""
    return dict(io=float(w.bytes_raw + w.bytes_index),
                event=float(w.n_samples),
                seed=float(w.n_lookups),
                chain=float(w.n_anchors_postvote) + 0.3 * float(w.n_votes))


def host_latency(w: Workload, rates: HostRates,
                 arith_scale: float = 1.0) -> Dict[str, float]:
    c = host_components(w)
    t = dict(io=c["io"] * rates.inv_io,
             event=c["event"] * rates.inv_event * arith_scale,
             seed=c["seed"] * rates.inv_seed,
             chain=c["chain"] * rates.inv_chain * arith_scale)
    t["total"] = sum(t.values())
    return t


# --------------------------------------------------------------------------- #
# MARS in-storage model (Table 1 first-principles)
# --------------------------------------------------------------------------- #
def _flash_read_time(nbytes: float, ssd: SSDConfig) -> float:
    per_channel = nbytes / ssd.channels
    return per_channel / ssd.channel_bw + ssd.t_read + ssd.t_dma


def mars_stage_times(w: Workload, ssd: SSDConfig) -> Dict[str, float]:
    au_rate = ssd.n_arith_units * ssd.arith_freq
    arith_scale = 1.0 if w.fixed_point else 2.4    # float emulation penalty
    t_ed = (w.n_samples * OPS["ed_per_sample"] +
            w.n_events * OPS["quant_per_event"]) * arith_scale / au_rate
    t_hash = w.n_seeds * OPS["hash_per_seed"] * arith_scale / au_rate
    qu_rate = ssd.n_query_units / (4 * ssd.dram_trc)
    t_query = w.n_lookups / qu_rate
    t_filters = (w.n_hits_raw * OPS["freq_per_hit"] +
                 w.n_votes * OPS["vote_per_anchor"]) * arith_scale / au_rate
    sort_rate = ssd.n_sorters * ssd.sorter_freq
    t_sort = w.n_sorted / sort_rate
    t_dp = w.n_dp_pairs * OPS["dp_per_pair"] * arith_scale / au_rate
    t_flash = _flash_read_time(w.bytes_raw + w.bytes_index, ssd)
    t_dram = w.bytes_intermediate / ssd.dram_bw
    return dict(flash=t_flash, event_detection=t_ed, seeding=t_hash + t_query,
                seeding_hash=t_hash, seeding_query=t_query,
                filters=t_filters, sorting=t_sort, chaining_dp=t_dp,
                dram_move=t_dram)


def mars_latency(w: Workload, ssd: SSDConfig = SSDConfig()) -> Dict[str, float]:
    st = mars_stage_times(w, ssd)
    compute = (st["event_detection"] + st["seeding"] + st["filters"] +
               st["sorting"] + st["chaining_dp"] + st["dram_move"])
    # Section 6.3: flash/index loading overlapped with computation.
    total = max(st["flash"], compute) + 0.02 * min(st["flash"], compute)
    return dict(total=total, compute=compute, **st)


def mars_energy(w: Workload, ssd: SSDConfig = SSDConfig()) -> float:
    arith_scale = 1.0 if w.fixed_point else 2.4
    au_ops = (w.n_samples * OPS["ed_per_sample"] +
              w.n_events * OPS["quant_per_event"] +
              w.n_seeds * OPS["hash_per_seed"] +
              w.n_hits_raw * OPS["freq_per_hit"] +
              w.n_votes * OPS["vote_per_anchor"] +
              w.n_dp_pairs * OPS["dp_per_pair"]) * arith_scale
    # static power over the run: SSD controller + DRAM refresh
    lat = mars_latency(w, ssd)
    static = SSD_ACTIVE_W * lat["total"]
    return (au_ops * ENERGY["au_op"]
            + w.n_lookups * ENERGY["qu_lookup"]
            + w.n_sorted * ENERGY["sort_elem"] * 7
            + w.bytes_intermediate * ENERGY["dram_byte"]
            + (w.bytes_raw + w.bytes_index) * ENERGY["flash_byte"]
            + static)


# --------------------------------------------------------------------------- #
# Evaluated systems (paper Section 7)
# --------------------------------------------------------------------------- #
SYSTEMS = ("BC", "RH2", "MS-CPU_Float", "MS-CPU_Fixed", "MS-EXT",
           "MS-SIMDRAM", "GenPIP", "MS-SmartSSD", "MARS")


def system_latency_energy(system: str, w: Workload,
                          rates: HostRates = HostRates(),
                          ssd: SSDConfig = SSDConfig(),
                          host: HostConfig = HostConfig()) -> Dict[str, float]:
    """Latency (s) + energy (J).  Pass the workload measured in the MATCHING
    pipeline mode (rh2 workload for RH2/BC, ms_float for MS-CPU_Float,
    ms_fixed for the rest)."""
    io_bytes = w.bytes_raw + w.bytes_index

    if system in ("RH2", "MS-CPU_Float", "MS-CPU_Fixed"):
        scale = {"RH2": 1.0, "MS-CPU_Float": 1.0,
                 "MS-CPU_Fixed": 0.8}[system]     # int16 SIMD density
        t = host_latency(w, rates, arith_scale=scale)
        busy = t["total"] - t["io"]
        e = (busy * (host.cpu_watts + host.dram_watts)
             + t["io"] * (0.4 * host.cpu_watts + host.dram_watts)
             + io_bytes * ENERGY["host_io_byte"])
        return dict(total=t["total"], compute=busy, io=t["io"], energy=e,
                    stages=t)

    if system == "MARS":
        lat = mars_latency(w, ssd)
        e = mars_energy(w, ssd)
        return dict(total=lat["total"], compute=lat["compute"],
                    io=lat["flash"], energy=e,
                    energy_dynamic=e - SSD_ACTIVE_W * lat["total"],
                    stages=lat)

    if system == "MS-EXT":
        # identical units placed OUTSIDE the SSD: raw data crosses PCIe and
        # bounces through host DRAM to the PIM DIMMs; the host CPU
        # orchestrates every partition pass (no in-storage FSM), and the
        # flash<->compute overlap of Section 6.3 is lost.
        lat = mars_latency(w, ssd)
        t_io = io_bytes / ssd.pcie_bw + 2 * io_bytes / 25.6e9
        t_orc = 0.6 * lat["compute"]              # host-driven scheduling
        total = t_io + 1.3 * lat["compute"] + t_orc   # no overlap, sync gaps
        e = (mars_energy(w, ssd)
             + io_bytes * (ENERGY["pcie_byte"] + 2 * ENERGY["dram_byte"])
             + (t_io + t_orc) * 0.5 * host.cpu_watts)
        return dict(total=total, compute=lat["compute"], io=t_io, energy=e)

    if system == "MS-SIMDRAM":
        lat = mars_latency(w, ssd)
        bitserial = 21.4                          # Section 8.2
        t_arith = (lat["event_detection"] + lat["filters"] +
                   lat["chaining_dp"]) * bitserial
        compute = t_arith + lat["seeding"] + lat["sorting"] + lat["dram_move"]
        total = max(lat["flash"], compute)
        # dynamic energy 3.5x lower (bit-serial rows, no ALU logic).
        # NOTE accounting: the paper's Fig. 12 "SIMDRAM beats MARS on
        # energy" holds for DYNAMIC component energy (CACTI-style); with
        # physical static power over the 21.4x longer run it inverts —
        # both are reported (EXPERIMENTS.md Energy notes).
        dyn = (mars_energy(w, ssd) - SSD_ACTIVE_W *
               mars_latency(w, ssd)["total"]) / 3.5
        e = dyn + 2.0 * total
        return dict(total=total, compute=compute, io=lat["flash"], energy=e,
                    energy_dynamic=dyn)

    if system == "MS-SmartSSD":
        lat = mars_latency(w, ssd)
        link_bw = 3.0e9
        t_link = (w.n_sorted * 4 * 2) / link_bw
        t_sort_fpga = lat["sorting"] * (ssd.sorter_freq / 300e6)
        compute = (lat["compute"] - lat["sorting"]) + t_sort_fpga + t_link
        total = max(lat["flash"], compute)
        e = (mars_energy(w, ssd) + (w.n_sorted * 8) * ENERGY["pcie_byte"]
             + t_sort_fpga * 25.0)
        return dict(total=total, compute=compute, io=lat["flash"], energy=e)

    if system == "BC":
        n_bases = w.n_samples / host.samples_per_base
        t_bc = w.n_samples / host.gpu_basecall_samples_per_sec
        t_mm = n_bases * host.minimap_ops_per_base / (
            host.cpu_threads * 2.0e9)
        t_io = io_bytes * rates.inv_io
        total = max(t_bc, t_mm) + t_io
        e = (t_bc * host.gpu_watts
             + t_mm * host.cpu_watts + t_io * 0.4 * host.cpu_watts
             + io_bytes * ENERGY["host_io_byte"])
        return dict(total=total, compute=max(t_bc, t_mm), io=t_io, energy=e)

    if system == "GenPIP":
        # NVM-PIM basecalling+mapping (MICRO'22): the CRF basecaller runs
        # in analog PIM (~8x the GPU's effective rate at ~1/25 the energy),
        # mapping in PIM (~5x CPU); host-side raw streaming remains.
        n_bases = w.n_samples / host.samples_per_base
        t_bc = w.n_samples / (host.gpu_basecall_samples_per_sec * 6.0)
        t_mm = n_bases * host.minimap_ops_per_base / (host.cpu_threads * 2.0e9) / 5.0
        t_io = io_bytes * rates.inv_io            # fast5 ingest like BC
        total = t_bc + t_mm + t_io
        e = ((w.n_samples / host.gpu_basecall_samples_per_sec)
             * host.gpu_watts / 25.0
             + io_bytes * (ENERGY["host_io_byte"] / 2)
             + t_io * 0.2 * host.cpu_watts)
        return dict(total=total, compute=t_bc + t_mm, io=t_io, energy=e)

    raise ValueError(f"unknown system {system!r}")


# --------------------------------------------------------------------------- #
# Multi-SSD array model + serving-latency queueing term
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SSDArrayConfig:
    """An array of N identical MARS SSDs behind one host.

    The reference index is bucket-range-partitioned across the drives with
    the SAME invariants as ``core/index.partition_index``: ``n_ssds`` must
    be a power of two, every drive owns an equal contiguous bucket range
    (1/N of the index bytes), and every seed's bucket lives on exactly ONE
    drive — so reads stripe evenly, each drive runs the full pipeline on
    its share with its own flash-load/compute overlap (Section 6.3), and
    per-drive results merge exactly (the host sums counter partials and
    concatenates per-read outputs, the analytic analogue of the
    ``query:ring`` / ``query:a2a`` hit-combining).

    ``result_bytes_per_read`` is the per-read record crossing PCIe to the
    host (t_start + score + flags); ``t_dispatch`` is the host-side
    orchestration cost per drive per batch (NVMe submission + completion
    handling).

    ``n_failed`` models the degraded array after a single-drive loss
    rebalanced by ``core/index.repartition_index``: the power-of-two
    partitioning folds to N/2 halves (each surviving pair's bucket ranges
    merge), so exactly ``n_serving = n_ssds // 2`` drives serve the whole
    index — every serving drive's share doubles, which is what the
    latency / energy / queueing models charge.
    """
    n_ssds: int = 4
    ssd: SSDConfig = SSDConfig()
    result_bytes_per_read: int = 16
    t_dispatch: float = 20e-6          # s per drive per batch
    n_failed: int = 0                  # 0 healthy, 1 degraded (N -> N/2)

    def __post_init__(self):
        if self.n_ssds < 1 or (self.n_ssds & (self.n_ssds - 1)):
            raise ValueError(f"n_ssds must be a power of two (bucket-range "
                             f"index partitioning); got {self.n_ssds}")
        if self.n_failed not in (0, 1):
            raise ValueError(f"n_failed must be 0 or 1 (repartition_index "
                             f"handles single-drive loss); "
                             f"got {self.n_failed}")
        if self.n_failed and self.n_ssds < 2:
            raise ValueError("a degraded array needs n_ssds >= 2: there is "
                             "no survivor to fold a failed drive onto")

    @property
    def n_serving(self) -> int:
        """Drives actually serving the index: all of them, or the N/2
        halving ``repartition_index`` folds a single-drive loss into."""
        return self.n_ssds if self.n_failed == 0 else self.n_ssds // 2


def mars_array_latency(w: Workload,
                       arr: SSDArrayConfig = SSDArrayConfig()) -> Dict[str, float]:
    """Batch latency of a Workload spread over the array.

    Each drive maps 1/N of the reads against its resident 1/N index
    partition (``Workload.scale`` divides both the read-proportional
    counts and ``bytes_index`` — exactly the bucket-range split), with
    per-SSD flash/compute overlap.  Drives are symmetric, so the array
    compute time is one drive's time; the host adds the result-merge
    transfer over PCIe and the per-drive dispatch overhead.  A degraded
    array (``n_failed``) serves with ``n_serving`` drives, each carrying
    the doubled post-rebalance share.
    """
    per = w.scale(1.0 / arr.n_serving)
    lat = mars_latency(per, arr.ssd)
    t_merge = (w.n_reads * arr.result_bytes_per_read) / arr.ssd.pcie_bw
    t_orch = arr.n_serving * arr.t_dispatch
    total = lat["total"] + t_merge + t_orch
    return dict(total=total, per_ssd=lat["total"], merge=t_merge,
                orchestration=t_orch, compute=lat["compute"],
                flash=lat["flash"])


def mars_array_energy(w: Workload,
                      arr: SSDArrayConfig = SSDArrayConfig()) -> float:
    """Array energy: N drives each running its 1/N share, plus the result
    merge over PCIe.  Dynamic energy is workload-proportional, so the
    per-drive dynamic energies sum back to (almost) the single-drive
    total; static power burns on every drive for the (shorter) array
    runtime — the energy cost of the latency win.  A degraded array
    burns static power only on the ``n_serving`` survivors."""
    per = w.scale(1.0 / arr.n_serving)
    per_dyn = mars_energy(per, arr.ssd) - SSD_ACTIVE_W * mars_latency(
        per, arr.ssd)["total"]
    static = arr.n_serving * SSD_ACTIVE_W * mars_array_latency(w, arr)["total"]
    merge = w.n_reads * arr.result_bytes_per_read * ENERGY["pcie_byte"]
    return arr.n_serving * per_dyn + static + merge


def _erlang_c(c: int, a: float) -> float:
    """Erlang-C waiting probability for an M/M/c queue with offered load
    ``a`` = lambda/mu erlangs (requires a < c).  Computed with the stable
    iterative Erlang-B recursion b = a*b/(k+a*b)."""
    b = 1.0
    for k in range(1, c + 1):
        b = a * b / (k + a * b)
    rho = a / c
    return b / (1.0 - rho + rho * b)


def queueing_percentiles(service: float, c: int, offered_load: float,
                         percentiles: Sequence[float] = (50.0, 99.0)
                         ) -> Dict[str, float]:
    """The shared M/D/c sojourn-percentile core (Poisson arrivals, ``c``
    servers of deterministic ``service`` each, ``offered_load`` requests
    per unit time).

    Mean wait uses the classic M/D/c ~= M/M/c / 2 correction on the
    Erlang-C formula; the waiting-tail is approximated exponential,
    P(W > t) = C(c,a) * exp(-2 (c*mu - lambda) t), which is exact for
    M/M/c up to the factor-2 deterministic-service correction.
    Percentile q of sojourn = service + max(0, ln(C/(1-q)) / (2(c*mu-l))).

    Beyond saturation (rho >= 1) the queue has no steady state: the
    percentiles are inf and ``saturated`` is set — the graceful-overload
    regime the serving driver's admission control (core/server.py) is
    built for.

    Both serving models are thin wrappers: ``serving_latency`` feeds the
    per-drive amortized batch service of the SSD array
    (c = drives); ``serving_latency_virtual`` feeds the serving driver's
    virtual-clock chunk service (c = chunk rows — a batch server of B
    requests per ``chunk_cost`` behaves like B parallel unit-cost
    servers at the same total capacity).
    """
    if not service > 0:
        raise ValueError(f"service time must be > 0; got {service}")
    c = int(c)
    if c < 1:
        raise ValueError(f"n_servers must be >= 1; got {c}")
    if offered_load < 0:
        raise ValueError(f"offered_load must be >= 0 (requests per unit "
                         f"time); got {offered_load}")
    if offered_load == 0:
        raise ValueError("offered_load must be > 0: an idle system has no "
                         "sojourn distribution (every percentile is just "
                         "the service time)")
    mu = 1.0 / service
    a = offered_load / mu
    rho = a / c
    out = dict(service=service, utilization=rho, n_servers=c,
               offered_load=offered_load, saturated=rho >= 1.0)
    if rho >= 1.0:
        out.update(mean=math.inf, wait_prob=1.0,
                   **{f"p{g:g}": math.inf for g in percentiles})
        return out
    pw = _erlang_c(c, a)
    decay = 2.0 * (c * mu - offered_load)       # M/D/c tail correction
    out.update(mean=service + pw / decay, wait_prob=pw)
    for q in percentiles:
        p = q / 100.0
        wait = 0.0 if (1.0 - p) >= pw else math.log(pw / (1.0 - p)) / decay
        out[f"p{q:g}"] = service + wait
    return out


def serving_latency(w: Workload, offered_load: float,
                    arr: SSDArrayConfig = SSDArrayConfig(),
                    percentiles: Sequence[float] = (50.0, 99.0)
                    ) -> Dict[str, float]:
    """Serving-latency percentiles for a stream of read requests at
    ``offered_load`` reads/second against the array — the queueing term
    that turns Workload *rates* into p50/p99 alongside the batch
    latencies.

    Each SERVING SSD is one server of the M/D/c queue
    (``queueing_percentiles``) — a degraded array has fewer, slower-share
    servers; service time is the per-read amortized batch latency of ONE
    drive serving its index partition, incl. the host merge/dispatch
    share.
    """
    # per-read deterministic service time on one drive (its post-rebalance
    # share, amortized over its reads)
    batch = mars_array_latency(w, arr)
    service = batch["total"] / max(w.n_reads, 1) * arr.n_serving
    out = queueing_percentiles(service, arr.n_serving, offered_load,
                               percentiles)
    out["n_ssds"] = out["n_servers"]
    return out


def serving_latency_virtual(chunk: int, offered_load: float,
                            chunk_cost: float = 1.0,
                            percentiles: Sequence[float] = (50.0, 99.0)
                            ) -> Dict[str, float]:
    """The virtual-clock twin of ``serving_latency``: modeled sojourn
    percentiles for ``core/server.ServeDriver`` at ``offered_load`` reads
    per virtual time unit.

    The serving driver is a *batch* server in virtual time — every
    dispatched chunk advances the clock by ``chunk_cost`` and completes up
    to ``chunk`` reads at once.  Two terms the plain M/D/c core misses
    (both calibrated against measured ``ServeDriver.serve_trace``
    latencies in ``benchmarks/calibrate_serving.py``):

      * the chunk a read rides always costs the FULL ``chunk_cost``
        regardless of occupancy (sojourn >= chunk_cost even when idle),
        which c = ``chunk`` parallel unit-cost servers reproduce; and
      * a read arriving while a chunk is in flight waits the *residual*
        of that dispatch before its own chunk starts.  The dispatcher is
        greedy (any queued read triggers a chunk), so its busy fraction B
        follows the gated-cycle renewal e^(l t)/(e^(l t) + 1/(l t))
        services per idle gap; the residual seen by a busy-period arrival
        is Uniform(0, chunk_cost), so percentile p of the boundary wait is
        chunk_cost * max(0, p - (1-B)) / B.

    Sojourn percentile = chunk_cost + boundary wait + M/D/c backlog wait
    (the Erlang term only bites once the backlog exceeds a whole chunk).
    tests/test_ssd_model.py asserts the modeled p50 tracks the measured
    trace percentile below saturation.
    """
    out = queueing_percentiles(chunk_cost, int(chunk), offered_load,
                               percentiles)
    out.update(chunk=int(chunk), chunk_cost=chunk_cost)
    if out["saturated"]:
        return out
    # dispatch-boundary residual: busy fraction of the greedy dispatcher
    lt = offered_load * chunk_cost
    e_busy = math.exp(lt)                     # services per busy period
    busy = (e_busy * chunk_cost) / (e_busy * chunk_cost + 1.0 /
                                    offered_load)
    out["dispatch_busy"] = busy
    out["mean"] += busy * chunk_cost / 2.0
    for q in percentiles:
        p = q / 100.0
        out[f"p{q:g}"] += chunk_cost * max(0.0, p - (1.0 - busy)) / busy
    return out


def dram_size_sensitivity(w: Workload, sizes=(2 << 30, 4 << 30, 8 << 30),
                          ssd: SSDConfig = SSDConfig()) -> Dict[int, float]:
    """Fig. 13: MARS runtime vs SSD-internal DRAM size: more compute-enabled
    subarrays (AUs/QUs scale with DRAM) and fewer index re-streams."""
    out = {}
    base = ssd.dram_bytes
    for size in sizes:
        f = size / base
        cfg = dataclasses.replace(
            ssd, dram_bytes=size,
            dram_subarrays=int(ssd.dram_subarrays * f),
            n_arith_units=int(ssd.n_arith_units * f),
            n_query_units=int(ssd.n_query_units * f))
        passes = max(1.0, w.bytes_index / (0.6 * size))
        ww = dataclasses.replace(w, bytes_index=int(w.bytes_index * passes))
        out[size] = mars_latency(ww, cfg)["total"]
    return out
