"""Configuration for the MARS RSGA pipeline.

All bounds are compile-time constants (static shapes); thresholds follow the
paper (Section 5.1): small genomes (thresh_freq, thresh_voting, voting_window)
= (2000, 5, 256), large genomes (20000, 2, 256).  Our datasets are scaled-down
synthetics, so thresh_freq scales with them (it is dataset-specific in the
paper as well).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# Pipeline modes (paper Section 7, "Evaluated Systems").
MODE_RH2 = "rh2"            # RawHash2 baseline: late quantization, float, no filters.
MODE_MS_FLOAT = "ms_float"  # MARS software: filters + early quantization, float.
MODE_MS_FIXED = "ms_fixed"  # MARS software: filters + early quantization, fixed point.

MODES = (MODE_RH2, MODE_MS_FLOAT, MODE_MS_FIXED)


@dataclasses.dataclass(frozen=True)
class MarsConfig:
    """Static configuration for one mapping run.  Hashable -> usable as a jit
    static argument."""

    # ---- signal / event detection -------------------------------------------------
    signal_len: int = 1024          # samples per read chunk (S)
    max_events: int = 192           # E: static bound on events per read
    tstat_window: int = 4           # w: half-window for the two-sample t-statistic
    tstat_threshold: float = 2.5    # boundary threshold on the t-stat
    peak_window: int = 3            # local-max suppression radius
    min_dwell: int = 1              # min samples per segment (1 = rely on
                                    # peak_window; keeps the kernel scan-free)

    # ---- quantization (paper Section 5.2) -----------------------------------------
    quant_bits: int = 3             # q: bits per event symbol (8 levels)
    quant_clip_sigma: float = 3.0   # quantize over [-clip, +clip] sigmas
    frac_bits: int = 8              # fixed-point fractional bits (Q7.8 -> int16)
    early_quantization: bool = True  # MARS: quantize raw signal BEFORE event detection
    fixed_point: bool = True        # MARS: int16/int32 arithmetic after quantization

    # ---- seeding -------------------------------------------------------------------
    seed_width: int = 7             # w: events per seed
    hash_bits: int = 18             # h: direct-address bucket table = 2^h buckets
    max_hits_per_seed: int = 16     # H: static bound on hits gathered per seed
    minimizer_radius: int = 0       # winnowing subsample radius (0 = off);
                                    # applied identically to reads + index

    # ---- filters (paper Section 5.1) -----------------------------------------------
    use_freq_filter: bool = True
    thresh_freq: int = 12           # drop seeds with > thresh_freq hits (scaled)
    use_vote_filter: bool = True
    thresh_voting: int = 4          # min votes per window
    voting_window_log2: int = 8     # window = 256 (events ~ bases)
    vote_bins: int = 4096           # mod-hash bins for window votes

    # ---- chaining -------------------------------------------------------------------
    max_anchors: int = 512          # A: anchors kept after sort-compaction
    chain_band: int = 32            # B: DP band (look-back window in sorted order)
    max_gap: int = 128              # max gap (events) between chained anchors
    gap_cost: float = 0.3           # beta: |gap_t - gap_q| penalty
    skip_cost: float = 0.05         # alpha: min(gap) penalty
    anchor_score: float = 1.0       # w_i: score per chained anchor
    min_chain_score: float = 4.0    # report threshold
    map_ratio: float = 1.25         # best/second-best score ratio to call unique

    # ---- chaining fast path (filter-aware; core/pipeline.py) -----------------------
    chain_compaction: bool = True   # gate chaining to reads with anchors left
    chain_capacity_frac: float = 0.75  # compacted chain batch = ceil(frac * R)
    chain_widths: Tuple[int, ...] = (64, 128)  # select-then-sort width ladder
    anchor_select: str = "count"    # smallest-key selection: "count" | "topk"

    # ---- bookkeeping ----------------------------------------------------------------
    mode: str = MODE_MS_FIXED

    # ------------------------------------------------------------------------------
    @property
    def quant_levels(self) -> int:
        return 1 << self.quant_bits

    @property
    def n_buckets(self) -> int:
        return 1 << self.hash_bits

    @property
    def voting_window(self) -> int:
        return 1 << self.voting_window_log2

    def with_mode(self, mode: str) -> "MarsConfig":
        """Derive the per-system variants of paper Section 7.

        RH2 keeps its own frequency filter (RawHash2 ships one — the paper's
        novelty is the freq+vote COMBINATION plus early quantization), but
        no seed-and-vote, float arithmetic, late quantization."""
        if mode == MODE_RH2:
            return dataclasses.replace(
                self, mode=mode, early_quantization=False, fixed_point=False,
                use_freq_filter=True, use_vote_filter=False)
        if mode == MODE_MS_FLOAT:
            return dataclasses.replace(
                self, mode=mode, early_quantization=True, fixed_point=False,
                use_freq_filter=True, use_vote_filter=True)
        if mode == MODE_MS_FIXED:
            return dataclasses.replace(
                self, mode=mode, early_quantization=True, fixed_point=True,
                use_freq_filter=True, use_vote_filter=True)
        raise ValueError(f"unknown mode {mode!r}")

    def replace(self, **kw) -> "MarsConfig":
        return dataclasses.replace(self, **kw)


DEFAULT = MarsConfig()
