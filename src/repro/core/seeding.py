"""Seeding (paper Fig. 1, mapping step 2): hash-table query + frequency filter.

Online, jit-compiled.  For each seed key we gather up to H entries from its
bucket, mask collisions (stored key != query key) and apply the exact
frequency filter (entries_cnt > thresh_freq -> drop, Section 5.1).

The bucket gathers are the operation MARS maps onto its pLUTo-based Querying
Units; the optimized pipeline path routes them through the `pluto_lookup`
Pallas kernel (kernels/pluto_lookup) instead of jnp.take.

Packed-entry fast path: the online index stores the entries as (2, N) int32
ROWS (``entries_packed``, core/index.py) — word 0 packs [key-distinguisher |
count], word 1 holds t_pos — so ``query_index`` issues exactly TWO gathers
per chunk: the fused bucket-boundary gather and ONE entry-row gather that
returns both words per probed entry (the pLUTo kernel reads the packed row
in a single table sweep, like pLUTo's row-wide sense amplifiers; the
unpacked layout needed three separate entry-table sweeps).  The unpacked
four-gather implementation survives as ``query_index_reference`` (parity
oracle + the "pre" side of the cheap-phase microbenchmark); both accept
per-read (E,) keys or a whole chunk (R, E) — batched calls lower to single
whole-chunk gathers (ONE pLUTo kernel sweep on the Pallas backend instead
of per-read unit batches).

Injectable ``gather(table, idx)`` contract: 1-D (N,) tables return
``idx``-shaped values (as before); the 2-D (2, N) packed-row table returns
(2, *idx.shape) — both words per index.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import MarsConfig


def _take_clip(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Default gather, hoisted to module level so every trace shares ONE
    callable instead of a fresh per-call lambda (stable jaxpr identity).
    2-D (2, N) packed-row tables gather along the entry axis and return
    both row words, (2, *idx.shape)."""
    return jnp.take(table, idx, axis=table.ndim - 1, mode="clip")


def unpack_entries(packed: jnp.ndarray, keys: jnp.ndarray, cfg: MarsConfig):
    """Split gathered packed-entry words back into (got_key, key_cnt).

    packed: (..., H) int32 — the [key & ~bucket_mask | cnt] half of the
    entry plane; keys: (...,) uint32 query keys.  Every in-bucket entry's
    low hash_bits equal the bucket id, i.e. the query key's own low bits —
    so the stored low bits are redundant and their field holds the count.
    Reconstruction ``(packed & ~mask) | (query_key & mask)`` equals the full
    stored key exactly for in-bucket entries; out-of-bucket slots are masked
    by ``match_entries``'s in_bucket test before the comparison matters.
    """
    mask = jnp.uint32(cfg.n_buckets - 1)
    pu = jax.lax.bitcast_convert_type(packed, jnp.uint32)
    got_key = (pu & ~mask) | (keys[..., None] & mask)
    key_cnt = (pu & mask).astype(jnp.int32)
    return got_key, key_cnt


def match_entries(keys: jnp.ndarray, valid: jnp.ndarray,
                  got_key: jnp.ndarray, key_cnt: jnp.ndarray,
                  cnt_bucket: jnp.ndarray, cfg: MarsConfig):
    """The post-gather query math, shared by the replicated-table path below
    and the partitioned-index backends (core/distributed.py) so the filter
    rules and counter semantics live in ONE place.

    keys/valid: (..., E); got_key/key_cnt: (..., E, H) gathered entry planes;
    cnt_bucket: (..., E).  Leading batch axes are allowed (the batched chunk
    program); reductions are per read.  ``valid`` is the seed mask for THIS
    table — the full seed mask on a replicated table, seed mask & partition
    ownership on a partitioned one (each seed's bucket lives in exactly one
    partition, so the per-partition scalars sum to the replicated-table
    values).

    Returns (hit_valid (..., E, H), probes, raw, exact int32 per-read
    counters): post-frequency-filter hits, bucket probes (capped at H per
    seed), raw pre-filter hits, and the uncapped exact hit count —
    occurrences of each matched key in the whole reference (entries_cnt),
    counted once per seed; what an unbounded software baseline (RawHash2)
    would chain over.
    """
    H = cfg.max_hits_per_seed
    red = (-2, -1)                                           # per-read axes
    j = jnp.arange(H, dtype=jnp.int32)                       # (H,)
    in_bucket = j < cnt_bucket[..., None]
    key_match = got_key == keys[..., None]
    raw_hit = in_bucket & key_match & valid[..., None]

    if cfg.use_freq_filter:
        hit_valid = raw_hit & (key_cnt <= cfg.thresh_freq)
    else:
        hit_valid = raw_hit

    first_match = key_match & in_bucket & (jnp.cumsum(
        (key_match & in_bucket).astype(jnp.int32), axis=-1) == 1)
    probes = (jnp.minimum(cnt_bucket, H) * valid).sum(-1)
    raw = raw_hit.sum(red)
    exact = jnp.where(first_match & valid[..., None], key_cnt, 0).sum(red)
    return hit_valid, probes, raw, exact


def _query_counters(valid, hit_valid, probes, raw, exact) -> Dict:
    return dict(
        n_seeds=valid.sum(-1),
        n_bucket_probes=probes,
        n_hits_raw=raw,
        n_hits_postfreq=hit_valid.sum((-2, -1)),
        n_hits_exact=exact,
    )


def query_index(keys: jnp.ndarray, valid: jnp.ndarray,
                index: Dict[str, jnp.ndarray], cfg: MarsConfig,
                gather=None) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """keys: (E,) or (R, E) uint32, valid: same-shape bool.

    Returns (t_pos (..., E, H) int32, hit_valid (..., E, H) bool, counters
    dict — scalars per read, (R,)-vectors for batched input).  `gather(table,
    idx)` is injectable so the Pallas pLUTo kernel can be swapped in;
    defaults to jnp.take.

    Dispatches on the index pytree layout: the packed single-plane layout
    (``index_arrays``) takes the two-gather fast path; the legacy unpacked
    dict falls through to ``query_index_reference``.
    """
    if "entries_packed" not in index:
        return query_index_reference(keys, valid, index, cfg, gather=gather)
    if gather is None:
        gather = _take_clip
    H = cfg.max_hits_per_seed
    mask = jnp.uint32(cfg.n_buckets - 1)
    bucket = (keys & mask).astype(jnp.int32)

    # gather 1: both bucket boundaries (start of bucket b and of b+1) in one
    # fused (2, ...) lookup
    start_end = gather(index["bucket_start"],
                       jnp.stack([bucket, bucket + 1]))      # (2, ..., E)
    start, end = start_end[0], start_end[1]
    cnt_bucket = end - start

    j = jnp.arange(H, dtype=jnp.int32)
    idx = start[..., None] + j                               # (..., E, H)
    n_entries = index["entries_packed"].shape[-1]
    idx_c = jnp.minimum(idx, n_entries - 1)

    # gather 2: ONE packed-row lookup returns both entry words
    ent = gather(index["entries_packed"], idx_c)             # (2, ..., E, H)
    got_key, key_cnt = unpack_entries(ent[0], keys, cfg)
    t_pos = ent[1]

    hit_valid, probes, raw, exact = match_entries(
        keys, valid, got_key, key_cnt, cnt_bucket, cfg)
    return t_pos, hit_valid, _query_counters(valid, hit_valid, probes, raw,
                                             exact)


def query_index_reference(keys: jnp.ndarray, valid: jnp.ndarray,
                          index: Dict[str, jnp.ndarray], cfg: MarsConfig,
                          gather=None) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                Dict]:
    """Pre-fast-path query over the UNPACKED index layout
    (``index_arrays_unpacked``): four separate table gathers.  Parity oracle
    + the "pre" side of the cheap-phase microbenchmark.  Same signature and
    batch semantics as ``query_index``.
    """
    if gather is None:
        gather = _take_clip
    H = cfg.max_hits_per_seed
    mask = jnp.uint32(cfg.n_buckets - 1)
    bucket = (keys & mask).astype(jnp.int32)

    start_end = gather(index["bucket_start"],
                       jnp.stack([bucket, bucket + 1]))      # (2, ..., E)
    start, end = start_end[0], start_end[1]
    cnt_bucket = end - start

    j = jnp.arange(H, dtype=jnp.int32)
    idx = start[..., None] + j                               # (..., E, H)
    n_entries = index["entries_key"].shape[0]
    idx_c = jnp.minimum(idx, n_entries - 1)

    got_key = gather(index["entries_key"], idx_c)            # (..., E, H)
    t_pos = gather(index["entries_pos"], idx_c)
    key_cnt = gather(index["entries_cnt"], idx_c)

    hit_valid, probes, raw, exact = match_entries(
        keys, valid, got_key, key_cnt, cnt_bucket, cfg)
    return t_pos, hit_valid, _query_counters(valid, hit_valid, probes, raw,
                                             exact)
