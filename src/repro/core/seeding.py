"""Seeding (paper Fig. 1, mapping step 2): hash-table query + frequency filter.

Online, jit-compiled.  For each seed key we gather up to H entries from its
bucket, mask collisions (stored key != query key) and apply the exact
frequency filter (entries_cnt > thresh_freq -> drop, Section 5.1).

The bucket gathers are the operation MARS maps onto its pLUTo-based Querying
Units; the optimized pipeline path routes them through the `pluto_lookup`
Pallas kernel (kernels/pluto_lookup) instead of jnp.take.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.core.config import MarsConfig


def _take_clip(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Default gather, hoisted to module level so every trace shares ONE
    callable instead of a fresh per-call lambda (stable jaxpr identity)."""
    return jnp.take(table, idx, axis=0, mode="clip")


def match_entries(keys: jnp.ndarray, valid: jnp.ndarray,
                  got_key: jnp.ndarray, key_cnt: jnp.ndarray,
                  cnt_bucket: jnp.ndarray, cfg: MarsConfig):
    """The post-gather query math, shared by the replicated-table path below
    and the partitioned-index backends (core/distributed.py) so the filter
    rules and counter semantics live in ONE place.

    keys/valid: (E,); got_key/key_cnt: (E,H) gathered entry planes;
    cnt_bucket: (E,).  ``valid`` is the seed mask for THIS table — the full
    seed mask on a replicated table, seed mask & partition ownership on a
    partitioned one (each seed's bucket lives in exactly one partition, so
    the per-partition scalars sum to the replicated-table values).

    Returns (hit_valid (E,H), probes, raw, exact int32 scalars):
    post-frequency-filter hits, bucket probes (capped at H per seed),
    raw pre-filter hits, and the uncapped exact hit count — occurrences of
    each matched key in the whole reference (entries_cnt), counted once per
    seed; what an unbounded software baseline (RawHash2) would chain over.
    """
    H = cfg.max_hits_per_seed
    j = jnp.arange(H, dtype=jnp.int32)[None, :]              # (1,H)
    in_bucket = j < cnt_bucket[:, None]
    key_match = got_key == keys[:, None]
    raw_hit = in_bucket & key_match & valid[:, None]

    if cfg.use_freq_filter:
        hit_valid = raw_hit & (key_cnt <= cfg.thresh_freq)
    else:
        hit_valid = raw_hit

    first_match = key_match & in_bucket & (jnp.cumsum(
        (key_match & in_bucket).astype(jnp.int32), axis=1) == 1)
    probes = (jnp.minimum(cnt_bucket, H) * valid).sum()
    raw = raw_hit.sum()
    exact = jnp.where(first_match & valid[:, None], key_cnt, 0).sum()
    return hit_valid, probes, raw, exact


def query_index(keys: jnp.ndarray, valid: jnp.ndarray,
                index: Dict[str, jnp.ndarray], cfg: MarsConfig,
                gather=None) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """keys: (E,) uint32, valid: (E,) bool.

    Returns (t_pos (E,H) int32, hit_valid (E,H) bool, counters dict).
    `gather(table, idx)` is injectable so the Pallas pLUTo kernel can be
    swapped in; defaults to jnp.take.
    """
    if gather is None:
        gather = _take_clip
    E, H = keys.shape[0], cfg.max_hits_per_seed
    mask = jnp.uint32(cfg.n_buckets - 1)
    bucket = (keys & mask).astype(jnp.int32)

    # one fused (2,E) gather for both bucket boundaries (start of bucket b
    # and of b+1) — the pLUTo backend then lowers ONE gather shape instead
    # of two separate (E,) lookups into the same table
    start_end = gather(index["bucket_start"],
                       jnp.stack([bucket, bucket + 1]))      # (2,E)
    start, end = start_end[0], start_end[1]
    cnt_bucket = end - start

    j = jnp.arange(H, dtype=jnp.int32)[None, :]              # (1,H)
    idx = start[:, None] + j                                 # (E,H)
    n_entries = index["entries_key"].shape[0]
    idx_c = jnp.minimum(idx, n_entries - 1)

    got_key = gather(index["entries_key"], idx_c)            # (E,H) uint32
    t_pos = gather(index["entries_pos"], idx_c)              # (E,H) int32
    key_cnt = gather(index["entries_cnt"], idx_c)            # (E,H) int32

    hit_valid, probes, raw, exact = match_entries(
        keys, valid, got_key, key_cnt, cnt_bucket, cfg)

    counters = dict(
        n_seeds=valid.sum(),
        n_bucket_probes=probes,
        n_hits_raw=raw,
        n_hits_postfreq=hit_valid.sum(),
        n_hits_exact=exact,
    )
    return t_pos, hit_valid, counters
