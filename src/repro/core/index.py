"""Reference index construction (paper Fig. 1, stage A — offline).

The reference genome's expected event sequence (forward ++ reverse strand,
"double genome") is quantized with global statistics, packed into seed keys
and stored in a direct-address bucket table:

    bucket_start : (2^h + 1,) int32   prefix offsets into the entry arrays
    entries_key  : (N,) uint32        full hash key per entry (collision check)
    entries_pos  : (N,) int32         seed position in double-genome coords
    entries_cnt  : (N,) int32         occurrences of this exact key in the
                                      reference (exact frequency-filter input)

Built offline with numpy (the paper treats indexing as offline as well); the
arrays are then device_put / sharded for the online mapping stage.

Packed online layout (cheap-phase fast path): every in-bucket entry's low
``hash_bits`` key bits equal its bucket id — implied by position, so the
online entry table stores the count in that field instead, and each entry
is ONE two-word row:

    entries_packed : (2, N) int32
        row 0   (key & ~bucket_mask) | cnt      key distinguisher + count
        row 1   t_pos                           seed position

``seeding.query_index`` therefore serves a whole chunk with exactly TWO
gathers (the fused bucket-boundary lookup and one entry-row lookup) instead
of four table reads, and the pLUTo kernel answers each entry query with one
packed-row sweep (kernels/pluto_lookup reads both words per activation,
like pLUTo's row-wide sense amplifiers).  ``build_index`` guards the
packing statically: every count must fit the ``hash_bits`` spare bits.  The
unpacked per-field arrays remain on the Index (offline source of truth,
``index_arrays_unpacked``) for the parity oracle and the partition builder.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import numpy as np

from repro.core.config import MarsConfig
from repro.core import chaining, hashing


@dataclasses.dataclass
class Index:
    bucket_start: np.ndarray   # (2^h + 1,) int32
    entries_key: np.ndarray    # (N,) uint32
    entries_pos: np.ndarray    # (N,) int32
    entries_cnt: np.ndarray    # (N,) int32
    n_ref_events: int          # Le (single strand)
    n_entries: int
    cfg: MarsConfig

    @property
    def nbytes(self) -> int:
        return (self.bucket_start.nbytes + self.entries_key.nbytes +
                self.entries_pos.nbytes + self.entries_cnt.nbytes)

    @property
    def entries_packed(self) -> np.ndarray:
        """(2, N) int32 packed online entry rows (module docstring).
        Packed once on first access (build_index's overflow guard) and
        memoized — index_arrays/partition_index reuse the same array."""
        packed = getattr(self, "_entries_packed", None)
        if packed is None:
            packed = pack_entries(self.entries_key, self.entries_pos,
                                  self.entries_cnt, self.cfg)
            self._entries_packed = packed
        return packed


def pack_entries(keys: np.ndarray, pos: np.ndarray, cnt: np.ndarray,
                 cfg: MarsConfig) -> np.ndarray:
    """Interleave (key, cnt, pos) into the (2, N) int32 online entry rows.

    The count occupies the low ``hash_bits`` (bucket-implied) key bits; a
    count that does not fit would corrupt its neighbour's key distinguisher,
    so overflow fails loudly here (``build_index`` calls this at build time).
    """
    mask = np.uint32(cfg.n_buckets - 1)
    if cnt.size and int(cnt.max()) >= cfg.n_buckets:
        raise ValueError(
            f"entry count {int(cnt.max())} does not fit the {cfg.hash_bits} "
            "bucket-implied spare bits of the packed entry plane "
            "(entries_packed); raise hash_bits or deduplicate the reference")
    keycnt = (keys.astype(np.uint32) & ~mask) | cnt.astype(np.uint32)
    return np.stack([keycnt.view(np.int32), pos.astype(np.int32)])


def quantize_stats(events: np.ndarray):
    """The global z-normalization statistics of ``quantize_reference_events``
    — exposed so the streaming builder can compute them once over the whole
    event stream and then quantize chunk-by-chunk with bit-identical
    results."""
    return float(events.mean()), float(events.std()) + 1e-6


def quantize_reference_events(events: np.ndarray, cfg: MarsConfig,
                              stats=None) -> np.ndarray:
    """Global z-normalization + uniform buckets (numpy twin of
    quantization.quantize_events_float).  ``stats`` overrides the
    (mean, std) pair for chunked callers (``build_index_streaming``)."""
    mean, std = quantize_stats(events) if stats is None else stats
    z = (events - mean) / std
    clip = cfg.quant_clip_sigma
    step = (2.0 * clip) / cfg.quant_levels
    sym = np.floor((np.clip(z, -clip, clip - 1e-4) + clip) / step)
    return np.clip(sym.astype(np.int64), 0, cfg.quant_levels - 1)


def build_index(ref_events_concat: np.ndarray, n_ref_events: int,
                cfg: MarsConfig) -> Index:
    """ref_events_concat: (2*Le,) f32 — forward ++ reverse expected events."""
    # overflow guard for the packed anchor sort key [t : T_BITS | q : Q_BITS]
    # (chaining.pack_anchor_keys): every t_pos (double-genome coordinate,
    # < 2*Le) must fit the t field of a NON-NEGATIVE int32, i.e.
    # n_ref_events < 2^(31 - _Q_BITS) / 2 per strand.
    if ref_events_concat.shape[0] >= (1 << chaining.T_BITS):
        raise ValueError(
            f"double genome must stay under 2^{chaining.T_BITS} events so "
            "(t_pos, q_pos) packs into a non-negative int32 sort key "
            "(chaining.pack_anchor_keys); shard larger references across "
            "the model axis instead.")
    if cfg.max_events > (1 << (31 - chaining.T_BITS)):
        raise ValueError(
            f"max_events must fit the {31 - chaining.T_BITS}-bit q_pos "
            "field of the packed anchor sort key")
    sym = quantize_reference_events(ref_events_concat.astype(np.float64), cfg)
    keys = hashing.pack_seeds_np(sym, cfg)                 # (2Le - w + 1,)
    pos = np.arange(keys.shape[0], dtype=np.int64)
    # drop seeds spanning the forward/reverse junction
    Le, w = n_ref_events, cfg.seed_width
    keep = ~((pos > Le - w) & (pos < Le))
    # minimizer winnowing (same rule as the online side)
    keep &= hashing.minimizer_mask_np(keys, cfg.minimizer_radius)
    keys, pos = keys[keep], pos[keep]

    # exact per-key occurrence counts (frequency filter input)
    order_k = np.argsort(keys, kind="stable")
    ks = keys[order_k]
    uniq, inv_start, counts = np.unique(ks, return_index=True,
                                        return_counts=True)
    cnt_sorted = np.repeat(counts, counts)
    cnt = np.empty_like(cnt_sorted)
    cnt[order_k] = cnt_sorted

    # bucket layout: sort by (bucket, key) so equal keys are contiguous
    mask = np.uint32(cfg.n_buckets - 1)
    bucket = (keys & mask).astype(np.int64)
    order = np.lexsort((keys, bucket))
    bucket_s, keys_s, pos_s, cnt_s = (bucket[order], keys[order], pos[order],
                                      cnt[order])
    bucket_start = np.zeros(cfg.n_buckets + 1, np.int64)
    np.add.at(bucket_start, bucket_s + 1, 1)
    bucket_start = np.cumsum(bucket_start)

    idx = Index(
        bucket_start=bucket_start.astype(np.int32),
        entries_key=keys_s.astype(np.uint32),
        entries_pos=pos_s.astype(np.int32),
        entries_cnt=np.minimum(cnt_s, np.iinfo(np.int32).max).astype(np.int32),
        n_ref_events=n_ref_events,
        n_entries=int(keys_s.shape[0]),
        cfg=cfg,
    )
    idx.entries_packed                 # packed-plane overflow guard, build time
    return idx


def index_arrays(index: Index):
    """The jit-friendly pytree of device arrays — packed two-plane layout
    (``seeding.query_index``'s two-gather fast path)."""
    return dict(
        bucket_start=index.bucket_start,
        entries_packed=index.entries_packed,
    )


def index_arrays_unpacked(index: Index):
    """The pre-fast-path four-plane pytree, consumed by
    ``seeding.query_index_reference`` (parity oracle / microbenchmark)."""
    return dict(
        bucket_start=index.bucket_start,
        entries_key=index.entries_key,
        entries_pos=index.entries_pos,
        entries_cnt=index.entries_cnt,
    )


# --------------------------------------------------------------------------- #
# Range partitioning (distributed query backends)
# --------------------------------------------------------------------------- #
# The mesh axis holding index partitions (the TP axis of the production
# mesh, launch/mesh.py) — the ONE name the query backends' collectives,
# the shard_map in_specs and the partition shardings all key on.
INDEX_AXIS = "model"

# The pytree keys of a partitioned index (every leaf has a leading
# (n_parts,) partition axis, sharded over INDEX_AXIS by
# distributed/sharding.partitioned_index_shardings).  The entry plane is
# the SAME packed [keycnt | t_pos] layout as the replicated table
# (entries_packed above), per partition.
PARTITIONED_INDEX_KEYS = ("p_bucket_start", "p_entries_packed")


def partition_index(index: Index, n_parts: int):
    """Range-partition by bucket: partition p owns an equal bucket range
    [p*B/n, (p+1)*B/n).  Entries are padded to the max partition size so
    every device holds the same (static) shapes.

    This is the flash-partition layout of the paper's Section 6.3: the
    `query:ring` / `query:a2a` stage backends (core/distributed.py) run
    the hash-table query against exactly one resident partition per step.
    Entry order inside a partition matches the global index (contiguous
    bucket ranges), so partitioned query results are bit-identical to the
    replicated table's; each partition carries the packed entry rows
    unchanged — ``p_entries_packed[p]`` is (2, emax) int32, the same
    [keycnt; t_pos] row layout as ``entries_packed``.
    """
    nb = index.cfg.n_buckets
    if n_parts & (n_parts - 1):
        raise ValueError(f"n_parts must be a power of two (bucket owner is "
                         f"key >> log2(bucket_range)); got {n_parts}")
    assert nb % n_parts == 0, (nb, n_parts)
    bl = nb // n_parts
    starts = index.bucket_start
    sizes = [int(starts[(p + 1) * bl] - starts[p * bl])
             for p in range(n_parts)]
    emax = max(max(sizes), 1)
    packed_all = index.entries_packed
    packed = np.zeros((n_parts, 2, emax), np.int32)
    bstart = np.zeros((n_parts, bl + 1), np.int32)
    for p in range(n_parts):
        lo, hi = int(starts[p * bl]), int(starts[(p + 1) * bl])
        n = hi - lo
        packed[p, :, :n] = packed_all[:, lo:hi]
        bstart[p] = starts[p * bl:(p + 1) * bl + 1] - starts[p * bl]
    return dict(p_bucket_start=bstart, p_entries_packed=packed)


def repartition_index(index: Index, n_parts: int, failed: int, parts=None):
    """Online drive-failure rebalancing: fold the failed drive's bucket
    range onto the survivors by HALVING the partition count (N -> N/2 —
    the owner rule stays `bucket >> log2(range)`, so the power-of-two
    invariants of ``partition_index`` survive a single-drive loss).

    Merged partition p owns the union of old partitions (2p, 2p+1):
    entries are the pairwise concatenation of the old planes (global
    bucket order preserved) and local bucket offsets rebase, so the result
    is BIT-IDENTICAL to a fresh ``partition_index(index, n_parts // 2)``
    — the rebalance parity oracle (tests/test_faults.py).  ``parts`` may
    pass the live N-partition pytree to merge from (the online path:
    survivors re-serve their resident planes; the failed rank's range is
    re-read from the host/flash replica — here the same plane, since this
    reproduction keeps the source index on the host).

    Returns ``(parts_half, remap)``: the N/2-partition pytree plus the
    remap table ``remap[p]`` = the surviving old drive serving merged
    partition p (old drive 2p when it survived, else 2p+1 — the partner
    already holds half the merged range, so data movement is minimal).
    """
    if n_parts < 2 or (n_parts & (n_parts - 1)):
        raise ValueError(f"n_parts must be a power of two >= 2 to fold a "
                         f"failed drive onto survivors; got {n_parts}")
    if not 0 <= failed < n_parts:
        raise ValueError(f"failed drive must be in [0, {n_parts}); "
                         f"got {failed}")
    if parts is None:
        parts = partition_index(index, n_parts)
    bs = np.asarray(parts["p_bucket_start"])
    pk = np.asarray(parts["p_entries_packed"])
    half = n_parts // 2
    bl = bs.shape[1] - 1                      # buckets per OLD partition
    sizes = bs[:, -1].astype(np.int64)        # true entries per partition
    emax = max(int((sizes[0::2] + sizes[1::2]).max()), 1)
    packed = np.zeros((half, 2, emax), np.int32)
    bstart = np.zeros((half, 2 * bl + 1), np.int32)
    remap = []
    for p in range(half):
        a, b = 2 * p, 2 * p + 1
        na, nb = int(sizes[a]), int(sizes[b])
        packed[p, :, :na] = pk[a, :, :na]
        packed[p, :, na:na + nb] = pk[b, :, :nb]
        bstart[p, :bl + 1] = bs[a]
        bstart[p, bl:] = bs[b] + na
        remap.append(a if a != failed else b)
    return (dict(p_bucket_start=bstart, p_entries_packed=packed),
            tuple(remap))


# --------------------------------------------------------------------------- #
# Out-of-core tiered index (host-resident bucket-range tiles)
# --------------------------------------------------------------------------- #
def tile_checksum(bstart_row: np.ndarray, ent_tile: np.ndarray) -> int:
    """CRC32 of one tile's planes (the (bl+1,) local offsets chained with
    the padded (2, emax) packed rows) — computed over the exact bytes that
    page into a device cache slot, so ``HotTileCache`` can verify every
    page-in and a corrupted transfer can never silently serve hits.
    CRC32 detects all single-bit and burst-<=32-bit errors, so every
    injected corruption (core/faults.py flips one bit) is caught.
    ``tier_index`` and ``build_index_streaming`` both compute it from the
    same (byte-identical) planes, so their checksum arrays agree too."""
    c = zlib.crc32(np.ascontiguousarray(bstart_row, np.int32).tobytes())
    c = zlib.crc32(np.ascontiguousarray(ent_tile, np.int32).tobytes(), c)
    return c & 0xFFFFFFFF



@dataclasses.dataclass
class TieredIndex:
    """The packed planes split into power-of-two bucket-range *tiles* that
    stay host-resident (plain numpy, optionally a memory-mapped entry
    plane) — the software analogue of MARS keeping the index in flash and
    loading partitions on demand (paper Section 6.3).

    Tile t owns buckets [t*bl, (t+1)*bl) with bl = n_buckets / n_tiles;
    ``tile_bucket_start[t]`` holds the (bl+1,) tile-local prefix offsets and
    ``tile_entries_packed[t]`` the (2, emax) packed [keycnt; t_pos] rows —
    the exact per-range slices of the global planes (``partition_index``
    layout), zero-padded to the max tile size so every tile pages into a
    fixed-size device cache slot (core/tiered.HotTileCache).  Entry order
    inside a tile matches the global index, so concatenating the unpadded
    tiles (``global_planes``) reproduces the in-memory ``Index`` planes
    byte for byte.
    """
    tile_bucket_start: np.ndarray    # (n_tiles, bl + 1) int32, tile-local
    tile_entries_packed: np.ndarray  # (n_tiles, 2, emax) int32 (may be memmap)
    tile_n_entries: np.ndarray       # (n_tiles,) int64 real entries per tile
    n_ref_events: int
    n_entries: int
    cfg: MarsConfig
    # (n_tiles,) uint32 per-tile CRC32 (``tile_checksum``) verified at every
    # cache page-in; builders populate it, hand-built instances get it
    # lazily on first access
    tile_checksums: Optional[np.ndarray] = None

    def checksum(self, t: int) -> int:
        """The expected CRC32 of tile ``t``'s planes, computing (and
        memoizing) the checksum array when the instance was built without
        one."""
        if self.tile_checksums is None:
            self.tile_checksums = np.asarray(
                [tile_checksum(self.tile_bucket_start[i],
                               self.tile_entries_packed[i])
                 for i in range(self.n_tiles)], np.uint32)
        return int(self.tile_checksums[t])

    @property
    def n_tiles(self) -> int:
        return self.tile_bucket_start.shape[0]

    @property
    def buckets_per_tile(self) -> int:
        return self.tile_bucket_start.shape[1] - 1

    @property
    def emax(self) -> int:
        return self.tile_entries_packed.shape[-1]

    @property
    def tile_nbytes(self) -> int:
        """Bytes paged host->device per tile load (both planes)."""
        return 4 * (self.tile_bucket_start.shape[1] +
                    2 * self.tile_entries_packed.shape[-1])

    @property
    def nbytes(self) -> int:
        return (self.tile_bucket_start.nbytes +
                self.tile_entries_packed.nbytes + self.tile_n_entries.nbytes)

    def global_planes(self):
        """Reassemble the resident-index planes: (bucket_start (2^h+1,)
        int32, entries_packed (2, N) int32) — byte-identical to the
        in-memory ``Index`` build (the streaming-build parity check)."""
        sizes = self.tile_n_entries.astype(np.int64)
        off = np.concatenate([[0], np.cumsum(sizes)])
        packed = np.zeros((2, int(off[-1])), np.int32)
        bs = np.zeros(self.cfg.n_buckets + 1, np.int64)
        bl = self.buckets_per_tile
        for t in range(self.n_tiles):
            n = int(sizes[t])
            packed[:, int(off[t]):int(off[t]) + n] = \
                self.tile_entries_packed[t, :, :n]
            bs[t * bl:(t + 1) * bl + 1] = \
                self.tile_bucket_start[t].astype(np.int64) + off[t]
        return bs.astype(np.int32), packed


def tier_index(index: Index, n_tiles: int) -> TieredIndex:
    """Split an in-memory ``Index`` into ``n_tiles`` host-resident
    bucket-range tiles (``partition_index`` math — same power-of-two guard,
    same per-range local offsets and padded packed planes)."""
    parts = partition_index(index, n_tiles)
    starts = index.bucket_start
    bl = index.cfg.n_buckets // n_tiles
    sizes = np.asarray([int(starts[(t + 1) * bl] - starts[t * bl])
                        for t in range(n_tiles)], np.int64)
    return TieredIndex(
        tile_bucket_start=parts["p_bucket_start"],
        tile_entries_packed=parts["p_entries_packed"],
        tile_n_entries=sizes,
        n_ref_events=index.n_ref_events,
        n_entries=index.n_entries,
        cfg=index.cfg,
        tile_checksums=np.asarray(
            [tile_checksum(parts["p_bucket_start"][t],
                           parts["p_entries_packed"][t])
             for t in range(n_tiles)], np.uint32))


def build_index_streaming(ref_events_concat: np.ndarray, n_ref_events: int,
                          cfg: MarsConfig, n_tiles: int,
                          chunk_events: int = 1 << 16,
                          mmap_path=None) -> TieredIndex:
    """Streaming out-of-core twin of ``build_index``: external bucket-range
    bucketing over the ``core/driver.py`` chunk loop instead of one giant
    in-memory sort.

    The event stream is consumed in ``driver.array_chunks`` blocks with a
    small carried overlap (seed width + minimizer radius), each block is
    quantized / seeded / winnowed with the exact in-memory math (global
    quantization stats from one vectorized pass; the minimizer window is
    fully buffered before a key is emitted, so block boundaries are
    invisible), and the surviving entries are scattered to their owning
    bucket-range tile.  Each tile is then counted, sorted and packed
    independently — equal keys share a bucket, so per-key counts and the
    stable (bucket, key) sort never cross a tile boundary, and the
    per-tile planes are byte-identical to ``tier_index(build_index(...))``
    (and ``global_planes()`` to the ``Index`` planes).  Peak memory is
    O(event stream + one tile's sort), not O(global entry sort); with
    ``mmap_path`` the padded entry plane lives in a memory-mapped file.
    """
    from repro.core import driver

    if ref_events_concat.shape[0] >= (1 << chaining.T_BITS):
        raise ValueError(
            f"double genome must stay under 2^{chaining.T_BITS} events so "
            "(t_pos, q_pos) packs into a non-negative int32 sort key "
            "(chaining.pack_anchor_keys); shard larger references across "
            "the model axis instead.")
    if cfg.max_events > (1 << (31 - chaining.T_BITS)):
        raise ValueError(
            f"max_events must fit the {31 - chaining.T_BITS}-bit q_pos "
            "field of the packed anchor sort key")
    if n_tiles < 1 or (n_tiles & (n_tiles - 1)):
        raise ValueError(f"n_tiles must be a power of two (tile owner is "
                         f"bucket >> log2(bucket_range)); got {n_tiles}")
    nb = cfg.n_buckets
    assert nb % n_tiles == 0, (nb, n_tiles)
    bl = nb // n_tiles
    tile_log = int(np.log2(bl))

    ref = np.asarray(ref_events_concat, np.float32)
    n_ev = ref.shape[0]
    Le, w, r = n_ref_events, cfg.seed_width, cfg.minimizer_radius
    nk = n_ev - w + 1
    # pass 1: global quantization statistics (one vectorized reduction over
    # the stream — the same float64 mean/std calls as the in-memory build,
    # so chunked quantization below is bit-identical)
    stats = quantize_stats(ref.astype(np.float64))
    kmask = np.uint32(nb - 1)

    spill_keys = [[] for _ in range(n_tiles)]
    spill_pos = [[] for _ in range(n_tiles)]

    def emit(lo, hi, buf, buf_start):
        """Emit keys [lo, hi): quantize + seed + winnow the buffered slice
        (extended by the minimizer radius so every emitted key sees its full
        window) and scatter survivors to their tiles."""
        klo, khi = max(0, lo - r), min(nk, hi + r)
        ev = buf[klo - buf_start:khi + w - 1 - buf_start].astype(np.float64)
        sym = quantize_reference_events(ev, cfg, stats=stats)
        keys_ext = hashing.pack_seeds_np(sym, cfg)
        mmask = hashing.minimizer_mask_np(keys_ext, r)[lo - klo:hi - klo]
        keys_b = keys_ext[lo - klo:hi - klo]
        pos_b = np.arange(lo, hi, dtype=np.int64)
        keep = ~((pos_b > Le - w) & (pos_b < Le)) & mmask
        keys_b, pos_b = keys_b[keep], pos_b[keep]
        tile = ((keys_b & kmask).astype(np.int64) >> tile_log)
        for t in np.unique(tile):
            m = tile == t
            spill_keys[int(t)].append(keys_b[m])
            spill_pos[int(t)].append(pos_b[m])

    # pass 2: stream event blocks through the shared chunk loop, carrying
    # the (w - 1 + r)-event overlap a key's seed window + minimizer window
    # need before it can be emitted
    emitted, buf_start = 0, 0
    buf = np.zeros(0, np.float32)
    for _ci, n_valid, block in driver.array_chunks(ref, chunk_events):
        buf = np.concatenate([buf, block[:n_valid]])
        have = buf_start + buf.shape[0]
        hi = nk if have >= n_ev else min(nk, have - (w - 1) - r)
        if hi > emitted:
            emit(emitted, hi, buf, buf_start)
            emitted = hi
            keep_from = max(0, emitted - r)
            buf = buf[keep_from - buf_start:]
            buf_start = keep_from

    # pass 3: per-tile count + stable (bucket, key) sort + pack.  Spill
    # arrival order is global position order, so each tile's lexsort equals
    # the global lexsort restricted to its bucket range.
    sizes = np.asarray([sum(a.shape[0] for a in sk) for sk in spill_keys],
                       np.int64)
    emax = max(int(sizes.max()) if sizes.size else 0, 1)
    if mmap_path is not None:
        packed = np.lib.format.open_memmap(
            str(mmap_path), mode="w+", dtype=np.int32,
            shape=(n_tiles, 2, emax))
        packed[:] = 0
    else:
        packed = np.zeros((n_tiles, 2, emax), np.int32)
    bstart = np.zeros((n_tiles, bl + 1), np.int32)
    checksums = np.zeros(n_tiles, np.uint32)
    for t in range(n_tiles):
        keys_t = (np.concatenate(spill_keys[t]) if spill_keys[t]
                  else np.zeros(0, np.uint32))
        pos_t = (np.concatenate(spill_pos[t]) if spill_pos[t]
                 else np.zeros(0, np.int64))
        spill_keys[t] = spill_pos[t] = None      # free as we go
        if keys_t.size:
            order_k = np.argsort(keys_t, kind="stable")
            _, counts = np.unique(keys_t[order_k], return_counts=True)
            cnt_sorted = np.repeat(counts, counts)
            cnt_t = np.empty_like(cnt_sorted)
            cnt_t[order_k] = cnt_sorted
        else:
            cnt_t = np.zeros(0, np.int64)
        bucket_t = (keys_t & kmask).astype(np.int64)
        order = np.lexsort((keys_t, bucket_t))
        keys_s, pos_s, cnt_s, bucket_s = (keys_t[order], pos_t[order],
                                          cnt_t[order], bucket_t[order])
        counts_b = np.zeros(bl + 1, np.int64)
        np.add.at(counts_b, (bucket_s - t * bl) + 1, 1)
        bstart[t] = np.cumsum(counts_b).astype(np.int32)
        cnt_s = np.minimum(cnt_s, np.iinfo(np.int32).max).astype(np.int32)
        packed[t, :, :keys_s.size] = pack_entries(
            keys_s.astype(np.uint32), pos_s, cnt_s, cfg)
        # planes are byte-identical to tier_index's (asserted in tests),
        # so the per-tile CRCs agree between the two builders too
        checksums[t] = tile_checksum(bstart[t], packed[t])
    if mmap_path is not None:
        packed.flush()
    return TieredIndex(
        tile_bucket_start=bstart, tile_entries_packed=packed,
        tile_n_entries=sizes, n_ref_events=n_ref_events,
        n_entries=int(sizes.sum()), cfg=cfg, tile_checksums=checksums)
