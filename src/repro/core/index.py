"""Reference index construction (paper Fig. 1, stage A — offline).

The reference genome's expected event sequence (forward ++ reverse strand,
"double genome") is quantized with global statistics, packed into seed keys
and stored in a direct-address bucket table:

    bucket_start : (2^h + 1,) int32   prefix offsets into the entry arrays
    entries_key  : (N,) uint32        full hash key per entry (collision check)
    entries_pos  : (N,) int32         seed position in double-genome coords
    entries_cnt  : (N,) int32         occurrences of this exact key in the
                                      reference (exact frequency-filter input)

Built offline with numpy (the paper treats indexing as offline as well); the
arrays are then device_put / sharded for the online mapping stage.

Packed online layout (cheap-phase fast path): every in-bucket entry's low
``hash_bits`` key bits equal its bucket id — implied by position, so the
online entry table stores the count in that field instead, and each entry
is ONE two-word row:

    entries_packed : (2, N) int32
        row 0   (key & ~bucket_mask) | cnt      key distinguisher + count
        row 1   t_pos                           seed position

``seeding.query_index`` therefore serves a whole chunk with exactly TWO
gathers (the fused bucket-boundary lookup and one entry-row lookup) instead
of four table reads, and the pLUTo kernel answers each entry query with one
packed-row sweep (kernels/pluto_lookup reads both words per activation,
like pLUTo's row-wide sense amplifiers).  ``build_index`` guards the
packing statically: every count must fit the ``hash_bits`` spare bits.  The
unpacked per-field arrays remain on the Index (offline source of truth,
``index_arrays_unpacked``) for the parity oracle and the partition builder.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.config import MarsConfig
from repro.core import chaining, hashing


@dataclasses.dataclass
class Index:
    bucket_start: np.ndarray   # (2^h + 1,) int32
    entries_key: np.ndarray    # (N,) uint32
    entries_pos: np.ndarray    # (N,) int32
    entries_cnt: np.ndarray    # (N,) int32
    n_ref_events: int          # Le (single strand)
    n_entries: int
    cfg: MarsConfig

    @property
    def nbytes(self) -> int:
        return (self.bucket_start.nbytes + self.entries_key.nbytes +
                self.entries_pos.nbytes + self.entries_cnt.nbytes)

    @property
    def entries_packed(self) -> np.ndarray:
        """(2, N) int32 packed online entry rows (module docstring).
        Packed once on first access (build_index's overflow guard) and
        memoized — index_arrays/partition_index reuse the same array."""
        packed = getattr(self, "_entries_packed", None)
        if packed is None:
            packed = pack_entries(self.entries_key, self.entries_pos,
                                  self.entries_cnt, self.cfg)
            self._entries_packed = packed
        return packed


def pack_entries(keys: np.ndarray, pos: np.ndarray, cnt: np.ndarray,
                 cfg: MarsConfig) -> np.ndarray:
    """Interleave (key, cnt, pos) into the (2, N) int32 online entry rows.

    The count occupies the low ``hash_bits`` (bucket-implied) key bits; a
    count that does not fit would corrupt its neighbour's key distinguisher,
    so overflow fails loudly here (``build_index`` calls this at build time).
    """
    mask = np.uint32(cfg.n_buckets - 1)
    if cnt.size and int(cnt.max()) >= cfg.n_buckets:
        raise ValueError(
            f"entry count {int(cnt.max())} does not fit the {cfg.hash_bits} "
            "bucket-implied spare bits of the packed entry plane "
            "(entries_packed); raise hash_bits or deduplicate the reference")
    keycnt = (keys.astype(np.uint32) & ~mask) | cnt.astype(np.uint32)
    return np.stack([keycnt.view(np.int32), pos.astype(np.int32)])


def quantize_reference_events(events: np.ndarray, cfg: MarsConfig) -> np.ndarray:
    """Global z-normalization + uniform buckets (numpy twin of
    quantization.quantize_events_float)."""
    mean, std = float(events.mean()), float(events.std()) + 1e-6
    z = (events - mean) / std
    clip = cfg.quant_clip_sigma
    step = (2.0 * clip) / cfg.quant_levels
    sym = np.floor((np.clip(z, -clip, clip - 1e-4) + clip) / step)
    return np.clip(sym.astype(np.int64), 0, cfg.quant_levels - 1)


def build_index(ref_events_concat: np.ndarray, n_ref_events: int,
                cfg: MarsConfig) -> Index:
    """ref_events_concat: (2*Le,) f32 — forward ++ reverse expected events."""
    # overflow guard for the packed anchor sort key [t : T_BITS | q : Q_BITS]
    # (chaining.pack_anchor_keys): every t_pos (double-genome coordinate,
    # < 2*Le) must fit the t field of a NON-NEGATIVE int32, i.e.
    # n_ref_events < 2^(31 - _Q_BITS) / 2 per strand.
    if ref_events_concat.shape[0] >= (1 << chaining.T_BITS):
        raise ValueError(
            f"double genome must stay under 2^{chaining.T_BITS} events so "
            "(t_pos, q_pos) packs into a non-negative int32 sort key "
            "(chaining.pack_anchor_keys); shard larger references across "
            "the model axis instead.")
    if cfg.max_events > (1 << (31 - chaining.T_BITS)):
        raise ValueError(
            f"max_events must fit the {31 - chaining.T_BITS}-bit q_pos "
            "field of the packed anchor sort key")
    sym = quantize_reference_events(ref_events_concat.astype(np.float64), cfg)
    keys = hashing.pack_seeds_np(sym, cfg)                 # (2Le - w + 1,)
    pos = np.arange(keys.shape[0], dtype=np.int64)
    # drop seeds spanning the forward/reverse junction
    Le, w = n_ref_events, cfg.seed_width
    keep = ~((pos > Le - w) & (pos < Le))
    # minimizer winnowing (same rule as the online side)
    keep &= hashing.minimizer_mask_np(keys, cfg.minimizer_radius)
    keys, pos = keys[keep], pos[keep]

    # exact per-key occurrence counts (frequency filter input)
    order_k = np.argsort(keys, kind="stable")
    ks = keys[order_k]
    uniq, inv_start, counts = np.unique(ks, return_index=True,
                                        return_counts=True)
    cnt_sorted = np.repeat(counts, counts)
    cnt = np.empty_like(cnt_sorted)
    cnt[order_k] = cnt_sorted

    # bucket layout: sort by (bucket, key) so equal keys are contiguous
    mask = np.uint32(cfg.n_buckets - 1)
    bucket = (keys & mask).astype(np.int64)
    order = np.lexsort((keys, bucket))
    bucket_s, keys_s, pos_s, cnt_s = (bucket[order], keys[order], pos[order],
                                      cnt[order])
    bucket_start = np.zeros(cfg.n_buckets + 1, np.int64)
    np.add.at(bucket_start, bucket_s + 1, 1)
    bucket_start = np.cumsum(bucket_start)

    idx = Index(
        bucket_start=bucket_start.astype(np.int32),
        entries_key=keys_s.astype(np.uint32),
        entries_pos=pos_s.astype(np.int32),
        entries_cnt=np.minimum(cnt_s, np.iinfo(np.int32).max).astype(np.int32),
        n_ref_events=n_ref_events,
        n_entries=int(keys_s.shape[0]),
        cfg=cfg,
    )
    idx.entries_packed                 # packed-plane overflow guard, build time
    return idx


def index_arrays(index: Index):
    """The jit-friendly pytree of device arrays — packed two-plane layout
    (``seeding.query_index``'s two-gather fast path)."""
    return dict(
        bucket_start=index.bucket_start,
        entries_packed=index.entries_packed,
    )


def index_arrays_unpacked(index: Index):
    """The pre-fast-path four-plane pytree, consumed by
    ``seeding.query_index_reference`` (parity oracle / microbenchmark)."""
    return dict(
        bucket_start=index.bucket_start,
        entries_key=index.entries_key,
        entries_pos=index.entries_pos,
        entries_cnt=index.entries_cnt,
    )


# --------------------------------------------------------------------------- #
# Range partitioning (distributed query backends)
# --------------------------------------------------------------------------- #
# The mesh axis holding index partitions (the TP axis of the production
# mesh, launch/mesh.py) — the ONE name the query backends' collectives,
# the shard_map in_specs and the partition shardings all key on.
INDEX_AXIS = "model"

# The pytree keys of a partitioned index (every leaf has a leading
# (n_parts,) partition axis, sharded over INDEX_AXIS by
# distributed/sharding.partitioned_index_shardings).  The entry plane is
# the SAME packed [keycnt | t_pos] layout as the replicated table
# (entries_packed above), per partition.
PARTITIONED_INDEX_KEYS = ("p_bucket_start", "p_entries_packed")


def partition_index(index: Index, n_parts: int):
    """Range-partition by bucket: partition p owns an equal bucket range
    [p*B/n, (p+1)*B/n).  Entries are padded to the max partition size so
    every device holds the same (static) shapes.

    This is the flash-partition layout of the paper's Section 6.3: the
    `query:ring` / `query:a2a` stage backends (core/distributed.py) run
    the hash-table query against exactly one resident partition per step.
    Entry order inside a partition matches the global index (contiguous
    bucket ranges), so partitioned query results are bit-identical to the
    replicated table's; each partition carries the packed entry rows
    unchanged — ``p_entries_packed[p]`` is (2, emax) int32, the same
    [keycnt; t_pos] row layout as ``entries_packed``.
    """
    nb = index.cfg.n_buckets
    if n_parts & (n_parts - 1):
        raise ValueError(f"n_parts must be a power of two (bucket owner is "
                         f"key >> log2(bucket_range)); got {n_parts}")
    assert nb % n_parts == 0, (nb, n_parts)
    bl = nb // n_parts
    starts = index.bucket_start
    sizes = [int(starts[(p + 1) * bl] - starts[p * bl])
             for p in range(n_parts)]
    emax = max(max(sizes), 1)
    packed_all = index.entries_packed
    packed = np.zeros((n_parts, 2, emax), np.int32)
    bstart = np.zeros((n_parts, bl + 1), np.int32)
    for p in range(n_parts):
        lo, hi = int(starts[p * bl]), int(starts[(p + 1) * bl])
        n = hi - lo
        packed[p, :, :n] = packed_all[:, lo:hi]
        bstart[p] = starts[p * bl:(p + 1) * bl + 1] - starts[p * bl]
    return dict(p_bucket_start=bstart, p_entries_packed=packed)
