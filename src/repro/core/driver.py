"""Unified streaming host driver: ONE copy of the chunk/pad/concat logic.

Every host-side consumer of the jit pipeline — ``Mapper.map_signals``,
real-time early-termination mapping (realtime.py) and the end-to-end
launcher (launch/map_reads.py) — used to carry its own chunking loop.
They all share this module now:

  * ``array_chunks`` produces fixed-size, zero-padded
    (chunk_idx, n_valid, signals) triples from an in-memory array; a
    streaming ``SignalReader`` yields the same triples directly;
  * ``stream_map`` is the double-buffered device loop: chunk i+1 is
    dispatched to the device *before* blocking on chunk i's host transfer,
    so host padding/serialization overlaps device compute (the host-side
    analogue of MARS's flash-load/compute overlap, Section 6.3);
  * ``collect`` folds the streamed per-chunk outputs into one MapOutput;
  * ``ProgressLog`` is the append-only JSONL checkpoint (with periodic
    compaction) used for resume-after-restart mapping jobs.

Pad rows are masked inside ``map_chunk`` via ``n_valid`` (counters never
see them) and trimmed from the per-read outputs here.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Callable, Dict, Iterable, Iterator, List, Tuple

import numpy as np

# (chunk_idx, n_valid, padded signals (chunk, S) f32)
Chunk = Tuple[int, int, np.ndarray]


def pad_rows(part: np.ndarray, chunk: int) -> np.ndarray:
    """Zero-pad the leading axis to the static chunk size."""
    if part.shape[0] == chunk:
        return part
    pad = np.zeros((chunk - part.shape[0],) + part.shape[1:], part.dtype)
    return np.concatenate([part, pad])


def array_chunks(signals: np.ndarray, chunk: int,
                 start_chunk: int = 0) -> Iterator[Chunk]:
    """Fixed-size chunks over an in-memory (R, S) array."""
    signals = np.asarray(signals, np.float32)
    n = signals.shape[0]
    n_chunks = (n + chunk - 1) // chunk
    for ci in range(start_chunk, n_chunks):
        part = signals[ci * chunk:(ci + 1) * chunk]
        yield ci, part.shape[0], pad_rows(part, chunk)


def stream_map(map_fn: Callable[[np.ndarray, int], "MapOutput"],
               chunks: Iterable[Chunk],
               prefetch: Callable[[np.ndarray, int], None] = None,
               trace: list = None,
               clock: Callable[[], float] = None,
               ) -> Iterator[Tuple[int, int, "MapOutput"]]:
    """Double-buffered device loop.

    ``map_fn(signals, n_valid)`` must be an async-dispatching jit program
    (map_chunk / map_chunk_sharded).  The next chunk is dispatched before
    the previous chunk's results are pulled to the host, so device compute
    overlaps host-side reading/padding/serialization.  Yields
    (chunk_idx, n_valid, MapOutput) with per-read fields on the host,
    trimmed to ``n_valid`` rows.

    With ``prefetch`` the loop additionally reads ONE chunk ahead: right
    after chunk i is dispatched, ``prefetch(signals, n_valid)`` runs on
    chunk i+1 so host->device staging (the tiered-index hot-tile cache,
    core/tiered.py) overlaps chunk i's compute.  Without it the pull order
    is unchanged — live chunk sources (the serving driver's ready queue)
    depend on the exact pull timing.

    With ``trace`` (a list) the loop appends the replayable chunk-event
    records ``("dispatch", t, ci, n_valid)`` at async dispatch and
    ``("complete", t, ci, n_valid)`` when the chunk's results reach the
    host — the batch-side half of the serving trace format
    (core/sim/serve_sim.py; ``ServeDriver`` records its richer
    virtual-time trace itself).  ``t`` comes from ``clock()`` when given
    (e.g. a virtual clock), else it counts dispatches.  Recording is pure
    observation: pull order and outputs are unchanged.

    A ``prefetch`` exception does NOT abandon the chunk already in flight
    on the device: the loop stops reading ahead, drains every dispatched
    chunk through the iterator, and re-raises the failure once at the end
    of the stream.
    """
    n_seen = 0

    def _note(kind: str, ci: int, n_valid: int) -> None:
        if trace is not None:
            trace.append((kind, clock() if clock is not None
                          else float(n_seen), ci, n_valid))

    def _emit(p):
        _note("complete", p[0], p[1])
        return _to_host(*p)

    pending = None
    exc = None
    if prefetch is None:
        for ci, n_valid, sig in chunks:
            out = map_fn(sig, n_valid)      # async dispatch
            n_seen += 1
            _note("dispatch", ci, n_valid)
            if pending is not None:
                yield _emit(pending)
            pending = (ci, n_valid, out)
    else:
        it = iter(chunks)
        nxt = next(it, None)
        if nxt is not None:
            try:
                prefetch(nxt[2], nxt[1])
            except Exception as e:          # nothing in flight yet
                exc, nxt = e, None
        while nxt is not None:
            ci, n_valid, sig = nxt
            out = map_fn(sig, n_valid)      # async dispatch
            n_seen += 1
            _note("dispatch", ci, n_valid)
            nxt = next(it, None)
            if nxt is not None:
                try:
                    prefetch(nxt[2], nxt[1])  # stage next chunk's tiles
                except Exception as e:
                    # chunk ci is mid-flight on the device: let it finish
                    # and yield, surface the prefetch failure at the tail
                    exc, nxt = e, None
            if pending is not None:
                yield _emit(pending)
            pending = (ci, n_valid, out)
    if pending is not None:
        yield _emit(pending)
    if exc is not None:
        raise exc


def _to_host(ci: int, n_valid: int, out) -> Tuple[int, int, "MapOutput"]:
    from repro.core.pipeline import MapOutput
    host = MapOutput(
        t_start=np.asarray(out.t_start)[:n_valid],
        score=np.asarray(out.score)[:n_valid],
        mapped=np.asarray(out.mapped)[:n_valid],
        n_events=np.asarray(out.n_events)[:n_valid],
        counters={k: int(v) for k, v in out.counters.items()})
    return ci, n_valid, host


def collect(stream: Iterable[Tuple[int, int, "MapOutput"]]) -> "MapOutput":
    """Fold a stream_map stream into one host MapOutput (concat per-read
    fields, sum counters).  An empty stream still carries the full
    zero-valued ``stages.CHUNK_COUNTER_SCHEMA`` so downstream consumers
    (workload.from_counters / ssd_model) work on a zero-read job."""
    from repro.core.pipeline import MapOutput
    parts: List = []
    counters: Dict[str, int] = {}
    for _, _, out in stream:
        parts.append(out)
        for k, v in out.counters.items():
            counters[k] = counters.get(k, 0) + int(v)
    if not parts:
        from repro.core.stages import CHUNK_COUNTER_SCHEMA
        z = np.zeros(0)
        return MapOutput(t_start=z.astype(np.int32), score=z.astype(np.float32),
                         mapped=z.astype(bool), n_events=z.astype(np.int32),
                         counters={k: 0 for k in CHUNK_COUNTER_SCHEMA})
    return MapOutput(
        t_start=np.concatenate([p.t_start for p in parts]),
        score=np.concatenate([p.score for p in parts]),
        mapped=np.concatenate([p.mapped for p in parts]),
        n_events=np.concatenate([p.n_events for p in parts]),
        counters=counters)


# --------------------------------------------------------------------------- #
# Resumable progress checkpointing
# --------------------------------------------------------------------------- #
class ProgressLog:
    """Append-only JSONL progress log with periodic compaction.

    Each mapped chunk appends ONE line ``{"next": ci+1, "rows": [...]}`` —
    O(chunk) per chunk instead of re-serializing the full result list
    (the old checkpoint was O(n^2) over a run).  Every ``compact_every``
    lines the log is rewritten as a single consolidated base line
    (atomic tmp+rename), bounding file size and resume parse time.
    """

    def __init__(self, path, compact_every: int = 64):
        self.path = pathlib.Path(path)
        self.compact_every = compact_every
        self.rows: List = []
        self.next_chunk = 0
        self._lines = 0

    def load(self) -> Tuple[int, List]:
        """Replay the log.  Returns (next_chunk, rows).

        A malformed line (a crash mid-append leaves a partial final line)
        stops the replay there: everything before it is consistent, and
        the chunk whose append was cut short is simply remapped.
        """
        self.rows, self.next_chunk, self._lines = [], 0, 0
        if self.path.exists():
            good = 0                       # bytes of consistent prefix
            with open(self.path, "rb") as f:
                for raw in f:
                    if not raw.endswith(b"\n"):
                        break              # torn tail (no terminator)
                    line = raw.decode("utf-8", "replace").strip()
                    if line:
                        try:
                            rec = json.loads(line)
                        except json.JSONDecodeError:
                            break
                        if rec.get("base"):
                            self.rows = [tuple(r) for r in rec["rows"]]
                        else:
                            self.rows.extend(tuple(r) for r in rec["rows"])
                        self.next_chunk = rec["next"]
                        self._lines += 1
                    good += len(raw)
            if good < self.path.stat().st_size:
                with open(self.path, "r+b") as f:
                    f.truncate(good)       # drop the torn tail; its chunk
                                           # is simply remapped
        return self.next_chunk, self.rows

    def append(self, next_chunk: int, rows: List) -> None:
        rows = [tuple(r) for r in rows]
        with open(self.path, "a") as f:
            f.write(json.dumps({"next": next_chunk, "rows": rows}) + "\n")
        self.rows.extend(rows)
        self.next_chunk = next_chunk
        self._lines += 1
        if self._lines >= self.compact_every:
            self.compact()

    def compact(self) -> None:
        tmp = self.path.with_name(self.path.name + ".tmp")
        tmp.write_text(json.dumps(
            {"next": self.next_chunk, "rows": self.rows, "base": True}) + "\n")
        os.replace(tmp, self.path)
        self._lines = 1

    def clear(self) -> None:
        self.path.unlink(missing_ok=True)
        self.rows, self.next_chunk, self._lines = [], 0, 0
