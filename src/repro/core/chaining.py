"""Chaining (paper Fig. 1, mapping step 3): anchor sort + banded DP.

Anchors are sorted by (t_pos, q_pos) — MARS does this on the in-controller
bitonic Sorter/Merger; the optimized pipeline path routes the sort through
the `bitonic_sort` Pallas kernel, the reference path uses jnp.sort.  The DP
is minimap2-style with a fixed look-back band B (MARS's Arithmetic Units are
word-serial, so RawHash2's bounded-predecessor heuristic maps directly).

    f[i] = w + max(0, max_{j in band, colinear} f[j] - beta*|dt - dq|
                                              - alpha*min(dt, dq))

The best chain's projected start (t_start - q_start) is the mapping position.

Fast path (this module + core/pipeline.py): MARS's filters exist so that
most reads reach chaining with few (often zero) anchors.  The chaining fast
path exploits that:

  * ``select_smallest_count`` / ``select_smallest_topk`` pull the W smallest
    packed keys out of the (E*H,) key array so the sorter runs on W keys
    instead of E*H ("select-then-sort" — the Pallas bitonic backend then
    sorts a W-slot block instead of the padded full block);
  * ``chain_dp`` carries only the B-slot band window as a ring buffer
    (fixed-position rotate/update) instead of dynamic-slicing a full
    (A+B,) array every scan step — the whole-array gather/scatter the old
    scan made vmap materialize per read is gone;
  * zero-anchor reads short-circuit to ``empty_chain_result`` (exactly what
    the full pipeline computes for them — see the proof in the docstring).

``sort_anchors_reference`` and ``chain_dp_reference`` keep the pre-fast-path
implementations: they are the parity oracles for the tests and the "pre"
side of benchmarks/microbench.py.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.config import MarsConfig

NEG = -1e9
_SENT = -(1 << 30)
_INVALID_KEY = jnp.int32(0x7FFFFFFF)
# packed sort key: [t_pos : 23 bits | q_pos : 8 bits] in a non-negative
# int32 — requires the double genome to have < 2^(31-8) = 2^23 events and
# max_events <= 2^8 = 256 (both checked at index build time; our scaled
# datasets are far below).  int32 keys are what the bitonic Pallas kernel
# sorts.
_Q_BITS = 8
T_BITS = 31 - _Q_BITS          # 23: t_pos field width (index.py guard)


class ChainResult(NamedTuple):
    t_start: jnp.ndarray     # () int32 — double-genome coords
    score: jnp.ndarray       # () f32
    score2: jnp.ndarray      # () f32 second-best (distinct location)
    mapped: jnp.ndarray      # () bool
    n_anchors: jnp.ndarray   # () int32 anchors entering the DP


# --------------------------------------------------------------------------- #
# Key packing / selection
# --------------------------------------------------------------------------- #
def pack_anchor_keys(q_pos: jnp.ndarray, t_pos: jnp.ndarray,
                     valid: jnp.ndarray) -> jnp.ndarray:
    """Flatten (E,H) anchors into (E*H,) packed sort keys [t:23 | q:8];
    invalid anchors become ``_INVALID_KEY`` (sorts last)."""
    t = t_pos.reshape(-1).astype(jnp.int32)
    q = jnp.minimum(q_pos.reshape(-1), (1 << _Q_BITS) - 1).astype(jnp.int32)
    v = valid.reshape(-1)
    key = (t << _Q_BITS) | q
    return jnp.where(v, key, _INVALID_KEY)


def decode_anchor_keys(skey: jnp.ndarray):
    """Inverse of ``pack_anchor_keys`` on a sorted key array: (sq, st, sv)."""
    sv = skey != _INVALID_KEY
    st = (skey >> _Q_BITS).astype(jnp.int32)
    sq = (skey & ((1 << _Q_BITS) - 1)).astype(jnp.int32)
    return sq, st, sv


def select_smallest_count(key: jnp.ndarray, width: int) -> jnp.ndarray:
    """The valid entries of ``key`` compacted to a (width,) array, padded
    with ``_INVALID_KEY``.

    Gather-based (cumsum + searchsorted): no scatter, so it vmaps into one
    batched gather.  EXACT equivalent of ``sort(key)[:width]`` as a multiset
    iff the number of valid keys is <= width — callers guarantee that with a
    batch-level ``n_anchors_postvote`` bound (core/pipeline.py) before
    taking this path.
    """
    valid = key != _INVALID_KEY
    cum = jnp.cumsum(valid.astype(jnp.int32))
    idx = jnp.searchsorted(cum, jnp.arange(1, width + 1, dtype=jnp.int32),
                           side="left")
    got = key[jnp.minimum(idx, key.shape[0] - 1)]
    return jnp.where(jnp.arange(width) < cum[-1], got, _INVALID_KEY)


def select_smallest_topk(key: jnp.ndarray, width: int) -> jnp.ndarray:
    """The ``width`` smallest keys, ascending, via ``lax.top_k`` on the
    negated keys.  Exact for ANY valid count (true smallest-k selection);
    on TPU top_k is a fast sampled-select, on CPU XLA lowers it to an
    O(n*k) pass — cfg.anchor_select picks the strategy."""
    neg = jax.lax.top_k(-key, width)[0]      # descending in -key
    return -neg                               # ascending in key


_SELECTORS = {
    "count": select_smallest_count,
    "topk": select_smallest_topk,
}


def sort_anchors(q_pos: jnp.ndarray, t_pos: jnp.ndarray, valid: jnp.ndarray,
                 cfg: MarsConfig, sorter=None, width: int = None):
    """Sort (E,H) anchors by (t_pos, q_pos) with invalids last and keep the
    first ``max_anchors``.  ``sorter(keys) -> sorted_keys`` is injectable
    (Pallas bitonic kernel); default jnp.sort.

    Packs (t_pos, q_pos) into an int32 key [t:23 | q:8] so the sort is a
    single-key sort (what the in-controller bitonic Sorter consumes).

    ``width=None`` sorts all E*H keys (the original full-sort behaviour).
    ``width=W`` is the select-then-sort fast path: the W smallest keys are
    selected first (strategy ``cfg.anchor_select``) and the sorter runs on
    the (W,) selection only — bit-identical to the full sort's first W slots
    provided the post-filter anchor count is <= W ("count" strategy) or
    unconditionally ("topk" strategy).
    """
    if sorter is None:
        sorter = jnp.sort
    key = pack_anchor_keys(q_pos, t_pos, valid)
    if width is None:
        skey = sorter(key)[: cfg.max_anchors]
    else:
        sel = _SELECTORS[cfg.anchor_select](key, width)
        skey = sorter(sel)
    return decode_anchor_keys(skey)


def sort_anchors_reference(q_pos, t_pos, valid, cfg: MarsConfig, sorter=None):
    """Pre-fast-path behaviour: always full-sort all E*H keys (parity oracle
    + "pre" side of the chaining microbenchmark)."""
    return sort_anchors(q_pos, t_pos, valid, cfg, sorter=sorter, width=None)


# --------------------------------------------------------------------------- #
# Banded DP
# --------------------------------------------------------------------------- #
def chain_dp(q: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray,
             cfg: MarsConfig):
    """Banded DP over sorted anchors — ring-buffer band window.

    q, t: (A,) int32 sorted by (t, q); valid: (A,) bool.
    Returns (f (A,) f32 chain scores, diag0 (A,) int32 start diag of the best
    chain ending at each anchor).

    The carried state is ONLY the B-slot band (f/diag/t/q of the last B
    anchors), held in a ring buffer: anchor i lives in slot i % B and each
    step overwrites exactly one fixed-position slot with a lane-mask select —
    no dynamic_slice gather of an (A+B,) array per step (which vmap turned
    into a whole-array gather/scatter per read in the old scan; see
    ``chain_dp_reference``).  Outputs stream out as scan ys.

    Bit-identical to ``chain_dp_reference``: the band holds the same values
    (only slot order differs — a rotation), the float expressions are
    verbatim the same, and argmax ties resolve to the OLDEST anchor in both
    (the reference window is age-ordered; here the explicit age rank
    ``k = (slot - i) mod B`` reproduces that tie-break).
    """
    A, B = q.shape[0], cfg.chain_band
    lane = jnp.arange(B)

    def step(carry, x):
        bf, bd, bt, bq = carry
        ti, qi, vi, i = x
        dt = ti - bt
        dq = qi - bq
        ok = (dt > 0) & (dq > 0) & (dt <= cfg.max_gap) & (dq <= cfg.max_gap)
        gap = jnp.abs(dt - dq).astype(jnp.float32)
        skip = jnp.minimum(dt, dq).astype(jnp.float32)
        cand = bf - cfg.gap_cost * gap - cfg.skip_cost * skip
        cand = jnp.where(ok & (bf > NEG / 2), cand, NEG)
        best = jnp.max(cand)
        # oldest-first tie-break: age rank k=0 is the oldest band slot
        k = (lane - i) % B
        kbest = jnp.min(jnp.where(cand == best, k, B))
        dbest = jnp.sum(jnp.where((cand == best) & (k == kbest), bd, 0))
        ext = best > 0.0
        fi = cfg.anchor_score + jnp.maximum(best, 0.0)
        fi = jnp.where(vi, fi, NEG)
        di = jnp.where(ext, dbest, ti - qi)
        wr = lane == i % B
        carry = (jnp.where(wr, fi, bf), jnp.where(wr, di, bd),
                 jnp.where(wr, ti, bt), jnp.where(wr, qi, bq))
        return carry, (fi, di)

    init = (jnp.full((B,), NEG, jnp.float32), jnp.zeros((B,), jnp.int32),
            jnp.full((B,), _SENT, jnp.int32), jnp.full((B,), _SENT, jnp.int32))
    _, (f, d) = jax.lax.scan(step, init, (t, q, valid, jnp.arange(A)))
    return f, d


def chain_dp_reference(q: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray,
                       cfg: MarsConfig):
    """Pre-fast-path DP: carries full (A+B,) f/diag arrays and dynamic-slices
    the band window each step.  Kept as the parity oracle for ``chain_dp``
    and the "pre" side of the chaining microbenchmark."""
    A, B = q.shape[0], cfg.chain_band
    # pad the carried state with B sentinel slots in front
    f0 = jnp.full(A + B, NEG, jnp.float32)
    d0 = jnp.zeros(A + B, jnp.int32)
    tp = jnp.concatenate([jnp.full(B, _SENT, jnp.int32), t])
    qp = jnp.concatenate([jnp.full(B, _SENT, jnp.int32), q])

    def step(carry, i):
        f, d = carry
        ti, qi, vi = t[i], q[i], valid[i]
        fw = jax.lax.dynamic_slice(f, (i,), (B,))
        dw = jax.lax.dynamic_slice(d, (i,), (B,))
        tw = jax.lax.dynamic_slice(tp, (i,), (B,))
        qw = jax.lax.dynamic_slice(qp, (i,), (B,))
        dt = ti - tw
        dq = qi - qw
        ok = (dt > 0) & (dq > 0) & (dt <= cfg.max_gap) & (dq <= cfg.max_gap)
        gap = jnp.abs(dt - dq).astype(jnp.float32)
        skip = jnp.minimum(dt, dq).astype(jnp.float32)
        cand = fw - cfg.gap_cost * gap - cfg.skip_cost * skip
        cand = jnp.where(ok & (fw > NEG / 2), cand, NEG)
        bj = jnp.argmax(cand)
        best = cand[bj]
        ext = best > 0.0
        fi = cfg.anchor_score + jnp.maximum(best, 0.0)
        fi = jnp.where(vi, fi, NEG)
        di = jnp.where(ext, dw[bj], ti - qi)
        f = jax.lax.dynamic_update_slice(f, fi[None], (i + B,))
        d = jax.lax.dynamic_update_slice(d, di[None], (i + B,))
        return (f, d), None

    (f, d), _ = jax.lax.scan(step, (f0, d0), jnp.arange(A))
    return f[B:], d[B:]


# --------------------------------------------------------------------------- #
# Finalize
# --------------------------------------------------------------------------- #
def best_chain(f: jnp.ndarray, diag0: jnp.ndarray, valid: jnp.ndarray,
               cfg: MarsConfig) -> ChainResult:
    """Best + second-best (distinct window) chain -> mapping decision."""
    fv = jnp.where(valid, f, NEG)
    i1 = jnp.argmax(fv)
    s1 = fv[i1]
    d1 = diag0[i1]
    far = jnp.abs(diag0 - d1) > cfg.voting_window
    fv2 = jnp.where(valid & far, f, NEG)
    s2 = jnp.maximum(jnp.max(fv2), 0.0)
    mapped = (s1 >= cfg.min_chain_score) & (s1 >= cfg.map_ratio * s2)
    t_start = jnp.maximum(d1, 0).astype(jnp.int32)
    return ChainResult(t_start=t_start, score=s1, score2=s2, mapped=mapped,
                       n_anchors=valid.sum().astype(jnp.int32))


def empty_chain_result(cfg: MarsConfig) -> ChainResult:
    """The EXACT ChainResult the full sort+dp+finalize pipeline produces for
    a read with zero valid anchors — in closed form.

    With no valid anchors every sorted slot holds ``_INVALID_KEY``; the DP
    gives every slot f = NEG (invalid) and diag = t - q of the decoded
    sentinel (its huge t fails the ``dt <= max_gap`` colinearity test against
    every predecessor, so no extension can fire).  best_chain then sees an
    all-NEG score vector: argmax lands on slot 0, the second-best window is
    empty, and the result is a constant independent of A.  The read-
    compaction gate (core/pipeline.py) uses this to finalize filtered-out
    reads without running the chaining phase.
    """
    st = int(_INVALID_KEY) >> _Q_BITS
    sq = (1 << _Q_BITS) - 1
    d = st - sq
    return ChainResult(
        t_start=jnp.int32(max(d, 0)),
        score=jnp.float32(NEG),
        score2=jnp.float32(0.0),
        mapped=jnp.asarray(False),
        n_anchors=jnp.int32(0),
    )


def chain_anchors(q_pos: jnp.ndarray, t_pos: jnp.ndarray, valid: jnp.ndarray,
                  cfg: MarsConfig, sorter=None, dp=None) -> (ChainResult, Dict):
    sq, st, sv = sort_anchors(q_pos, t_pos, valid, cfg, sorter=sorter)
    if dp is None:
        f, d0 = chain_dp(sq, st, sv, cfg)
    else:
        f, d0 = dp(sq, st, sv)
    res = best_chain(f, d0, sv, cfg)
    counters = dict(
        n_sorted=jnp.minimum(valid.sum(), cfg.max_anchors),
        n_dp_pairs=sv.sum() * cfg.chain_band,
    )
    return res, counters
