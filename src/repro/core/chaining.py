"""Chaining (paper Fig. 1, mapping step 3): anchor sort + banded DP.

Anchors are sorted by (t_pos, q_pos) — MARS does this on the in-controller
bitonic Sorter/Merger; the optimized pipeline path routes the sort through
the `bitonic_sort` Pallas kernel, the reference path uses jnp.sort.  The DP
is minimap2-style with a fixed look-back band B (MARS's Arithmetic Units are
word-serial, so RawHash2's bounded-predecessor heuristic maps directly).

    f[i] = w + max(0, max_{j in band, colinear} f[j] - beta*|dt - dq|
                                              - alpha*min(dt, dq))

The best chain's projected start (t_start - q_start) is the mapping position.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.config import MarsConfig

NEG = -1e9
_INVALID_KEY = jnp.int32(0x7FFFFFFF)
# packed sort key: [t_pos : 23 bits | q_pos : 8 bits] in a non-negative
# int32 — requires the double genome to have < 2^23 events and
# max_events <= 256 (checked at index build time; our scaled datasets are
# far below).  int32 keys are what the bitonic Pallas kernel sorts.
_Q_BITS = 8


class ChainResult(NamedTuple):
    t_start: jnp.ndarray     # () int32 — double-genome coords
    score: jnp.ndarray       # () f32
    score2: jnp.ndarray      # () f32 second-best (distinct location)
    mapped: jnp.ndarray      # () bool
    n_anchors: jnp.ndarray   # () int32 anchors entering the DP


def sort_anchors(q_pos: jnp.ndarray, t_pos: jnp.ndarray, valid: jnp.ndarray,
                 cfg: MarsConfig, sorter=None):
    """Flatten (E,H) anchors, sort by (t_pos, q_pos) with invalids last, and
    keep the first `max_anchors`.  `sorter(keys) -> sorted_keys` is injectable
    (Pallas bitonic kernel); default jnp.sort.

    Packs (t_pos, q_pos) into a uint32 key [t:24 | q:8] so the sort is a
    single-key sort (what the in-controller bitonic Sorter consumes).
    """
    if sorter is None:
        sorter = jnp.sort
    t = t_pos.reshape(-1).astype(jnp.int32)
    q = jnp.minimum(q_pos.reshape(-1), (1 << _Q_BITS) - 1).astype(jnp.int32)
    v = valid.reshape(-1)
    key = (t << _Q_BITS) | q
    key = jnp.where(v, key, _INVALID_KEY)
    skey = sorter(key)[: cfg.max_anchors]
    sv = skey != _INVALID_KEY
    st = (skey >> _Q_BITS).astype(jnp.int32)
    sq = (skey & ((1 << _Q_BITS) - 1)).astype(jnp.int32)
    return sq, st, sv


def chain_dp(q: jnp.ndarray, t: jnp.ndarray, valid: jnp.ndarray,
             cfg: MarsConfig):
    """Banded DP over sorted anchors.

    q, t: (A,) int32 sorted by (t, q); valid: (A,) bool.
    Returns (f (A,) f32 chain scores, diag0 (A,) int32 start diag of the best
    chain ending at each anchor).
    """
    A, B = q.shape[0], cfg.chain_band
    # pad the carried state with B sentinel slots in front
    f0 = jnp.full(A + B, NEG, jnp.float32)
    d0 = jnp.zeros(A + B, jnp.int32)
    tp = jnp.concatenate([jnp.full(B, -(1 << 30), jnp.int32), t])
    qp = jnp.concatenate([jnp.full(B, -(1 << 30), jnp.int32), q])

    def step(carry, i):
        f, d = carry
        ti, qi, vi = t[i], q[i], valid[i]
        fw = jax.lax.dynamic_slice(f, (i,), (B,))
        dw = jax.lax.dynamic_slice(d, (i,), (B,))
        tw = jax.lax.dynamic_slice(tp, (i,), (B,))
        qw = jax.lax.dynamic_slice(qp, (i,), (B,))
        dt = ti - tw
        dq = qi - qw
        ok = (dt > 0) & (dq > 0) & (dt <= cfg.max_gap) & (dq <= cfg.max_gap)
        gap = jnp.abs(dt - dq).astype(jnp.float32)
        skip = jnp.minimum(dt, dq).astype(jnp.float32)
        cand = fw - cfg.gap_cost * gap - cfg.skip_cost * skip
        cand = jnp.where(ok & (fw > NEG / 2), cand, NEG)
        bj = jnp.argmax(cand)
        best = cand[bj]
        ext = best > 0.0
        fi = cfg.anchor_score + jnp.maximum(best, 0.0)
        fi = jnp.where(vi, fi, NEG)
        di = jnp.where(ext, dw[bj], ti - qi)
        f = jax.lax.dynamic_update_slice(f, fi[None], (i + B,))
        d = jax.lax.dynamic_update_slice(d, di[None], (i + B,))
        return (f, d), None

    (f, d), _ = jax.lax.scan(step, (f0, d0), jnp.arange(A))
    return f[B:], d[B:]


def best_chain(f: jnp.ndarray, diag0: jnp.ndarray, valid: jnp.ndarray,
               cfg: MarsConfig) -> ChainResult:
    """Best + second-best (distinct window) chain -> mapping decision."""
    fv = jnp.where(valid, f, NEG)
    i1 = jnp.argmax(fv)
    s1 = fv[i1]
    d1 = diag0[i1]
    far = jnp.abs(diag0 - d1) > cfg.voting_window
    fv2 = jnp.where(valid & far, f, NEG)
    s2 = jnp.maximum(jnp.max(fv2), 0.0)
    mapped = (s1 >= cfg.min_chain_score) & (s1 >= cfg.map_ratio * s2)
    t_start = jnp.maximum(d1, 0).astype(jnp.int32)
    return ChainResult(t_start=t_start, score=s1, score2=s2, mapped=mapped,
                       n_anchors=valid.sum().astype(jnp.int32))


def chain_anchors(q_pos: jnp.ndarray, t_pos: jnp.ndarray, valid: jnp.ndarray,
                  cfg: MarsConfig, sorter=None, dp=None) -> (ChainResult, Dict):
    sq, st, sv = sort_anchors(q_pos, t_pos, valid, cfg, sorter=sorter)
    if dp is None:
        f, d0 = chain_dp(sq, st, sv, cfg)
    else:
        f, d0 = dp(sq, st, sv)
    res = best_chain(f, d0, sv, cfg)
    counters = dict(
        n_sorted=jnp.minimum(valid.sum(), cfg.max_anchors),
        n_dp_pairs=sv.sum() * cfg.chain_band,
    )
    return res, counters
