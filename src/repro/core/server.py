"""Continuous-batching serving driver: many concurrent read streams, one
chunk pipeline.

MARS's headline claim is throughput at sequencer line rate: the
orchestrator overlaps flash loads with compute so the storage system
serves many concurrent read streams, not one batch job (Sections
6.3-6.4).  ``ServeDriver`` is the host-side serving analogue over the
existing stage engine:

  * **Admission** — clients ``submit`` reads tagged with a stream id,
    priority and (virtual-time) deadline into ONE bounded ready queue.
    When the queue is full, admission is priority-aware: a new read
    evicts the worst-ranked queued read only if it outranks it,
    otherwise it is rejected — bounded memory and graceful degradation
    under overload instead of unbounded growth.
  * **Packing** — each scheduling round takes the best-ranked ready
    reads (priority desc, deadline asc, arrival order) that share a
    ladder stage and packs them into the fixed-size padded chunks
    ``map_chunk`` already consumes: ``driver.pad_rows`` + the traced
    ``n_valid`` mask keep the counters exact, so chunk composition is
    invisible to per-read results AND to counter totals.
  * **One loop** — chunks are driven through the unified double-buffered
    ``driver.stream_map`` loop (the same loop Mapper / realtime / the
    launcher use), so host packing overlaps device compute exactly as in
    batch mapping.  The chunk source is a generator over the live ready
    queue: results routed from chunk i re-enter the queue in time to be
    packed while chunk i+1 is still on the device.
  * **Routing** — every chunk remembers which (stream, read) occupies
    each row; results are trimmed to ``n_valid`` and scattered back to
    their owning stream in submission order.
  * **Early termination** — with ``early_term=True`` reads climb the
    realtime.py prefix ladder (``realtime.stage_cfg``): a read that maps
    confidently at a short prefix frees its slot immediately (the Read
    Until path), unresolved reads re-enter the queue at the next prefix
    length.  Decision thresholds are bit-identical to
    ``realtime.map_realtime``, so per-read serving results equal the
    batch realtime results for ANY interleaving.

Bit-parity is structural: each read's program depends only on its own
signal (chunk-mates only pick between branches that are bit-identical
per read — compaction gate, width ladder), so ServeDriver output equals
``Mapper.map_signals`` on the same reads (early_term off) or
``realtime.map_realtime`` (early_term on), for every admission order,
including under ``map_chunk_sharded`` and the ``query:ring`` /
``query:a2a`` partitioned-index backends (tests/test_server.py,
tests/test_distributed_serve.py).

Time: the driver keeps a *virtual clock* (arbitrary units) used for
arrival traces, deadlines and per-read latency accounting — every
dispatched chunk advances it by ``chunk_cost`` scaled by the prefix
fraction, and virtual time the tiered storage path loses to page-in
retry/backoff (``HotTileCache.vtime_penalty``) is folded in as it
accrues.  Wall-clock throughput is measured separately by the caller
(benchmarks/microbench.py, launch/serve_rsga.py).

Overload (the closed loop): with ``shed=True`` the driver feeds its
overload evidence into the configured ``CostModel``
(``core/costmodel.py``, ``cost_model="analytic"`` by default) through
``shed_signal``: the trailing offered load (the queueing model's
no-steady-state check) AND the *measured* per-read queue delays at
dispatch — the second term trips on effective-capacity loss the offered
load cannot see, e.g. storage-path retry/backoff stretching the virtual
clock.  While the signal holds, the driver sheds the least-worthy
sheddable read (lowest priority, then latest deadline, then newest) per
admission and — with ``early_term`` — packs the SHORTEST prefix stage
first so slots free as early as possible.  ``SLOClass`` tags reads with
per-class priority / relative-deadline defaults and a shed exemption;
``class_report()`` aggregates latency percentiles per class.

Fairness (multi-tenant): streams are bound to *tenants*
(``submit(..., tenant=...)``) and ``TenantBudget`` gives each tenant a
fair-share token bucket over the virtual clock.  Budgets never
hard-reject — every read is admitted if a slot exists — but the shed
loop and the full-queue eviction pick OUT-OF-BUDGET reads first, so a
flooding tenant's overflow is charged to the flooder (its own newest
reads shed at their own admission) and a within-budget tenant's
admitted set, results and latency trace are untouched by a co-tenant's
flood (tests/test_tenants.py asserts the isolation exactly).
``tenant_report()`` is the audit trail: per-tenant sheds, over-budget
admissions and latency percentiles.  With no budgets configured the
driver is bit-identical to the tenant-free one.

Trace: the driver records a replayable chunk-event trace on its virtual
clock (``self.events``): ``("arrival", t, stream, n)`` at submission,
``("dispatch", t, ci, stage, n_valid, stage_frac)`` when a chunk is
packed, ``("complete", t, ci, n_valid)`` when it routes.  The trace is
the input format of the serving simulator
(``core/sim/serve_sim.replay_chunk_trace``); recording is pure
observation — outputs are byte-identical with or without consumers.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel, driver, ssd_model


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One serving class.  ``priority`` / ``deadline`` are admission
    defaults (``deadline`` is RELATIVE: virtual-time budget from arrival);
    ``sheddable=False`` exempts the class from closed-loop load shedding
    (it can still be rejected by the hard ``max_queue`` bound)."""
    name: str
    priority: int = 0
    deadline: float = math.inf
    sheddable: bool = True

    def __post_init__(self):
        if not self.name:
            raise ValueError("SLO class needs a non-empty name")
        if self.deadline <= 0:
            raise ValueError(f"SLO deadline must be a positive relative "
                             f"budget; got {self.deadline}")


@dataclasses.dataclass(frozen=True)
class TenantBudget:
    """Per-tenant fair-share admission budget: a token bucket over the
    serving driver's VIRTUAL clock.  ``rate`` is the tenant's fair share
    (reads per virtual-time unit refilled into the bucket); ``burst`` is
    the bucket capacity (defaults to ``rate * shed_window`` at driver
    construction, floored at 1 token).  Every admitted read charges one
    token; a read arriving on an empty bucket is still ADMITTED but
    stamped out-of-budget — the budget never hard-rejects on its own, it
    only steers who the closed-loop shed / full-queue eviction picks
    first.  That makes budgets observation-only until overload: with
    ``shed=False`` and a non-full queue, tenant accounting changes no
    behavior at all."""
    name: str
    rate: float
    burst: Optional[float] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant budget needs a non-empty tenant name")
        if self.rate < 0:
            raise ValueError(f"tenant budget rate must be >= 0 reads per "
                             f"virtual-time unit; got {self.rate}")
        if self.burst is not None and self.burst <= 0:
            raise ValueError(f"tenant budget burst must be > 0 tokens; "
                             f"got {self.burst}")


@dataclasses.dataclass
class _Slot:
    """One admitted read waiting for (or climbing) the stage ladder."""
    stream: str
    idx: int                  # read index within its stream
    signal: np.ndarray        # full-length (S,) f32
    t_arrive: float           # virtual admission time
    priority: int
    deadline: float
    seq: int                  # global admission order (fairness tie-break)
    stage: int = 0            # current prefix-ladder stage
    slo: Optional[str] = None # SLO class name (None = untagged)
    sheddable: bool = True
    tenant: Optional[str] = None  # owning tenant (None = untenanted)
    in_budget: bool = True    # bucket had a token at admission

    def rank(self) -> Tuple:
        """Scheduling rank: smaller is served first."""
        return (-self.priority, self.deadline, self.seq)

    def shed_rank(self) -> Tuple:
        """Shedding rank: SMALLER is shed first — lowest priority, then
        latest deadline, then newest admission."""
        return (self.priority, -self.deadline, -self.seq)


@dataclasses.dataclass
class StreamState:
    """Per-stream result buffers, filled in submission order."""
    t_start: List[int] = dataclasses.field(default_factory=list)
    score: List[float] = dataclasses.field(default_factory=list)
    mapped: List[bool] = dataclasses.field(default_factory=list)
    n_events: List[int] = dataclasses.field(default_factory=list)
    samples_used: List[int] = dataclasses.field(default_factory=list)
    stage_of: List[int] = dataclasses.field(default_factory=list)
    latency: List[float] = dataclasses.field(default_factory=list)
    admitted: List[bool] = dataclasses.field(default_factory=list)
    slo_of: List[Optional[str]] = dataclasses.field(default_factory=list)
    n_rejected: int = 0
    n_done: int = 0
    n_shed: int = 0           # closed-loop shed (subset of n_rejected)
    n_nonfinite: int = 0      # NaN/Inf rows refused at admission (ditto)
    tenant: Optional[str] = None  # owning tenant (bound at first submit)

    def _new_read(self) -> int:
        self.t_start.append(0)
        self.score.append(0.0)
        self.mapped.append(False)
        self.n_events.append(0)
        self.samples_used.append(0)
        self.stage_of.append(-1)
        self.latency.append(math.inf)
        self.admitted.append(True)
        self.slo_of.append(None)
        return len(self.t_start) - 1


@dataclasses.dataclass
class StreamReport:
    """Per-stream serving summary (virtual-time latencies)."""
    n_reads: int
    n_mapped: int
    n_rejected: int
    p50_latency: float
    p99_latency: float
    mean_latency: float
    n_shed: int = 0
    n_nonfinite: int = 0


@dataclasses.dataclass
class ClassReport:
    """Per-SLO-class serving summary, aggregated across streams
    (``name=None`` collects untagged reads)."""
    name: Optional[str]
    n_reads: int
    n_mapped: int
    n_rejected: int
    n_shed: int
    p50_latency: float
    p99_latency: float
    mean_latency: float


@dataclasses.dataclass
class TenantReport:
    """Per-tenant serving summary, aggregated across the tenant's streams
    (``name=None`` collects untenanted streams).  ``n_shed`` counts
    closed-loop sheds charged to the tenant; ``n_over_budget`` counts
    admissions that found the tenant's token bucket empty (a leading
    indicator of who is flooding, whether or not shedding is on)."""
    name: Optional[str]
    n_reads: int
    n_mapped: int
    n_rejected: int
    n_shed: int
    n_over_budget: int
    p50_latency: float
    p99_latency: float
    mean_latency: float


class ServeDriver:
    """Continuous-batching serving front-end over one chunk pipeline.

    ``mapper`` is any object exposing ``cfg`` and ``chunk_fn()`` — a
    ``pipeline.Mapper`` (any registry backend, optionally with a mesh:
    sharded and partitioned-index plans serve identically) or a
    lightweight stand-in (benchmarks).  With ``early_term=True`` it must
    also expose ``with_cfg`` (Mapper does) so the prefix-ladder
    specializations share the resident index.

    Parameters
    ----------
    chunk:        static rows per device chunk (with a mesh: must divide
                  over its devices, as in Mapper.map_signals).
    max_queue:    bound on outstanding reads (queued + in flight).
                  Admission beyond it is priority-aware (evict a
                  strictly-worse queued read, else reject) — the
                  backpressure contract.  Ladder re-entry (early_term)
                  never grows past the bound: an unresolved read moves
                  from in-flight back to queued.
    early_term:   run the realtime.py prefix ladder; reads resolving at a
                  short prefix free their slot early.
    prefix_stages: ladder of prefix lengths (last must equal
                  cfg.signal_len). Defaults to realtime's quarters.
    min_score:    early-decision score threshold (non-final stages).
    chunk_cost:   virtual-time cost of a full-length chunk dispatch;
                  stage chunks cost chunk_cost * L / signal_len.
    drop_expired: drop queued reads whose deadline passed at packing
                  time (recorded as rejected; off by default so parity
                  holds for any deadline assignment).
    slo_classes:  ``SLOClass`` definitions reads can be submitted under
                  (per-class priority/deadline defaults + shed exemption
                  + ``class_report()`` accounting).
    shed:         close the loop: while the configured cost model's
                  ``shed_signal`` (trailing offered load + measured
                  queue delays) reports overload, shed the least-worthy
                  sheddable read per admission and (with early_term)
                  pack shortest-prefix chunks first.  Off by default —
                  a shed-free driver is bit-identical to the pre-shed
                  ServeDriver.
    shed_window:  trailing virtual-time window the offered load is
                  measured over.
    cost_model:   the ``core/costmodel.py`` backend the shed controller
                  consults ("analytic" / "sim", or a CostModel
                  instance).
    shed_delay_limit: measured-delay trip point, in chunk services: the
                  signal also fires when the recent mean per-read queue
                  delay at dispatch exceeds this many ``chunk_cost``
                  units (catching capacity loss offered load misses).
    tenant_budgets: ``TenantBudget`` fair-share definitions.  Streams are
                  bound to a tenant at ``submit(..., tenant=...)``; every
                  admitted read charges one token from its tenant's
                  bucket (refilled at ``rate`` over the virtual clock, up
                  to ``burst``).  Budgets never hard-reject: they steer
                  victim selection — the closed-loop shed and the
                  full-queue eviction pick OUT-OF-BUDGET reads first, so
                  a flooding tenant's overflow is charged to the flooder
                  and a within-budget tenant's traffic is isolated.  With
                  no budgets configured (the default) tenant tags are
                  observation-only and the driver is bit-identical to the
                  tenant-free one.
    """

    def __init__(self, mapper, chunk: int = 64, max_queue: int = 4096,
                 early_term: bool = False,
                 prefix_stages: Optional[Sequence[int]] = None,
                 min_score: float = 8.0, chunk_cost: float = 1.0,
                 drop_expired: bool = False,
                 slo_classes: Optional[Sequence[SLOClass]] = None,
                 shed: bool = False, shed_window: float = 8.0,
                 cost_model="analytic",
                 shed_delay_limit: float = costmodel.SHED_DELAY_LIMIT,
                 tenant_budgets: Optional[Sequence[TenantBudget]] = None):
        self.mapper = mapper
        self.cfg = mapper.cfg
        self.chunk = int(chunk)
        self.max_queue = int(max_queue)
        self.early_term = bool(early_term)
        self.min_score = float(min_score)
        self.chunk_cost = float(chunk_cost)
        self.drop_expired = bool(drop_expired)
        self.slo_classes: Dict[str, SLOClass] = {
            c.name: c for c in (slo_classes or ())}
        self.shed = bool(shed)
        if shed_window <= 0:
            raise ValueError(f"shed_window must be > 0 virtual time units; "
                             f"got {shed_window}")
        self.shed_window = float(shed_window)
        self.cost_model = costmodel.get_model(cost_model)
        if shed_delay_limit <= 0:
            raise ValueError(f"shed_delay_limit must be > 0 chunk services; "
                             f"got {shed_delay_limit}")
        self.shed_delay_limit = float(shed_delay_limit)
        self.tenant_budgets: Dict[str, TenantBudget] = {
            b.name: b for b in (tenant_budgets or ())}
        # bucket capacity: explicit burst, else one shed_window's worth of
        # the tenant's fair-share rate (>= 1 token so a within-rate tenant
        # can always admit)
        self._tenant_burst: Dict[str, float] = {
            name: (b.burst if b.burst is not None
                   else max(1.0, b.rate * self.shed_window))
            for name, b in self.tenant_budgets.items()}
        # name -> [tokens, last refill virtual time]; buckets start full
        self._tenant_tokens: Dict[str, List[float]] = {
            name: [self._tenant_burst[name], 0.0]
            for name in self.tenant_budgets}
        self._shed_by_tenant: Dict[Optional[str], int] = {}
        self._over_budget: Dict[Optional[str], int] = {}
        # virtual time the tiered storage path loses to page-in
        # retry/backoff is folded into the serving clock as it accrues
        # (zero on the happy path -> parity intact)
        self._cache = getattr(mapper, "cache", None)
        self._vtime_seen = float(getattr(self._cache, "vtime_penalty", 0.0)
                                 or 0.0)

        S = self.cfg.signal_len
        if early_term:
            if prefix_stages is None:
                prefix_stages = tuple(S * k // 4 for k in range(1, 5))
            self.stages = tuple(int(L) for L in prefix_stages)
            if self.stages[-1] != S:
                raise ValueError(f"prefix_stages must end at signal_len="
                                 f"{S}; got {self.stages}")
            from repro.core.realtime import stage_cfg
            self._stage_fns = [mapper.with_cfg(stage_cfg(self.cfg, L)
                                               ).chunk_fn()
                               for L in self.stages]
            self._stage_thresh = [
                (stage_cfg(self.cfg, L).min_chain_score
                 if si == len(self.stages) - 1 else self.min_score)
                for si, L in enumerate(self.stages)]
        else:
            self.stages = (S,)
            self._stage_fns = [mapper.chunk_fn()]
            self._stage_thresh = [self.cfg.min_chain_score]

        self.clock = 0.0
        self.counters: Dict[str, int] = {}
        self.n_chunks = 0
        self.n_pad_rows = 0
        self.n_shed = 0
        self._queue: List[_Slot] = []
        self._streams: Dict[str, StreamState] = {}
        self._arrivals: collections.deque = collections.deque()
        # ci -> (ladder stage, row slots, virtual completion time)
        self._inflight: Dict[int, Tuple[int, List[_Slot], float]] = {}
        self._stage_fifo: collections.deque = collections.deque()
        self._seq = 0
        self._admit_times: collections.deque = collections.deque()
        self._shed_by_class: Dict[Optional[str], int] = {}
        # the replayable chunk-event trace (arrival/dispatch/complete in
        # virtual time) — the serving simulator's input format
        self.events: List[Tuple] = []
        # measured per-read queue delays at dispatch, trailing window —
        # the shed controller's second (capacity-loss) overload signal
        self._queue_delays: collections.deque = collections.deque(maxlen=64)

    # ------------------------------------------------------------------ #
    # Admission (bounded queue, priority-aware backpressure)
    # ------------------------------------------------------------------ #
    def stream(self, stream_id: str) -> StreamState:
        return self._streams.setdefault(stream_id, StreamState())

    def _bucket_refill(self, tenant: str, t: float) -> List[float]:
        """Refill a tenant's token bucket up to virtual time ``t``."""
        b = self.tenant_budgets[tenant]
        s = self._tenant_tokens[tenant]
        s[0] = min(self._tenant_burst[tenant],
                   s[0] + b.rate * max(0.0, t - s[1]))
        s[1] = max(s[1], t)
        return s

    def _charge_tenant(self, tenant: Optional[str], t: float) -> bool:
        """Charge one admission token.  True = the read is in budget.
        Tenants without a configured budget (and untenanted reads) are
        always in budget — the legacy behavior."""
        if tenant is None or tenant not in self.tenant_budgets:
            return True
        s = self._bucket_refill(tenant, t)
        if s[0] >= 1.0:
            s[0] -= 1.0
            return True
        self._over_budget[tenant] = self._over_budget.get(tenant, 0) + 1
        return False

    def _tenant_over(self, tenant: Optional[str]) -> bool:
        """Live (no-charge) check: is the tenant's bucket empty NOW?"""
        if tenant is None or tenant not in self.tenant_budgets:
            return False
        return self._bucket_refill(tenant, self.clock)[0] < 1.0

    def tenant_tokens(self, tenant: str) -> float:
        """The tenant's remaining budget tokens at the current clock."""
        return self._bucket_refill(tenant, self.clock)[0]

    def submit(self, stream_id: str, signals: np.ndarray,
               priority: Optional[int] = None,
               deadline: Optional[float] = None,
               t: Optional[float] = None,
               slo: Optional[str] = None,
               tenant: Optional[str] = None) -> int:
        """Admit a batch of reads for ``stream_id``.  Returns the number
        admitted; the rest were rejected (or evicted a worse read whose
        stream records the rejection).  ``t`` stamps the virtual arrival
        time (defaults to the current clock; never rewinds it).

        ``slo`` names a registered ``SLOClass`` supplying priority /
        deadline defaults (its deadline is a RELATIVE budget from ``t``)
        and the shed exemption; explicit ``priority`` / ``deadline``
        override the class.  ``tenant`` binds the stream to a tenant (a
        stream keeps its first-bound tenant; re-binding to a different
        one is an error) and, when a ``TenantBudget`` is configured for
        it, charges one token per read from the tenant's bucket —
        out-of-budget reads are still admitted but are first in line for
        the closed-loop shed and the full-queue eviction (fair-share
        isolation; see ``tenant_budgets`` in the class docstring).  Rows
        containing NaN/Inf are refused at admission (counted per stream
        as ``n_nonfinite``, recorded as rejected) — they would otherwise
        poison every chunk-mate's counters inside ``map_chunk``."""
        signals = np.asarray(signals, np.float32)
        if signals.ndim == 1:
            signals = signals[None]
        if signals.shape[1] != self.cfg.signal_len:
            raise ValueError(f"signals must be (n, {self.cfg.signal_len}); "
                             f"got {signals.shape}")
        cls = None
        if slo is not None:
            cls = self.slo_classes.get(slo)
            if cls is None:
                raise ValueError(f"unknown SLO class {slo!r}; registered: "
                                 f"{sorted(self.slo_classes)}")
        t = self.clock if t is None else float(t)
        self.clock = max(self.clock, t)
        self.events.append(("arrival", t, stream_id, int(signals.shape[0])))
        prio = int(priority) if priority is not None else (
            cls.priority if cls else 0)
        dl = float(deadline) if deadline is not None else (
            t + cls.deadline if cls else math.inf)
        st = self.stream(stream_id)
        if tenant is not None:
            if st.tenant is not None and st.tenant != tenant:
                raise ValueError(
                    f"stream {stream_id!r} already belongs to tenant "
                    f"{st.tenant!r}; cannot re-bind it to {tenant!r}")
            st.tenant = tenant
        tenant = st.tenant
        finite = np.isfinite(signals).all(axis=1)
        admitted = 0
        for row, ok in zip(signals, finite):
            idx = st._new_read()
            st.slo_of[idx] = slo
            if not ok:
                st.n_nonfinite += 1
                st.admitted[idx] = False
                st.n_rejected += 1
                st.n_done += 1
                continue
            self._admit_times.append(t)
            slot = _Slot(stream=stream_id, idx=idx, signal=row, t_arrive=t,
                         priority=prio, deadline=dl, seq=self._seq, slo=slo,
                         sheddable=cls.sheddable if cls else True,
                         tenant=tenant,
                         in_budget=self._charge_tenant(tenant, self.clock))
            self._seq += 1
            if self._admit(slot):
                admitted += 1
        return admitted

    def _outstanding(self) -> int:
        """Reads holding a slot: queued + in flight.  The max_queue bound
        applies to this total, so ladder re-entry of an in-flight read
        (early_term) moves it back to the queue without ever growing past
        the bound."""
        return len(self._queue) + sum(len(slots) for _, slots, _t
                                      in self._inflight.values())

    def _saturated(self) -> bool:
        """The closed loop's overload signal, via the cost model's
        ``shed_signal``: trailing offered load (reads per virtual time
        over ``shed_window``, the queueing model's no-steady-state check)
        OR the measured recent per-read queue delays at dispatch tripping
        ``shed_delay_limit`` chunk services — the latter catches
        effective-capacity loss (storage retry/backoff stretching the
        clock) that offered load alone cannot see."""
        horizon = self.clock - self.shed_window
        while self._admit_times and self._admit_times[0] < horizon:
            self._admit_times.popleft()
        if not self._admit_times and not self._queue_delays:
            return False
        load = len(self._admit_times) / self.shed_window
        return bool(self.cost_model.shed_signal(
            self.chunk, self.chunk_cost, load,
            tuple(self._queue_delays),
            delay_limit=self.shed_delay_limit))

    def _admit(self, slot: _Slot) -> bool:
        if self.shed and self._saturated():
            # shed the least-worthy sheddable read: OUT-OF-BUDGET tenants
            # first (the fair-share rule — with no budgets configured
            # every read is in budget and the key degenerates to the
            # legacy shed_rank), then lowest priority, then latest
            # deadline, then newest — the new read itself when it is the
            # least worthy.  SLO shed exemption always wins: an
            # unsheddable read is never a candidate, budget or not.
            cands = [s for s in self._queue if s.sheddable]
            if slot.sheddable:
                cands.append(slot)
            if not slot.in_budget:
                # an over-budget arrival may only displace its own
                # tenant's traffic: the overload it causes is charged to
                # it, never to a within-budget co-tenant (if the tenant
                # has nothing sheddable queued, nothing is shed)
                cands = [s for s in cands if s.tenant == slot.tenant]
            if cands:
                victim = min(cands, key=lambda s: (s.in_budget,
                                                   s.shed_rank()))
                if victim is slot:
                    self._shed(slot)
                    return False
                self._queue.remove(victim)
                self._shed(victim)
        if self._outstanding() < self.max_queue:
            self._queue.append(slot)
            return True
        if self.tenant_budgets and slot.in_budget:
            # full queue, in-budget arrival: a tenant over its fair share
            # RIGHT NOW cannot hold slots against a within-budget tenant
            # — evict the least-worthy such read (charged as a shed to
            # its own tenant), never an unsheddable one
            over = [s for s in self._queue if s.sheddable
                    and (not s.in_budget or self._tenant_over(s.tenant))]
            if over:
                victim = min(over, key=lambda s: (s.in_budget,
                                                  s.shed_rank()))
                self._queue.remove(victim)
                self._shed(victim)
                self._queue.append(slot)
                return True
        if self._queue:
            worst = max(self._queue, key=lambda s: s.rank())
            if slot.rank() < worst.rank():
                self._queue.remove(worst)
                self._reject(worst)
                self._queue.append(slot)
                return True
        self._reject(slot)
        return False

    def _shed(self, slot: _Slot) -> None:
        self.n_shed += 1
        self._streams[slot.stream].n_shed += 1
        self._shed_by_class[slot.slo] = \
            self._shed_by_class.get(slot.slo, 0) + 1
        self._shed_by_tenant[slot.tenant] = \
            self._shed_by_tenant.get(slot.tenant, 0) + 1
        self._reject(slot)

    def _reject(self, slot: _Slot) -> None:
        st = self._streams[slot.stream]
        st.admitted[slot.idx] = False
        st.n_rejected += 1
        st.n_done += 1

    # ------------------------------------------------------------------ #
    # Packing + the ONE double-buffered loop
    # ------------------------------------------------------------------ #
    def _admit_due(self) -> None:
        while self._arrivals and self._arrivals[0][0] <= self.clock:
            t, stream_id, signals, priority, deadline, slo, tenant = \
                self._arrivals.popleft()
            self.submit(stream_id, signals, priority=priority,
                        deadline=deadline, t=t, slo=slo, tenant=tenant)

    def _next_chunk(self) -> Optional[driver.Chunk]:
        self._admit_due()
        if self.drop_expired:
            expired = [s for s in self._queue if s.deadline < self.clock]
            for s in expired:
                self._queue.remove(s)
                self._reject(s)
        if not self._queue:
            return None
        self._queue.sort(key=_Slot.rank)
        stage = self._queue[0].stage
        if (self.shed and self.early_term and len(self.stages) > 1
                and self._saturated()):
            # early-term-first degradation: under overload pack the
            # SHORTEST prefix stage present — the cheapest chunk, with the
            # best odds of resolving reads early and freeing slots
            stage = min(s.stage for s in self._queue)
        take, rest = [], []
        for s in self._queue:
            (take if (s.stage == stage and len(take) < self.chunk)
             else rest).append(s)
        self._queue = rest
        L = self.stages[stage]
        part = np.stack([s.signal[:L] for s in take])
        ci = self.n_chunks
        self.n_chunks += 1
        self.n_pad_rows += self.chunk - len(take)
        # measured queue delay: how long each packed read waited between
        # admission and this dispatch (pre-advance clock) — the shed
        # controller's capacity-loss evidence
        for s in take:
            self._queue_delays.append(self.clock - s.t_arrive)
        self.events.append(("dispatch", self.clock, ci, stage, len(take),
                            L / self.stages[-1]))
        self.clock += self.chunk_cost * L / self.stages[-1]
        # completion time is fixed at dispatch: stream_map's double buffer
        # routes chunk i only after pulling chunk i+1, so reading the live
        # clock at routing time would overcharge every chunk but the last
        self._inflight[ci] = (stage, take, self.clock)
        self._stage_fifo.append(stage)
        return ci, len(take), driver.pad_rows(part, self.chunk)

    def _chunk_source(self) -> Iterable[driver.Chunk]:
        while True:
            c = self._next_chunk()
            if c is None:
                return
            yield c

    def _map_fn(self, signals, n_valid):
        # stream_map dispatches each chunk right after pulling it from the
        # source, so the FIFO of stage ids pushed by _next_chunk is in
        # dispatch order.
        out = self._stage_fns[self._stage_fifo.popleft()](signals, n_valid)
        if self._cache is not None:
            # charge storage-path retry/backoff virtual time (accrued
            # paging this chunk's tiles) to the serving clock; zero on the
            # happy path
            pen = float(self._cache.vtime_penalty)
            if pen > self._vtime_seen:
                self.clock += pen - self._vtime_seen
                self._vtime_seen = pen
        return out

    def _route(self, ci: int, n_valid: int, out) -> None:
        stage, slots, done_t = self._inflight.pop(ci)
        assert n_valid == len(slots), (ci, n_valid, len(slots))
        self.events.append(("complete", done_t, ci, n_valid))
        for k, v in out.counters.items():
            self.counters[k] = self.counters.get(k, 0) + int(v)
        last = stage == len(self.stages) - 1
        thresh = self._stage_thresh[stage]
        L = self.stages[stage]
        t = np.asarray(out.t_start)
        s = np.asarray(out.score)
        m = np.asarray(out.mapped)
        ne = np.asarray(out.n_events)
        for i, slot in enumerate(slots):
            st = self._streams[slot.stream]
            if not self.early_term:
                # batch semantics: record the full chunk outputs verbatim
                # (bit-parity with Mapper.map_signals, mapped or not)
                st.t_start[slot.idx] = int(t[i])
                st.score[slot.idx] = float(s[i])
                st.mapped[slot.idx] = bool(m[i])
                st.n_events[slot.idx] = int(ne[i])
                st.samples_used[slot.idx] = L
                st.stage_of[slot.idx] = stage
                st.latency[slot.idx] = done_t - slot.t_arrive
                st.n_done += 1
                continue
            # realtime.map_realtime decision rule, bit for bit
            decide = (bool(m[i]) and float(s[i]) >= thresh) if not last \
                else bool(m[i])
            if decide:
                st.t_start[slot.idx] = int(t[i])
                st.score[slot.idx] = float(s[i])
                st.mapped[slot.idx] = True
                st.n_events[slot.idx] = int(ne[i])
                st.samples_used[slot.idx] = L
                st.stage_of[slot.idx] = stage
                st.latency[slot.idx] = done_t - slot.t_arrive
                st.n_done += 1
            elif last:
                # unresolved at full length: zeros, like map_realtime
                st.samples_used[slot.idx] = L
                st.stage_of[slot.idx] = -1
                st.latency[slot.idx] = done_t - slot.t_arrive
                st.n_done += 1
            else:
                slot.stage = stage + 1
                self._queue.append(slot)   # keeps seq -> no starvation

    # ------------------------------------------------------------------ #
    # Draining
    # ------------------------------------------------------------------ #
    def _pending(self) -> bool:
        return bool(self._queue or self._inflight or self._arrivals)

    def drain(self) -> None:
        """Serve until every admitted read (and queued arrival) resolves.

        One ``driver.stream_map`` invocation runs as long as the ready
        queue can keep the double buffer full; reads advancing the ladder
        out of an in-flight chunk re-enter in time for the next pull.
        The loop restarts only when the queue momentarily drains with
        work still in flight (a wave boundary)."""
        while self._pending():
            if not self._queue and not self._inflight and self._arrivals:
                self.clock = max(self.clock, self._arrivals[0][0])
                self._admit_due()
                continue
            for ci, n_valid, out in driver.stream_map(self._map_fn,
                                                      self._chunk_source()):
                self._route(ci, n_valid, out)

    def serve_trace(self, trace: Iterable[Tuple]) -> Dict[str, StreamReport]:
        """Run an arrival trace to completion.

        ``trace`` rows are ``(t, stream_id, signals[, priority[,
        deadline[, slo[, tenant]]]])`` in virtual-time units; rows need
        not be sorted.  ``priority`` / ``deadline`` may be None to take
        the SLO class defaults; ``tenant`` binds the stream's tenant
        (see ``submit``).  Returns the per-stream reports
        (``report()``)."""
        rows = []
        for row in trace:
            t, stream_id, signals = row[0], row[1], row[2]
            priority = row[3] if len(row) > 3 else None
            deadline = row[4] if len(row) > 4 else None
            slo = row[5] if len(row) > 5 else None
            tenant = row[6] if len(row) > 6 else None
            rows.append((float(t), str(stream_id),
                         np.asarray(signals, np.float32),
                         None if priority is None else int(priority),
                         None if deadline is None else float(deadline),
                         None if slo is None else str(slo),
                         None if tenant is None else str(tenant)))
        rows.sort(key=lambda r: r[0])
        self._arrivals.extend(rows)
        self.drain()
        return self.report()

    # ------------------------------------------------------------------ #
    # Results
    # ------------------------------------------------------------------ #
    def results(self, stream_id: str):
        """Per-read results for one stream, in submission order, as a
        ``pipeline.MapOutput`` (plus the serving extras on the stream
        state).  Rejected reads read as unmapped zeros with
        ``admitted[i] == False``.  ``counters`` is empty: chunks mix
        streams, so exact per-stream counter splits do not exist — the
        serving-wide totals live on ``self.counters``."""
        from repro.core.pipeline import MapOutput
        st = self._streams[stream_id]
        return MapOutput(
            t_start=np.asarray(st.t_start, np.int64),
            score=np.asarray(st.score, np.float32),
            mapped=np.asarray(st.mapped, bool),
            n_events=np.asarray(st.n_events, np.int32),
            counters={})

    def stream_ids(self) -> Tuple[str, ...]:
        return tuple(self._streams)

    def report(self) -> Dict[str, StreamReport]:
        out = {}
        for sid, st in self._streams.items():
            lat = np.asarray([l for l, a in zip(st.latency, st.admitted)
                              if a and math.isfinite(l)], np.float64)
            out[sid] = StreamReport(
                n_reads=len(st.latency), n_mapped=int(sum(st.mapped)),
                n_rejected=st.n_rejected,
                p50_latency=float(np.percentile(lat, 50)) if lat.size else math.nan,
                p99_latency=float(np.percentile(lat, 99)) if lat.size else math.nan,
                mean_latency=float(lat.mean()) if lat.size else math.nan,
                n_shed=st.n_shed, n_nonfinite=st.n_nonfinite)
        return out

    def class_report(self) -> Dict[Optional[str], ClassReport]:
        """Per-SLO-class latency accounting aggregated across streams.
        Keyed by class name (None = reads submitted without a class)."""
        acc: Dict[Optional[str], Dict] = {}

        def bucket(name):
            return acc.setdefault(name, dict(n_reads=0, n_mapped=0,
                                             n_rejected=0, lat=[]))
        for st in self._streams.values():
            for i, name in enumerate(st.slo_of):
                b = bucket(name)
                b["n_reads"] += 1
                b["n_mapped"] += bool(st.mapped[i])
                if not st.admitted[i]:
                    b["n_rejected"] += 1
                elif math.isfinite(st.latency[i]):
                    b["lat"].append(st.latency[i])
        for name in self._shed_by_class:
            bucket(name)
        out = {}
        for name, b in acc.items():
            lat = np.asarray(b["lat"], np.float64)
            out[name] = ClassReport(
                name=name, n_reads=b["n_reads"], n_mapped=b["n_mapped"],
                n_rejected=b["n_rejected"],
                n_shed=self._shed_by_class.get(name, 0),
                p50_latency=float(np.percentile(lat, 50)) if lat.size else math.nan,
                p99_latency=float(np.percentile(lat, 99)) if lat.size else math.nan,
                mean_latency=float(lat.mean()) if lat.size else math.nan)
        return out

    def tenant_report(self) -> Dict[Optional[str], TenantReport]:
        """Per-tenant fair-share accounting aggregated across each
        tenant's streams.  Keyed by tenant name (None = streams submitted
        without a tenant).  The shed and over-budget columns are the
        fairness audit trail: under a one-tenant flood with budgets
        configured, every shed lands in the flooder's row."""
        acc: Dict[Optional[str], Dict] = {}

        def bucket(name):
            return acc.setdefault(name, dict(n_reads=0, n_mapped=0,
                                             n_rejected=0, lat=[]))
        for st in self._streams.values():
            b = bucket(st.tenant)
            b["n_reads"] += len(st.latency)
            b["n_mapped"] += int(sum(st.mapped))
            b["n_rejected"] += st.n_rejected
            b["lat"].extend(l for l, a in zip(st.latency, st.admitted)
                            if a and math.isfinite(l))
        for name in self._shed_by_tenant:
            bucket(name)
        for name in self._over_budget:
            bucket(name)
        out = {}
        for name, b in acc.items():
            lat = np.asarray(b["lat"], np.float64)
            out[name] = TenantReport(
                name=name, n_reads=b["n_reads"], n_mapped=b["n_mapped"],
                n_rejected=b["n_rejected"],
                n_shed=self._shed_by_tenant.get(name, 0),
                n_over_budget=self._over_budget.get(name, 0),
                p50_latency=float(np.percentile(lat, 50)) if lat.size else math.nan,
                p99_latency=float(np.percentile(lat, 99)) if lat.size else math.nan,
                mean_latency=float(lat.mean()) if lat.size else math.nan)
        return out
