"""Stage-graph execution engine for the MARS RSGA pipeline.

The MARS Control Unit (paper Section 6.1.3) sequences fine-grained tasks —
event detection, quantization, seeding, hash-table query, seed-and-vote,
anchor sort, chaining DP — across heterogeneous in-storage units.  This
module is the software analogue: the per-read program is an explicit graph
of named ``Stage``s, each with one or more registered ``Backend``s
(a pure-jnp *reference* implementation and, where a Pallas kernel exists,
an accelerated *pallas* one).  Backend selection is resolved per-config
into a static, hashable *plan* — no per-stage callables ever thread
through ``map_read``/``map_chunk``.

Dataflow state is a flat dict of arrays keyed by the names below; every
stage consumes/produces a documented subset:

    signal      (S,)   f32   raw read samples            [input]
    events      (E,)   f32   event means                 [detect]
    n_events    ()     i32   valid event count           [detect]
    symbols     (E,)   i32   quantized event symbols     [quantize]
    keys        (E,)   u32   seed hash keys              [seed]
    seed_valid  (E,)   bool  valid seed mask             [seed]
    q_pos       (E,H)  i32   query positions of anchors  [query]
    t_pos       (E,H)  i32   target positions of anchors [query]
    hit_valid   (E,H)  bool  surviving anchors           [query, vote]
    sq, st, sv  (A,)         sorted anchors + validity   [sort]
    f, diag0    (A,)         DP chain scores/start diags [dp]
    result      ChainResult  mapping decision            [finalize]
    counters    dict         uniform counter schema (COUNTER_SCHEMA)

Registering an accelerated backend (each kernel's ``ops.py`` does this at
import; ``resolve_plan`` imports them lazily):

    from repro.core import stages
    stages.register_backend("query", stages.PALLAS, my_backend_fn,
                            supports=lambda cfg: True)

Backends unavailable for a config (``supports`` false) or unregistered
fall back to the reference implementation, so a plan always covers every
stage.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import chaining, events, hashing, quantization, seeding, vote
from repro.core.config import MarsConfig

State = Dict[str, Any]

# Execution order of the per-read program (paper Fig. 1 steps 1a-3i).
STAGE_ORDER: Tuple[str, ...] = (
    "detect",     # (1a/1b) signal -> event means
    "quantize",   # (1b)    event means -> symbols
    "seed",       # (2c)    symbols -> hash keys (+ minimizer winnowing)
    "query",      # (2d/2e) hash-table gather + frequency filter
    "vote",       # (2f)    seed-and-vote filter
    "sort",       # (3g/3h) anchor sort (bitonic Sorter/Merger)
    "dp",         # (3i)    banded chaining DP
    "finalize",   #         best/second-best chain -> mapping decision
)

# The filter-aware split used by the chunk program (core/pipeline.py): the
# cheap phase runs on every read; the chaining phase runs only on the
# compacted batch of reads that still have anchors after the filters.
CHEAP_STAGES: Tuple[str, ...] = STAGE_ORDER[:5]   # detect .. vote
CHAIN_STAGES: Tuple[str, ...] = STAGE_ORDER[5:]   # sort, dp, finalize

# Canonical backend names.
REFERENCE = "reference"
PALLAS = "pallas"

# Modules that register accelerated backends (imported lazily the first
# time a plan asks for them, so importing core never pulls in Pallas).
# The "ring"/"a2a" entries are the distributed query backends: the same
# chunk program over a bucket-range-partitioned index, with the partition
# schedule (collective-permute ring / one all-to-all) as just another
# registered `query` implementation (core/distributed.py).
_BACKEND_MODULES: Dict[str, Tuple[str, ...]] = {
    PALLAS: (
        "repro.kernels.event_detect.ops",
        "repro.kernels.pluto_lookup.ops",
        "repro.kernels.bitonic_sort.ops",
        "repro.kernels.chain_dp.ops",
        # whole-phase fused cheap kernel (registers through
        # register_fused_cheap, not the per-stage registry)
        "repro.kernels.cheap_fused.ops",
    ),
    "ring": ("repro.core.distributed",),
    "a2a": ("repro.core.distributed",),
    # out-of-core query over host-resident bucket-range tiles + the
    # traffic-keyed hot-tile device cache (core/tiered.py)
    "tiered": ("repro.core.tiered",),
}
_loaded_backend_modules = set()

# Uniform counter schema: every map_chunk output carries exactly these
# per-chunk counters (plus n_reads / n_samples added by the chunk program).
# workload.from_counters / ssd_model consume them by name.  The full
# contract — which counters are closed-form, the debug-counters-never-
# change-the-chunk-schema rule, and the consumer table — is
# docs/COUNTERS.md.
COUNTER_SCHEMA: Tuple[str, ...] = (
    "n_events", "n_seeds", "n_bucket_probes", "n_hits_raw",
    "n_hits_postfreq", "n_hits_exact", "n_votes_cast",
    "n_anchors_postvote", "n_sorted", "n_dp_pairs",
)
CHUNK_COUNTER_SCHEMA: Tuple[str, ...] = COUNTER_SCHEMA + (
    "n_reads", "n_samples")

# Per-stage DEBUG counters: diagnostics a stage may emit alongside the
# uniform schema (e.g. the vote filter's clip-guard tally).  The chunk
# program DROPS them from MapOutput.counters so CHUNK_COUNTER_SCHEMA —
# and every consumer keyed on it (workload, ssd_model, psum specs) —
# stays exactly as-is; read them by running the stage (or cheap_phase)
# directly.  See docs/COUNTERS.md for the full contract.
DEBUG_COUNTER_SCHEMA: Tuple[str, ...] = (
    "n_votes_clipped",
    # tiered-index hot-tile cache traffic (core/tiered.py): per-chunk tile
    # hits / misses / host->device paged bytes (int32, clamped; exact
    # host-side totals live on HotTileCache)
    "n_tile_hits", "n_tile_misses", "n_tile_paged_bytes",
    # fault-tolerant paging (core/tiered.py + core/faults.py): per-chunk
    # page-in re-reads and checksum mismatches caught before retry/raise
    "n_tile_retries", "n_tile_corruptions",
)


@dataclasses.dataclass(frozen=True)
class Backend:
    """One implementation of one stage.

    fn(state, cfg, index) -> new state dict.  ``supports`` gates configs
    the implementation cannot serve (e.g. the fixed-point event-detect
    kernel under a float config); unsupported backends resolve to the
    reference implementation instead.

    ``primitive`` is the stage's underlying array-level kernel, exposed so
    batch-level fast paths can call it outside the per-read state-dict
    protocol.  The chaining fast path (core/pipeline.py) runs sort/dp on a
    compacted read batch at a reduced anchor width; the cheap-phase fast
    path runs detect once per chunk and routes the query gathers through
    one whole-chunk lookup:

        sort:   primitive(keys (L,) int32) -> sorted keys (L,)
        dp:     primitive(q, t, valid (A,), cfg) -> (f (A,) f32, d (A,) i32)
        detect: primitive(signals (R,S) f32, cfg) -> (means (R,E) f32,
                n_events (R,) i32) — batch-level, no unit-batch vmap
        query:  primitive(table (N,), idx (...,)) -> values (...,) — the
                entry-plane gather (pLUTo lookup)

    ``index_kind`` declares the index layout the backend consumes:
    "replicated" (the plain ``index_arrays`` dict, whole table on every
    device), "partitioned" (the ``partition_index`` dict with a leading
    partition axis, range-partitioned by bucket over the mesh 'model'
    axis), or "tiered" (the out-of-core hot-tile cache view from
    ``core/tiered.HotTileCache.prepare`` — host-resident bucket-range
    tiles paged into fixed device slots).  ``plan_index_kind`` lets the
    chunk drivers pick matching shard_map in_specs.
    """
    stage: str
    name: str
    fn: Callable[[State, MarsConfig, Dict[str, jnp.ndarray]], State]
    supports: Optional[Callable[[MarsConfig], bool]] = None
    primitive: Optional[Callable] = None
    index_kind: str = "replicated"


_REGISTRY: Dict[Tuple[str, str], Backend] = {}


def register_backend(stage: str, name: str, fn,
                     supports=None, replace: bool = False,
                     primitive=None, index_kind: str = "replicated") -> None:
    """Register ``fn`` as backend ``name`` for ``stage``.

    ``fn(state, cfg, index) -> state`` must be bit-exact to the stage's
    reference backend — same state keys, same values, and the exact
    COUNTER_SCHEMA counter increments (extra diagnostics are allowed only
    as DEBUG_COUNTER_SCHEMA keys, which the chunk program drops; see
    docs/COUNTERS.md).  ``supports(cfg)`` gates eligibility (unsupported
    configs fall back to reference in resolve_plan); ``primitive``
    optionally exposes a batch-level entry point the cheap phase can fuse;
    ``index_kind`` declares the index layout the backend consumes
    (replicated / partitioned / tiered).
    """
    if stage not in STAGE_ORDER:
        raise ValueError(f"unknown stage {stage!r}; stages: {STAGE_ORDER}")
    if index_kind not in ("replicated", "partitioned", "tiered"):
        raise ValueError(f"unknown index_kind {index_kind!r}")
    key = (stage, name)
    if key in _REGISTRY and not replace:
        raise ValueError(f"backend {key} already registered")
    _REGISTRY[key] = Backend(stage=stage, name=name, fn=fn, supports=supports,
                             primitive=primitive, index_kind=index_kind)


def get_backend(stage: str, name: str) -> Backend:
    return _REGISTRY[(stage, name)]


def registered_backends(stage: str) -> Tuple[str, ...]:
    return tuple(sorted(n for (s, n) in _REGISTRY if s == stage))


def _ensure_backend_loaded(name: str) -> None:
    if name in _loaded_backend_modules:
        return
    # resolve_plan may run inside a jit trace (map_chunk with plan=None);
    # module-level jnp constants in the kernel packages must be created
    # eagerly, not staged as tracers of the surrounding trace
    import jax
    with jax.ensure_compile_time_eval():
        for mod in _BACKEND_MODULES.get(name, ()):
            importlib.import_module(mod)
    _loaded_backend_modules.add(name)


Plan = Tuple[Tuple[str, str], ...]


def resolve_plan(cfg: MarsConfig, backend: str = REFERENCE) -> Plan:
    """Resolve the per-stage backend choice for one config.

    Returns a hashable ((stage, backend_name), ...) tuple in STAGE_ORDER —
    usable as a static jit argument.  Stages without the requested backend
    (or whose backend does not support ``cfg``) fall back to reference.
    """
    _ensure_backend_loaded(backend)
    known = ({REFERENCE} | set(_BACKEND_MODULES)
             | {n for _, n in _REGISTRY})
    if backend not in known:
        raise ValueError(f"unknown backend {backend!r}; known: "
                         f"{sorted(known)}")
    plan = []
    for stage in STAGE_ORDER:
        b = _REGISTRY.get((stage, backend))
        if b is None or (b.supports is not None and not b.supports(cfg)):
            b = _REGISTRY[(stage, REFERENCE)]
        plan.append((stage, b.name))
    return tuple(plan)


def plan_index_kind(plan: Plan) -> str:
    """The index layout ``plan`` consumes: "replicated" (index_arrays dict,
    whole table everywhere) or "partitioned" (partition_index dict, bucket
    ranges over the mesh 'model' axis).  Only the query stage touches the
    index, so its backend decides."""
    return _REGISTRY[("query", dict(plan)["query"])].index_kind


def execute_stages(state: State, index: Dict[str, jnp.ndarray],
                   cfg: MarsConfig, plan: Plan,
                   subset: Tuple[str, ...]) -> State:
    """Run the stages of ``plan`` named in ``subset`` (in plan order) over an
    existing state dict.  The chunk program uses this to split the per-read
    graph into the cheap phase (CHEAP_STAGES, every read) and the chaining
    phase (CHAIN_STAGES, compacted reads only)."""
    for stage, bname in plan:
        if stage in subset:
            state = _REGISTRY[(stage, bname)].fn(state, cfg, index)
    return state


def execute_read(signal: jnp.ndarray, index: Dict[str, jnp.ndarray],
                 cfg: MarsConfig, plan: Plan):
    """Run the per-read stage graph.  signal: (S,) f32.

    Returns (ChainResult, counters) with counters exactly COUNTER_SCHEMA.
    """
    state: State = {"signal": signal, "counters": {}}
    state = execute_stages(state, index, cfg, plan, STAGE_ORDER)
    counters = state["counters"]
    missing = missing_counters(counters)
    if missing:
        raise RuntimeError(f"plan {plan} produced incomplete counters; "
                           f"missing {missing}")
    return state["result"], counters


def chain_primitives(plan: Plan, cfg: MarsConfig):
    """Resolve the (sorter, dp) array-level primitives of ``plan``'s chaining
    stages for the batched fast path, or None when the plan's chain stages
    cannot be expressed through primitives (a registered backend without a
    ``primitive`` and a non-reference finalize must go through the per-read
    stage bodies instead).

    Returns (sorter(keys)->keys, dp(q, t, valid)->(f, d)) — both per-read,
    vmap-safe.
    """
    p = dict(plan)
    if p["finalize"] != REFERENCE:
        return None
    prims = []
    for stage in ("sort", "dp"):
        b = _REGISTRY[(stage, p[stage])]
        if b.name != REFERENCE and b.primitive is None:
            return None
        prims.append(b.primitive)
    sorter = prims[0] if prims[0] is not None else jnp.sort
    if prims[1] is not None:
        dp_prim = prims[1]
        dp = lambda q, t, v: dp_prim(q, t, v, cfg)
    else:
        dp = lambda q, t, v: chaining.chain_dp(q, t, v, cfg)
    return sorter, dp


@dataclasses.dataclass(frozen=True)
class FusedCheapBackend:
    """A whole-phase fused implementation of CHEAP_STAGES.

    fn(signals (R,S), index, cfg) -> (q_pos, t_pos, hit_valid, counters) —
    the exact ``pipeline.cheap_phase`` contract, produced by ONE kernel
    launch instead of per-stage programs.  ``supports`` gates configs the
    kernel cannot serve; unsupported configs silently resolve to the
    per-stage plan (pipeline.cheap_phase's existing dispatch ladder).
    """
    name: str
    fn: Callable
    supports: Optional[Callable[[MarsConfig], bool]] = None


_FUSED_CHEAP: Dict[str, FusedCheapBackend] = {}


def register_fused_cheap(name: str, fn, supports=None,
                         replace: bool = False) -> None:
    """Register a whole-phase fused cheap kernel under backend ``name``.

    The fused kernel engages only for plans whose detect AND query stages
    resolved to ``name`` with quantize/seed/vote at reference — i.e. the
    per-stage programs it replaces are exactly the ones it fuses, so parity
    is against the plan's own math, never a different backend's.
    """
    if name in _FUSED_CHEAP and not replace:
        raise ValueError(f"fused cheap backend {name!r} already registered")
    _FUSED_CHEAP[name] = FusedCheapBackend(name=name, fn=fn,
                                           supports=supports)


def fused_cheap_backend(plan: Plan,
                        cfg: MarsConfig) -> Optional[FusedCheapBackend]:
    """Resolve ``plan``'s whole-phase fused kernel, or None when the plan's
    cheap stages are not the exact per-stage shape the fusion covers (or the
    kernel's ``supports`` gate rejects ``cfg``)."""
    p = dict(plan)
    b = _FUSED_CHEAP.get(p["detect"])
    if b is None or p["query"] != b.name:
        return None
    if any(p[s] != REFERENCE for s in ("quantize", "seed", "vote")):
        return None
    if b.supports is not None and not b.supports(cfg):
        return None
    return b


@dataclasses.dataclass(frozen=True)
class CheapPrimitives:
    """Resolved batch-level implementations of a plan's cheap phase
    (core/pipeline.cheap_phase).

    ``detector``: batch detect (signals (R,S)) -> (means, n_events), or None
    for the reference math (the per-read detect stage body, vmapped).
    ``gather``: entry-plane gather for a whole-chunk ``seeding.query_index``
    call, or None for jnp.take.  ``query_fn``: set instead of ``gather``
    when the query backend is not gather-expressible (the partitioned-index
    ring/a2a schedules) — the registered stage body, vmapped per read.
    ``fused``: the whole-phase mega-kernel (register_fused_cheap) when the
    plan's cheap stages match one — signals in, (q_pos, t_pos, hit_valid,
    counters) out, no per-stage launches at all.
    """
    detector: Optional[Callable] = None
    gather: Optional[Callable] = None
    query_fn: Optional[Callable] = None
    fused: Optional[Callable] = None


def cheap_primitives(plan: Plan, cfg: MarsConfig) -> Optional[CheapPrimitives]:
    """Resolve the batch-level cheap-phase program for ``plan``, or None when
    the plan's cheap stages cannot be expressed at batch level (a registered
    non-reference quantize/seed/vote backend, or a non-reference detect
    backend without a batch primitive) — those plans fall back to the
    per-read vmap of the stage bodies.
    """
    p = dict(plan)
    for stage in ("quantize", "seed", "vote"):
        if p[stage] != REFERENCE:
            return None
    det = _REGISTRY[("detect", p["detect"])]
    if det.name != REFERENCE and det.primitive is None:
        return None
    det_prim = det.primitive
    detector = (None if det.name == REFERENCE
                else (lambda signals: det_prim(signals, cfg)))
    fused_b = fused_cheap_backend(plan, cfg)
    fused = (None if fused_b is None
             else (lambda signals, index: fused_b.fn(signals, index, cfg)))
    q = _REGISTRY[("query", p["query"])]
    if q.name == REFERENCE:
        return CheapPrimitives(detector=detector, fused=fused)
    if q.primitive is not None:
        return CheapPrimitives(detector=detector, gather=q.primitive,
                               fused=fused)
    return CheapPrimitives(detector=detector, query_fn=q.fn, fused=fused)


def missing_counters(counters: Dict[str, Any]) -> Tuple[str, ...]:
    return tuple(k for k in COUNTER_SCHEMA if k not in counters)


# --------------------------------------------------------------------------- #
# Parametrized stage bodies.  Reference backends call these with the jnp
# default; kernel ops.py modules call them with their accelerated primitive
# (gather / sorter / dp / detector) — keeping the math in ONE place.
# --------------------------------------------------------------------------- #
def detect_with(state: State, cfg: MarsConfig, index, detector=None) -> State:
    if detector is None:
        ev, n_ev, _ = events.detect_events(state["signal"], cfg)
    else:
        ev, n_ev = detector(state["signal"])
    return {**state, "events": ev, "n_events": n_ev,
            "counters": {**state["counters"], "n_events": n_ev}}


def quantize_ref(state: State, cfg: MarsConfig, index) -> State:
    ev_valid = jnp.arange(cfg.max_events) < state["n_events"]
    sym = quantization.quantize_events(state["events"], ev_valid, cfg)
    return {**state, "symbols": sym}


def seed_ref(state: State, cfg: MarsConfig, index) -> State:
    keys, valid = hashing.pack_seeds(state["symbols"], state["n_events"], cfg)
    valid = hashing.minimizer_mask(keys, valid, cfg.minimizer_radius)
    return {**state, "keys": keys, "seed_valid": valid}


def query_with(state: State, cfg: MarsConfig, index, gather=None) -> State:
    t_pos, hit_valid, c = seeding.query_index(
        state["keys"], state["seed_valid"], index, cfg, gather=gather)
    q_pos = jnp.broadcast_to(
        jnp.arange(cfg.max_events, dtype=jnp.int32)[:, None], t_pos.shape)
    return {**state, "q_pos": q_pos, "t_pos": t_pos, "hit_valid": hit_valid,
            "counters": {**state["counters"], **c}}


def vote_ref(state: State, cfg: MarsConfig, index) -> State:
    hit_valid, c = vote.vote_filter(state["q_pos"], state["t_pos"],
                                    state["hit_valid"], cfg)
    return {**state, "hit_valid": hit_valid,
            "counters": {**state["counters"], **c}}


def sort_with(state: State, cfg: MarsConfig, index, sorter=None) -> State:
    sq, st, sv = chaining.sort_anchors(state["q_pos"], state["t_pos"],
                                       state["hit_valid"], cfg, sorter=sorter)
    n_sorted = jnp.minimum(state["hit_valid"].sum(), cfg.max_anchors)
    return {**state, "sq": sq, "st": st, "sv": sv,
            "counters": {**state["counters"], "n_sorted": n_sorted}}


def dp_with(state: State, cfg: MarsConfig, index, dp=None) -> State:
    if dp is None:
        f, diag0 = chaining.chain_dp(state["sq"], state["st"], state["sv"],
                                     cfg)
    else:
        f, diag0 = dp(state["sq"], state["st"], state["sv"])
    n_dp_pairs = state["sv"].sum() * cfg.chain_band
    return {**state, "f": f, "diag0": diag0,
            "counters": {**state["counters"], "n_dp_pairs": n_dp_pairs}}


def finalize_ref(state: State, cfg: MarsConfig, index) -> State:
    res = chaining.best_chain(state["f"], state["diag0"], state["sv"], cfg)
    return {**state, "result": res}


def _detect_ref(state, cfg, index):
    return detect_with(state, cfg, index, detector=None)


def _query_ref(state, cfg, index):
    return query_with(state, cfg, index, gather=None)


def _sort_ref(state, cfg, index):
    return sort_with(state, cfg, index, sorter=None)


def _dp_ref(state, cfg, index):
    return dp_with(state, cfg, index, dp=None)


register_backend("detect", REFERENCE, _detect_ref)
register_backend("quantize", REFERENCE, quantize_ref)
register_backend("seed", REFERENCE, seed_ref)
register_backend("query", REFERENCE, _query_ref)
register_backend("vote", REFERENCE, vote_ref)
register_backend("sort", REFERENCE, _sort_ref)
register_backend("dp", REFERENCE, _dp_ref)
register_backend("finalize", REFERENCE, finalize_ref)
