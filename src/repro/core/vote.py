"""Seed-and-vote filtering (paper Section 5.1, Fig. 2) — first applied to raw
signals by MARS, placed after quantization + hash query to tolerate noise.

The reference is partitioned into overlapping, equal-length windows over the
*projected alignment start* (t_pos - q_pos).  Each anchor votes for the two
overlapping windows containing it (50% overlap); anchors whose best window
gathers fewer than `thresh_voting` votes are discarded before chaining.

Votes accumulate in a mod-hash bin table (vote_bins) — the same bounded-
memory trade the in-storage Arithmetic Units make (they own a fixed register
file per subarray pair).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import MarsConfig


def vote_filter(q_pos: jnp.ndarray, t_pos: jnp.ndarray, valid: jnp.ndarray,
                cfg: MarsConfig) -> Tuple[jnp.ndarray, Dict]:
    """q_pos, t_pos: (E,H) int32; valid: (E,H) bool.  Returns (valid', counters).

    Window id = projected start >> voting_window_log2; anchors vote for wid
    and wid+1 (overlapping windows); an anchor survives if either window it
    voted for reaches thresh_voting.
    """
    if not cfg.use_vote_filter:
        return valid, dict(n_anchors_postvote=valid.sum(),
                           n_votes_cast=jnp.int32(0))
    v = cfg.voting_window_log2
    nbins = cfg.vote_bins
    diag = t_pos - q_pos                                    # projected start
    # shift to non-negative before the bit ops (diag can be slightly < 0)
    diag = diag + (1 << 20)
    w1 = (diag >> v) % nbins
    w2 = ((diag >> v) + 1) % nbins
    ones = valid.astype(jnp.int32).reshape(-1)
    votes = jax.ops.segment_sum(ones, w1.reshape(-1), num_segments=nbins)
    votes = votes + jax.ops.segment_sum(ones, w2.reshape(-1),
                                        num_segments=nbins)
    v1 = jnp.take(votes, w1, axis=0)
    v2 = jnp.take(votes, w2, axis=0)
    keep = valid & (jnp.maximum(v1, v2) >= cfg.thresh_voting)
    counters = dict(n_anchors_postvote=keep.sum(),
                    n_votes_cast=2 * valid.sum())
    return keep, counters
