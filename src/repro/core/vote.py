"""Seed-and-vote filtering (paper Section 5.1, Fig. 2) — first applied to raw
signals by MARS, placed after quantization + hash query to tolerate noise.

The reference is partitioned into overlapping, equal-length windows over the
*projected alignment start* (t_pos - q_pos).  Each anchor votes for the two
overlapping windows containing it (50% overlap); anchors whose best window
gathers fewer than `thresh_voting` votes are discarded before chaining.

Votes accumulate in a mod-hash bin table (vote_bins) — the same bounded-
memory trade the in-storage Arithmetic Units make (they own a fixed register
file per subarray pair).

Cheap-phase fast path: ``vote_filter`` accepts a whole chunk of reads at
once — (R, E, H) anchors fuse into ONE segment-sum scatter over per-read
bin blocks instead of 2R per-read scatters (integer sums, so the fusion is
bit-identical).  The pre-fast-path per-read implementation survives as
``vote_filter_reference`` (parity oracle + the "pre" side of the
microbenchmark).  The projected-start shift is clip-guarded: a diag below
-2^20 no longer wraps into a wrong bin; clipped votes are tallied in the
``n_votes_clipped`` debug counter (OUTSIDE stages.CHUNK_COUNTER_SCHEMA —
the chunk program drops it from the uniform per-chunk counters).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import MarsConfig

# Projected starts are shifted by +2^20 before the window bit-ops so that
# slightly-negative diags (t_pos - q_pos < 0 near the reference start) stay
# non-negative.  Anything below -DIAG_SHIFT is clip-guarded (and counted).
DIAG_SHIFT = 1 << 20


def vote_filter_reference(q_pos: jnp.ndarray, t_pos: jnp.ndarray,
                          valid: jnp.ndarray,
                          cfg: MarsConfig) -> Tuple[jnp.ndarray, Dict]:
    """Pre-fast-path per-read vote filter: two segment-sum scatters, no clip
    guard.  q_pos, t_pos: (E,H) int32; valid: (E,H) bool.  Parity oracle +
    the "pre" side of the cheap-phase microbenchmark."""
    if not cfg.use_vote_filter:
        return valid, dict(n_anchors_postvote=valid.sum(),
                           n_votes_cast=jnp.int32(0))
    v = cfg.voting_window_log2
    nbins = cfg.vote_bins
    diag = t_pos - q_pos                                    # projected start
    # shift to non-negative before the bit ops (diag can be slightly < 0)
    diag = diag + DIAG_SHIFT
    w1 = (diag >> v) % nbins
    w2 = ((diag >> v) + 1) % nbins
    ones = valid.astype(jnp.int32).reshape(-1)
    votes = jax.ops.segment_sum(ones, w1.reshape(-1), num_segments=nbins)
    votes = votes + jax.ops.segment_sum(ones, w2.reshape(-1),
                                        num_segments=nbins)
    v1 = jnp.take(votes, w1, axis=0)
    v2 = jnp.take(votes, w2, axis=0)
    keep = valid & (jnp.maximum(v1, v2) >= cfg.thresh_voting)
    counters = dict(n_anchors_postvote=keep.sum(),
                    n_votes_cast=2 * valid.sum())
    return keep, counters


def vote_filter(q_pos: jnp.ndarray, t_pos: jnp.ndarray, valid: jnp.ndarray,
                cfg: MarsConfig) -> Tuple[jnp.ndarray, Dict]:
    """q_pos, t_pos: (E,H) or (R,E,H) int32; valid: same-shape bool.
    Returns (valid', counters) — counters are scalars for per-read input and
    (R,) vectors for a batched chunk.

    Window id = projected start >> voting_window_log2; anchors vote for wid
    and wid+1 (overlapping windows); an anchor survives if either window it
    voted for reaches thresh_voting.

    Batched input fuses the whole chunk into ONE segment-sum over R
    consecutive nbins-blocks (segment id = read * nbins + window) — integer
    votes, so per-read results are bit-identical to the per-read oracle.
    The +DIAG_SHIFT projected-start shift is clipped at zero: a diag below
    -DIAG_SHIFT lands in bin 0 instead of silently wrapping through the
    arithmetic shift, and is counted in the ``n_votes_clipped`` debug
    counter (outside CHUNK_COUNTER_SCHEMA).
    """
    batched = q_pos.ndim == 3
    red = (-2, -1)                       # per-read reduction axes
    if not cfg.use_vote_filter:
        return valid, dict(
            n_anchors_postvote=valid.sum(red),
            n_votes_cast=jnp.zeros(valid.shape[:-2], jnp.int32),
            n_votes_clipped=jnp.zeros(valid.shape[:-2], jnp.int32))
    v = cfg.voting_window_log2
    nbins = cfg.vote_bins
    diag = t_pos - q_pos                                    # projected start
    shifted = diag + DIAG_SHIFT
    clipped = jnp.maximum(shifted, 0)
    n_clipped = (valid & (shifted < 0)).sum(red)
    w1 = (clipped >> v) % nbins
    w2 = ((clipped >> v) + 1) % nbins
    R = q_pos.shape[0] if batched else 1
    base = (jnp.arange(R, dtype=jnp.int32) * nbins).reshape(
        (R,) + (1,) * (q_pos.ndim - 1)) if batched else 0
    ones = valid.astype(jnp.int32).reshape(-1)
    seg = jnp.concatenate([(base + w1).reshape(-1), (base + w2).reshape(-1)])
    votes = jax.ops.segment_sum(jnp.concatenate([ones, ones]), seg,
                                num_segments=R * nbins)
    if batched:
        votes = votes.reshape(R, nbins)
        v1 = jnp.take_along_axis(votes, w1.reshape(R, -1), axis=1)
        v2 = jnp.take_along_axis(votes, w2.reshape(R, -1), axis=1)
        v1, v2 = v1.reshape(w1.shape), v2.reshape(w2.shape)
    else:
        v1 = jnp.take(votes, w1, axis=0)
        v2 = jnp.take(votes, w2, axis=0)
    keep = valid & (jnp.maximum(v1, v2) >= cfg.thresh_voting)
    counters = dict(n_anchors_postvote=keep.sum(red),
                    n_votes_cast=2 * valid.sum(red),
                    n_votes_clipped=n_clipped)
    return keep, counters
