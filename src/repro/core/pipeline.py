"""End-to-end MARS read-mapping pipeline (paper Fig. 1 / Fig. 7 dataflow).

The per-read program is the stage graph of ``core/stages.py`` — the same
fine-grained tasks the MARS Control Unit sequences (Section 6.1.3):

    (1) event detection: signal-to-event conversion (1a) + quantization (1b)
    (2) seeding: hash-value generation (c), frequency filter (d),
        hash-table query (e), seed-and-vote filter (f)
    (3) chaining: bucket/sort (g,h) + dynamic programming (i)

Backend selection (reference jnp vs accelerated Pallas) flows ONLY through
the stage registry: ``map_chunk`` takes a static, hashable *plan* resolved
by ``stages.resolve_plan`` — no per-stage callables.  ``use_kernels=True``
routes every stage through its registered Pallas backend (falling back to
reference where a kernel does not support the config).

Everything is static-shape and jit-compiled; ``map_chunk`` vmaps the
per-read program over a chunk of reads (a "channel stripe" in MARS terms)
and ``map_chunk_sharded`` runs the identical program under ``shard_map``
with reads sharded over the mesh and the index replicated — bit-identical
outputs, counters combined with integer psum.  Counter outputs follow the
uniform schema ``stages.CHUNK_COUNTER_SCHEMA`` consumed by the analytic
SSD performance model (ssd_model.py via workload.py).

Pad rows (chunks shorter than the static chunk size) are masked out of
every counter and of ``mapped`` via the traced ``n_valid`` argument, so
workload counts never inflate on non-multiple-of-chunk inputs.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chaining, driver, seeding, stages, vote
from repro.core.config import MarsConfig
from repro.core.index import Index, index_arrays


class MapOutput(NamedTuple):
    t_start: jnp.ndarray    # (R,) int32 double-genome event coords
    score: jnp.ndarray      # (R,) f32
    mapped: jnp.ndarray     # (R,) bool
    n_events: jnp.ndarray   # (R,) int32
    counters: Dict[str, jnp.ndarray]


def map_read(signal: jnp.ndarray, index: Dict[str, jnp.ndarray],
             cfg: MarsConfig, plan: Optional[stages.Plan] = None):
    """signal: (S,) f32 -> (ChainResult, counters) via the stage engine."""
    if plan is None:
        plan = stages.resolve_plan(cfg, stages.REFERENCE)
    return stages.execute_read(signal, index, cfg, plan)


# --------------------------------------------------------------------------- #
# Cheap-phase fast path (batch-level detect / query / vote)
# --------------------------------------------------------------------------- #
def cheap_phase_vmap(signals: jnp.ndarray, index: Dict[str, jnp.ndarray],
                     cfg: MarsConfig, plan: stages.Plan):
    """The per-read cheap phase: vmap CHEAP_STAGES (detect..vote) over a
    chunk through the state-dict stage bodies.  Fallback for plans whose
    cheap stages have no batch-level expression, and the parity comparand
    for ``cheap_phase`` (tests/test_cheap_fastpath.py)."""
    def one(signal):
        state = stages.execute_stages({"signal": signal, "counters": {}},
                                      index, cfg, plan, stages.CHEAP_STAGES)
        return (state["q_pos"], state["t_pos"], state["hit_valid"],
                state["counters"])
    return jax.vmap(one)(signals)


def cheap_phase(signals: jnp.ndarray, index: Dict[str, jnp.ndarray],
                cfg: MarsConfig, plan: stages.Plan, use_fused: bool = True):
    """The cheap phase (detect..vote) over a chunk, batch-level where the
    plan allows (``stages.cheap_primitives``).

    Returns (q_pos (R,E,H), t_pos (R,E,H), hit_valid (R,E,H), per-read
    counters dict) — everything the chaining phase and the chunk counter
    schema need.  ``counters["n_anchors_postvote"]`` is the per-read
    post-filter anchor count the compaction gate keys on.

    Dispatch ladder, most-fused first: (1) the whole-phase mega-kernel
    (``stages.register_fused_cheap``) when the plan's cheap stages match one
    — detect..vote in ONE kernel launch, index tiles DMA-streamed through
    scratch (kernels/cheap_fused); (2) the per-stage batch level below;
    (3) ``cheap_phase_vmap``.  ``use_fused=False`` pins level (2) — the
    fused-vs-per-stage microbenchmark pair and parity tests use it.

    Batch level means: detect runs ONCE per chunk (the Pallas event_detect
    kernel's native grid, no unit-batch vmap), the hash-table query issues
    two whole-chunk fused gathers against the packed index (one pLUTo sweep
    each on the Pallas backend), and the vote filter accumulates the whole
    chunk in one segment-sum.  Quantize/seed (pure per-read arithmetic) and
    non-gather query backends (ring/a2a) run their registered stage bodies
    under vmap, so the math stays in ONE place — outputs and counters are
    bit-identical to ``cheap_phase_vmap``.
    """
    prims = stages.cheap_primitives(plan, cfg)
    if prims is None:
        return cheap_phase_vmap(signals, index, cfg, plan)

    if use_fused and prims.fused is not None and "t_pre_keys" not in index:
        return prims.fused(signals, index)

    if "t_pre_keys" in index:
        # the tiered traffic pre-pass already ran the plan's own
        # detect/quantize/seed over this exact chunk (core/tiered.py,
        # PREPASS_KEYS) — consume its outputs instead of recomputing.
        # Bit-identical by construction: same stages, same plan, same
        # padded signals.
        n_ev = index["t_pre_nev"]
        keys = index["t_pre_keys"]
        seed_valid = index["t_pre_valid"]
        counters = {"n_events": n_ev}
    else:
        if prims.detector is not None:
            means, n_ev = prims.detector(signals)
        else:
            def detect_one(signal):
                st = stages.execute_stages({"signal": signal,
                                            "counters": {}},
                                           index, cfg, plan, ("detect",))
                return st["events"], st["n_events"]
            means, n_ev = jax.vmap(detect_one)(signals)
        counters = {"n_events": n_ev}

        def quant_seed(ev, n):
            st = stages.execute_stages({"events": ev, "n_events": n,
                                        "counters": {}},
                                       index, cfg, plan,
                                       ("quantize", "seed"))
            return st["keys"], st["seed_valid"]
        keys, seed_valid = jax.vmap(quant_seed)(means, n_ev)

    if prims.query_fn is not None:
        def query_one(k, v):
            st = prims.query_fn({"keys": k, "seed_valid": v, "counters": {}},
                                cfg, index)
            return st["t_pos"], st["hit_valid"], st["counters"]
        t_pos, hit_valid, qc = jax.vmap(query_one)(keys, seed_valid)
    else:
        t_pos, hit_valid, qc = seeding.query_index(
            keys, seed_valid, index, cfg, gather=prims.gather)
    counters.update(qc)
    q_pos = jnp.broadcast_to(
        jnp.arange(cfg.max_events, dtype=jnp.int32)[None, :, None],
        t_pos.shape)

    hit_valid, vc = vote.vote_filter(q_pos, t_pos, hit_valid, cfg)
    counters.update(vc)
    return q_pos, t_pos, hit_valid, counters


def _chain_widths(cfg: MarsConfig, n_keys: int):
    """The select-then-sort width ladder: configured widths that actually
    shrink the sorted array, ascending, deduplicated."""
    full = min(cfg.max_anchors, n_keys)
    return tuple(sorted({w for w in cfg.chain_widths if 0 < w < full}))


def chain_phase(q_pos: jnp.ndarray, t_pos: jnp.ndarray, hit_valid: jnp.ndarray,
                cnt: jnp.ndarray, cfg: MarsConfig, prims) -> tuple:
    """The batched chaining phase (sort -> dp -> finalize) over N reads.

    Runs at the smallest width W of ``cfg.chain_widths`` that bounds every
    active read's post-vote anchor count (``cnt``), falling back to the
    original full-sort path when none does: with cnt <= W the W smallest
    packed keys are ALL surviving anchors, so select-then-sort at width W,
    the banded DP over W slots and best_chain over W slots are bit-identical
    to the full-width pipeline (the truncated tail holds only invalid
    sentinel slots, which the DP maps to (NEG, const) and best_chain masks).
    The width choice is a batch-level runtime branch (lax.cond), so only the
    chosen program executes.

    Returns (t_start (N,), score (N,), mapped (N,)) int32/f32/bool.
    """
    sorter, dp = prims
    key = jax.vmap(chaining.pack_anchor_keys)(q_pos, t_pos, hit_valid)
    select = chaining._SELECTORS[cfg.anchor_select]
    maxcnt = jnp.max(cnt)

    def finalize(skey):
        sq, st, sv = chaining.decode_anchor_keys(skey)
        f, d = jax.vmap(dp)(sq, st, sv)
        res = jax.vmap(lambda ff, dd, vv: chaining.best_chain(ff, dd, vv, cfg)
                       )(f, d, sv)
        return res.t_start, res.score, res.mapped

    def run_full():
        return finalize(jax.vmap(lambda k: sorter(k)[: cfg.max_anchors])(key))

    def run_at(width):
        return finalize(jax.vmap(lambda k: sorter(select(k, width)))(key))

    out = run_full
    for w in reversed(_chain_widths(cfg, key.shape[1])):
        def out(w_=w, fallback=out):
            return jax.lax.cond(maxcnt <= w_,
                                functools.partial(run_at, w_), fallback)
    return out()


def _chain_outputs(q_pos, t_pos, hit_valid, cnt, cfg: MarsConfig, prims):
    """Read-compaction gating around ``chain_phase``.

    Only reads with anchors surviving the filters (``cnt > 0``) can reach
    ``min_chain_score`` — under the paper's configurations the vote filter
    already enforces reachability, since a surviving anchor implies a vote
    window with >= thresh_voting anchors and thresh_voting * anchor_score >=
    min_chain_score.  Zero-anchor reads are finalized directly with the
    closed-form ``empty_chain_result`` (bit-identical to what the chain
    phase computes for them).  The survivors are compacted into a
    capacity-bounded batch of C = ceil(chain_capacity_frac * R) slots and
    their results scattered back; when more than C reads survive, a runtime
    branch (lax.cond) falls back to chaining the whole chunk — every read is
    exact either way, so the branch choice is invisible (including across
    shard_map partitions that take different branches).
    """
    R = cnt.shape[0]
    empty = chaining.empty_chain_result(cfg)
    cap = min(R, max(1, math.ceil(R * cfg.chain_capacity_frac)))
    needs = cnt > 0

    def run_all():
        return chain_phase(q_pos, t_pos, hit_valid, cnt, cfg, prims)

    if cap >= R:
        return run_all()

    def run_compacted():
        order = jnp.argsort(~needs)          # stable: survivors first, in order
        idx = order[:cap]
        taken = needs[idx]
        t_c, s_c, m_c = chain_phase(
            q_pos[idx], t_pos[idx], hit_valid[idx],
            jnp.where(taken, cnt[idx], 0), cfg, prims)
        sidx = jnp.where(taken, idx, R)      # out-of-bounds rows -> dropped
        t0 = jnp.full((R,), empty.t_start, jnp.int32)
        s0 = jnp.full((R,), empty.score, jnp.float32)
        m0 = jnp.zeros((R,), bool)
        return (t0.at[sidx].set(t_c, mode="drop"),
                s0.at[sidx].set(s_c, mode="drop"),
                m0.at[sidx].set(m_c, mode="drop"))

    return jax.lax.cond(needs.sum() <= cap, run_compacted, run_all)


def _chunk_program(signals: jnp.ndarray, index: Dict[str, jnp.ndarray],
                   cfg: MarsConfig, plan: stages.Plan,
                   row_valid: jnp.ndarray) -> MapOutput:
    """The shared chunk body: run the stage graph over a chunk, mask pad rows
    out of the counters, and sum to the uniform per-chunk counter schema.

    With ``cfg.chain_compaction`` (default) the graph is split: CHEAP_STAGES
    vmap over every read, then the chaining phase runs via the filter-aware
    fast path (``_chain_outputs``).  The chain-stage counters are exact in
    closed form from the per-read post-vote anchor count (n_sorted =
    min(cnt, A); n_dp_pairs = n_sorted * B), so the counter schema is
    identical to the unpartitioned path.  Disabling compaction (or a plan
    whose chain stages expose no primitives) falls back to the original
    whole-graph vmap.
    """
    rv = row_valid
    prims = (stages.chain_primitives(plan, cfg)
             if cfg.chain_compaction else None)
    if prims is None:
        fn = lambda s: stages.execute_read(s, index, cfg, plan)
        res, counters = jax.vmap(fn)(signals)
        t_start, score, mapped = res.t_start, res.score, res.mapped
    else:
        q_pos, t_pos, hit_valid, counters = cheap_phase(
            signals, index, cfg, plan)
        cnt = counters["n_anchors_postvote"]
        n_sorted = jnp.minimum(cnt, cfg.max_anchors)
        counters = {**counters, "n_sorted": n_sorted,
                    "n_dp_pairs": n_sorted * cfg.chain_band}
        missing = stages.missing_counters(counters)
        if missing:
            raise RuntimeError(f"plan {plan} produced incomplete counters; "
                               f"missing {missing}")
        t_start, score, mapped = _chain_outputs(
            q_pos, t_pos, hit_valid, cnt, cfg, prims)
    # sum per-read counters over valid rows; per-stage DEBUG counters (e.g.
    # n_votes_clipped) are dropped so MapOutput.counters is exactly
    # CHUNK_COUNTER_SCHEMA — unchanged for every schema-keyed consumer
    summed = {k: jnp.where(rv, v, jnp.zeros_like(v)).sum().astype(jnp.int32)
              for k, v in counters.items()
              if k not in stages.DEBUG_COUNTER_SCHEMA}
    summed["n_reads"] = rv.sum().astype(jnp.int32)
    summed["n_samples"] = (rv.sum() * signals.shape[1]).astype(jnp.int32)
    return MapOutput(
        t_start=t_start, score=score, mapped=mapped & rv,
        n_events=jnp.where(rv, counters["n_events"], 0).astype(jnp.int32),
        counters=summed)


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernels", "plan"))
def map_chunk(signals: jnp.ndarray, index: Dict[str, jnp.ndarray],
              cfg: MarsConfig, use_kernels: bool = False,
              n_valid=None, plan: Optional[stages.Plan] = None) -> MapOutput:
    """signals: (R, S) f32.  The jit'd mapping program for one chunk.

    ``plan`` (static) overrides backend selection; otherwise it resolves
    from the registry: every stage's Pallas backend when ``use_kernels``,
    reference backends when not.  ``n_valid`` (traced; defaults to R) masks
    trailing pad rows out of counters and the ``mapped`` flags.

    Contract: plan choice is result-invisible — every plan produces
    bit-identical per-read outputs and the returned ``counters`` dict
    carries exactly ``stages.CHUNK_COUNTER_SCHEMA`` (docs/COUNTERS.md),
    so cost models and benchmarks can compare backends on one schema.
    """
    if plan is None:
        plan = stages.resolve_plan(
            cfg, stages.PALLAS if use_kernels else stages.REFERENCE)
    if stages.plan_index_kind(plan) == "partitioned":
        raise ValueError(
            f"plan {plan} uses a partitioned-index query backend; run it "
            "through map_chunk_sharded with a mesh (partitions live on the "
            "'model' axis)")
    R = signals.shape[0]
    if n_valid is None:
        row_valid = jnp.ones((R,), bool)
    else:
        row_valid = jnp.arange(R) < n_valid
    return _chunk_program(signals, index, cfg, plan, row_valid)


# --------------------------------------------------------------------------- #
# Sharded chunk mapping (shard_map over the read axis)
# --------------------------------------------------------------------------- #
@functools.lru_cache(maxsize=None)
def _sharded_chunk_fn(cfg: MarsConfig, mesh, plan: stages.Plan,
                      index_keys: Optional[Tuple[str, ...]] = None):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)

    def body(signals, index, n_valid):
        # local shard: (R_loc, S); reconstruct global row ids for masking
        shard_id = jnp.int32(0)
        for a in axes:
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)
        r_loc = signals.shape[0]
        row_valid = (shard_id * r_loc + jnp.arange(r_loc)) < n_valid
        out = _chunk_program(signals, index, cfg, plan, row_valid)
        counters = {k: jax.lax.psum(v, axes) for k, v in out.counters.items()}
        return out.t_start, out.score, out.mapped, out.n_events, counters

    # index layout follows the plan's query backend: the whole table on
    # every device, or one bucket-range partition per INDEX_AXIS rank
    # (query:ring / query:a2a, core/distributed.py)
    if stages.plan_index_kind(plan) == "partitioned":
        from repro.core.index import INDEX_AXIS, PARTITIONED_INDEX_KEYS
        index_spec = {k: P(INDEX_AXIS) for k in PARTITIONED_INDEX_KEYS}
    elif index_keys is not None:
        # tiered view carrying the traffic pre-pass's per-read planes
        # (core/tiered.PREPASS_KEYS): those shard over the read axis like
        # the signals so cheap_phase reuse survives the mesh; the tile
        # planes stay replicated
        per_read = {"t_pre_keys": P(axes, None),
                    "t_pre_valid": P(axes, None),
                    "t_pre_nev": P(axes)}
        index_spec = {k: per_read.get(k, P()) for k in index_keys}
    else:
        index_spec = P()
    counter_spec = {k: P() for k in stages.CHUNK_COUNTER_SCHEMA}
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axes, None), index_spec, P()),
                   out_specs=(P(axes), P(axes), P(axes), P(axes),
                              counter_spec),
                   check_rep=False)
    return jax.jit(fn)


def sharded_chunk_fn(cfg: MarsConfig, mesh, plan: stages.Plan):
    """The jit'd sharded chunk program for a resolved plan:
    ``fn(signals (R,S), index pytree, n_valid) -> (t_start, score, mapped,
    n_events, counters)``.  Public accessor for callers that need the raw
    program rather than ``map_chunk_sharded``'s host conveniences — e.g.
    the legacy distributed-mapper wrapper and abstract ``.lower`` dry-runs
    (launch/dryrun.py), where device_put on ShapeDtypeStructs is
    impossible.  Cached per (cfg, mesh, plan)."""
    return _sharded_chunk_fn(cfg, mesh, plan)


def _prepass_index_keys(index) -> Optional[Tuple[str, ...]]:
    """The index pytree's key set when it carries per-read traffic-pre-pass
    planes (tiered reuse_prepass under a mesh) — the sharded chunk fn needs
    per-key in_specs for those; None for every other index layout."""
    try:
        keys = tuple(sorted(index))
    except TypeError:
        return None
    return keys if "t_pre_keys" in keys else None


def map_chunk_sharded(signals: jnp.ndarray, index: Dict[str, jnp.ndarray],
                      cfg: MarsConfig, mesh, use_kernels: bool = False,
                      n_valid=None,
                      plan: Optional[stages.Plan] = None) -> MapOutput:
    """Data-parallel ``map_chunk``: reads sharded over EVERY mesh axis (the
    MARS "channel stripe"), counters psum-combined.  The index is either
    replicated (default plans) or, for the `query:ring` / `query:a2a`
    backends, the ``partition_index`` pytree with one bucket-range
    partition resident per 'model' rank — either way the chunk program is
    IDENTICAL to the single-device path.

    Per-read programs are independent and each seed's bucket lives in
    exactly one partition, so outputs are bit-identical to the
    single-device path; integer counter sums are associative, so the psum
    is exact.  R must divide evenly over the mesh.
    """
    if plan is None:
        plan = stages.resolve_plan(
            cfg, stages.PALLAS if use_kernels else stages.REFERENCE)
    R = signals.shape[0]
    n_dev = int(np.prod(tuple(mesh.shape.values())))
    if R % n_dev != 0:
        raise ValueError(f"chunk of {R} reads does not shard over {n_dev} "
                         f"devices; pad the chunk to a multiple")
    from repro.core.index import INDEX_AXIS
    if (stages.plan_index_kind(plan) == "partitioned"
            and INDEX_AXIS not in mesh.axis_names):
        raise ValueError(f"plan {plan} partitions the index over the "
                         f"'{INDEX_AXIS}' axis, absent from mesh "
                         f"{mesh.axis_names}")
    from repro.distributed.sharding import mapping_chunk_shardings
    sig_sh, _ = mapping_chunk_shardings(mesh)
    signals = jax.device_put(signals, sig_sh)
    nv = jnp.int32(R if n_valid is None else n_valid)
    t, s, m, ne, counters = _sharded_chunk_fn(
        cfg, mesh, plan, _prepass_index_keys(index))(signals, index, nv)
    return MapOutput(t_start=t, score=s, mapped=m, n_events=ne,
                     counters=counters)


# --------------------------------------------------------------------------- #
# Host-side driver + accuracy scoring
# --------------------------------------------------------------------------- #
class Mapper:
    """Convenience host wrapper: owns the index arrays, resolves the
    backend plan once, and streams chunks through the unified driver.

    ``backend`` names a registry backend ("reference"/"pallas", or the
    partitioned-index query schedules "ring"/"a2a"); the legacy
    ``use_kernels=True`` flag is shorthand for backend="pallas".  With a
    ``mesh`` the chunks run through ``map_chunk_sharded`` instead; plans
    whose query backend is partitioned build the ``partition_index``
    arrays (one bucket-range partition per 'model' rank) instead of the
    replicated table, and REQUIRE a mesh with a 'model' axis.

    backend="tiered" keeps the index OUT OF CORE: the packed planes are
    split into ``tiles`` host-resident bucket-range tiles and only the
    tiles each chunk's seed traffic touches are paged into a
    ``cache_slots``-slot device cache (core/tiered.py), prefetching the
    next chunk's tiles while the current chunk computes.  Results are
    bit-identical to the resident table for every cache size and eviction
    order; the cache object (``self.cache``) carries hit/miss/paged-bytes
    telemetry.  ``index`` may also be a pre-built ``TieredIndex`` (e.g.
    from the streaming ``build_index_streaming``), in which case ``tiles``
    is ignored.  ``reuse_prepass`` (default) forwards the traffic
    pre-pass's detect/quantize/seed outputs to the main pass so that work
    runs once per chunk, not twice — bit-identical to recomputing, on the
    sharded path too (the sharded chunk program's index in_specs shard the
    per-read pre-pass planes over the read axis).

    ``fault_plan`` (tiered backend only) attaches a seeded
    ``core/faults.FaultPlan`` injection harness to the cache's page-in
    path; ``cache_retries`` / ``cache_backoff`` bound the checksummed
    retry loop (core/tiered.py).  A plan injecting nothing is
    byte-identical to no plan at all.  ``cache_replicas=K`` pins the K
    hottest tiles (by cumulative seed traffic) into extra replica slots
    — result-invisible, skewed-traffic residency (HotTileCache docs).
    """

    def __init__(self, index: Index, cfg: Optional[MarsConfig] = None,
                 use_kernels: bool = False, backend: Optional[str] = None,
                 mesh=None, tiles: int = 8, cache_slots: int = 4,
                 cache_policy: str = "lru", cache_seed: int = 0,
                 fault_plan=None, cache_retries: int = 3,
                 cache_backoff: float = 1.0, reuse_prepass: bool = True,
                 cache_replicas: int = 0):
        self.index = index
        self.cfg = cfg or index.cfg
        self.backend = backend or (
            stages.PALLAS if use_kernels else stages.REFERENCE)
        self.plan = stages.resolve_plan(self.cfg, self.backend)
        self.mesh = mesh
        self.cache = None
        if (fault_plan is not None
                and stages.plan_index_kind(self.plan) != "tiered"):
            raise ValueError(
                f"fault_plan hooks the tiered backend's tile page-in path; "
                f"backend {self.backend!r} resolves to index kind "
                f"{stages.plan_index_kind(self.plan)!r} (no page-in to "
                "inject into)")
        if stages.plan_index_kind(self.plan) == "tiered":
            from repro.core.index import TieredIndex, tier_index
            from repro.core.tiered import HotTileCache
            ti = (index if isinstance(index, TieredIndex)
                  else tier_index(index, tiles))
            self.cache = HotTileCache(ti, cache_slots, mesh=mesh,
                                      policy=cache_policy, seed=cache_seed,
                                      faults=fault_plan,
                                      max_retries=cache_retries,
                                      backoff_base=cache_backoff,
                                      reuse_prepass=reuse_prepass,
                                      replicas=cache_replicas)
            self.arrays = None
        elif stages.plan_index_kind(self.plan) == "partitioned":
            from repro.core.index import INDEX_AXIS, partition_index
            from repro.distributed.sharding import partitioned_index_shardings
            if mesh is None or INDEX_AXIS not in mesh.axis_names:
                raise ValueError(
                    f"backend {self.backend!r} partitions the index over "
                    f"the '{INDEX_AXIS}' axis; pass a mesh with one")
            parts = partition_index(index, mesh.shape[INDEX_AXIS])
            shardings = partitioned_index_shardings(mesh)
            self.arrays = {k: jax.device_put(jnp.asarray(v), shardings[k])
                           for k, v in parts.items()}
        else:
            self.arrays = {k: jnp.asarray(v)
                           for k, v in index_arrays(index).items()}
            if mesh is not None:
                from repro.distributed.sharding import mapping_chunk_shardings
                _, rep = mapping_chunk_shardings(mesh)
                self.arrays = {k: jax.device_put(v, rep)
                               for k, v in self.arrays.items()}

    # cfg fields known NOT to shape the index arrays — the only ones
    # with_cfg may change.  An allowlist so a future index-shaping field
    # fails closed instead of silently querying a stale resident table.
    _NON_INDEX_CFG_FIELDS = frozenset((
        "signal_len", "max_events", "tstat_window", "tstat_threshold",
        "peak_window", "min_dwell", "max_hits_per_seed",
        "use_freq_filter", "thresh_freq", "use_vote_filter",
        "thresh_voting", "voting_window_log2", "vote_bins",
        "max_anchors", "chain_band", "max_gap", "gap_cost", "skip_cost",
        "anchor_score", "min_chain_score", "map_ratio",
        "chain_compaction", "chain_capacity_frac", "chain_widths",
        "anchor_select",
    ))

    def with_cfg(self, cfg: MarsConfig) -> "Mapper":
        """A Mapper over the SAME device-resident index arrays with a
        different config (the plan re-resolves; the index upload — or
        partitioning — is not repeated).  Realtime mapping uses this for
        its per-prefix-length pipeline specializations; only fields that do
        not shape the index (signal_len, max_events, thresholds, ...) may
        change."""
        import copy
        import dataclasses
        changed = [f.name for f in dataclasses.fields(MarsConfig)
                   if (getattr(cfg, f.name) != getattr(self.cfg, f.name)
                       and f.name not in self._NON_INDEX_CFG_FIELDS)]
        if changed:
            raise ValueError(
                f"with_cfg changes fields {changed} not known to leave the "
                "index unchanged; build a new Mapper (the resident index "
                "arrays could be stale)")
        m = copy.copy(self)
        m.cfg = cfg
        m.plan = stages.resolve_plan(cfg, self.backend)
        return m

    def chunk_fn(self):
        """The (signals, n_valid) -> MapOutput program for driver.stream_map
        consumers that bring their own chunk source (e.g. the launcher's
        SignalReader)."""
        if self.cache is not None:
            cache, cfg, plan = self.cache, self.cfg, self.plan
            if self.mesh is not None:
                def fn(sig, nv):
                    view = cache.prepare(sig, cfg, plan)
                    return map_chunk_sharded(jnp.asarray(sig), view, cfg,
                                             self.mesh, n_valid=nv, plan=plan)
                return fn

            def fn(sig, nv):
                view = cache.prepare(sig, cfg, plan)
                return map_chunk(jnp.asarray(sig), view, cfg, n_valid=nv,
                                 plan=plan)
            return fn
        if self.mesh is not None:
            return lambda sig, nv: map_chunk_sharded(
                jnp.asarray(sig), self.arrays, self.cfg, self.mesh,
                n_valid=nv, plan=self.plan)
        return lambda sig, nv: map_chunk(jnp.asarray(sig), self.arrays,
                                         self.cfg, n_valid=nv, plan=self.plan)

    def map_signals(self, signals: np.ndarray, chunk: int = 64) -> MapOutput:
        prefetch = None
        if self.cache is not None:
            cache, cfg, plan = self.cache, self.cfg, self.plan
            # page the NEXT chunk's tiles while this chunk computes — the
            # software analogue of MARS's flash-load/compute overlap
            prefetch = lambda sig, nv: cache.prefetch(sig, cfg, plan)
        stream = driver.stream_map(self.chunk_fn(),
                                   driver.array_chunks(signals, chunk),
                                   prefetch=prefetch)
        return driver.collect(stream)

    def serve(self, **kw):
        """A continuous-batching ``ServeDriver`` over this mapper: many
        concurrent client streams packed into this pipeline's chunks
        (core/server.py).  Results are bit-identical to ``map_signals``
        on each stream's reads for any interleaving."""
        from repro.core.server import ServeDriver
        return ServeDriver(self, **kw)


def score_accuracy(out: MapOutput, true_pos: np.ndarray,
                   true_strand: np.ndarray, mappable: np.ndarray,
                   n_bases: np.ndarray, n_ref_events: int,
                   tol: int = 100) -> Dict[str, float]:
    """Precision/recall/F1 against simulator ground truth (UNCALLED
    pafstats-style; paper Section 8.1)."""
    t = np.asarray(out.t_start).astype(np.int64)
    strand = (t >= n_ref_events).astype(np.int8)
    span = np.maximum(np.asarray(n_bases).astype(np.int64), 1)
    fwd = np.where(strand == 0, t, n_ref_events - 1 - ((t - n_ref_events) + span - 1))
    mapped = np.asarray(out.mapped)
    correct = (np.abs(fwd - true_pos) <= tol) & (strand == true_strand)
    tp = int(np.sum(mapped & mappable & correct))
    fp = int(np.sum(mapped & ~(mappable & correct)))
    fn = int(np.sum(~mapped & mappable))
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    return dict(precision=prec, recall=rec, f1=f1, tp=tp, fp=fp, fn=fn)
