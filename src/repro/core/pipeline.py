"""End-to-end MARS read-mapping pipeline (paper Fig. 1 / Fig. 7 dataflow).

The per-read program chains the fine-grained tasks exactly as the MARS
Control Unit sequences them (Section 6.1.3):

    (1) event detection: signal-to-event conversion (1a) + quantization (1b)
    (2) seeding: hash-value generation (c), frequency filter (d),
        hash-table query (e), seed-and-vote filter (f)
    (3) chaining: bucket/sort (g,h) + dynamic programming (i)

Everything is static-shape and jit-compiled; `map_chunk` vmaps the per-read
program over a chunk of reads (a "channel stripe" in MARS terms).  Counter
outputs feed the analytic SSD performance model (ssd_model.py).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chaining, events, hashing, quantization, seeding, vote
from repro.core.config import MarsConfig
from repro.core.index import Index, index_arrays


class MapOutput(NamedTuple):
    t_start: jnp.ndarray    # (R,) int32 double-genome event coords
    score: jnp.ndarray      # (R,) f32
    mapped: jnp.ndarray     # (R,) bool
    n_events: jnp.ndarray   # (R,) int32
    counters: Dict[str, jnp.ndarray]


def map_read(signal: jnp.ndarray, index: Dict[str, jnp.ndarray],
             cfg: MarsConfig, gather=None, sorter=None, dp=None,
             detector=None):
    """signal: (S,) f32 -> per-read mapping + counters."""
    # (1) event detection
    if detector is None:
        ev, n_ev, _ = events.detect_events(signal, cfg)
    else:
        ev, n_ev = detector(signal)
    ev_valid = jnp.arange(cfg.max_events) < n_ev
    sym = quantization.quantize_events(ev, ev_valid, cfg)
    # (2) seeding
    keys, seed_valid = hashing.pack_seeds(sym, n_ev, cfg)
    seed_valid = hashing.minimizer_mask(keys, seed_valid,
                                        cfg.minimizer_radius)
    t_pos, hit_valid, c_seed = seeding.query_index(keys, seed_valid, index,
                                                   cfg, gather=gather)
    q_pos = jnp.broadcast_to(
        jnp.arange(cfg.max_events, dtype=jnp.int32)[:, None], t_pos.shape)
    hit_valid, c_vote = vote.vote_filter(q_pos, t_pos, hit_valid, cfg)
    # (3) chaining
    res, c_chain = chaining.chain_anchors(q_pos, t_pos, hit_valid, cfg,
                                          sorter=sorter, dp=dp)
    counters = dict(n_events=n_ev, **c_seed, **c_vote, **c_chain)
    return res, counters


@functools.partial(jax.jit, static_argnames=("cfg", "use_kernels"))
def map_chunk(signals: jnp.ndarray, index: Dict[str, jnp.ndarray],
              cfg: MarsConfig, use_kernels: bool = False) -> MapOutput:
    """signals: (R, S) f32.  The jit'd mapping program for one chunk."""
    gather = sorter = dp = detector = None
    if use_kernels:
        from repro.kernels.pluto_lookup import ops as pluto_ops
        from repro.kernels.bitonic_sort import ops as bitonic_ops
        from repro.kernels.chain_dp import ops as dp_ops
        from repro.kernels.event_detect import ops as ed_ops
        gather = pluto_ops.lookup
        sorter = bitonic_ops.sort1d
        dp = lambda q, t, v: tuple(
            x[0] for x in dp_ops.chain_dp(q[None], t[None], v[None], cfg))
        if cfg.fixed_point and cfg.early_quantization:
            detector = lambda s: tuple(
                x[0] for x in ed_ops.event_detect(s[None], cfg))
    fn = lambda s: map_read(s, index, cfg, gather=gather, sorter=sorter,
                            dp=dp, detector=detector)
    res, counters = jax.vmap(fn)(signals)
    summed = {k: v.sum().astype(jnp.int32) for k, v in counters.items()}
    summed["n_reads"] = jnp.int32(signals.shape[0])
    summed["n_samples"] = jnp.int32(signals.shape[0] * signals.shape[1])
    return MapOutput(t_start=res.t_start, score=res.score, mapped=res.mapped,
                     n_events=counters["n_events"].astype(jnp.int32),
                     counters=summed)


# --------------------------------------------------------------------------- #
# Host-side driver + accuracy scoring
# --------------------------------------------------------------------------- #
class Mapper:
    """Convenience host wrapper: owns the index arrays and chunks reads."""

    def __init__(self, index: Index, cfg: Optional[MarsConfig] = None,
                 use_kernels: bool = False):
        self.index = index
        self.cfg = cfg or index.cfg
        self.use_kernels = use_kernels
        self.arrays = {k: jnp.asarray(v) for k, v in index_arrays(index).items()}

    def map_signals(self, signals: np.ndarray, chunk: int = 64) -> MapOutput:
        outs = []
        for lo in range(0, signals.shape[0], chunk):
            part = signals[lo:lo + chunk]
            if part.shape[0] < chunk:   # pad to static chunk size
                pad = chunk - part.shape[0]
                part = np.concatenate([part, np.zeros((pad,) + part.shape[1:],
                                                      part.dtype)])
            outs.append(map_chunk(jnp.asarray(part), self.arrays, self.cfg,
                                  self.use_kernels))
        n = signals.shape[0]
        t_start = np.concatenate([np.asarray(o.t_start) for o in outs])[:n]
        score = np.concatenate([np.asarray(o.score) for o in outs])[:n]
        mapped = np.concatenate([np.asarray(o.mapped) for o in outs])[:n]
        n_events = np.concatenate([np.asarray(o.n_events) for o in outs])[:n]
        counters: Dict[str, int] = {}
        for o in outs:
            for k, v in o.counters.items():
                counters[k] = counters.get(k, 0) + int(v)
        return MapOutput(t_start=t_start, score=score, mapped=mapped,
                         n_events=n_events, counters=counters)


def score_accuracy(out: MapOutput, true_pos: np.ndarray,
                   true_strand: np.ndarray, mappable: np.ndarray,
                   n_bases: np.ndarray, n_ref_events: int,
                   tol: int = 100) -> Dict[str, float]:
    """Precision/recall/F1 against simulator ground truth (UNCALLED
    pafstats-style; paper Section 8.1)."""
    t = np.asarray(out.t_start).astype(np.int64)
    strand = (t >= n_ref_events).astype(np.int8)
    span = np.maximum(np.asarray(n_bases).astype(np.int64), 1)
    fwd = np.where(strand == 0, t, n_ref_events - 1 - ((t - n_ref_events) + span - 1))
    mapped = np.asarray(out.mapped)
    correct = (np.abs(fwd - true_pos) <= tol) & (strand == true_strand)
    tp = int(np.sum(mapped & mappable & correct))
    fp = int(np.sum(mapped & ~(mappable & correct)))
    fn = int(np.sum(~mapped & mappable))
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    return dict(precision=prec, recall=rec, f1=f1, tp=tp, fp=fp, fn=fn)
