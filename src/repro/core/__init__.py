"""MARS core: the paper's contribution as composable JAX modules.

Public API:
    MarsConfig            static pipeline configuration
    build_index           offline reference indexing
    stages                stage-graph engine + backend registry
    Mapper / map_chunk    online read mapping (jit)
    map_chunk_sharded     data-parallel mapping over a device mesh
    driver                unified streaming host driver + ProgressLog
    ServeDriver           continuous-batching multi-stream serving driver
    SLOClass              serving class (priority/deadline/shed contract)
    TenantBudget          per-tenant fair-share shed budget (token bucket)
    FaultPlan             seeded storage-fault injection harness
    repartition_index     online drive-loss rebalancing (N -> N/2 fold)
    score_accuracy        P/R/F1 vs. ground truth
    costmodel             unified Workload->cost interface (analytic | sim)
"""
from repro.core import costmodel, driver, stages
from repro.core.server import (ClassReport, ServeDriver, SLOClass,
                               StreamReport, TenantBudget, TenantReport)
from repro.core.config import (DEFAULT, MODE_MS_FIXED, MODE_MS_FLOAT,
                               MODE_RH2, MODES, MarsConfig)
from repro.core.faults import (FaultPlan, InjectedPrefetchError,
                               TileReadError, sample_fault_plans)
from repro.core.index import (Index, build_index, index_arrays,
                              index_arrays_unpacked, partition_index,
                              repartition_index)
from repro.core.pipeline import (MapOutput, Mapper, map_chunk,
                                 map_chunk_sharded, map_read, score_accuracy)

__all__ = [
    "DEFAULT", "MODES", "MODE_RH2", "MODE_MS_FLOAT", "MODE_MS_FIXED",
    "MarsConfig", "Index", "build_index", "index_arrays",
    "index_arrays_unpacked", "partition_index", "repartition_index",
    "MapOutput", "Mapper", "map_chunk", "map_chunk_sharded", "map_read",
    "costmodel", "driver", "stages", "score_accuracy", "ServeDriver",
    "StreamReport",
    "SLOClass", "ClassReport", "TenantBudget", "TenantReport",
    "FaultPlan", "TileReadError",
    "InjectedPrefetchError", "sample_fault_plans",
]
