"""Deterministic fault injection for the storage path.

MARS's in-storage pipeline assumes the storage subsystem behaves; real SSD
arrays lose channels and whole drives, return corrupted pages, and stall
under load (the degraded-array regimes GenStore and MegIS design for
explicitly).  This module is the seeded fault harness the reproduction's
storage path — the host-resident tiled index and its hot-tile device cache
(core/tiered.py) — is exercised against:

  * ``FaultPlan`` is an immutable, fully seeded description of which
    faults fire where.  Every decision is a *keyed* draw — a fresh
    ``np.random.Generator`` seeded by ``(plan.seed, site, tile, attempt)``
    — so a plan is deterministic regardless of call order, cache policy or
    chunk schedule: the same plan over the same inputs reproduces the same
    faults, which is what makes a failing sweep entry replayable from its
    seed alone.
  * ``FaultInjector`` applies a plan at the tile page-in boundary
    (``HotTileCache._fetch_tile``): transient read failures (raises
    ``TransientTileError`` — retried), payload corruption (a deterministic
    bit flip on a *copy* of the paged planes — caught by the per-tile
    CRC32 and retried), transient latency spikes (virtual-time accounted),
    sticky-corrupt tiles (corrupt on every attempt, so retries exhaust and
    ``TileReadError`` surfaces loudly), and prefetch-hook exceptions.
  * drive loss for partitioned plans is described, not injected: a plan's
    ``failed_drive`` names the rank whose bucket range must be folded onto
    the survivors via ``core/index.repartition_index`` — the sweep driver
    (scripts/fault_sweep.py, launch/serve_rsga.py --fault-plan) wires it.

The happy path is untouched when no plan is attached (``HotTileCache``
only consults an injector when one exists), and a plan that injects
nothing is byte-identical to no harness at all — the bit-parity oracle of
tests/test_faults.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


class TileReadError(RuntimeError):
    """A tile page-in failed for good: every attempt (1 + max_retries) was
    lost to a read failure or a checksum mismatch.  Raised by
    ``HotTileCache._fetch_tile`` so a corrupted tile can NEVER silently
    contribute wrong hits — the no-silent-wrong-answers contract."""


class TransientTileError(TileReadError):
    """One injected tile-read failure (a lost flash page / channel hiccup).
    Internal to the retry loop: the cache backs off and re-reads; only an
    exhausted retry budget escalates to ``TileReadError``."""


class InjectedPrefetchError(RuntimeError):
    """An injected failure of the driver loop's prefetch hook (the
    read-ahead tile staging of ``driver.stream_map(prefetch=...)``)."""


# Keyed-draw site tags (the `site` component of the RNG key).  Distinct
# per fault type so e.g. a read-failure draw never correlates with the
# corruption draw at the same (tile, attempt).
_SITE_READ = 1
_SITE_CORRUPT = 2
_SITE_LATENCY = 3
_SITE_FLIP = 4
_SITE_PREFETCH = 5


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One seeded storage-fault scenario.

    Probabilities are per (tile, attempt) page-in draw; sets are exact.
    ``failed_drive`` marks a partitioned-index drive loss for the
    rebalancing path (``core/index.repartition_index``) — it does not
    affect tile paging.  ``prefetch_error_serials`` are 0-based prefetch
    invocation counts at which the prefetch hook raises
    ``InjectedPrefetchError`` (the ``driver.stream_map`` regression).
    """
    seed: int = 0
    p_read_error: float = 0.0          # transient page-in failure
    p_corrupt: float = 0.0             # transient payload corruption
    p_latency: float = 0.0             # transient latency spike
    latency_units: float = 4.0         # virtual time added per spike
    sticky_corrupt_tiles: frozenset = frozenset()   # never heal -> raise
    failed_drive: Optional[int] = None              # partitioned plans
    prefetch_error_serials: frozenset = frozenset()

    def __post_init__(self):
        for name in ("p_read_error", "p_corrupt", "p_latency"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be a probability in [0, 1]; "
                                 f"got {p}")
        if self.latency_units < 0:
            raise ValueError(f"latency_units must be >= 0; "
                             f"got {self.latency_units}")
        # frozenset-ify so hand-written plans with lists/tuples still hash
        object.__setattr__(self, "sticky_corrupt_tiles",
                           frozenset(int(t) for t in
                                     self.sticky_corrupt_tiles))
        object.__setattr__(self, "prefetch_error_serials",
                           frozenset(int(s) for s in
                                     self.prefetch_error_serials))

    @property
    def enabled(self) -> bool:
        """Whether this plan can inject ANYTHING at the tile-paging
        boundary.  A disabled plan is never consulted — the cache drops
        the injector entirely, so attaching it is byte-identical to no
        harness at all (the zero-fault parity oracle)."""
        return bool(self.p_read_error or self.p_corrupt or self.p_latency
                    or self.sticky_corrupt_tiles
                    or self.prefetch_error_serials)


def _draw(plan: FaultPlan, site: int, *key: int) -> np.random.Generator:
    """A fresh generator keyed by (plan.seed, site, *key) — deterministic
    for the key regardless of global RNG state or call order."""
    return np.random.default_rng(
        (np.uint64(plan.seed & 0xFFFFFFFF), np.uint64(site))
        + tuple(np.uint64(k & 0xFFFFFFFFFFFFFFFF) for k in key))


class FaultInjector:
    """Applies a ``FaultPlan`` at the storage-path hook points.

    Stateless apart from the plan (every decision is a keyed draw), so one
    injector can be shared by a cache and its prefetch path.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    # ------------------------------------------------------------- paging
    def tile_read(self, tile: int, attempt: int,
                  bstart: np.ndarray, ent: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray, float]:
        """One tile page-in attempt.  Returns (bstart, ent, latency_units)
        — possibly corrupted COPIES (the host index is never mutated) —
        or raises ``TransientTileError`` for an injected read failure.
        """
        p = self.plan
        lat = 0.0
        if p.p_latency and _draw(p, _SITE_LATENCY, tile,
                                 attempt).random() < p.p_latency:
            lat = p.latency_units
        if p.p_read_error and _draw(p, _SITE_READ, tile,
                                    attempt).random() < p.p_read_error:
            raise TransientTileError(
                f"injected read failure: tile {tile}, attempt {attempt} "
                f"(plan seed {p.seed})")
        corrupt = tile in p.sticky_corrupt_tiles
        if not corrupt and p.p_corrupt:
            corrupt = _draw(p, _SITE_CORRUPT, tile,
                            attempt).random() < p.p_corrupt
        if corrupt:
            ent = self._flip_bit(ent, tile, attempt)
        return bstart, ent, lat

    def _flip_bit(self, ent: np.ndarray, tile: int,
                  attempt: int) -> np.ndarray:
        """Flip one deterministic bit in a COPY of the entry plane.  CRC32
        detects every single-bit error, so an injected corruption is
        always caught at verify time — healed by a clean re-read or, for a
        sticky tile, escalated to ``TileReadError``; never silent."""
        ent = np.array(ent, copy=True)
        rng = _draw(self.plan, _SITE_FLIP, tile, attempt)
        pos = int(rng.integers(ent.size))
        bit = int(rng.integers(31))
        flat = ent.reshape(-1)
        flat[pos] = np.int32(np.uint32(flat[pos]) ^ np.uint32(1 << bit))
        return ent

    # ----------------------------------------------------------- prefetch
    def check_prefetch(self, serial: int) -> None:
        """Raise ``InjectedPrefetchError`` when the plan marks this
        prefetch invocation (0-based count) as failing."""
        if serial in self.plan.prefetch_error_serials:
            raise InjectedPrefetchError(
                f"injected prefetch failure at prefetch serial {serial} "
                f"(plan seed {self.plan.seed})")


def sample_fault_plans(n: int, seed: int = 0, n_tiles: int = 8,
                       n_drives: int = 4) -> Tuple[FaultPlan, ...]:
    """A deterministic sweep of ``n`` mixed fault plans derived from ONE
    seed — the reproducible grid tests/test_faults.py and
    scripts/fault_sweep.py assert the no-silent-wrong-answers contract
    over.  Covers transient read errors, transient + sticky corruption,
    latency spikes, prefetch failures and drive loss, alone and combined.
    """
    if n < 1:
        raise ValueError(f"need n >= 1 plans; got {n}")
    rng = np.random.default_rng((np.uint64(seed), np.uint64(0xFA017)))
    plans = []
    for i in range(n):
        kind = i % 5
        p = dict(seed=int(rng.integers(1 << 31)))
        if kind == 0:                       # transient read errors
            p["p_read_error"] = float(rng.uniform(0.05, 0.5))
        elif kind == 1:                     # transient corruption
            p["p_corrupt"] = float(rng.uniform(0.05, 0.5))
        elif kind == 2:                     # sticky corruption (must raise)
            p["sticky_corrupt_tiles"] = frozenset(
                {int(rng.integers(n_tiles))})
        elif kind == 3:                     # latency + mixed transients
            p["p_latency"] = float(rng.uniform(0.1, 0.8))
            p["p_read_error"] = float(rng.uniform(0.0, 0.3))
            p["p_corrupt"] = float(rng.uniform(0.0, 0.3))
        else:                               # drive loss + light corruption
            p["failed_drive"] = int(rng.integers(n_drives))
            p["p_corrupt"] = float(rng.uniform(0.0, 0.2))
        plans.append(FaultPlan(**p))
    return tuple(plans)
