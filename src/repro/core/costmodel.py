"""The unified Workload->cost interface over both performance backends.

Every consumer of the performance model — the Fig. 11/12/13 benchmarks,
``benchmarks/calibrate_serving.py``, ``launch/serve_rsga.py`` and the
serving driver's closed-loop shed controller — goes through ONE
``CostModel`` protocol with two registered implementations:

  * ``analytic`` — the closed forms of ``core/ssd_model.py`` (kept as
    the calibration oracle: Table-1 first-principles rates + the
    M/D/c queueing core);
  * ``sim``      — the discrete-event machine of ``core/sim/`` (flash
    channels x dies, controller-sequenced PNM units, internal-DRAM and
    host links), which must agree with the analytic forms to <1% on
    degenerate no-contention configs and adds the per-component
    busy/idle/queue-delay breakdown under contention.

Host-side baseline systems (RH2 / BC / MS-CPU / GenPIP ...) are modeled
by the analytic host formulas under EITHER backend — only the MARS
in-storage path has an event-driven twin; ``system_latency_energy``
routes exactly that path through the selected model.

The shed controller's overload signal also lives here
(``shed_signal``): offered-load saturation from the queueing model OR a
measured-queue-delay trip (recent per-read dispatch delays exceeding
``delay_limit`` chunk services) — the second term catches effective-
capacity loss (e.g. storage-path retry/backoff stretching the virtual
clock) that offered load alone cannot see.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Union

from repro.core import ssd_model
from repro.core.workload import Workload

# Measured-queue-delay trip point: shed when the recent mean per-read
# queue delay exceeds this many chunk services (a healthy driver below
# saturation keeps the mean delay near one chunk_cost).
SHED_DELAY_LIMIT = 4.0


def _delay_tripped(queue_delays: Sequence[float], chunk_cost: float,
                   delay_limit: float) -> bool:
    if not queue_delays:
        return False
    mean = sum(queue_delays) / len(queue_delays)
    return mean > delay_limit * max(chunk_cost, 1e-12)


def skew_factors(traffic: Sequence[float], replicas: int = 0,
                 copies: int = 2) -> tuple:
    """Query-lane load-imbalance factors from a per-tile probe histogram
    (``HotTileCache.tile_traffic()``).

    Tiles stripe 1:1 over query lanes, so the hottest tile sets the pace:
    ``factor = n_tiles * max_i p_i`` where ``p_i`` is tile i's probe
    share — 1.0 for uniform traffic, ``n_tiles`` when every probe lands
    on one tile.  Replicating the top-``replicas`` tiles (same
    traffic-then-tile-id order as ``HotTileCache._refresh_replicas``)
    serves each from ``copies`` lanes, dividing its load.  Returns
    ``(factor, factor_replicated)``, both floored at the uniform 1.0.
    """
    if replicas < 0:
        raise ValueError(f"replicas must be >= 0; got {replicas}")
    if copies < 1:
        raise ValueError(f"copies must be >= 1; got {copies}")
    t = [max(0.0, float(x)) for x in traffic]
    total = sum(t)
    n = len(t)
    if n == 0 or total <= 0:
        return 1.0, 1.0
    top = set(sorted(range(n), key=lambda i: (-t[i], i))[:int(replicas)])
    factor = max(1.0, n * max(t) / total)
    eff = max(t[i] / (copies if i in top else 1) for i in range(n))
    factor_repl = max(1.0, n * eff / total)
    return factor, factor_repl


class CostModel:
    """The Workload->cost protocol both backends implement."""

    name: str = "base"

    # ---- batch latency / energy ------------------------------------- #
    def latency(self, w: Workload,
                ssd: ssd_model.SSDConfig = ssd_model.SSDConfig()) -> Dict:
        raise NotImplementedError

    def energy(self, w: Workload,
               ssd: ssd_model.SSDConfig = ssd_model.SSDConfig()) -> float:
        raise NotImplementedError

    # ---- multi-SSD array -------------------------------------------- #
    def array_latency(self, w: Workload,
                      arr: ssd_model.SSDArrayConfig = ssd_model.SSDArrayConfig()
                      ) -> Dict:
        raise NotImplementedError

    def array_energy(self, w: Workload,
                     arr: ssd_model.SSDArrayConfig = ssd_model.SSDArrayConfig()
                     ) -> float:
        raise NotImplementedError

    # ---- serving queues --------------------------------------------- #
    def serving(self, w: Workload, offered_load: float,
                arr: ssd_model.SSDArrayConfig = ssd_model.SSDArrayConfig(),
                percentiles: Sequence[float] = (50.0, 99.0)) -> Dict:
        raise NotImplementedError

    def serving_virtual(self, chunk: int, offered_load: float,
                        chunk_cost: float = 1.0,
                        percentiles: Sequence[float] = (50.0, 99.0)) -> Dict:
        raise NotImplementedError

    # ---- sensitivity + full system table ---------------------------- #
    def dram_sensitivity(self, w: Workload,
                         sizes=(2 << 30, 4 << 30, 8 << 30),
                         ssd: ssd_model.SSDConfig = ssd_model.SSDConfig()
                         ) -> Dict[int, float]:
        raise NotImplementedError

    def system_latency_energy(self, system: str, w: Workload,
                              rates: ssd_model.HostRates = ssd_model.HostRates(),
                              ssd: ssd_model.SSDConfig = ssd_model.SSDConfig(),
                              host: ssd_model.HostConfig = ssd_model.HostConfig()
                              ) -> Dict:
        """Latency + energy for any evaluated system.  The MARS in-storage
        path routes through this model's ``latency``/``energy``; the
        host-side baselines keep the analytic host formulas (they have no
        event-driven twin)."""
        if system != "MARS":
            return ssd_model.system_latency_energy(system, w, rates, ssd,
                                                   host)
        lat = self.latency(w, ssd)
        e = self.energy(w, ssd)
        return dict(total=lat["total"], compute=lat["compute"],
                    io=lat["flash"], energy=e,
                    energy_dynamic=e - ssd_model.SSD_ACTIVE_W * lat["total"],
                    stages=lat)

    # ---- skewed traffic + hot-tile replication ----------------------- #
    def skewed_serving(self, w: Workload, traffic: Sequence[float],
                       replicas: int = 0, copies: int = 2,
                       ssd: ssd_model.SSDConfig = ssd_model.SSDConfig()
                       ) -> Dict:
        """Price hot-bucket skew and the replication win: stretch the
        query stage by the load-imbalance ``skew_factors`` of ``traffic``
        (a per-tile probe histogram, e.g. ``HotTileCache.tile_traffic()``)
        and re-price the batch with the top-``replicas`` tiles served
        from ``copies`` lanes.  Returns the factors, the skewed and
        replicated totals, and ``replication_speedup`` (>= 1; exactly 1
        on uniform traffic, where both totals equal ``latency(w)``)."""
        raise NotImplementedError

    # ---- the shed controller's overload signal ----------------------- #
    def shed_signal(self, chunk: int, chunk_cost: float, offered_load: float,
                    queue_delays: Sequence[float] = (),
                    delay_limit: float = SHED_DELAY_LIMIT) -> bool:
        """True when the serving driver should shed: the queueing model
        reports no steady state at the trailing offered load, OR the
        measured recent queue delays trip ``delay_limit`` chunk
        services."""
        raise NotImplementedError


class AnalyticModel(CostModel):
    """The closed forms of ``core/ssd_model.py``."""

    name = "analytic"

    def latency(self, w, ssd=ssd_model.SSDConfig()):
        return ssd_model.mars_latency(w, ssd)

    def energy(self, w, ssd=ssd_model.SSDConfig()):
        return ssd_model.mars_energy(w, ssd)

    def array_latency(self, w, arr=ssd_model.SSDArrayConfig()):
        return ssd_model.mars_array_latency(w, arr)

    def array_energy(self, w, arr=ssd_model.SSDArrayConfig()):
        return ssd_model.mars_array_energy(w, arr)

    def serving(self, w, offered_load, arr=ssd_model.SSDArrayConfig(),
                percentiles=(50.0, 99.0)):
        return ssd_model.serving_latency(w, offered_load, arr, percentiles)

    def serving_virtual(self, chunk, offered_load, chunk_cost=1.0,
                        percentiles=(50.0, 99.0)):
        return ssd_model.serving_latency_virtual(chunk, offered_load,
                                                 chunk_cost, percentiles)

    def dram_sensitivity(self, w, sizes=(2 << 30, 4 << 30, 8 << 30),
                         ssd=ssd_model.SSDConfig()):
        return ssd_model.dram_size_sensitivity(w, sizes, ssd)

    def skewed_serving(self, w, traffic, replicas=0, copies=2,
                       ssd=ssd_model.SSDConfig()):
        f, fr = skew_factors(traffic, replicas, copies)
        st = ssd_model.mars_stage_times(w, ssd)
        compute = (st["event_detection"] + st["seeding"] + st["filters"] +
                   st["sorting"] + st["chaining_dp"] + st["dram_move"])
        q = st["seeding_query"]

        def law(c):
            # the Section 6.3 overlap law of mars_latency
            return max(st["flash"], c) + 0.02 * min(st["flash"], c)

        total = law(compute + q * (f - 1.0))
        total_repl = law(compute + q * (fr - 1.0))
        return dict(factor=f, factor_replicated=fr, total=total,
                    total_replicated=total_repl, query=q * f,
                    query_replicated=q * fr,
                    replication_speedup=total / total_repl,
                    n_tiles=len(traffic), replicas=int(replicas))

    def shed_signal(self, chunk, chunk_cost, offered_load, queue_delays=(),
                    delay_limit=SHED_DELAY_LIMIT):
        if offered_load > 0 and ssd_model.serving_latency_virtual(
                chunk, offered_load, chunk_cost)["saturated"]:
            return True
        return _delay_tripped(queue_delays, chunk_cost, delay_limit)


class SimModel(CostModel):
    """The discrete-event machine of ``core/sim/``.

    Energy keeps the analytic DYNAMIC component energies (they are
    per-op constants, not timing) and charges static power over the
    SIMULATED runtime — identical accounting, simulated clock.
    """

    name = "sim"

    def __init__(self, n_stripes: Optional[int] = None, seed: int = 0):
        from repro.core.sim import ssdsim
        self.n_stripes = int(n_stripes or ssdsim.N_STRIPES)
        self.seed = int(seed)

    def latency(self, w, ssd=ssd_model.SSDConfig()):
        from repro.core.sim import ssdsim
        return ssdsim.simulate_batch(w, ssd, n_stripes=self.n_stripes)

    def energy(self, w, ssd=ssd_model.SSDConfig()):
        dyn = (ssd_model.mars_energy(w, ssd) - ssd_model.SSD_ACTIVE_W
               * ssd_model.mars_latency(w, ssd)["total"])
        return dyn + ssd_model.SSD_ACTIVE_W * self.latency(w, ssd)["total"]

    def array_latency(self, w, arr=ssd_model.SSDArrayConfig()):
        from repro.core.sim import ssdsim
        return ssdsim.simulate_array_latency(w, arr,
                                             n_stripes=self.n_stripes)

    def array_energy(self, w, arr=ssd_model.SSDArrayConfig()):
        per = w.scale(1.0 / arr.n_serving)
        per_dyn = (ssd_model.mars_energy(per, arr.ssd)
                   - ssd_model.SSD_ACTIVE_W
                   * ssd_model.mars_latency(per, arr.ssd)["total"])
        static = (arr.n_serving * ssd_model.SSD_ACTIVE_W
                  * self.array_latency(w, arr)["total"])
        merge = (w.n_reads * arr.result_bytes_per_read
                 * ssd_model.ENERGY["pcie_byte"])
        return arr.n_serving * per_dyn + static + merge

    def serving(self, w, offered_load, arr=ssd_model.SSDArrayConfig(),
                percentiles=(50.0, 99.0)):
        from repro.core.sim import serve_sim
        return serve_sim.simulate_serving(w, offered_load, arr, percentiles,
                                          seed=self.seed)

    def serving_virtual(self, chunk, offered_load, chunk_cost=1.0,
                        percentiles=(50.0, 99.0)):
        from repro.core.sim import serve_sim
        return serve_sim.simulate_serving_virtual(chunk, offered_load,
                                                  chunk_cost, percentiles,
                                                  seed=self.seed)

    def dram_sensitivity(self, w, sizes=(2 << 30, 4 << 30, 8 << 30),
                         ssd=ssd_model.SSDConfig()):
        from repro.core.sim import ssdsim
        return ssdsim.simulate_dram_sensitivity(w, sizes, ssd,
                                                n_stripes=self.n_stripes)

    def skewed_serving(self, w, traffic, replicas=0, copies=2,
                       ssd=ssd_model.SSDConfig()):
        from repro.core.sim import ssdsim
        f, fr = skew_factors(traffic, replicas, copies)
        skewed = ssdsim.simulate_batch(w, ssd, n_stripes=self.n_stripes,
                                       query_scale=f)
        repl = ssdsim.simulate_batch(w, ssd, n_stripes=self.n_stripes,
                                     query_scale=fr)
        return dict(factor=f, factor_replicated=fr, total=skewed["total"],
                    total_replicated=repl["total"],
                    query=skewed["seeding_query"],
                    query_replicated=repl["seeding_query"],
                    replication_speedup=skewed["total"] / repl["total"],
                    n_tiles=len(traffic), replicas=int(replicas))

    def shed_signal(self, chunk, chunk_cost, offered_load, queue_delays=(),
                    delay_limit=SHED_DELAY_LIMIT):
        # per-admission calls must stay cheap: the saturation term is the
        # batch server's stability bound (rho >= 1), not a full DES run
        rho = offered_load * chunk_cost / max(int(chunk), 1)
        if rho >= 1.0:
            return True
        return _delay_tripped(queue_delays, chunk_cost, delay_limit)


MODELS = {"analytic": AnalyticModel, "sim": SimModel}


def get_model(model: Union[str, CostModel, None]) -> CostModel:
    """Resolve a model name (or pass a CostModel through).  ``None``
    means the default analytic backend."""
    if model is None:
        return AnalyticModel()
    if isinstance(model, CostModel):
        return model
    try:
        return MODELS[model]()
    except KeyError:
        raise ValueError(f"unknown cost model {model!r}; "
                         f"registered: {sorted(MODELS)}") from None
