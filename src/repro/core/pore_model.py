"""Nanopore pore model: k-mer -> expected current level.

A deterministic stand-in for the ONT 6-mer model used by RawHash2/Sigmap.
Levels are drawn from a fixed-seed hash so the simulator, the reference
index and the tests all agree without shipping a real model file.
"""
from __future__ import annotations

import numpy as np

K = 6                      # k-mer length of the pore model
N_KMERS = 4 ** K           # 4096
LEVEL_MEAN = 100.0         # ~pA, matches ONT R9 scale
LEVEL_SPAN = 60.0          # levels uniform in [70, 130]


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mix (SplitMix64)."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    return z ^ (z >> np.uint64(31))


def pore_table(seed: int = 7) -> np.ndarray:
    """(4096,) float32 expected current level for every 6-mer."""
    idx = np.arange(N_KMERS, dtype=np.uint64) + np.uint64(seed) * np.uint64(N_KMERS)
    h = _splitmix64(idx)
    u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)   # uniform [0,1)
    levels = LEVEL_MEAN - LEVEL_SPAN / 2 + u * LEVEL_SPAN
    return levels.astype(np.float32)


def kmer_ids(bases: np.ndarray) -> np.ndarray:
    """bases: (L,) int in {0..3} -> (L-K+1,) int32 k-mer ids (forward strand)."""
    L = bases.shape[0]
    n = L - K + 1
    if n <= 0:
        return np.zeros((0,), np.int32)
    ids = np.zeros(n, dtype=np.int64)
    for j in range(K):
        ids = ids * 4 + bases[j:j + n].astype(np.int64)
    return ids.astype(np.int32)


def revcomp(bases: np.ndarray) -> np.ndarray:
    """Reverse complement (A<->T, C<->G with A=0,C=1,G=2,T=3)."""
    return (3 - bases)[::-1]


def expected_events(bases: np.ndarray, table: np.ndarray) -> np.ndarray:
    """(L,) bases -> (L-K+1,) float32 expected event levels."""
    return table[kmer_ids(bases)]
