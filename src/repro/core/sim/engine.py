"""Deterministic discrete-event core: event heap + stat-keeping components.

The heap orders events by (time, schedule sequence), so simultaneous
events fire in schedule order and a run is a pure function of its inputs
— the determinism contract tests/test_sim.py pins (same trace + seed ->
identical event log).

``Component`` is the one resource abstraction: ``n_servers`` identical
servers over a FIFO queue.  Every component keeps the same stats dict
(busy_time / queue_delay / n_tasks / work), the per-component
decomposition idiom of accelerator simulators — idle time and
utilization derive from the makespan at report time (``stats_table``),
so "where did the time go" is answerable per flash channel, per die, per
PNM unit and for the DRAM/host links from one table.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple


class Simulator:
    """Event heap with a deterministic total order and an event log."""

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: List[Tuple[float, int, Callable, tuple]] = []
        self._seq = 0
        self.event_log: List[Tuple[float, str, str, object]] = []
        self.n_events = 0

    def schedule(self, t: float, fn: Callable, *args) -> None:
        if t < self.now:
            raise ValueError(f"cannot schedule into the past: {t} < {self.now}")
        heapq.heappush(self._heap, (float(t), self._seq, fn, args))
        self._seq += 1

    def log(self, component: str, kind: str, tag=None) -> None:
        self.event_log.append((self.now, component, kind, tag))

    def run(self) -> float:
        """Drain the heap; returns the final clock (the makespan)."""
        while self._heap:
            t, _, fn, args = heapq.heappop(self._heap)
            self.now = t
            self.n_events += 1
            fn(*args)
        return self.now


@dataclasses.dataclass
class _Task:
    duration: float
    done: Optional[Callable]
    tag: object
    t_enqueue: float
    work: float


class Component:
    """``n_servers`` identical servers over one FIFO queue.

    ``submit(duration=..)`` (or ``work=..`` against a ``rate``) enqueues a
    task; it starts as soon as a server frees, in FIFO order, and ``done``
    fires at completion.  Stats accumulate on the component:

        busy_time    total server-seconds spent serving
        queue_delay  total time tasks waited between enqueue and start
        n_tasks      tasks served
        work         total work units (bytes / ops) pushed through

    ``t_last`` is the component's last completion (its local makespan).
    """

    def __init__(self, sim: Simulator, name: str, n_servers: int = 1,
                 rate: Optional[float] = None) -> None:
        if n_servers < 1:
            raise ValueError(f"{name}: n_servers must be >= 1; got {n_servers}")
        self.sim = sim
        self.name = name
        self.n_servers = int(n_servers)
        self.rate = rate
        self._busy = 0
        self._fifo: List[_Task] = []
        self.t_last = 0.0
        self.stats: Dict[str, float] = dict(
            busy_time=0.0, queue_delay=0.0, n_tasks=0, work=0.0)

    def submit(self, duration: Optional[float] = None,
               work: Optional[float] = None,
               done: Optional[Callable] = None, tag=None) -> None:
        if duration is None:
            if work is None or self.rate is None:
                raise ValueError(f"{self.name}: submit needs duration, or "
                                 f"work with a configured rate")
            duration = work / self.rate
        if duration < 0:
            raise ValueError(f"{self.name}: negative duration {duration}")
        t = _Task(float(duration), done, tag, self.sim.now,
                  float(work if work is not None else 0.0))
        self._fifo.append(t)
        self.sim.log(self.name, "enqueue", tag)
        self._try_start()

    def _try_start(self) -> None:
        while self._fifo and self._busy < self.n_servers:
            task = self._fifo.pop(0)
            self._busy += 1
            self.stats["queue_delay"] += self.sim.now - task.t_enqueue
            self.stats["n_tasks"] += 1
            self.stats["work"] += task.work
            self.sim.log(self.name, "start", task.tag)
            self.sim.schedule(self.sim.now + task.duration,
                              self._finish, task)

    def _finish(self, task: _Task) -> None:
        self._busy -= 1
        self.stats["busy_time"] += task.duration
        self.t_last = max(self.t_last, self.sim.now)
        self.sim.log(self.name, "done", task.tag)
        if task.done is not None:
            task.done()
        self._try_start()


def stats_table(components: List[Component],
                makespan: float) -> Dict[str, Dict[str, float]]:
    """Per-component busy/idle/queue-delay/utilization decomposition over
    the run's makespan (server-seconds; utilization is busy fraction of
    the component's aggregate server capacity)."""
    out = {}
    for c in components:
        cap = c.n_servers * makespan
        busy = c.stats["busy_time"]
        out[c.name] = dict(
            busy_time=busy,
            idle_time=max(0.0, cap - busy),
            queue_delay=c.stats["queue_delay"],
            n_tasks=int(c.stats["n_tasks"]),
            work=c.stats["work"],
            utilization=(busy / cap) if cap > 0 else 0.0)
    return out
