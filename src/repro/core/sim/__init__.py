"""Discrete-event in-storage simulator (the `sim` CostModel backend).

Three layers:

  * ``engine``   — the deterministic event heap + ``Component`` resource
    (k servers, FIFO queue, per-component busy/idle/queue-delay stats);
  * ``ssdsim``   — the MARS SSD model built on it: flash channels x dies
    with per-die busy windows, controller-sequenced PNM compute units
    (AU/QU/sorter), internal-DRAM bandwidth accounting, host link;
  * ``serve_sim`` — virtual-time serving twins: replay of ``ServeDriver``
    chunk-event traces and event-driven M/D/c / batch-server queues.

The analytic closed forms in ``core/ssd_model.py`` stay the calibration
oracle: degenerate (no-contention) configs must agree to <1%
(tests/test_sim.py, scripts/bench_sim.py); contended configs add the
per-component breakdown the closed forms cannot express.
"""
from repro.core.sim.engine import Component, Simulator  # noqa: F401
from repro.core.sim.ssdsim import (simulate_array_latency,  # noqa: F401
                                   simulate_batch,
                                   simulate_dram_sensitivity)
from repro.core.sim.serve_sim import (replay_chunk_trace,  # noqa: F401
                                      simulate_serving,
                                      simulate_serving_virtual)
