"""Virtual-time serving simulators: ServeDriver trace replay + queues.

``core/server.ServeDriver`` records a replayable chunk-event trace on
its virtual clock (``ServeDriver.events``):

    ("arrival",  t, stream_id, n_reads)
    ("dispatch", t, chunk_idx, stage, n_valid, stage_frac)
    ("complete", t, chunk_idx, n_valid)

``replay_chunk_trace`` re-runs the dispatch/complete timeline of such a
trace through the virtual-clock dispatch law (every dispatched chunk
advances the clock by ``chunk_cost * stage_frac``; its completion time
is fixed at dispatch) and checks the recorded completions reproduce
exactly — the trace IS sufficient input for the simulator, which is what
lets recorded serving runs be re-analyzed offline.

``simulate_serving_virtual`` / ``simulate_serving`` are the event-driven
twins of the two analytic queueing wrappers in ``ssd_model``: instead of
the Erlang-C closed form they run seeded Poisson arrivals through the
actual service discipline (a greedy batch server of ``chunk`` reads per
``chunk_cost``, or c = n_serving drive servers) and report measured
sojourn percentiles.  Deterministic given the seed.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.core import ssd_model
from repro.core.workload import Workload


# --------------------------------------------------------------------------- #
# ServeDriver chunk-event trace replay
# --------------------------------------------------------------------------- #
def replay_chunk_trace(events: Iterable[Tuple], chunk_cost: float = 1.0
                       ) -> Dict[str, object]:
    """Replay a ``ServeDriver.events`` trace in virtual time.

    Recomputes every chunk's completion time from its dispatch record
    (``complete = dispatch_t + chunk_cost * stage_frac``) and compares it
    against the recorded completion.  Returns per-chunk rows, the
    dispatcher's busy fraction over the trace makespan, and
    ``max_drift`` — the largest |replayed - recorded| completion gap
    (0.0 exactly for traces recorded on the clean virtual-clock path;
    storage-path retry/backoff penalties shift later DISPATCHES, never a
    chunk's own dispatch->complete span, so replay stays exact there
    too).
    """
    dispatches: Dict[int, Tuple[float, float]] = {}
    recorded: Dict[int, float] = {}
    arrivals: List[Tuple[float, str, int]] = []
    for ev in events:
        kind = ev[0]
        if kind == "dispatch":
            _, t, ci, _stage, _n_valid, frac = ev
            dispatches[ci] = (float(t), float(frac))
        elif kind == "complete":
            _, t, ci = ev[0], ev[1], ev[2]
            recorded[ci] = float(t)
        elif kind == "arrival":
            arrivals.append((float(ev[1]), ev[2], int(ev[3])))
    rows = []
    max_drift = 0.0
    busy = 0.0
    makespan = 0.0
    for ci in sorted(dispatches):
        t_disp, frac = dispatches[ci]
        replayed = t_disp + chunk_cost * frac
        rec = recorded.get(ci)
        drift = abs(replayed - rec) if rec is not None else math.inf
        max_drift = max(max_drift, drift)
        busy += chunk_cost * frac
        makespan = max(makespan, replayed,
                       rec if rec is not None else 0.0)
        rows.append(dict(chunk=ci, dispatch=t_disp, frac=frac,
                         replayed_complete=replayed, recorded_complete=rec,
                         drift=drift))
    return dict(chunks=rows, n_chunks=len(rows), n_arrival_events=len(arrivals),
                n_reads_arrived=sum(n for _, _, n in arrivals),
                makespan=makespan, max_drift=max_drift,
                dispatch_busy=(busy / makespan) if makespan > 0 else 0.0)


# --------------------------------------------------------------------------- #
# Event-driven queueing twins
# --------------------------------------------------------------------------- #
def _percentile_out(sojourns: np.ndarray, service: float, c: int,
                    offered_load: float,
                    percentiles: Sequence[float]) -> Dict[str, float]:
    out = dict(service=service, n_servers=int(c),
               offered_load=float(offered_load),
               utilization=offered_load * service / c, saturated=False,
               mean=float(sojourns.mean()),
               wait_prob=float(np.mean(sojourns > service + 1e-12)))
    for q in percentiles:
        out[f"p{q:g}"] = float(np.percentile(sojourns, q))
    return out


def _saturated_out(service: float, c: int, offered_load: float,
                   percentiles: Sequence[float]) -> Dict[str, float]:
    out = dict(service=service, n_servers=int(c),
               offered_load=float(offered_load),
               utilization=offered_load * service / c, saturated=True,
               mean=math.inf, wait_prob=1.0)
    out.update({f"p{q:g}": math.inf for q in percentiles})
    return out


def simulate_serving_virtual(chunk: int, offered_load: float,
                             chunk_cost: float = 1.0,
                             percentiles: Sequence[float] = (50.0, 99.0),
                             n_reads: int = 20_000, seed: int = 0
                             ) -> Dict[str, float]:
    """Event-driven twin of ``ssd_model.serving_latency_virtual``: the
    greedy virtual-clock batch server (one chunk of up to ``chunk`` queued
    reads per ``chunk_cost``) under seeded Poisson arrivals.  Matches the
    analytic contract: ValueError on non-positive load, inf percentiles
    at/beyond saturation (rho = load * chunk_cost / chunk >= 1)."""
    if offered_load <= 0:
        raise ValueError(f"offered_load must be > 0; got {offered_load}")
    chunk = int(chunk)
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1; got {chunk}")
    rho = offered_load * chunk_cost / chunk
    if rho >= 1.0:
        out = _saturated_out(chunk_cost, chunk, offered_load, percentiles)
        out.update(chunk=chunk, chunk_cost=chunk_cost)
        return out
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / offered_load, int(n_reads)))
    sojourns = np.empty(int(n_reads))
    free_at = 0.0
    i = 0
    n = int(n_reads)
    while i < n:
        start = max(free_at, arr[i])
        j = i + 1                          # greedy: everyone queued rides
        while j < n and j - i < chunk and arr[j] <= start:
            j += 1
        done = start + chunk_cost
        sojourns[i:j] = done - arr[i:j]
        free_at = done
        i = j
    out = _percentile_out(sojourns, chunk_cost, chunk, offered_load,
                          percentiles)
    out.update(chunk=chunk, chunk_cost=chunk_cost, n_reads=n, seed=seed)
    return out


def simulate_serving(w: Workload, offered_load: float,
                     arr: ssd_model.SSDArrayConfig = ssd_model.SSDArrayConfig(),
                     percentiles: Sequence[float] = (50.0, 99.0),
                     n_reads: int = 20_000, seed: int = 0
                     ) -> Dict[str, float]:
    """Event-driven twin of ``ssd_model.serving_latency``: c = serving
    drives, each a deterministic server at the per-read amortized batch
    service of its index share, under seeded Poisson arrivals."""
    if offered_load <= 0:
        raise ValueError(f"offered_load must be > 0; got {offered_load}")
    batch = ssd_model.mars_array_latency(w, arr)
    service = batch["total"] / max(w.n_reads, 1) * arr.n_serving
    c = arr.n_serving
    rho = offered_load * service / c
    if rho >= 1.0:
        out = _saturated_out(service, c, offered_load, percentiles)
        out["n_ssds"] = c
        return out
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / offered_load, int(n_reads)))
    free_at = np.zeros(c)
    sojourns = np.empty(int(n_reads))
    for k, t in enumerate(arrivals):
        s = int(np.argmin(free_at))        # first server to free up
        start = max(free_at[s], t)
        free_at[s] = start + service
        sojourns[k] = free_at[s] - t
    out = _percentile_out(sojourns, service, c, offered_load, percentiles)
    out.update(n_ssds=c, n_reads=int(n_reads), seed=seed)
    return out
