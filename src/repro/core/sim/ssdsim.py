"""Event-driven MARS in-storage batch simulator.

The analytic model (``core/ssd_model.py``) collapses a batch into
``max(flash, compute) + 0.02 * min(flash, compute)``.  This module plays
the same Workload through an explicit machine instead:

  * the raw signal + index bytes stripe evenly over ``ssd.channels``
    flash channels; each channel's share is read in ``n_stripes``
    stripe segments by its ``chips_per_channel`` dies (per-die busy
    windows: a die is occupied ``t_read`` per segment; the one-time DMA
    setup ``t_dma`` rides the first segment) and streamed over the
    channel at ``channel_bw``;
  * a stripe becomes computable when EVERY channel has delivered its
    segment; the controller then sequences the stripe's PNM chain —
    event detection / hashing / filters / DP on the arithmetic units,
    the pLUTo query sweep on the query units, bucket sort on the
    sorter pairs, intermediate traffic over the internal DRAM — one
    stripe at a time (the units share the internal DRAM subarrays, so
    stripes do not overlap each other's compute);
  * flash prefetch runs ``buffer_depth`` stripes ahead of compute
    (Section 6.3 double buffering), which is exactly what produces the
    analytic overlap law: with ``n_stripes = 50`` the non-overlapped
    residual is 1/50 = the closed form's 0.02 factor, so degenerate
    (no-contention) configs reproduce ``mars_latency`` to <1% — the
    calibration gate of tests/test_sim.py and scripts/bench_sim.py.

Per-stage service times come from the same Table-1 rate constants the
analytic model uses (``ssd_model.mars_stage_times``); what the simulator
adds is WHERE the time goes — per-channel / per-die / per-unit busy,
idle and queue-delay stats (``engine.stats_table``) and controller
stalls the closed form cannot express under contention.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core import ssd_model
from repro.core.sim import engine
from repro.core.workload import Workload

# Stripes per batch.  1/N_STRIPES is the non-overlapped pipeline residual,
# matching the analytic model's 0.02 factor (Section 6.3 calibration).
N_STRIPES = 50


def simulate_batch(w: Workload, ssd: ssd_model.SSDConfig = ssd_model.SSDConfig(),
                   n_stripes: int = N_STRIPES,
                   buffer_depth: int = 2,
                   query_scale: float = 1.0) -> Dict[str, object]:
    """Event-driven batch latency of ``w`` on one MARS SSD.

    ``query_scale`` stretches the pLUTo query-unit stage by a load-
    imbalance factor (>= 1 under hot-bucket skew, back toward 1 with
    replication — see ``costmodel.skew_factors``): the query units serve
    buckets bank-by-bank, so probes concentrating on few buckets serialize
    on the hot bank while the rest idle.  The default 1.0 is bit-exact
    with the unscaled simulator.

    Returns the ``mars_latency`` keys (total / compute / flash / per-stage
    times) plus ``components`` (per-component busy/idle/queue-delay
    decomposition), ``controller`` (compute busy + flash-stall time) and
    ``event_log`` (the deterministic event trace).
    """
    if n_stripes < 1:
        raise ValueError(f"n_stripes must be >= 1; got {n_stripes}")
    if buffer_depth < 1:
        raise ValueError(f"buffer_depth must be >= 1; got {buffer_depth}")
    if query_scale <= 0:
        raise ValueError(f"query_scale must be > 0; got {query_scale}")
    st = dict(ssd_model.mars_stage_times(w, ssd))
    # q - old == 0.0 exactly at scale 1.0, keeping the default bit-exact
    q = st["seeding_query"] * query_scale
    st["seeding"] = st["seeding"] + (q - st["seeding_query"])
    st["seeding_query"] = q
    P = int(n_stripes)

    sim = engine.Simulator()
    dies = [engine.Component(sim, f"ch{c}.dies", ssd.chips_per_channel)
            for c in range(ssd.channels)]
    chans = [engine.Component(sim, f"ch{c}", 1, rate=ssd.channel_bw)
             for c in range(ssd.channels)]
    au = engine.Component(sim, "arith_units", 1)
    qu = engine.Component(sim, "query_units", 1)
    sorter = engine.Component(sim, "sorter", 1)
    dram = engine.Component(sim, "internal_dram", 1, rate=ssd.dram_bw)
    comps: List[engine.Component] = dies + chans + [au, qu, sorter, dram]

    share = (w.bytes_raw + w.bytes_index) / ssd.channels
    seg_bytes = share / P
    # the stripe's PNM chain, controller-sequenced in stage order
    chain = [(au, st["event_detection"] / P, "ed"),
             (au, st["seeding_hash"] / P, "hash"),
             (qu, st["seeding_query"] / P, "query"),
             (au, st["filters"] / P, "filters"),
             (sorter, st["sorting"] / P, "sort"),
             (au, st["chaining_dp"] / P, "dp"),
             (dram, st["dram_move"] / P, "dram")]

    pending = [ssd.channels] * P          # undelivered channel segments
    flash_done: List[Optional[float]] = [None] * P
    released = [False] * P
    state = dict(next=0, busy=False, compute_end=0.0, last_delivery=0.0)
    controller = dict(busy_time=0.0, stall_flash=0.0, n_stripes=P)

    def release(i: int) -> None:
        if i >= P or released[i]:
            return
        released[i] = True
        for c in range(ssd.channels):
            dies[c].submit(duration=ssd.t_read,
                           done=_transfer(c, i), tag=("read", i))

    def _transfer(c: int, i: int):
        def go():
            dur = seg_bytes / ssd.channel_bw + (ssd.t_dma if i == 0 else 0.0)
            chans[c].submit(duration=dur, done=_delivered(i), tag=("xfer", i))
        return go

    def _delivered(i: int):
        def go():
            pending[i] -= 1
            if pending[i] == 0:
                flash_done[i] = sim.now
                state["last_delivery"] = sim.now
                _try_compute()
        return go

    def _try_compute() -> None:
        i = state["next"]
        if state["busy"] or i >= P or flash_done[i] is None:
            return
        state["busy"] = True
        # double buffering: pull the next flash stripe as compute starts
        release(i + buffer_depth)
        controller["stall_flash"] += max(0.0, flash_done[i]
                                         - state["compute_end"])
        _run_chain(i, 0)

    def _run_chain(i: int, k: int) -> None:
        if k == len(chain):
            state["compute_end"] = sim.now
            state["busy"] = False
            state["next"] = i + 1
            controller["busy_time"] += sum(d for _, d, _ in chain)
            _try_compute()
            return
        comp, dur, tag = chain[k]
        comp.submit(duration=dur, done=lambda: _run_chain(i, k + 1),
                    tag=(tag, i))

    for i in range(min(buffer_depth, P)):
        release(i)
    total = sim.run()

    compute = (st["event_detection"] + st["seeding"] + st["filters"] +
               st["sorting"] + st["chaining_dp"] + st["dram_move"])
    # the flash subsystem's own (ungated) completion: per-channel busy is
    # t_dma + share/bw; the first die read adds the t_read startup
    flash = max(c.stats["busy_time"] for c in chans) + ssd.t_read
    out: Dict[str, object] = dict(total=total, compute=compute, flash=flash,
                                  **{k: v for k, v in st.items()
                                     if k != "flash"})
    out["components"] = engine.stats_table(comps, total)
    out["controller"] = controller
    out["n_stripes"] = P
    out["event_log"] = sim.event_log
    return out


def simulate_array_latency(w: Workload,
                           arr: ssd_model.SSDArrayConfig = ssd_model.SSDArrayConfig(),
                           n_stripes: int = N_STRIPES) -> Dict[str, object]:
    """Event-driven twin of ``ssd_model.mars_array_latency``: every serving
    drive runs its 1/N bucket-range share (drives are symmetric, so one
    simulated drive stands for all), then the host link carries the
    per-read result merge and the controller pays per-drive dispatch."""
    per = w.scale(1.0 / arr.n_serving)
    drive = simulate_batch(per, arr.ssd, n_stripes=n_stripes)
    t_merge = (w.n_reads * arr.result_bytes_per_read) / arr.ssd.pcie_bw
    t_orch = arr.n_serving * arr.t_dispatch
    comps = dict(drive["components"])
    comps["host_link"] = dict(busy_time=t_merge, idle_time=0.0,
                              queue_delay=0.0, n_tasks=int(w.n_reads),
                              work=float(w.n_reads * arr.result_bytes_per_read),
                              utilization=1.0 if t_merge > 0 else 0.0)
    return dict(total=drive["total"] + t_merge + t_orch,
                per_ssd=drive["total"], merge=t_merge, orchestration=t_orch,
                compute=drive["compute"], flash=drive["flash"],
                components=comps, controller=drive["controller"])


def simulate_dram_sensitivity(w: Workload, sizes=(2 << 30, 4 << 30, 8 << 30),
                              ssd: ssd_model.SSDConfig = ssd_model.SSDConfig(),
                              n_stripes: int = N_STRIPES) -> Dict[int, float]:
    """Fig. 13 through the simulator: the same config scaling rule as
    ``ssd_model.dram_size_sensitivity`` (compute units scale with DRAM,
    small DRAM re-streams the index), with each point simulated."""
    import dataclasses
    out = {}
    base = ssd.dram_bytes
    for size in sizes:
        f = size / base
        cfg = dataclasses.replace(
            ssd, dram_bytes=size,
            dram_subarrays=int(ssd.dram_subarrays * f),
            n_arith_units=int(ssd.n_arith_units * f),
            n_query_units=int(ssd.n_query_units * f))
        passes = max(1.0, w.bytes_index / (0.6 * size))
        ww = dataclasses.replace(w, bytes_index=int(w.bytes_index * passes))
        out[size] = simulate_batch(ww, cfg, n_stripes=n_stripes)["total"]
    return out
