"""Distributed query backends: the partitioned-index mapper as stage-engine
`query` implementations.

MARS distributes raw reads across flash channels and queries index
partitions sequentially, overlapping partition loads with compute
(paper Section 6.3).  The TPU mapping (DESIGN.md Section 3):

  * reads are sharded over ALL mesh axes (every chip maps its own reads —
    the "channel stripe");
  * the reference index is range-partitioned by bucket over the 'model'
    axis (``core/index.partition_index``: partition p owns buckets
    [p*B/n, (p+1)*B/n));
  * `query:ring` rotates each read's seed keys (and accumulated hits +
    counter partials) around the 'model' axis with collective_permute; at
    step k a chip queries its resident partition with keys that originated
    k ranks upstream.  After n_model steps every seed has visited every
    partition and its hits are home — the collective is overlapped with
    query compute exactly like MARS overlaps flash loads with PIM work.
  * `query:a2a` rotates ONLY the keys; each shard accumulates hits for
    every source rank locally and ONE all_to_all returns them home — the
    (E,H) hit payload crosses the wire once instead of n_model times.

There is NO separate per-read program here: the backends are registered
`query` stages, so ``stages.resolve_plan(cfg, "ring"|"a2a")`` plus
``pipeline.map_chunk_sharded`` run the IDENTICAL chunk program as the
single-device path — cheap phase, compaction-gated chaining fast path,
width ladder, and the exact ``stages.CHUNK_COUNTER_SCHEMA`` (per-read
counter partials ride home with the hits, so pad-row masking via
``n_valid`` works in the distributed path too).

``make_distributed_mapper`` survives as a thin compatibility wrapper over
the shared sharded chunk program; ``partition_index`` lives next to Index
construction in ``core/index.py`` and is re-exported here.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeding, stages
from repro.core.config import MarsConfig
from repro.core.index import (INDEX_AXIS, PARTITIONED_INDEX_KEYS,  # noqa: F401 (re-export)
                              partition_index)


# --------------------------------------------------------------------------- #
# Device-local query of one resident partition (per read, vmap-safe)
# --------------------------------------------------------------------------- #
def _query_partition(keys: jnp.ndarray, valid: jnp.ndarray,
                     part: Dict[str, jnp.ndarray], my_part: jnp.ndarray,
                     n_parts: int, cfg: MarsConfig):
    """keys: (E,) uint32, valid: (E,) bool; ``part`` is THIS device's
    partition (leading axis squeezed).

    Returns (t_pos (E,H), hit (E,H), probes, raw, exact) for the seeds whose
    bucket lives in this partition: ``hit`` is post-frequency-filter, and
    the three scalars are this partition's int32 share of the read's
    n_bucket_probes / n_hits_raw / n_hits_exact counters.  The filter and
    counter math itself is ``seeding.match_entries`` with the seed mask
    restricted to owned seeds — each seed's bucket lives in exactly one
    partition, so the per-partition partials sum to the replicated-table
    counters exactly.
    """
    H = cfg.max_hits_per_seed
    bl_log = cfg.hash_bits - int(np.log2(n_parts))
    bucket = (keys & jnp.uint32(cfg.n_buckets - 1)).astype(jnp.int32)
    owner = bucket >> bl_log
    local_b = bucket & ((1 << bl_log) - 1)
    mine = (owner == my_part) & valid

    # the same two fused gathers as seeding.query_index, against the
    # resident partition's packed planes
    bstart = part["p_bucket_start"]
    start_end = jnp.take(bstart, jnp.stack([local_b, local_b + 1]), axis=0,
                         mode="clip")                        # (2,E)
    start, end = start_end[0], start_end[1]
    cnt_bucket = end - start
    j = jnp.arange(H, dtype=jnp.int32)[None, :]
    idx = start[:, None] + j                                 # (E,H)
    n_entries = part["p_entries_packed"].shape[-1]
    idx_c = jnp.minimum(idx, n_entries - 1)
    ent = jnp.take(part["p_entries_packed"], idx_c, axis=1,
                   mode="clip")                              # (2,E,H)
    got_key, key_cnt = seeding.unpack_entries(ent[0], keys, cfg)
    t_pos = ent[1]

    hit, probes, raw, exact = seeding.match_entries(
        keys, mine, got_key, key_cnt, cnt_bucket, cfg)
    return t_pos, hit, probes, raw, exact


def _partition_view(index: Dict[str, jnp.ndarray], cfg: MarsConfig):
    """Squeeze the local (1, ...) shard of a partitioned index and recover
    the (static) partition count from the local bucket range."""
    missing = [k for k in PARTITIONED_INDEX_KEYS if k not in index]
    if missing:
        raise ValueError(
            f"partitioned query backend needs index keys "
            f"{PARTITIONED_INDEX_KEYS} (core/index.partition_index); "
            f"missing {missing} — got {sorted(index)}")
    if index["p_bucket_start"].ndim != 2 or index["p_bucket_start"].shape[0] != 1:
        raise ValueError(
            "partitioned index must arrive as ONE resident partition per "
            "device (leading partition axis sharded over the mesh "
            f"'{INDEX_AXIS}' axis); got local p_bucket_start shape "
            f"{index['p_bucket_start'].shape}")
    part = {k: index[k][0] for k in PARTITIONED_INDEX_KEYS}
    bl = part["p_bucket_start"].shape[0] - 1
    n_parts = cfg.n_buckets // bl
    return part, n_parts


# --------------------------------------------------------------------------- #
# The `query` stage backends
# --------------------------------------------------------------------------- #
def _query_ring(state: stages.State, cfg: MarsConfig, index) -> stages.State:
    """Ring schedule (paper Section 6.3 analogue): keys, accumulated packed
    hits and counter partials all rotate around the index axis; after
    n_parts steps everything is back on the read's home device."""
    part, n_parts = _partition_view(index, cfg)
    keys, valid = state["keys"], state["seed_valid"]
    E, H = keys.shape[0], cfg.max_hits_per_seed
    my_rank = jax.lax.axis_index(INDEX_AXIS)
    perm = [(i, (i + 1) % n_parts) for i in range(n_parts)]

    def rot(x):
        return jax.lax.ppermute(x, INDEX_AXIS, perm)

    def step(carry, _):
        keys_r, valid_r, packed, probes, raw, exact = carry
        tp, hv, pr, rw, ex = _query_partition(keys_r, valid_r, part,
                                              my_rank, n_parts, cfg)
        # hit -> t_pos+1, miss -> 0: ONE int32 plane on the wire instead of
        # separate int32 + bool planes; each (e,h) slot is hit by at most
        # one partition, so max-combining is exact.
        packed = jnp.maximum(packed, jnp.where(hv, tp + 1, 0))
        carry = (keys_r, valid_r, packed, probes + pr, raw + rw, exact + ex)
        return tuple(rot(x) for x in carry), None

    z = jnp.zeros((), jnp.int32)
    carry = (keys, valid, jnp.zeros((E, H), jnp.int32), z, z, z)
    (_, _, packed, probes, raw, exact), _ = jax.lax.scan(
        step, carry, None, length=n_parts)
    # after n_parts rotations everything is back home
    return _finish_query(state, cfg, packed, probes, raw, exact)


def _query_a2a(state: stages.State, cfg: MarsConfig, index) -> stages.State:
    """All-to-all schedule (§Perf iteration, default): only (keys, valid)
    rotate; hits and counter partials accumulate locally per source rank and
    ONE all_to_all returns them home — the (E,H) hit payload crosses the
    wire once instead of n_parts times."""
    part, n_parts = _partition_view(index, cfg)
    keys, valid = state["keys"], state["seed_valid"]
    E, H = keys.shape[0], cfg.max_hits_per_seed
    my_rank = jax.lax.axis_index(INDEX_AXIS)
    perm = [(i, (i + 1) % n_parts) for i in range(n_parts)]

    def step(carry, k):
        keys_r, valid_r, pbuf, sbuf = carry
        tp, hv, pr, rw, ex = _query_partition(keys_r, valid_r, part,
                                              my_rank, n_parts, cfg)
        packed = jnp.where(hv, tp + 1, 0)
        src = jnp.mod(my_rank - k, n_parts)      # originating rank
        pbuf = jax.lax.dynamic_update_slice(pbuf, packed[None], (src, 0, 0))
        sbuf = jax.lax.dynamic_update_slice(
            sbuf, jnp.stack([pr, rw, ex])[None], (src, 0))
        keys_r = jax.lax.ppermute(keys_r, INDEX_AXIS, perm)
        valid_r = jax.lax.ppermute(valid_r, INDEX_AXIS, perm)
        return (keys_r, valid_r, pbuf, sbuf), None

    pbuf0 = jnp.zeros((n_parts, E, H), jnp.int32)
    sbuf0 = jnp.zeros((n_parts, 3), jnp.int32)
    (_, _, pbuf, sbuf), _ = jax.lax.scan(
        step, (keys, valid, pbuf0, sbuf0), jnp.arange(n_parts))
    # send each source rank its hits + counter partials
    packed = jax.lax.all_to_all(pbuf, INDEX_AXIS, 0, 0).max(axis=0)
    scal = jax.lax.all_to_all(sbuf, INDEX_AXIS, 0, 0).sum(axis=0)
    return _finish_query(state, cfg, packed, scal[0], scal[1], scal[2])


def _finish_query(state, cfg: MarsConfig, packed, probes, raw, exact):
    """Unpack the combined hit plane and emit the exact query-stage counter
    schema of seeding.query_index."""
    hit_valid = packed > 0
    t_pos = jnp.maximum(packed - 1, 0)
    q_pos = jnp.broadcast_to(
        jnp.arange(cfg.max_events, dtype=jnp.int32)[:, None], t_pos.shape)
    counters = dict(
        n_seeds=state["seed_valid"].sum(),
        n_bucket_probes=probes,
        n_hits_raw=raw,
        n_hits_postfreq=hit_valid.sum(),
        n_hits_exact=exact,
    )
    return {**state, "q_pos": q_pos, "t_pos": t_pos, "hit_valid": hit_valid,
            "counters": {**state["counters"], **counters}}


stages.register_backend("query", "ring", _query_ring,
                        index_kind="partitioned")
stages.register_backend("query", "a2a", _query_a2a,
                        index_kind="partitioned")


# --------------------------------------------------------------------------- #
# Compatibility wrappers (legacy distributed-mapper API)
# --------------------------------------------------------------------------- #
def make_distributed_mapper(cfg: MarsConfig, mesh, schedule: str = "a2a"):
    """Thin compatibility wrapper: the old (signals, parts) ->
    (t_start, score, mapped, counters) jit signature over the SHARED sharded
    chunk program (``pipeline.map_chunk_sharded``'s body) with the
    ``query:ring`` / ``query:a2a`` backend.

    New code should call ``stages.resolve_plan(cfg, schedule)`` +
    ``pipeline.map_chunk_sharded`` (or drive chunks through ``Mapper`` /
    ``core/driver.py``) directly; counters now carry the full
    ``stages.CHUNK_COUNTER_SCHEMA``.
    """
    from repro.core.pipeline import sharded_chunk_fn
    plan = stages.resolve_plan(cfg, schedule)
    inner = sharded_chunk_fn(cfg, mesh, plan)

    def fn(signals, parts):
        t, s, m, _, counters = inner(signals, parts,
                                     jnp.int32(signals.shape[0]))
        return t, s, m, counters
    return jax.jit(fn)


def input_shardings(mesh):
    """(signals sharding, partitioned-index shardings) for the wrapper."""
    from repro.distributed.sharding import mapping_chunk_shardings
    return mapping_chunk_shardings(mesh, partitioned_index=True)
