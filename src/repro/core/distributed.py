"""Distributed MARS mapping: shard_map over the production mesh.

MARS distributes raw reads across flash channels and queries index
partitions sequentially, overlapping partition loads with compute
(paper Section 6.3).  The TPU mapping (DESIGN.md Section 3):

  * reads are sharded over ALL mesh axes (every chip maps its own reads —
    the "channel stripe");
  * the reference index is range-partitioned by bucket over the 'model'
    axis (partition p owns buckets [p*B/n, (p+1)*B/n));
  * a RING schedule rotates each chip's seed keys (and accumulated hits)
    around the 'model' axis with collective_permute; at step k a chip
    queries its resident partition with the keys that originated k ranks
    upstream.  After n_model steps every seed has visited every partition
    and its hits have returned home — the collective is overlapped with
    query compute exactly like MARS overlaps flash loads with PIM work.

Everything after seeding (vote filter, sort, chaining DP) runs locally on
the read's home chip.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import chaining, events, hashing, quantization, vote
from repro.core.config import MarsConfig
from repro.core.index import Index


# --------------------------------------------------------------------------- #
# Host-side index partitioning
# --------------------------------------------------------------------------- #
def partition_index(index: Index, n_parts: int) -> Dict[str, np.ndarray]:
    """Range-partition by bucket: partition p owns an equal bucket range.
    Entries padded to the max partition size (device-uniform shapes)."""
    nb = index.cfg.n_buckets
    assert nb % n_parts == 0
    bl = nb // n_parts
    starts = index.bucket_start
    sizes = [int(starts[(p + 1) * bl] - starts[p * bl])
             for p in range(n_parts)]
    emax = max(max(sizes), 1)
    keys = np.zeros((n_parts, emax), np.uint32)
    pos = np.zeros((n_parts, emax), np.int32)
    cnt = np.zeros((n_parts, emax), np.int32)
    bstart = np.zeros((n_parts, bl + 1), np.int32)
    for p in range(n_parts):
        lo, hi = int(starts[p * bl]), int(starts[(p + 1) * bl])
        n = hi - lo
        keys[p, :n] = index.entries_key[lo:hi]
        pos[p, :n] = index.entries_pos[lo:hi]
        cnt[p, :n] = index.entries_cnt[lo:hi]
        bstart[p] = starts[p * bl:(p + 1) * bl + 1] - starts[p * bl]
    return dict(p_bucket_start=bstart, p_entries_key=keys,
                p_entries_pos=pos, p_entries_cnt=cnt)


# --------------------------------------------------------------------------- #
# Device-local query of one partition
# --------------------------------------------------------------------------- #
def _query_partition(keys, valid, part: Dict[str, jnp.ndarray],
                     my_part: jnp.ndarray, n_parts: int, cfg: MarsConfig):
    """keys: (R, E) uint32.  Returns (t_pos (R,E,H), hit_valid (R,E,H),
    probes) for seeds whose bucket lives in THIS partition."""
    H = cfg.max_hits_per_seed
    bl_log = cfg.hash_bits - int(np.log2(n_parts))
    bucket_g = (keys & jnp.uint32(cfg.n_buckets - 1)).astype(jnp.int32)
    owner = bucket_g >> bl_log
    local_b = bucket_g & ((1 << bl_log) - 1)
    mine = (owner == my_part) & valid

    bstart = part["p_bucket_start"]
    start = jnp.take(bstart, local_b, axis=0, mode="clip")
    end = jnp.take(bstart, local_b + 1, axis=0, mode="clip")
    cnt_bucket = end - start
    j = jnp.arange(H, dtype=jnp.int32)
    idx = start[..., None] + j                      # (R,E,H)
    n_entries = part["p_entries_key"].shape[0]
    idx_c = jnp.minimum(idx, n_entries - 1)
    got_key = jnp.take(part["p_entries_key"], idx_c, axis=0, mode="clip")
    t_pos = jnp.take(part["p_entries_pos"], idx_c, axis=0, mode="clip")
    key_cnt = jnp.take(part["p_entries_cnt"], idx_c, axis=0, mode="clip")

    in_bucket = j < cnt_bucket[..., None]
    hit = in_bucket & (got_key == keys[..., None].astype(jnp.uint32)) & \
        mine[..., None]
    if cfg.use_freq_filter:
        hit = hit & (key_cnt <= cfg.thresh_freq)
    probes = (jnp.minimum(cnt_bucket, H) * mine).sum()
    return t_pos, hit, probes


# --------------------------------------------------------------------------- #
# The shard_map program
# --------------------------------------------------------------------------- #
def make_distributed_mapper(cfg: MarsConfig, mesh: Mesh,
                            schedule: str = "a2a"):
    """Returns (fn, in_shardings builder).  fn(signals, parts) -> results.

    signals: (R, S) f32 sharded over all axes on R.
    parts: partition_index() arrays with leading axis n_model sharded over
    'model'.

    schedule='ring' rotates keys AND their accumulated hit tensors around
    the model axis (baseline, Section 6.3 analogue).  schedule='a2a' (§Perf
    iteration, default) rotates ONLY the keys; each shard accumulates hits
    for every source rank locally and ONE all_to_all returns them home —
    the (R,E,H) hit payload crosses the wire once instead of n_model times
    (~17x less permute traffic at default bounds).
    """
    dp_all = tuple(mesh.axis_names)                 # reads over every axis
    n_model = mesh.shape["model"]

    def body(signals, parts):
        # local shapes: signals (R_loc, S); parts leaves (1, ...) -> squeeze
        parts_l = {k: v[0] for k, v in parts.items()}
        my_rank = jax.lax.axis_index("model")

        def per_read(sig):
            ev, n_ev, _ = events.detect_events(sig, cfg)
            ev_valid = jnp.arange(cfg.max_events) < n_ev
            sym = quantization.quantize_events(ev, ev_valid, cfg)
            keys, seed_valid = hashing.pack_seeds(sym, n_ev, cfg)
            return keys, seed_valid, n_ev

        keys, seed_valid, n_ev = jax.vmap(per_read)(signals)
        R, E = keys.shape
        H = cfg.max_hits_per_seed

        # ---- ring over index partitions -------------------------------- #
        perm = [(i, (i + 1) % n_model) for i in range(n_model)]

        if schedule == "ring":
            def ring_step(carry, _):
                keys_r, valid_r, t_pos, hit, probes = carry
                tp, hv, pr = _query_partition(keys_r, valid_r, parts_l,
                                              my_rank, n_model, cfg)
                t_pos = jnp.where(hv, tp, t_pos)
                hit = hit | hv
                probes = probes + pr
                # rotate the query set (and its accumulated hits) to the
                # next partition holder.
                keys_r = jax.lax.ppermute(keys_r, "model", perm)
                valid_r = jax.lax.ppermute(valid_r, "model", perm)
                t_pos = jax.lax.ppermute(t_pos, "model", perm)
                hit = jax.lax.ppermute(hit, "model", perm)
                return (keys_r, valid_r, t_pos, hit, probes), None

            t0 = jnp.zeros((R, E, H), jnp.int32)
            h0 = jnp.zeros((R, E, H), bool)
            carry = (keys, seed_valid, t0, h0, jnp.zeros((), jnp.int32))
            (keys, seed_valid, t_pos, hit, probes), _ = jax.lax.scan(
                ring_step, carry, None, length=n_model)
            # after n_model rotations everything is back home
        else:
            # a2a schedule: only (keys, valid) rotate; hits accumulate
            # locally per source rank, one all_to_all returns them home.
            # (t_pos, hit) pack into ONE int32 (hit -> t_pos+1, miss -> 0):
            # 20% less payload than separate int32 + bool planes.
            def ring_step(carry, k):
                keys_r, valid_r, packed_buf, probes = carry
                tp, hv, pr = _query_partition(keys_r, valid_r, parts_l,
                                              my_rank, n_model, cfg)
                packed = jnp.where(hv, tp + 1, 0)
                src = jnp.mod(my_rank - k, n_model)
                packed_buf = jax.lax.dynamic_update_slice(
                    packed_buf, packed[None], (src, 0, 0, 0))
                keys_r = jax.lax.ppermute(keys_r, "model", perm)
                valid_r = jax.lax.ppermute(valid_r, "model", perm)
                return (keys_r, valid_r, packed_buf, probes + pr), None

            p0 = jnp.zeros((n_model, R, E, H), jnp.int32)
            carry = (keys, seed_valid, p0, jnp.zeros((), jnp.int32))
            (_, _, packed_buf, probes), _ = jax.lax.scan(
                ring_step, carry, jnp.arange(n_model))
            # send each source rank its hits; combine (each seed's hits
            # come from exactly one partition, so a max suffices)
            packed_home = jax.lax.all_to_all(packed_buf, "model", 0, 0)
            packed = packed_home.max(axis=0)
            hit = packed > 0
            t_pos = jnp.maximum(packed - 1, 0)

        # ---- local filters + chaining ----------------------------------- #
        q_pos = jnp.broadcast_to(
            jnp.arange(E, dtype=jnp.int32)[None, :, None], (R, E, H))

        def tail(qp, tp, hv):
            hv2, c_vote = vote.vote_filter(qp, tp, hv, cfg)
            res, c_chain = chaining.chain_anchors(qp, tp, hv2, cfg)
            return res, {**c_vote, **c_chain}

        res, counters = jax.vmap(tail)(q_pos, t_pos, hit)
        counters = {k: v.sum() for k, v in counters.items()}
        counters["n_hits_postfreq"] = hit.sum()
        counters["n_bucket_probes"] = probes
        counters["n_seeds"] = seed_valid.sum()
        counters["n_events"] = n_ev.sum()
        counters = {k: jax.lax.psum(v, tuple(mesh.axis_names))
                    for k, v in counters.items()}
        return (res.t_start, res.score, res.mapped, counters)

    parts_spec = {k: P("model") for k in
                  ("p_bucket_start", "p_entries_key", "p_entries_pos",
                   "p_entries_cnt")}
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_all, None), parts_spec),
        out_specs=(P(dp_all), P(dp_all), P(dp_all),
                   {k: P() for k in ("n_anchors_postvote", "n_votes_cast",
                                     "n_sorted", "n_dp_pairs",
                                     "n_hits_postfreq", "n_bucket_probes",
                                     "n_seeds", "n_events")}),
        check_rep=False)
    return jax.jit(fn)


def input_shardings(mesh: Mesh):
    dp_all = tuple(mesh.axis_names)
    sig = NamedSharding(mesh, P(dp_all, None))
    parts = {k: NamedSharding(mesh, P("model"))
             for k in ("p_bucket_start", "p_entries_key", "p_entries_pos",
                       "p_entries_cnt")}
    return sig, parts
