"""Workload-count extraction: pipeline counters -> hardware-model inputs.

The analytic SSD model (ssd_model.py) consumes *workload counts* — how many
samples were segmented, seeds hashed, buckets probed, anchors sorted, DP
pairs evaluated, and bytes moved between stages.  We measure these on the
real JAX pipeline over a benchmark read set, then linearly extrapolate
per-read averages to the paper-scale datasets (datasets.py), exactly how
MQSim-style simulation drives component models.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import stages
from repro.core.config import MarsConfig


@dataclasses.dataclass
class Workload:
    n_reads: int
    n_samples: int            # raw signal samples
    n_events: int             # detected events
    n_seeds: int              # valid seed keys hashed
    n_lookups: int            # hash-table queries (seeds probed)
    n_hits_raw: int           # seed hits before the frequency filter (capped)
    n_hits_exact: int         # uncapped exact hits (unbounded-baseline load)
    n_hits_postfreq: int
    n_votes: int              # votes cast by seed-and-vote
    n_anchors_postvote: int
    n_sorted: int             # anchors entering the sorter
    n_dp_pairs: int           # band DP (i,j) evaluations
    bytes_raw: int            # raw signal bytes read from flash
    bytes_index: int          # index bytes resident/streamed
    bytes_intermediate: int   # inter-stage traffic inside DRAM
    fixed_point: bool

    def scale(self, factor: float) -> "Workload":
        d = dataclasses.asdict(self)
        fixed = d.pop("fixed_point")
        scaled = {k: int(round(v * factor)) for k, v in d.items()}
        return Workload(fixed_point=fixed, **scaled)


def from_counters(counters: Dict[str, int], cfg: MarsConfig,
                  index_bytes: int) -> Workload:
    """Build a Workload from MapOutput.counters (the uniform per-chunk
    schema stages.CHUNK_COUNTER_SCHEMA every backend plan must emit)."""
    missing = [k for k in stages.CHUNK_COUNTER_SCHEMA if k not in counters]
    if missing:
        raise ValueError(f"counters missing {missing}; got {sorted(counters)}")
    n_reads = int(counters["n_reads"])
    n_samples = int(counters["n_samples"])
    n_events = int(counters["n_events"])
    n_seeds = int(counters["n_seeds"])
    n_hits_raw = int(counters["n_hits_raw"])
    n_hits_exact = int(counters.get("n_hits_exact", n_hits_raw))
    n_hits_postfreq = int(counters["n_hits_postfreq"])
    n_votes = int(counters.get("n_votes_cast", 0))
    n_postvote = int(counters["n_anchors_postvote"])
    n_sorted = int(counters["n_sorted"])
    n_dp = int(counters["n_dp_pairs"])

    sample_bytes = 2                       # raw signal stored as int16 DAC
    ev_bytes = 2 if cfg.fixed_point else 4
    bytes_raw = n_samples * sample_bytes
    bytes_intermediate = (
        n_events * ev_bytes                # events written back
        + n_seeds * 4                      # hash keys
        + n_hits_raw * 8                   # (t_pos, q_pos) anchors
        + n_sorted * 4                     # sort keys to controller + back
        + n_dp * 0                         # DP reads counted as AU ops
    )
    return Workload(
        n_reads=n_reads, n_samples=n_samples, n_events=n_events,
        n_seeds=n_seeds, n_lookups=n_seeds, n_hits_raw=n_hits_raw,
        n_hits_exact=n_hits_exact,
        n_hits_postfreq=n_hits_postfreq, n_votes=n_votes,
        n_anchors_postvote=n_postvote, n_sorted=n_sorted, n_dp_pairs=n_dp,
        bytes_raw=bytes_raw, bytes_index=index_bytes,
        bytes_intermediate=bytes_intermediate, fixed_point=cfg.fixed_point)
