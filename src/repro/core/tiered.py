"""Out-of-core tiered query backend: host-resident bucket-range tiles paged
into a fixed-slot device cache keyed on per-chunk bucket traffic.

MARS keeps the reference index in flash and overlaps partition loads with
compute (paper Section 6.3); GenStore/MegIS win by shrinking what crosses
the storage boundary at all.  This module is the host/device software
analogue over the stage engine:

  * the index lives on the host as a ``core/index.TieredIndex`` — the
    packed planes split into power-of-two bucket-range tiles (plain numpy,
    optionally memory-mapped);
  * ``HotTileCache`` owns a fixed number of device tile *slots*.  Before a
    chunk runs, a tiny jitted pre-pass (the plan's own detect/quantize/seed
    stages) histograms the chunk's seed traffic per tile; exactly the
    touched tiles are paged in, evicting by LRU over per-slot touch
    counts (``policy="random"`` exists so tests can prove results are
    eviction-order-independent).  A chunk touching more tiles than slots
    falls back to a transient wide view (every needed tile, padded to a
    power-of-two slot count) — correctness never depends on cache size,
    only traffic does;
  * ``query:tiered`` is a registered `query` stage backend
    (``Backend.index_kind = "tiered"``), so ``stages.resolve_plan`` +
    ``map_chunk`` / ``map_chunk_sharded`` / ``ServeDriver`` pick it up with
    zero pipeline copies.  The per-seed math routes every bucket through
    its tile's slot with the same two fused gathers as
    ``seeding.query_index`` and the shared ``seeding.match_entries``
    filter/counter math, so results are bit-identical to the resident
    table for every cache size and eviction order (non-resident slots are
    reachable only by invalid seeds, which ``match_entries`` masks; hit
    positions are packed ring-style so garbage slots never leak).

Cache-traffic telemetry (hits / misses / paged bytes / retries /
corruptions) rides the ``stages.DEBUG_COUNTER_SCHEMA`` — the chunk
program drops those names before summing, so ``CHUNK_COUNTER_SCHEMA`` and
every consumer keyed on it stay byte-identical; host-side totals live on
the cache object (``hits`` / ``misses`` / ``paged_bytes`` / ``hit_rate``
/ ``retries`` / ``corruptions``) for the microbenchmark cache group.

Fault tolerance: every page-in is verified against the tile's build-time
CRC32 (``core/index.tile_checksum``).  A failed or corrupted read is
retried with exponential backoff (accounted in virtual time,
``vtime_penalty``) up to ``max_retries`` times; an exhausted budget
raises a loud ``faults.TileReadError`` — a corrupted tile can never
silently serve hits.  The seeded injection harness (``core/faults.py``)
hooks exactly this boundary and is a no-op when absent.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core import seeding, stages
from repro.core.config import MarsConfig
from repro.core.index import TieredIndex, tile_checksum

# The pytree keys of a device tile-cache view (what the `query:tiered`
# stage body consumes).  Shapes for a cache of n_view slots over n_tiles
# tiles (bl = buckets per tile, emax = padded entries per tile):
#
#   t_bucket_start   (n_view, bl + 1) int32   per-slot local prefix offsets
#   t_entries_packed (2, n_view, emax) int32  per-slot packed entry rows
#   t_tile_slot      (n_tiles,) int32         tile -> slot, -1 non-resident
#   t_cache_stats    (5,) int32               this chunk's (hits, misses,
#                                             paged bytes, page-in retries,
#                                             checksum mismatches) telemetry
TIERED_INDEX_KEYS = ("t_bucket_start", "t_entries_packed", "t_tile_slot",
                     "t_cache_stats")

# Optional view planes carrying the traffic pre-pass's detect->quantize->
# seed outputs forward to the main pass (HotTileCache(reuse_prepass=True),
# the default off the sharded path): the chunk program consumes them
# instead of recomputing the cheap prefix on the host's critical path.
#
#   t_pre_keys  (R, E) uint32   seed keys        t_pre_valid (R, E) bool
#   t_pre_nev   (R,)   int32    per-read event counts
PREPASS_KEYS = ("t_pre_keys", "t_pre_valid", "t_pre_nev")


# --------------------------------------------------------------------------- #
# The `query:tiered` stage backend
# --------------------------------------------------------------------------- #
def _cache_view(index: Dict[str, jnp.ndarray]):
    missing = [k for k in TIERED_INDEX_KEYS if k not in index]
    if missing:
        raise ValueError(
            f"tiered query backend needs a HotTileCache view with keys "
            f"{TIERED_INDEX_KEYS} (core/tiered.HotTileCache.prepare); "
            f"missing {missing} — got {sorted(index)}")
    return index


def query_tiered(keys: jnp.ndarray, valid: jnp.ndarray,
                 index: Dict[str, jnp.ndarray], cfg: MarsConfig):
    """Query seed keys against the device tile-cache view.

    keys: (E,) uint32 (or batched (R, E)), valid: same-shape bool.  Every
    VALID seed's tile must be resident (``HotTileCache.prepare`` guarantees
    it); seeds whose tile is not resident are treated as invalid, so a
    garbage slot can never contribute a hit or a counter.  Returns
    (t_pos, hit_valid, counters) with ``seeding.query_index`` semantics;
    t_pos is packed ring-style (0 for non-hits), which the downstream
    stages provably never distinguish (the ring/a2a backends' parity).
    """
    view = _cache_view(index)
    H = cfg.max_hits_per_seed
    bstart = view["t_bucket_start"]          # (n_view, bl + 1)
    ent = view["t_entries_packed"]           # (2, n_view, emax)
    tile_slot = view["t_tile_slot"]          # (n_tiles,)
    blp1 = bstart.shape[1]
    emax = ent.shape[-1]
    n_tiles = tile_slot.shape[0]
    tile_log = int(np.log2(cfg.n_buckets // n_tiles))

    bucket = (keys & jnp.uint32(cfg.n_buckets - 1)).astype(jnp.int32)
    tile = bucket >> tile_log
    local_b = bucket & ((1 << tile_log) - 1)
    slot = jnp.take(tile_slot, tile, mode="clip")            # (..., E)
    valid = valid & (slot >= 0)

    # the same two fused gathers as seeding.query_index, routed through the
    # resident slot planes (flattened so one gather serves every slot);
    # non-resident (slot -1) indices clamp to 0 — deterministic garbage,
    # fully masked by the residency-anded `valid` above
    flat_b = slot * blp1 + local_b
    start_end = jnp.take(bstart.reshape(-1),
                         jnp.stack([flat_b, flat_b + 1]), mode="clip")
    start, end = start_end[0], start_end[1]
    cnt_bucket = end - start

    j = jnp.arange(H, dtype=jnp.int32)
    eidx = jnp.minimum(start[..., None] + j, emax - 1)       # (..., E, H)
    flat_e = slot[..., None] * emax + eidx
    ent2 = jnp.take(ent.reshape(2, -1), flat_e, axis=1, mode="clip")
    got_key, key_cnt = seeding.unpack_entries(ent2[0], keys, cfg)

    hit_valid, probes, raw, exact = seeding.match_entries(
        keys, valid, got_key, key_cnt, cnt_bucket, cfg)
    t_pos = jnp.where(hit_valid, ent2[1], 0)
    counters = seeding._query_counters(valid, hit_valid, probes, raw, exact)
    return t_pos, hit_valid, counters


def _query_tiered(state: stages.State, cfg: MarsConfig, index) -> stages.State:
    t_pos, hit_valid, c = query_tiered(state["keys"], state["seed_valid"],
                                       index, cfg)
    q_pos = jnp.broadcast_to(
        jnp.arange(cfg.max_events, dtype=jnp.int32)[:, None], t_pos.shape)
    # chunk-level cache telemetry rides the DEBUG schema (dropped by the
    # chunk program before summing — CHUNK_COUNTER_SCHEMA is unchanged)
    s = index["t_cache_stats"]
    c = {**c, "n_tile_hits": s[0], "n_tile_misses": s[1],
         "n_tile_paged_bytes": s[2], "n_tile_retries": s[3],
         "n_tile_corruptions": s[4]}
    return {**state, "q_pos": q_pos, "t_pos": t_pos, "hit_valid": hit_valid,
            "counters": {**state["counters"], **c}}


stages.register_backend("query", "tiered", _query_tiered, index_kind="tiered")


# --------------------------------------------------------------------------- #
# Per-chunk tile-traffic pre-pass
# --------------------------------------------------------------------------- #
@functools.lru_cache(maxsize=None)
def _prepass_fn(cfg: MarsConfig, plan: stages.Plan, n_tiles: int):
    """The jitted traffic probe: run the plan's own detect/quantize/seed
    stages over a chunk and histogram valid seeds per tile.  The keys it
    computes are bit-identical to the chunk program's own cheap phase, so
    the resident set it pages in covers every seed the real query will
    issue (pad rows included — their lanes stay bit-identical too).
    Cached per (cfg, plan, n_tiles): the serving prefix ladder reuses one
    compiled probe per stage config."""
    tile_log = int(np.log2(cfg.n_buckets // n_tiles))
    subset = ("detect", "quantize", "seed")

    def fn(signals):
        def one(signal):
            st = stages.execute_stages({"signal": signal, "counters": {}},
                                       {}, cfg, plan, subset)
            return st["keys"], st["seed_valid"], st["n_events"]
        keys, valid, n_ev = jax.vmap(one)(signals)
        tile = ((keys & jnp.uint32(cfg.n_buckets - 1)).astype(jnp.int32)
                >> tile_log)
        hist = jnp.zeros((n_tiles,), jnp.int32).at[tile].add(
            valid.astype(jnp.int32), mode="drop")
        # the probe's detect/quantize/seed outputs ride along so the main
        # pass can reuse them instead of recomputing (PREPASS_KEYS)
        return hist, keys, valid, n_ev.astype(jnp.int32)
    return jax.jit(fn)


# --------------------------------------------------------------------------- #
# The traffic-keyed device cache
# --------------------------------------------------------------------------- #
class HotTileCache:
    """Fixed device tile slots over a host-resident ``TieredIndex``.

    ``prepare(signals, cfg, plan)`` runs the traffic pre-pass, pages the
    chunk's touched tiles into slots (evicting per ``policy``) and returns
    the device view dict for ``map_chunk`` / ``map_chunk_sharded``.  The
    view's arrays are immutable snapshots (functional updates), so a
    prefetch for chunk i+1 never disturbs chunk i's in-flight program —
    that is what lets ``driver.stream_map`` page next-chunk tiles while the
    current chunk computes.  ``prefetch`` memoizes the prepared view by
    signal-array identity; the matching ``prepare`` call pops it.

    policy: "lru" (least-recent chunk serial, then touch count — empty
    slots first) or "random" (seeded; the eviction-order parity tests).
    A chunk needing more tiles than slots gets a transient wide view of
    every needed tile (power-of-two slot count, so compile shapes stay
    bounded); the persistent slots are untouched and misses are charged
    for the non-resident tiles — the cache-of-1 thrash regime.

    replicas: K extra slots pinned to the top-K hottest tiles by the
    cumulative traffic histogram (``tile_traffic()``) — the MegIS-style
    skewed-workload optimization: hot-bucket tiles absorbing most probes
    stay resident no matter how cold traffic churns the primary slots.
    Replicas are loaded through the same CRC-verified path, hold
    byte-identical tile planes, win the tile->slot routing, and are
    never eviction victims; results are bit-identical to ``replicas=0``
    for every cache size × K (tests/test_tiered.py).  Replica paging is
    accounted separately (``replica_loads`` / ``replica_bytes``) so
    hit/miss telemetry still describes the primary working set.

    Telemetry (cumulative, host ints): ``hits`` / ``misses`` (tile
    touches found/not found resident), ``paged_bytes`` (host->device bytes
    for missed tiles), ``retries`` (page-in re-reads), ``corruptions``
    (checksum mismatches caught), ``n_chunks``; ``hit_rate`` derives.
    Per-chunk values ride the view's ``t_cache_stats`` into the DEBUG
    counters.

    Every page-in is CRC-verified against the build-time per-tile checksum
    and retried with exponential backoff (``backoff_base * 2**k`` virtual
    time units, accumulated in ``vtime_penalty``) up to ``max_retries``
    times; exhaustion raises ``faults.TileReadError`` — never a silent
    wrong answer.  ``faults`` attaches a seeded ``core/faults.FaultPlan``
    injection harness at exactly this boundary; a plan that injects
    nothing (``FaultPlan.enabled`` false) is dropped entirely, so the
    happy path is byte-identical with or without it.
    """

    def __init__(self, tiered: TieredIndex, n_slots: int, mesh=None,
                 policy: str = "lru", seed: int = 0,
                 faults: Optional[faults_mod.FaultPlan] = None,
                 max_retries: int = 3, backoff_base: float = 1.0,
                 reuse_prepass: bool = True, replicas: int = 0):
        if n_slots < 1:
            raise ValueError(f"need at least one cache slot; got {n_slots}")
        if policy not in ("lru", "random"):
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             "use 'lru' or 'random'")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0; got {max_retries}")
        if backoff_base < 0:
            raise ValueError(f"backoff_base must be >= 0; "
                             f"got {backoff_base}")
        if replicas < 0:
            raise ValueError(f"replicas must be >= 0 extra hot-tile slots; "
                             f"got {replicas}")
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self._inj = (faults_mod.FaultInjector(faults)
                     if faults is not None and faults.enabled else None)
        self._prefetch_serial = 0
        self.tiered = tiered
        self.n_slots = min(int(n_slots), tiered.n_tiles)
        self.mesh = mesh
        # the pre-pass's detect/quantize/seed outputs feed the main pass on
        # the sharded path too: the sharded chunk program's index in_specs
        # shard the per-read PREPASS_KEYS planes over the read axis while
        # the tile planes stay replicated (pipeline._sharded_chunk_fn)
        self.reuse_prepass = bool(reuse_prepass)
        self.policy = policy
        self._rng = np.random.default_rng(seed)
        self._rep = None
        if mesh is not None:
            from repro.distributed.sharding import mapping_chunk_shardings
            _, self._rep = mapping_chunk_shardings(mesh)
        # Replica slots sit AFTER the n_slots primary slots: each holds a
        # byte-identical copy of one of the top-K hottest tiles (by the
        # cumulative traffic histogram), is never an eviction victim, and
        # wins the tile->slot routing over the tile's primary copy.  All
        # view gathers therefore read the same words either way —
        # replication is result-invisible by construction; what it buys is
        # residency: a hot tile stays servable while cold traffic churns
        # the primary slots.
        self.n_replicas = min(int(replicas), tiered.n_tiles)
        self.n_total = self.n_slots + self.n_replicas
        blp1 = tiered.buckets_per_tile + 1
        self._slot_tile = np.full(self.n_total, -1, np.int64)
        self._slot_last = np.zeros(self.n_total, np.int64)   # chunk serial
        self._slot_touch = np.zeros(self.n_total, np.int64)  # seed traffic
        self._tile_traffic = np.zeros(tiered.n_tiles, np.int64)
        self._serial = 0
        self._dev_bstart = self._put(jnp.zeros((self.n_total, blp1),
                                               jnp.int32))
        self._dev_ent = self._put(jnp.zeros((2, self.n_total, tiered.emax),
                                            jnp.int32))
        self._ready: Dict[int, Dict] = {}    # id(signals) -> prepared view
        self._keep: Dict[int, object] = {}   # keeps ids unique until popped
        self.reset_stats()

    def _put(self, x):
        return x if self._rep is None else jax.device_put(x, self._rep)

    # -------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        self.hits = 0
        self.misses = 0
        self.paged_bytes = 0
        self.n_chunks = 0
        self.retries = 0          # page-in re-reads (failures + mismatches)
        self.corruptions = 0      # checksum mismatches caught at page-in
        self.vtime_penalty = 0.0  # virtual time lost to spikes + backoff
        self.replica_loads = 0    # hot-tile copies paged into replica slots
        self.replica_bytes = 0    # host->device bytes those copies cost
        self._chunk_retries = 0
        self._chunk_corruptions = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.hits + self.misses, 1)

    @property
    def cache_nbytes(self) -> int:
        return self.n_total * self.tiered.tile_nbytes

    def tile_traffic(self) -> np.ndarray:
        """Cumulative per-tile seed-traffic histogram (a copy) — the
        replication policy's input, and the skew statistic the cost
        model's ``skewed_serving`` term consumes."""
        return self._tile_traffic.copy()

    # ---------------------------------------------------------- prefetch
    def prefetch(self, signals, cfg: MarsConfig, plan: stages.Plan) -> None:
        """Page the tiles a future chunk needs NOW (called by the driver
        loop on chunk i+1 while chunk i computes).  The prepared view is
        handed back by the ``prepare`` call for the same signals object."""
        key = id(signals)
        if key in self._ready:
            return
        serial = self._prefetch_serial
        self._prefetch_serial += 1
        if self._inj is not None:
            self._inj.check_prefetch(serial)
        # build the view BEFORE memoizing: a failed page-in must not leak
        # a dangling `_keep` pin or a half-built `_ready` entry
        view = self._prepare(signals, cfg, plan)
        self._keep[key] = signals
        self._ready[key] = view

    def prepare(self, signals, cfg: MarsConfig,
                plan: stages.Plan) -> Dict[str, jnp.ndarray]:
        """The device view for this chunk: every tile its valid seeds touch
        is resident.  Pops a prefetched view when one exists."""
        key = id(signals)
        view = self._ready.pop(key, None)
        self._keep.pop(key, None)
        if view is not None:
            return view
        return self._prepare(signals, cfg, plan)

    # ---------------------------------------------------------- internals
    def _read_tile(self, t: int, attempt: int):
        """One raw page-in attempt: contiguous int32 copies of the tile's
        planes (the 'DMA' — copies so an injected corruption can never
        reach the host index), routed through the fault injector when one
        is attached.  Raises ``TransientTileError`` on an injected read
        failure; latency spikes land in ``vtime_penalty``."""
        ti = self.tiered
        bstart = np.ascontiguousarray(ti.tile_bucket_start[t],
                                      dtype=np.int32)
        ent = np.ascontiguousarray(ti.tile_entries_packed[t],
                                   dtype=np.int32)
        if self._inj is not None:
            bstart, ent, lat = self._inj.tile_read(t, attempt, bstart, ent)
            if lat:
                self.vtime_penalty += lat
        return bstart, ent

    def _fetch_tile(self, t: int):
        """Page in one tile, verified: read -> CRC32 check -> (bstart, ent)
        or bounded retry with exponential backoff (virtual-time accounted).
        Every read failure / checksum mismatch is counted; an exhausted
        budget raises ``TileReadError`` loudly — a corrupted tile never
        serves hits silently."""
        t = int(t)
        expect = self.tiered.checksum(t)
        last: Optional[Exception] = None
        for attempt in range(self.max_retries + 1):
            if attempt:
                self.retries += 1
                self._chunk_retries += 1
                self.vtime_penalty += self.backoff_base * 2.0 ** (attempt - 1)
            try:
                bstart, ent = self._read_tile(t, attempt)
            except faults_mod.TransientTileError as e:
                last = e
                continue
            if tile_checksum(bstart, ent) == expect:
                return bstart, ent
            self.corruptions += 1
            self._chunk_corruptions += 1
            last = faults_mod.TileReadError(
                f"checksum mismatch paging tile {t} "
                f"(attempt {attempt}, expected {expect:#010x})")
        raise faults_mod.TileReadError(
            f"tile {t} page-in failed after {self.max_retries + 1} "
            f"attempts: {last}") from last

    def _refresh_replicas(self) -> None:
        """Keep the replica slots holding the current top-K hottest tiles
        (highest cumulative traffic, ties to the lower tile id).  Loads go
        through the same CRC-verified ``_fetch_tile`` path, so a replica's
        planes are byte-identical to the host tile — routing through a
        replica slot gathers exactly the words the primary would."""
        if not self.n_replicas:
            return
        traffic = self._tile_traffic
        hot = np.nonzero(traffic > 0)[0]
        hot = hot[np.lexsort((hot, -traffic[hot]))][:self.n_replicas]
        for j, t in enumerate(hot):
            s = self.n_slots + j
            if self._slot_tile[s] == int(t):
                continue
            bstart, ent = self._fetch_tile(int(t))
            self._dev_bstart = self._dev_bstart.at[s].set(jnp.asarray(bstart))
            self._dev_ent = self._dev_ent.at[:, s, :].set(jnp.asarray(ent))
            self._slot_tile[s] = int(t)
            self._slot_touch[s] = 0
            self.replica_loads += 1
            self.replica_bytes += self.tiered.tile_nbytes

    def _prepare(self, signals, cfg, plan):
        ti = self.tiered
        hist_d, keys, valid, n_ev = _prepass_fn(cfg, plan, ti.n_tiles)(
            jnp.asarray(signals))
        hist = np.asarray(hist_d)
        needed = np.nonzero(hist > 0)[0]
        self._serial += 1
        self.n_chunks += 1
        self._chunk_retries = 0
        self._chunk_corruptions = 0
        self._tile_traffic += hist
        self._refresh_replicas()
        if needed.size <= self.n_slots:
            view = self._ensure_resident(needed, hist)
        else:
            view = self._overflow_view(needed, hist)
        if self.reuse_prepass:
            # hand the probe's outputs to the chunk program (PREPASS_KEYS):
            # bit-identical to the cheap phase it would recompute, since
            # both run the plan's own detect/quantize/seed stages
            if self.mesh is not None:
                # per-read planes shard over the read axis like the signals
                # (the sharded chunk program's index in_specs expect it)
                from jax.sharding import NamedSharding, PartitionSpec
                axes = tuple(self.mesh.axis_names)
                sh2 = NamedSharding(self.mesh, PartitionSpec(axes, None))
                sh1 = NamedSharding(self.mesh, PartitionSpec(axes))
                keys = jax.device_put(keys, sh2)
                valid = jax.device_put(valid, sh2)
                n_ev = jax.device_put(n_ev, sh1)
            view = dict(view, t_pre_keys=keys, t_pre_valid=valid,
                        t_pre_nev=n_ev)
        return view

    def _victim(self, needed: set) -> int:
        """A PRIMARY slot whose tile is not needed this chunk; empty slots
        first, then least-recently-used / least-trafficked (or random).
        Replica slots are never victims — that is the replication win:
        hot tiles stay resident while cold traffic churns the primaries."""
        cands = [s for s in range(self.n_slots)
                 if self._slot_tile[s] not in needed]
        empties = [s for s in cands if self._slot_tile[s] < 0]
        if empties:
            return empties[0]
        if self.policy == "random":
            return int(self._rng.choice(cands))
        return min(cands, key=lambda s: (self._slot_last[s],
                                         self._slot_touch[s], s))

    def _load_slot(self, s: int, t: int) -> None:
        # fetch (verify + retry) BEFORE touching device state: a failed
        # page-in raises here and leaves every persistent slot unchanged
        bstart, ent = self._fetch_tile(t)
        self._dev_bstart = self._dev_bstart.at[s].set(jnp.asarray(bstart))
        self._dev_ent = self._dev_ent.at[:, s, :].set(jnp.asarray(ent))
        self._slot_tile[s] = t
        self._slot_touch[s] = 0

    def _view(self, bstart, ent, tile_slot, chunk_hits, chunk_misses):
        paged = chunk_misses * self.tiered.tile_nbytes
        self.hits += chunk_hits
        self.misses += chunk_misses
        self.paged_bytes += paged
        stats = jnp.asarray([chunk_hits, chunk_misses,
                             min(paged, np.iinfo(np.int32).max),
                             self._chunk_retries,
                             self._chunk_corruptions], jnp.int32)
        return dict(t_bucket_start=bstart, t_entries_packed=ent,
                    t_tile_slot=self._put(jnp.asarray(tile_slot)),
                    t_cache_stats=self._put(stats))

    def _ensure_resident(self, needed, hist):
        nset = set(int(t) for t in needed)
        resident = {int(t): s for s, t in enumerate(self._slot_tile)
                    if t >= 0}
        missing = [t for t in nset if t not in resident]
        for t in sorted(missing):
            self._load_slot(self._victim(nset), t)
        slot_of = {int(t): s for s, t in enumerate(self._slot_tile)}
        for t in nset:
            s = slot_of[t]
            self._slot_last[s] = self._serial
            self._slot_touch[s] += int(hist[t])
        tile_slot = np.full(self.tiered.n_tiles, -1, np.int32)
        for s, t in enumerate(self._slot_tile):
            if t >= 0:
                tile_slot[int(t)] = s
        return self._view(self._dev_bstart, self._dev_ent, tile_slot,
                          len(nset) - len(missing), len(missing))

    def _overflow_view(self, needed, hist):
        """More tiles touched than slots: a transient view holding every
        needed tile (padded to a power-of-two slot count — bounded compile
        shapes).  Persistent slots are left as-is; misses are charged for
        the tiles that were not resident."""
        ti = self.tiered
        n_need = int(needed.size)
        n_view = 1 << (n_need - 1).bit_length()
        blp1 = ti.buckets_per_tile + 1
        bstart = np.zeros((n_view, blp1), np.int32)
        ent = np.zeros((2, n_view, ti.emax), np.int32)
        tile_slot = np.full(ti.n_tiles, -1, np.int32)
        for i, t in enumerate(needed):
            bstart[i], ent[:, i, :] = self._fetch_tile(t)
            tile_slot[int(t)] = i
        resident = {int(t) for t in self._slot_tile if t >= 0}
        hits = sum(1 for t in needed if int(t) in resident)
        for s, t in enumerate(self._slot_tile):
            if int(t) in set(int(x) for x in needed):
                self._slot_last[s] = self._serial
                self._slot_touch[s] += int(hist[int(t)])
        return self._view(self._put(jnp.asarray(bstart)),
                          self._put(jnp.asarray(ent)), tile_slot,
                          hits, n_need - hits)
