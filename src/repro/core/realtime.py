"""Real-time incremental mapping with early termination (Read Until).

The motivation for real-time RSGA (paper Section 1) is that a mapping
decision made BEFORE the full read is sequenced lets the sequencer eject
the molecule — saving pore time and enabling targeted sequencing
(UNCALLED / Readfish / RawHash use-case).  This module maps each read
incrementally over growing signal prefixes and stops at the first
confident decision.

Each prefix length is a separate jit specialization of the same pipeline
(static shapes); the host side advances only unresolved reads to the next
stage — mirroring how a sequencer streams chunks per channel.  Chunking,
padding and device streaming go through the unified driver
(core/driver.py), and each stage's chunk program is a ``Mapper`` —
the same machinery batch mapping and the launcher use, so any registry
backend (reference / pallas / the distributed ``query:ring`` /
``query:a2a`` schedules with a mesh) serves real-time mapping too.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import driver
from repro.core.config import MarsConfig
from repro.core.index import Index
from repro.core.pipeline import Mapper


@dataclasses.dataclass
class RealtimeResult:
    t_start: np.ndarray       # (R,) final mapping position
    score: np.ndarray         # (R,)
    mapped: np.ndarray        # (R,) bool
    samples_used: np.ndarray  # (R,) samples consumed before the decision
    stage_of: np.ndarray      # (R,) stage index of the decision (-1 = full)

    @property
    def mean_fraction_used(self) -> float:
        return float(self.samples_used.mean() / self.samples_used.max())


def stage_cfg(cfg: MarsConfig, length: int) -> MarsConfig:
    """The per-prefix-length pipeline specialization shared by
    ``map_realtime`` and the serving driver's early-termination ladder
    (core/server.py) — identical config => identical jit programs =>
    bit-identical early decisions in both paths."""
    return cfg.replace(signal_len=length,
                       max_events=max(32, min(cfg.max_events, length // 5)))


def map_realtime(signals: np.ndarray, index: Index, cfg: MarsConfig,
                 stages: Sequence[int] = (256, 512, 768, 1024),
                 min_score: float = 8.0, chunk: int = 64,
                 backend: Optional[str] = None, mesh=None) -> RealtimeResult:
    """signals: (R, S) f32.  `stages` are prefix lengths (last == S).

    A read is resolved at the earliest stage where it maps with
    score >= min_score; unresolved reads fall through to the full-length
    decision (scored with cfg.min_chain_score as usual).

    ``backend``/``mesh`` select the chunk program exactly as in ``Mapper``
    (with a mesh, ``chunk`` must divide over its devices).
    """
    R, S = signals.shape
    assert stages[-1] == S, (stages, S)
    # ONE index upload (or partitioning); per-stage Mappers share it
    base = Mapper(index, cfg, backend=backend, mesh=mesh)

    t_start = np.zeros(R, np.int64)
    score = np.zeros(R, np.float32)
    mapped = np.zeros(R, bool)
    samples_used = np.full(R, S, np.int64)
    stage_of = np.full(R, -1, np.int32)
    unresolved = np.ones(R, bool)

    for si, L in enumerate(stages):
        idxs = np.nonzero(unresolved)[0]
        if idxs.size == 0:
            break
        scfg = stage_cfg(cfg, L)
        last = si == len(stages) - 1
        thresh = scfg.min_chain_score if last else min_score
        fn = base.with_cfg(scfg).chunk_fn()

        def sel_chunks():
            # slice the unresolved rows lazily, one chunk at a time (no
            # full (n_unresolved, L) copy up front)
            for ci, lo in enumerate(range(0, idxs.size, chunk)):
                sel = idxs[lo:lo + chunk]
                part = np.asarray(signals[sel, :L], np.float32)
                yield ci, sel.size, driver.pad_rows(part, chunk)

        for ci, n_valid, out in driver.stream_map(fn, sel_chunks()):
            sel = idxs[ci * chunk:ci * chunk + n_valid]
            o_t = np.asarray(out.t_start)
            o_s = np.asarray(out.score)
            o_m = np.asarray(out.mapped)
            decide = (o_m & (o_s >= thresh)) if not last else o_m
            done = sel[decide]
            t_start[done] = o_t[decide]
            score[done] = o_s[decide]
            mapped[done] = True
            samples_used[done] = L
            stage_of[done] = si
            unresolved[done] = False
            if last:
                rest = sel[~decide]
                samples_used[rest] = L
                unresolved[rest] = False
    return RealtimeResult(t_start=t_start, score=score, mapped=mapped,
                          samples_used=samples_used, stage_of=stage_of)
