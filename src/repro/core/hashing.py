"""Seed hashing: pack w consecutive quantized event symbols into hash keys.

RawHash2-style: a seed is the concatenation of q-bit symbols from w
consecutive events, mixed through an avalanche hash so the direct-address
bucket table (index.py) spreads uniformly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.config import MarsConfig

_MIX_C1 = 0x85EBCA6B
_MIX_C2 = 0xC2B2AE35


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 finalizer on uint32 (wrapping multiply is native)."""
    c1 = jnp.uint32(_MIX_C1)
    c2 = jnp.uint32(_MIX_C2)
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * c1
    x = x ^ (x >> 13)
    x = x * c2
    x = x ^ (x >> 16)
    return x


def mix32_np(x: np.ndarray) -> np.ndarray:
    """numpy twin on uint64 with explicit 32-bit masking."""
    m = np.uint64(0xFFFFFFFF)
    x = x.astype(np.uint64) & m
    x = x ^ (x >> np.uint64(16))
    x = (x * np.uint64(_MIX_C1)) & m
    x = x ^ (x >> np.uint64(13))
    x = (x * np.uint64(_MIX_C2)) & m
    x = x ^ (x >> np.uint64(16))
    return x


def pack_seeds(symbols: jnp.ndarray, n_events: jnp.ndarray,
               cfg: MarsConfig):
    """symbols: (E,) int32 in [0, 2^q).  Returns (keys (E,) uint32,
    valid (E,) bool) — seed i covers events [i, i+w)."""
    E = symbols.shape[0]
    w, q = cfg.seed_width, cfg.quant_bits
    s = symbols.astype(jnp.uint32)
    key = jnp.zeros(E, jnp.uint32)
    for j in range(w):
        shifted = jnp.roll(s, -j)              # symbols[i+j] at slot i
        key = (key << q) | shifted
    key = mix32(key)
    idx = jnp.arange(E)
    valid = idx + w <= n_events
    return key, valid


def minimizer_mask(keys: jnp.ndarray, valid: jnp.ndarray,
                   radius: int) -> jnp.ndarray:
    """Winnowing subsample: keep seed i iff its key is the minimum within
    +-radius positions (RawHash2-style minimizer seeding; the same rule on
    read and reference keeps matches consistent).  radius=0 -> keep all."""
    if radius <= 0:
        return valid
    E = keys.shape[0]
    big = jnp.uint32(0xFFFFFFFF)
    kv = jnp.where(valid, keys, big)
    wmin = kv
    for d in range(1, radius + 1):
        left = jnp.concatenate([jnp.full((d,), big, jnp.uint32), kv[:-d]])
        right = jnp.concatenate([kv[d:], jnp.full((d,), big, jnp.uint32)])
        wmin = jnp.minimum(wmin, jnp.minimum(left, right))
    return valid & (kv == wmin)


def minimizer_mask_np(keys: np.ndarray, radius: int) -> np.ndarray:
    if radius <= 0:
        return np.ones(keys.shape[0], bool)
    big = np.uint32(0xFFFFFFFF)
    kv = keys.astype(np.uint32)
    wmin = kv.copy()
    for d in range(1, radius + 1):
        left = np.concatenate([np.full(d, big, np.uint32), kv[:-d]])
        right = np.concatenate([kv[d:], np.full(d, big, np.uint32)])
        wmin = np.minimum(wmin, np.minimum(left, right))
    return kv == wmin


def pack_seeds_np(symbols: np.ndarray, cfg: MarsConfig) -> np.ndarray:
    """Offline numpy twin used by the index builder.  symbols: (N,) int."""
    N = symbols.shape[0]
    w, q = cfg.seed_width, cfg.quant_bits
    n = N - w + 1
    key = np.zeros(n, np.uint64)
    for j in range(w):
        key = (key << np.uint64(q)) | symbols[j:j + n].astype(np.uint64)
    return mix32_np(key).astype(np.uint32)
