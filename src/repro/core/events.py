"""Signal-to-event conversion (event detection).

Implements the two-sample t-statistic segmentation used by RawHash2 /
scrappie, with two arithmetic paths:

* float path (RH2 baseline / MS-CPU_Float): f32 throughout;
* fixed-point path (MARS, Section 5.2): the raw signal is quantized EARLY
  (robust-normalized then converted to Q7.8 int16) and segmentation runs in
  integer arithmetic (int32/int64 accumulators, sqrt-free boundary test).

Static shapes: each read yields exactly `max_events` event slots plus a
validity count.  Segment means are computed as a one-hot segment-sum — the
same formulation the `event_detect` Pallas kernel maps onto the MXU.

Cheap-phase fast path (this PR's half of the PR-2 treatment): the float
normalization sorts the signal ONCE and derives both the median and the MAD
from the shared sorted array (``robust_normalize``); the fixed-point segment
reduction replaces the two segment-sum scatters with cumsum-at-boundary
gathers (``segment_means``).  Both are bit-identical to the previous
implementations — kept here as ``robust_normalize_reference`` /
``segment_means_reference`` parity oracles, exactly as PR 2 kept
``chain_dp_reference``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import MarsConfig

_EPS = 1e-6

# Early-quantization clip: normalized signals are clipped to +-SIGNAL_CLIP
# sigmas before the Q-format conversion, so |xq| <= SIGNAL_CLIP * 2^frac_bits
# — the static amplitude bound the integer boundary test's overflow check
# (fixed_tstat_bounds) is derived from.
SIGNAL_CLIP = 8.0


# --------------------------------------------------------------------------- #
# Normalization + early quantization (paper Section 5.2)
# --------------------------------------------------------------------------- #
def robust_normalize_reference(signal: jnp.ndarray) -> jnp.ndarray:
    """Pre-fast-path per-read median/MAD normalization: two full
    ``jnp.median`` sorts per read.  Parity oracle + the "pre" side of the
    cheap-phase microbenchmark."""
    med = jnp.median(signal, axis=-1, keepdims=True)
    mad = jnp.median(jnp.abs(signal - med), axis=-1, keepdims=True)
    scale = 1.4826 * mad + _EPS
    return (signal - med) / scale


def _median_two_sorted(a: jnp.ndarray, b: jnp.ndarray, m1: int, m2: int):
    """Values at ranks ``m1 <= m2`` of the merged multiset of two sorted 1-D
    arrays, via stable-merge rank arithmetic (no sort of the union).

    rank(a[i]) counts b-elements strictly smaller; rank(b[j]) counts
    a-elements smaller-or-equal — together a permutation of 0..len(a+b)-1
    (the stable merge), so each rank selects exactly one element.
    """
    ra = jnp.arange(a.shape[0]) + jnp.searchsorted(b, a, side="left")
    rb = jnp.arange(b.shape[0]) + jnp.searchsorted(a, b, side="right")

    def at(k):
        return (jnp.sum(jnp.where(ra == k, a, 0.0)) +
                jnp.sum(jnp.where(rb == k, b, 0.0)))

    return at(m1), at(m2)


def _robust_normalize_row(signal: jnp.ndarray) -> jnp.ndarray:
    """One-sort median/MAD of a 1-D signal, bit-identical to the reference.

    The median interpolation mirrors jnp.quantile's "linear" method at
    q=0.5 exactly (lo*0.5 + hi*0.5 — for odd S, lo == hi).  |x - med| over
    the sorted signal is two sorted runs (descending-left, ascending-right
    of the median), so the MAD is the median of a 2-way merge — rank
    selection instead of a second full sort.
    """
    S = signal.shape[0]
    m1, m2 = (S - 1) // 2, S // 2
    half = jnp.float32(0.5)
    xs = jnp.sort(signal)
    med = xs[m1] * half + xs[m2] * half
    h = S // 2
    dev_lo = (med - xs[:h])[::-1]        # ascending: xs[:h] <= med
    dev_hi = xs[h:] - med                # ascending: xs[h:] >= med
    lo, hi = _median_two_sorted(dev_lo, dev_hi, m1, m2)
    mad = lo * half + hi * half
    scale = 1.4826 * mad + _EPS
    return (signal - med) / scale


def robust_normalize(signal: jnp.ndarray) -> jnp.ndarray:
    """Per-read median/MAD normalization (f32).  signal: (..., S).

    One shared sort per read: the MAD median is rank-selected from the
    sorted signal instead of sorting |x - med| again.  Bit-identical to
    ``robust_normalize_reference`` (asserted by tests/test_cheap_fastpath).
    """
    shape = signal.shape
    rows = signal.reshape(-1, shape[-1])
    out = jax.vmap(_robust_normalize_row)(rows)
    return out.reshape(shape)


def quantize_signal_fixed(signal_norm: jnp.ndarray, frac_bits: int,
                          clip: float = SIGNAL_CLIP) -> jnp.ndarray:
    """Early quantization: normalized f32 -> Q(15-f).f int16."""
    scaled = jnp.clip(signal_norm, -clip, clip) * (1 << frac_bits)
    return jnp.round(scaled).astype(jnp.int16)


def dequantize_fixed(x: jnp.ndarray, frac_bits: int) -> jnp.ndarray:
    return x.astype(jnp.float32) / (1 << frac_bits)


# --------------------------------------------------------------------------- #
# t-statistic boundary detection
# --------------------------------------------------------------------------- #
def _windowed_sums(x: jnp.ndarray, w: int):
    """Left/right window sums of x and x^2 at each position.

    x: (S,).  Returns (sum_l, sum_r, sq_l, sq_r), each (S,), where
    sum_l[i] = sum(x[i-w:i]) and sum_r[i] = sum(x[i:i+w]) (zero-padded at
    the borders).  Works for float32 or int32.
    """
    S = x.shape[0]
    zero = jnp.zeros((1,), x.dtype)
    c = jnp.concatenate([zero, jnp.cumsum(x)])              # (S+1,)
    c2 = jnp.concatenate([zero, jnp.cumsum(x * x)])
    idx = jnp.arange(S)
    lo = jnp.maximum(idx - w, 0)
    hi = jnp.minimum(idx + w, S)
    sum_l = c[idx] - c[lo]
    sum_r = c[hi] - c[idx]
    sq_l = c2[idx] - c2[lo]
    sq_r = c2[hi] - c2[idx]
    return sum_l, sum_r, sq_l, sq_r


def tstat_float(x: jnp.ndarray, w: int) -> jnp.ndarray:
    """|mean_r - mean_l| / sqrt(var_l/w + var_r/w).  x: (S,) f32."""
    sum_l, sum_r, sq_l, sq_r = _windowed_sums(x, w)
    wf = float(w)
    mean_l, mean_r = sum_l / wf, sum_r / wf
    var_l = jnp.maximum(sq_l / wf - mean_l**2, 0.0)
    var_r = jnp.maximum(sq_r / wf - mean_r**2, 0.0)
    denom = jnp.sqrt((var_l + var_r) / wf + _EPS)
    return jnp.abs(mean_r - mean_l) / denom


def boundary_mask_float(x: jnp.ndarray, cfg: MarsConfig) -> jnp.ndarray:
    """Peak-picked boundary mask (S,) bool, float path."""
    t = tstat_float(x, cfg.tstat_window)
    return _peak_pick(t, t > cfg.tstat_threshold, cfg)


def fixed_tstat_bounds(cfg: MarsConfig):
    """Static worst-case int32 magnitudes of the integer boundary test.

    Derived from the early-quantization amplitude bound
    M = SIGNAL_CLIP * 2^frac_bits (|xq| <= M by construction):

        sq      <= w * M^2            (windowed sum of squares)
        |diff|  <= (2*w*M) >> 2       (prescaled window-sum difference)
        lhs     <= diff^2 * w
        |ssd|   <= w^2 * M^2          (w*sq - sum^2, both sides)
        rhs     <= tau2 * ((2*w^2*M^2) >> 4 + eps)

    Returns a dict of the four bounds; every one must stay below 2^31 for
    the int32 arithmetic of ``boundary_mask_fixed`` (and the `event_detect`
    Pallas kernel, which evaluates the identical expressions) to be exact.
    The cumsums inside ``_windowed_sums`` may wrap — two's-complement
    differences recover the window sums exactly as long as the window sums
    themselves fit, which the ``sq`` bound guarantees.
    """
    w = cfg.tstat_window
    M = int(SIGNAL_CLIP * (1 << cfg.frac_bits))
    tau2 = int(round(cfg.tstat_threshold ** 2))
    eps = 1 << max(2 * cfg.frac_bits - 8, 0)
    diff = (2 * w * M) >> 2
    return dict(
        sq=w * M * M,
        ssd=2 * w * w * M * M,
        lhs=diff * diff * w,
        rhs=tau2 * (((2 * w * w * M * M) >> 4) + eps),
    )


def fixed_tstat_in_range(cfg: MarsConfig) -> bool:
    """True iff the integer boundary test cannot overflow int32 for cfg."""
    return max(fixed_tstat_bounds(cfg).values()) < (1 << 31)


def check_fixed_tstat_range(cfg: MarsConfig) -> None:
    """Static overflow guard for the fixed-point boundary test.

    ``diff * diff * w`` grows as tstat_window^3 x (Q-format amplitude)^2 —
    beyond the bound it silently wraps int32 and flips boundary decisions.
    Fail fast at trace time instead (tests/test_cheap_fastpath pins the
    boundary: tstat_window=12 is the largest safe window at frac_bits=8).
    """
    if fixed_tstat_in_range(cfg):
        return
    w_max = 0
    while fixed_tstat_in_range(cfg.replace(tstat_window=w_max + 1)):
        w_max += 1
    bounds = fixed_tstat_bounds(cfg)
    worst = max(bounds, key=bounds.get)
    raise ValueError(
        f"fixed-point boundary test overflows int32 for tstat_window="
        f"{cfg.tstat_window} at frac_bits={cfg.frac_bits} ({worst} bound "
        f"{bounds[worst]:#x} >= 2^31); the largest safe tstat_window for "
        f"this config is {w_max} — lower tstat_window/frac_bits or use the "
        "float path (fixed_point=False)")


def boundary_mask_fixed(xq: jnp.ndarray, cfg: MarsConfig) -> jnp.ndarray:
    """Integer (sqrt-free) boundary test on int16 Q-format signal.

    Compare  (sum_r - sum_l)^2 * w  >  tau^2 * (ssd_l + ssd_r)
    where ssd = w*sq - sum^2 (scaled sum of squared deviations), in int32
    with a >>2 / >>4 prescale on the two sides to stay in range — equivalent
    to tstat > tau without division or sqrt, matching what a word-serial
    Arithmetic Unit would evaluate (add/mul/compare only).  Configs whose
    worst case exceeds int32 are rejected statically
    (``check_fixed_tstat_range``).
    """
    check_fixed_tstat_range(cfg)
    w = cfg.tstat_window
    x32 = xq.astype(jnp.int32)
    sum_l, sum_r, sq_l, sq_r = _windowed_sums(x32, w)
    diff = (sum_r - sum_l) >> 2                            # prescale 1/4
    ssd_l = w * sq_l - sum_l * sum_l                       # w^2 * var_l
    ssd_r = w * sq_r - sum_r * sum_r
    # tstat^2 = diff^2*w / (ssd_l + ssd_r)  (after w^2 cancellation);
    # both sides carry the same 1/16 prescale.
    tau2 = int(round(cfg.tstat_threshold ** 2))
    eps = 1 << (2 * cfg.frac_bits - 8)                     # small int epsilon
    lhs = diff * diff * w
    rhs = tau2 * (((ssd_l + ssd_r) >> 4) + eps)
    # score for peak picking: use lhs/rhs ratio in float only for argmax (the
    # comparison itself is integer); monotone transform keeps peaks aligned.
    score = lhs.astype(jnp.float32) / (rhs.astype(jnp.float32) + 1.0)
    return _peak_pick(score, lhs > rhs, cfg)


def _peak_pick(score: jnp.ndarray, above: jnp.ndarray,
               cfg: MarsConfig) -> jnp.ndarray:
    """Local-max suppression: keep i if above[i] and score[i] is the max in
    a +-peak_window neighborhood (ties broken toward the left)."""
    r = cfg.peak_window
    S = score.shape[0]
    win = 2 * r + 1
    padded = jnp.pad(score, (r, r), constant_values=-jnp.inf)
    # windowed max via reduce_window
    wmax = jax.lax.reduce_window(padded, -jnp.inf, jax.lax.max, (win,), (1,),
                                 "valid")
    # tie-break: position of first occurrence — accept if strictly greater
    # than everything to the left in the window.
    lmax = jax.lax.reduce_window(padded[:S + r], -jnp.inf, jax.lax.max,
                                 (r + 1,), (1,), "valid")  # max over [i-r, i]
    is_peak = (score >= wmax) & (score >= lmax) & above
    if cfg.min_dwell <= 1:
        # the peak window already enforces spacing; skip the sequential pass
        # (this is the TPU-kernel-friendly default — measured accuracy is
        # identical, see EXPERIMENTS Accuracy notes).
        return is_peak
    # enforce min dwell: suppress boundaries closer than min_dwell using a
    # prefix-scan over positions (greedy left-to-right).
    def scan_fn(last, inp):
        i, p = inp
        keep = p & (i - last >= cfg.min_dwell)
        last = jnp.where(keep, i, last)
        return last, keep
    idx = jnp.arange(S)
    _, kept = jax.lax.scan(scan_fn, -cfg.min_dwell, (idx, is_peak))
    return kept


# --------------------------------------------------------------------------- #
# Segment means: one-hot segment-sum (oracle) / cumsum-at-boundary gathers
# --------------------------------------------------------------------------- #
def segment_means_reference(x: jnp.ndarray, boundaries: jnp.ndarray,
                            valid_len: int, max_events: int):
    """Pre-fast-path segment reduction: two ``segment_sum`` scatters.
    Parity oracle + the "pre" side of the cheap-phase microbenchmark.

    x: (S,) signal, boundaries: (S,) bool.  Returns (means (E,), n_events,
    counts).  Event id at sample i = cumsum(boundaries)[i] clipped to E-1;
    samples past valid_len are dropped.  Means = segsum(x)/segsum(1) —
    identical math to the Pallas kernel's one-hot matmul.
    """
    S = x.shape[0]
    sample_valid = jnp.arange(S) < valid_len
    eid = jnp.cumsum(boundaries.astype(jnp.int32))
    eid = jnp.minimum(eid, max_events - 1)
    eid_masked = jnp.where(sample_valid, eid, max_events)   # overflow bin
    xf = x.astype(jnp.float32)
    sums = jax.ops.segment_sum(jnp.where(sample_valid, xf, 0.0), eid_masked,
                               num_segments=max_events + 1)[:max_events]
    cnts = jax.ops.segment_sum(sample_valid.astype(jnp.float32), eid_masked,
                               num_segments=max_events + 1)[:max_events]
    means = sums / jnp.maximum(cnts, 1.0)
    n_events = jnp.minimum(eid[valid_len - 1] + 1, max_events)
    return means, n_events, cnts


def segment_means(x: jnp.ndarray, boundaries: jnp.ndarray, valid_len: int,
                  max_events: int, max_abs: int = None):
    """Segment reduction via cumsum-at-boundary gathers (no scatters).

    Same contract as ``segment_means_reference``.  The event-id array is
    nondecreasing, so each event's sample range is [starts[e], starts[e+1])
    with ``starts = searchsorted(eid, 0..E)``, and per-event sums are
    differences of ONE prefix sum — gathers only, which vmap into a single
    batched gather across a chunk instead of per-read scatters.

    Bit-identical to the reference for integer-valued ``x`` whose whole-
    signal prefix sum stays exact in f32: the caller must certify the
    static amplitude bound ``max_abs`` (for the MARS fixed-point path,
    SIGNAL_CLIP * 2^frac_bits) and ``S * max_abs`` must stay below 2^24.
    Anything else — float signals (whose scatter addition order must be
    preserved exactly), an uncertified bound, or a signal long/loud enough
    to round the prefix sum — falls back to the scatter-based reference.
    """
    if (not jnp.issubdtype(x.dtype, jnp.integer) or max_abs is None
            or x.shape[0] * max_abs >= (1 << 24)):
        return segment_means_reference(x, boundaries, valid_len, max_events)
    S = x.shape[0]
    sample_valid = jnp.arange(S) < valid_len
    eid = jnp.cumsum(boundaries.astype(jnp.int32))
    eid = jnp.minimum(eid, max_events - 1)
    g = jnp.where(sample_valid, eid, max_events)            # nondecreasing
    starts = jnp.searchsorted(
        g, jnp.arange(max_events + 1, dtype=jnp.int32), side="left")
    xf = jnp.where(sample_valid, x.astype(jnp.float32), 0.0)
    c = jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(xf)])
    sums = c[starts[1:]] - c[starts[:-1]]
    cnts = (starts[1:] - starts[:-1]).astype(jnp.float32)
    means = sums / jnp.maximum(cnts, 1.0)
    n_events = jnp.minimum(eid[valid_len - 1] + 1, max_events)
    return means, n_events, cnts


def detect_events(signal: jnp.ndarray, cfg: MarsConfig):
    """Full per-read event detection.  signal: (S,) f32 raw.

    Returns (event_means (E,) f32 in normalized units, n_events i32,
    counts (E,) f32).  Dispatches on cfg.early_quantization / fixed_point.
    """
    x = robust_normalize(signal)
    if cfg.early_quantization and cfg.fixed_point:
        xq = quantize_signal_fixed(x, cfg.frac_bits)
        b = boundary_mask_fixed(xq, cfg)
        means, n, cnts = segment_means(
            xq.astype(jnp.int32), b, signal.shape[0], cfg.max_events,
            max_abs=int(SIGNAL_CLIP * (1 << cfg.frac_bits)))
        means = means / float(1 << cfg.frac_bits)
    elif cfg.early_quantization:
        # early quantization, float compute: quantize/dequantize to model the
        # precision loss, then float segmentation.
        xq = dequantize_fixed(quantize_signal_fixed(x, cfg.frac_bits),
                              cfg.frac_bits)
        b = boundary_mask_float(xq, cfg)
        means, n, cnts = segment_means(xq, b, signal.shape[0], cfg.max_events)
    else:
        b = boundary_mask_float(x, cfg)
        means, n, cnts = segment_means(x, b, signal.shape[0], cfg.max_events)
    return means, n, cnts


detect_events_batch = jax.vmap(detect_events, in_axes=(0, None),
                               out_axes=(0, 0, 0))


def detect_events_reference(signal: jnp.ndarray, cfg: MarsConfig):
    """Pre-fast-path ``detect_events``: two-sort median/MAD normalization +
    scatter-based segment reduction.  Parity oracle and the "pre" side of
    the cheap-phase microbenchmark (benchmarks/microbench.py)."""
    x = robust_normalize_reference(signal)
    if cfg.early_quantization and cfg.fixed_point:
        xq = quantize_signal_fixed(x, cfg.frac_bits)
        b = boundary_mask_fixed(xq, cfg)
        means, n, cnts = segment_means_reference(
            xq.astype(jnp.int32), b, signal.shape[0], cfg.max_events)
        means = means / float(1 << cfg.frac_bits)
    elif cfg.early_quantization:
        xq = dequantize_fixed(quantize_signal_fixed(x, cfg.frac_bits),
                              cfg.frac_bits)
        b = boundary_mask_float(xq, cfg)
        means, n, cnts = segment_means_reference(xq, b, signal.shape[0],
                                                 cfg.max_events)
    else:
        b = boundary_mask_float(x, cfg)
        means, n, cnts = segment_means_reference(x, b, signal.shape[0],
                                                 cfg.max_events)
    return means, n, cnts
