"""Signal-to-event conversion (event detection).

Implements the two-sample t-statistic segmentation used by RawHash2 /
scrappie, with two arithmetic paths:

* float path (RH2 baseline / MS-CPU_Float): f32 throughout;
* fixed-point path (MARS, Section 5.2): the raw signal is quantized EARLY
  (robust-normalized then converted to Q7.8 int16) and segmentation runs in
  integer arithmetic (int32/int64 accumulators, sqrt-free boundary test).

Static shapes: each read yields exactly `max_events` event slots plus a
validity count.  Segment means are computed as a one-hot segment-sum — the
same formulation the `event_detect` Pallas kernel maps onto the MXU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import MarsConfig

_EPS = 1e-6


# --------------------------------------------------------------------------- #
# Normalization + early quantization (paper Section 5.2)
# --------------------------------------------------------------------------- #
def robust_normalize(signal: jnp.ndarray) -> jnp.ndarray:
    """Per-read median/MAD normalization (f32).  signal: (..., S)."""
    med = jnp.median(signal, axis=-1, keepdims=True)
    mad = jnp.median(jnp.abs(signal - med), axis=-1, keepdims=True)
    scale = 1.4826 * mad + _EPS
    return (signal - med) / scale


def quantize_signal_fixed(signal_norm: jnp.ndarray, frac_bits: int,
                          clip: float = 8.0) -> jnp.ndarray:
    """Early quantization: normalized f32 -> Q(15-f).f int16."""
    scaled = jnp.clip(signal_norm, -clip, clip) * (1 << frac_bits)
    return jnp.round(scaled).astype(jnp.int16)


def dequantize_fixed(x: jnp.ndarray, frac_bits: int) -> jnp.ndarray:
    return x.astype(jnp.float32) / (1 << frac_bits)


# --------------------------------------------------------------------------- #
# t-statistic boundary detection
# --------------------------------------------------------------------------- #
def _windowed_sums(x: jnp.ndarray, w: int):
    """Left/right window sums of x and x^2 at each position.

    x: (S,).  Returns (sum_l, sum_r, sq_l, sq_r), each (S,), where
    sum_l[i] = sum(x[i-w:i]) and sum_r[i] = sum(x[i:i+w]) (zero-padded at
    the borders).  Works for float32 or int32.
    """
    S = x.shape[0]
    zero = jnp.zeros((1,), x.dtype)
    c = jnp.concatenate([zero, jnp.cumsum(x)])              # (S+1,)
    c2 = jnp.concatenate([zero, jnp.cumsum(x * x)])
    idx = jnp.arange(S)
    lo = jnp.maximum(idx - w, 0)
    hi = jnp.minimum(idx + w, S)
    sum_l = c[idx] - c[lo]
    sum_r = c[hi] - c[idx]
    sq_l = c2[idx] - c2[lo]
    sq_r = c2[hi] - c2[idx]
    return sum_l, sum_r, sq_l, sq_r


def tstat_float(x: jnp.ndarray, w: int) -> jnp.ndarray:
    """|mean_r - mean_l| / sqrt(var_l/w + var_r/w).  x: (S,) f32."""
    sum_l, sum_r, sq_l, sq_r = _windowed_sums(x, w)
    wf = float(w)
    mean_l, mean_r = sum_l / wf, sum_r / wf
    var_l = jnp.maximum(sq_l / wf - mean_l**2, 0.0)
    var_r = jnp.maximum(sq_r / wf - mean_r**2, 0.0)
    denom = jnp.sqrt((var_l + var_r) / wf + _EPS)
    return jnp.abs(mean_r - mean_l) / denom


def boundary_mask_float(x: jnp.ndarray, cfg: MarsConfig) -> jnp.ndarray:
    """Peak-picked boundary mask (S,) bool, float path."""
    t = tstat_float(x, cfg.tstat_window)
    return _peak_pick(t, t > cfg.tstat_threshold, cfg)


def boundary_mask_fixed(xq: jnp.ndarray, cfg: MarsConfig) -> jnp.ndarray:
    """Integer (sqrt-free) boundary test on int16 Q-format signal.

    Compare  (sum_r - sum_l)^2 * w  >  tau^2 * (ssd_l + ssd_r)
    where ssd = w*sq - sum^2 (scaled sum of squared deviations), in int32
    with a >>2 / >>4 prescale on the two sides to stay in range — equivalent
    to tstat > tau without division or sqrt, matching what a word-serial
    Arithmetic Unit would evaluate (add/mul/compare only).
    """
    w = cfg.tstat_window
    x32 = xq.astype(jnp.int32)
    sum_l, sum_r, sq_l, sq_r = _windowed_sums(x32, w)
    diff = (sum_r - sum_l) >> 2                            # prescale 1/4
    ssd_l = w * sq_l - sum_l * sum_l                       # w^2 * var_l
    ssd_r = w * sq_r - sum_r * sum_r
    # tstat^2 = diff^2*w / (ssd_l + ssd_r)  (after w^2 cancellation);
    # both sides carry the same 1/16 prescale.
    tau2 = int(round(cfg.tstat_threshold ** 2))
    eps = 1 << (2 * cfg.frac_bits - 8)                     # small int epsilon
    lhs = diff * diff * w
    rhs = tau2 * (((ssd_l + ssd_r) >> 4) + eps)
    # score for peak picking: use lhs/rhs ratio in float only for argmax (the
    # comparison itself is integer); monotone transform keeps peaks aligned.
    score = lhs.astype(jnp.float32) / (rhs.astype(jnp.float32) + 1.0)
    return _peak_pick(score, lhs > rhs, cfg)


def _peak_pick(score: jnp.ndarray, above: jnp.ndarray,
               cfg: MarsConfig) -> jnp.ndarray:
    """Local-max suppression: keep i if above[i] and score[i] is the max in
    a +-peak_window neighborhood (ties broken toward the left)."""
    r = cfg.peak_window
    S = score.shape[0]
    win = 2 * r + 1
    padded = jnp.pad(score, (r, r), constant_values=-jnp.inf)
    # windowed max via reduce_window
    wmax = jax.lax.reduce_window(padded, -jnp.inf, jax.lax.max, (win,), (1,),
                                 "valid")
    # tie-break: position of first occurrence — accept if strictly greater
    # than everything to the left in the window.
    lmax = jax.lax.reduce_window(padded[:S + r], -jnp.inf, jax.lax.max,
                                 (r + 1,), (1,), "valid")  # max over [i-r, i]
    is_peak = (score >= wmax) & (score >= lmax) & above
    if cfg.min_dwell <= 1:
        # the peak window already enforces spacing; skip the sequential pass
        # (this is the TPU-kernel-friendly default — measured accuracy is
        # identical, see EXPERIMENTS Accuracy notes).
        return is_peak
    # enforce min dwell: suppress boundaries closer than min_dwell using a
    # prefix-scan over positions (greedy left-to-right).
    def scan_fn(last, inp):
        i, p = inp
        keep = p & (i - last >= cfg.min_dwell)
        last = jnp.where(keep, i, last)
        return last, keep
    idx = jnp.arange(S)
    _, kept = jax.lax.scan(scan_fn, -cfg.min_dwell, (idx, is_peak))
    return kept


# --------------------------------------------------------------------------- #
# Segment means via one-hot segment-sum
# --------------------------------------------------------------------------- #
def segment_means(x: jnp.ndarray, boundaries: jnp.ndarray, valid_len: int,
                  max_events: int):
    """x: (S,) signal, boundaries: (S,) bool.  Returns (means (E,), n_events).

    Event id at sample i = cumsum(boundaries)[i] clipped to E-1; samples past
    valid_len are dropped.  Means = segsum(x)/segsum(1) — identical math to the
    Pallas kernel's one-hot matmul.
    """
    S = x.shape[0]
    sample_valid = jnp.arange(S) < valid_len
    eid = jnp.cumsum(boundaries.astype(jnp.int32))
    eid = jnp.minimum(eid, max_events - 1)
    eid_masked = jnp.where(sample_valid, eid, max_events)   # overflow bin
    xf = x.astype(jnp.float32)
    sums = jax.ops.segment_sum(jnp.where(sample_valid, xf, 0.0), eid_masked,
                               num_segments=max_events + 1)[:max_events]
    cnts = jax.ops.segment_sum(sample_valid.astype(jnp.float32), eid_masked,
                               num_segments=max_events + 1)[:max_events]
    means = sums / jnp.maximum(cnts, 1.0)
    n_events = jnp.minimum(eid[valid_len - 1] + 1, max_events)
    return means, n_events, cnts


def detect_events(signal: jnp.ndarray, cfg: MarsConfig):
    """Full per-read event detection.  signal: (S,) f32 raw.

    Returns (event_means (E,) f32 in normalized units, n_events i32,
    counts (E,) f32).  Dispatches on cfg.early_quantization / fixed_point.
    """
    x = robust_normalize(signal)
    if cfg.early_quantization and cfg.fixed_point:
        xq = quantize_signal_fixed(x, cfg.frac_bits)
        b = boundary_mask_fixed(xq, cfg)
        means, n, cnts = segment_means(xq.astype(jnp.int32), b,
                                       signal.shape[0], cfg.max_events)
        means = means / float(1 << cfg.frac_bits)
    elif cfg.early_quantization:
        # early quantization, float compute: quantize/dequantize to model the
        # precision loss, then float segmentation.
        xq = dequantize_fixed(quantize_signal_fixed(x, cfg.frac_bits),
                              cfg.frac_bits)
        b = boundary_mask_float(xq, cfg)
        means, n, cnts = segment_means(xq, b, signal.shape[0], cfg.max_events)
    else:
        b = boundary_mask_float(x, cfg)
        means, n, cnts = segment_means(x, b, signal.shape[0], cfg.max_events)
    return means, n, cnts


detect_events_batch = jax.vmap(detect_events, in_axes=(0, None),
                               out_axes=(0, 0, 0))
