"""Event quantization: normalized event means -> q-bit symbols.

RawHash2 quantizes events into a small alphabet so that nearby signal levels
share a symbol (noise tolerance).  MARS keeps the scheme but moves the
raw-signal quantization earlier (events.py) and runs this step in integer
arithmetic on the fixed-point path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import MarsConfig

_EPS = 1e-6


def quantize_events_float(events: jnp.ndarray, valid: jnp.ndarray,
                          cfg: MarsConfig) -> jnp.ndarray:
    """events: (E,) f32 (already in normalized signal units); valid: (E,) bool.
    Returns (E,) int32 symbols in [0, 2^q)."""
    vf = valid.astype(jnp.float32)
    n = jnp.maximum(vf.sum(), 1.0)
    mean = (events * vf).sum() / n
    var = (jnp.square(events - mean) * vf).sum() / n
    std = jnp.sqrt(var) + _EPS
    z = (events - mean) / std
    clip = cfg.quant_clip_sigma
    step = (2.0 * clip) / cfg.quant_levels
    sym = jnp.floor((jnp.clip(z, -clip, clip - 1e-4) + clip) / step)
    return jnp.clip(sym.astype(jnp.int32), 0, cfg.quant_levels - 1)


def quantize_events_fixed(events_q: jnp.ndarray, valid: jnp.ndarray,
                          cfg: MarsConfig) -> jnp.ndarray:
    """Integer-arithmetic variant.  events_q: (E,) int32 event means in the
    Q-format of cfg.frac_bits (i.e. value * 2^frac_bits).

    Uses int32 adds, multiplies and divides only (the Arithmetic Unit's op
    set, paper Section 6.2); the variance accumulation carries a >>1
    prescale per operand so the sum over max_events stays in int32.
    """
    v = valid.astype(jnp.int32)
    e = events_q.astype(jnp.int32)
    n = jnp.maximum(v.sum(), 1)
    mean = (e * v).sum() // n
    d = e - mean
    d2 = d >> 1
    var = ((d2 * d2 * v).sum() // n) << 2
    # integer sqrt via Newton iterations (fixed 24 steps covers int32 range)
    def newton(_, s):
        return (s + var // jnp.maximum(s, 1)) // 2
    s0 = jnp.maximum(var, 1)
    std = jax.lax.fori_loop(0, 24, newton, s0)
    std = jnp.maximum(std, 1)
    # z in Q-format: z_q = d * 2^f / std ; symbol = floor((z+clip)/step)
    f = cfg.frac_bits
    clip_q = jnp.int32(round(cfg.quant_clip_sigma * (1 << f)))
    z_q = (d << f) // std
    z_q = jnp.clip(z_q, -clip_q, clip_q - 1)
    step_q = (2 * clip_q) // cfg.quant_levels
    sym = (z_q + clip_q) // jnp.maximum(step_q, 1)
    return jnp.clip(sym.astype(jnp.int32), 0, cfg.quant_levels - 1)


def quantize_events(events: jnp.ndarray, valid: jnp.ndarray,
                    cfg: MarsConfig) -> jnp.ndarray:
    """Dispatch on the arithmetic path.  `events` is always f32 in normalized
    units (events.py already folded the Q-format scale back)."""
    if cfg.fixed_point:
        eq = jnp.round(events * (1 << cfg.frac_bits)).astype(jnp.int32)
        return quantize_events_fixed(eq, valid, cfg)
    return quantize_events_float(events, valid, cfg)
