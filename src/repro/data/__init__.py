"""Data pipelines: deterministic, restartable synthetic token streams."""
