"""Deterministic, restartable synthetic token pipeline.

Batches are a pure function of (seed, step) — a counter-mode PRNG — so (a)
resuming from a checkpoint replays the exact stream (the checkpoint stores
{seed, step}), and (b) every data-parallel host can independently generate
its own shard (no coordinator), exactly how large-scale loaders index into
a global dataset order.

The synthetic LM task is next-token prediction over structured sequences
(Zipf-ish unigram mix + a copy motif) so small models show a real,
monotonically decreasing loss during the examples' training runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class TokenStreamState:
    seed: int
    step: int

    def as_dict(self) -> Dict:
        return dict(seed=self.seed, step=self.step)

    @classmethod
    def from_dict(cls, d: Dict) -> "TokenStreamState":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class TokenStream:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 start_step: int = 0, n_ctx: int = 0, d_model: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.n_ctx, self.d_model = n_ctx, d_model
        self.state = TokenStreamState(seed=seed, step=start_step)
        # Zipf-ish unigram distribution (shared across steps)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.state.seed * 1_000_003 + self.state.step) & 0x7FFFFFFF)
        toks = rng.choice(self.vocab, size=(self.batch, self.seq),
                          p=self._probs).astype(np.int32)
        # plant copy motifs: second half of some rows repeats the first
        rows = rng.random(self.batch) < 0.5
        half = self.seq // 2
        toks[rows, half:2 * half] = toks[rows, :half]
        batch = dict(tokens=toks,
                     labels=np.roll(toks, -1, axis=1).astype(np.int32))
        if self.n_ctx:
            batch["ctx"] = rng.normal(
                0, 1, size=(self.batch, self.n_ctx, self.d_model)
            ).astype(np.float32)
        self.state.step += 1
        return batch
