"""Deterministic fallback for the ``hypothesis`` property-testing API.

The property tests use a tiny subset of hypothesis (``@given`` over
integers/floats/lists with ``@settings``).  When hypothesis is not
installed — it is not part of this container — tests import this module
instead and each property runs over a fixed number of deterministic,
seeded examples.  No shrinking, no database, no adaptive search: just
reproducible coverage so the suite collects and runs everywhere.

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from repro.testing import given, settings
        from repro.testing import strategies as st
"""
from __future__ import annotations

import types

import numpy as np

# Examples per property in fallback mode.  Kept small: the properties run
# in the FULL tier-1 pass (`pytest -x -q`); the fast gate
# (scripts/run_tier1.sh, `-m "not slow"`) deselects them since with real
# hypothesis installed they are the long tail of the suite.
FALLBACK_EXAMPLES = 8
_SALT = 0x5EED


class _Strategy:
    """A deterministic example generator: example(i) -> i-th sample."""

    def _rng(self, i: int):
        return np.random.default_rng((_SALT + 7919 * i) & 0xFFFFFFFF)

    def example(self, i: int):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def example(self, i: int):
        # pin the corners first — they are the likeliest failure inputs
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        return int(self._rng(i).integers(self.lo, self.hi, endpoint=True))


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float, **_kw):
        self.lo, self.hi = lo, hi

    def example(self, i: int):
        if i == 0:
            return float(self.lo)
        if i == 1:
            return float(self.hi)
        return float(self._rng(i).uniform(self.lo, self.hi))


class _Lists(_Strategy):
    def __init__(self, elem: _Strategy, min_size: int = 0,
                 max_size: int = 16, **_kw):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, i: int):
        n = int(self._rng(i).integers(self.min_size, self.max_size,
                                      endpoint=True))
        n = max(n, self.min_size)
        return [self.elem.example(1000 * (i + 1) + j) for j in range(n)]


strategies = types.SimpleNamespace(
    integers=_Integers, floats=_Floats, lists=_Lists)
st = strategies


def given(*strats: _Strategy):
    """Run the test once per deterministic example tuple.

    The wrapper deliberately exposes a ZERO-ARG signature (no
    functools.wraps): pytest must not mistake the property's generated
    parameters for fixtures.
    """
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_fallback_examples", FALLBACK_EXAMPLES)
            for i in range(n):
                fn(*[s.example(i) for s in strats])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._fallback_examples = FALLBACK_EXAMPLES
        return wrapper
    return deco


def settings(max_examples: int = FALLBACK_EXAMPLES, **_kw):
    """Accepts (and mostly ignores) hypothesis settings; caps the example
    count so fallback property runs stay fast."""
    def deco(fn):
        fn._fallback_examples = min(max_examples, FALLBACK_EXAMPLES)
        return fn
    return deco
