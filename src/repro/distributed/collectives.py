"""Quantized collectives — MARS's arithmetic-conversion idea (paper
Section 5.2) applied to the LM substrate's communication.

int8 block-scaled gradient all-reduce: each block of 256 values is scaled
to int8 before the all-reduce (4x fewer bytes on the wire), accumulated in
int32, and rescaled after.  Stochastic rounding keeps the quantizer
unbiased; an optional error-feedback buffer makes the compression
asymptotically lossless across steps.

Used inside shard_map programs (axis_name present) and exposed as a
gradient transform for the training step (`compress_grads` /
`decompress_sum` pair around psum).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
BLOCK = 256


def _pad_to_block(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    n = x.size
    r = (-n) % BLOCK
    flat = x.reshape(-1)
    if r:
        flat = jnp.concatenate([flat, jnp.zeros((r,), x.dtype)])
    return flat, n


def quantize_int8(x: jnp.ndarray, rng: Optional[jax.Array] = None):
    """x: any shape f32/bf16 -> (q int8 (nb, BLOCK), scale f32 (nb, 1), n)."""
    flat, n = _pad_to_block(x.astype(F32))
    blocks = flat.reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    y = blocks / scale
    if rng is not None:                       # stochastic rounding
        noise = jax.random.uniform(rng, y.shape, F32) - 0.5
        q = jnp.clip(jnp.round(y + noise), -127, 127)
    else:
        q = jnp.clip(jnp.round(y), -127, 127)
    return q.astype(jnp.int8), scale, n


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, n: int,
                    shape) -> jnp.ndarray:
    blocks = q.astype(F32) * scale
    return blocks.reshape(-1)[:n].reshape(shape)


def psum_int8(x: jnp.ndarray, axis_name: str,
              rng: Optional[jax.Array] = None) -> jnp.ndarray:
    """All-reduce with int8 payload (inside shard_map).

    Values are quantized to int8, summed in int32 across the axis, and the
    per-block scales (f32, 1/256 of the payload) are max-combined.  Wire
    bytes: ~1/4 of an f32 psum, ~1/2 of bf16.
    """
    q, scale, n = quantize_int8(x, rng)
    # shared scale across participants so the int32 sum is coherent
    scale_max = jax.lax.pmax(scale, axis_name)
    requant = jnp.clip(jnp.round(
        q.astype(F32) * (scale / scale_max)), -127, 127).astype(jnp.int8)
    acc = jax.lax.psum(requant.astype(jnp.int32), axis_name)
    out = acc.astype(F32) * scale_max
    return out.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


class ErrorFeedback:
    """Residual accumulator for error-feedback compression (host-side pytree
    helper; the residual lives alongside the optimizer state)."""

    @staticmethod
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, F32), params)

    @staticmethod
    def apply(grads, residual):
        """returns (compress_input, new_residual_fn) — caller quantizes
        compress_input, then calls new_residual_fn(dequantized)."""
        g_plus = jax.tree_util.tree_map(
            lambda g, r: g.astype(F32) + r, grads, residual)

        def new_residual(dequant):
            return jax.tree_util.tree_map(
                lambda gp, dq: gp - dq.astype(F32), g_plus, dequant)
        return g_plus, new_residual


def quantize_kv_int8(kv: jnp.ndarray):
    """Per-(token, head) int8 KV-cache quantization: (..., Dh) blocks."""
    amax = jnp.max(jnp.abs(kv.astype(F32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(kv.astype(F32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(F32)


def dequantize_kv_int8(q: jnp.ndarray, scale: jnp.ndarray,
                       dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(F32) * scale).astype(dtype)
