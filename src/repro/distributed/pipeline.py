"""GPipe-style pipeline-parallel stage utility (optional mesh axis 'pipe').

The production dry-run mesh does not allocate a 'pipe' axis (scan-over-
layers + FSDP + TP covers the assigned shapes; DESIGN.md Section 6), but the
framework supports PP when the launcher is given a mesh with one:
microbatches flow through `n_stages` shard_map stages connected by
collective_permute, with the classic (n_micro + n_stages - 1) schedule.

Tested on small host meshes (tests/test_pipeline_pp.py).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(fn_stage: Callable, x: jnp.ndarray, stage_params,
                   mesh: Mesh, n_micro: int, axis: str = "pipe"):
    """Run `fn_stage(params_for_stage, micro_batch)` as a GPipe pipeline.

    x: (B, ...) global batch, split into n_micro microbatches along axis 0.
    stage_params: pytree with leading stage axis (n_stages, ...), sharded
    over `axis` so each device row holds its stage's weights.
    Returns fn's output with the same batch layout as x.
    """
    n_stages = mesh.shape[axis]
    assert x.shape[0] % n_micro == 0

    def stage_body(params_local, x_local):
        # params_local: (1, ...) this stage's params; x_local: full batch
        # (replicated over pipe axis — each stage computes every microbatch
        # but only its own stage transform, passing activations around the
        # ring).
        sid = jax.lax.axis_index(axis)
        p_own = jax.tree_util.tree_map(lambda t: t[0], params_local)
        micros = x_local.reshape(n_micro, -1, *x_local.shape[1:])
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(micros[0])
        outs = jnp.zeros_like(micros)

        def tick(carry, t):
            buf, outs = carry
            mb_idx = t - sid
            # stages 0 feeds new microbatches; others consume the permuted
            feed = micros[jnp.clip(mb_idx, 0, n_micro - 1)]
            cur = jnp.where(sid == 0, feed, buf)
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            y = fn_stage(p_own, cur)
            y = jnp.where(active, y, cur)
            # last stage writes its finished microbatch
            outs = jax.lax.cond(
                active & (sid == n_stages - 1),
                lambda o: o.at[jnp.clip(mb_idx, 0, n_micro - 1)].set(y),
                lambda o: o, outs)
            # rotate activations stage i -> i+1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(n_ticks))
        # broadcast the last stage's outputs to all rows so the result is
        # replicated over the pipe axis
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(x_local.shape)

    spec_p = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    fn = shard_map(stage_body, mesh=mesh, in_specs=(spec_p, P()),
                   out_specs=P(), check_rep=False)
    return fn(stage_params, x)
