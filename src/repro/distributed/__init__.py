"""Distribution: sharding rules, quantized collectives, pipeline stages."""
