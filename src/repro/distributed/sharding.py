"""Sharding rules: parameter / optimizer / activation / cache layouts.

Policy (DESIGN.md Section 6):
  * TP  — attention heads, FFN hidden, vocab, experts over 'model';
  * FSDP — the other big dim over ('pod','data') (ZeRO-3 under GSPMD:
    optimizer states inherit param shardings);
  * activations/batches over the DP axes; KV caches shard batch over DP and
    heads (or head_dim when head count is not divisible) over 'model'.

Every rule degrades gracefully: an axis is applied to a dim only if the dim
is divisible by the axis size (else that dim is replicated) — this is what
lets the same rules drive the 2x16x16 production mesh and a 1x2x2 test mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import axis_size, dp_axes


def _maybe(mesh: Mesh, dim: int, axes) -> Optional[Any]:
    """Use `axes` for this dim only if divisible; else replicate.  Axes not
    present in the mesh are dropped (pure-FSDP meshes have no 'model')."""
    if axes is None:
        return None
    if isinstance(axes, str):
        if axes not in mesh.axis_names:
            return None
    else:
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            return None
    if dim % axis_size(mesh, axes) == 0:
        return axes
    # try a suffix of the axis tuple (e.g. drop 'pod', keep 'data')
    if isinstance(axes, tuple) and len(axes) > 1:
        return _maybe(mesh, dim, axes[1:])
    return None


def _spec(mesh: Mesh, shape: Tuple[int, ...], template) -> P:
    assert len(template) == len(shape), (template, shape)
    return P(*[_maybe(mesh, d, t) for d, t in zip(shape, template)])


# --------------------------------------------------------------------------- #
# Parameters
# --------------------------------------------------------------------------- #
def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    fsdp = dp_axes(mesh)
    tp = "model"
    name = path.split("/")[-1]
    nd = len(shape)

    if name == "embed":
        return _spec(mesh, shape, (tp, fsdp))
    if name == "lm_head":
        return _spec(mesh, shape, (fsdp, tp))
    if name == "enc_pos":
        return P(*([None] * nd))
    if name == "router":                      # (G, d, E): E over model (EP)
        return _spec(mesh, shape, (None, None, tp))
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "sh_gate", "sh_up",
                "in_proj"):
        if nd == 4:                           # MoE expert stack (G,E,d,f)
            return _spec(mesh, shape, (None, tp, fsdp, None))
        return _spec(mesh, shape, (None, fsdp, tp))
    if name in ("wo", "w_down", "sh_down", "out_proj"):
        if nd == 4:                           # (G,E,f,d)
            return _spec(mesh, shape, (None, tp, None, fsdp))
        return _spec(mesh, shape, (None, tp, fsdp))
    # norms, conv weights, scalars: replicated
    return P(*([None] * nd))


def _tree_paths(tree) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        path = "/".join(
            k.key if hasattr(k, "key") else str(k.idx) for k in kp)
        out[path] = leaf
    return out, treedef


def param_shardings(params_abstract, mesh: Mesh):
    """Pytree of NamedSharding matching a (possibly abstract) param tree."""
    def one(kp, leaf):
        path = "/".join(k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
                        for k in kp)
        return NamedSharding(mesh, param_spec(path, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params_abstract)


# --------------------------------------------------------------------------- #
# Activations / batches / caches
# --------------------------------------------------------------------------- #
def batch_specs(cfg: ArchConfig, mesh: Mesh, batch_abstract) -> Any:
    dp = dp_axes(mesh)

    def one(kp, leaf):
        name = kp[-1].key if hasattr(kp[-1], "key") else str(kp[-1])
        shape = leaf.shape
        if name in ("tokens", "labels"):
            return NamedSharding(mesh, _spec(mesh, shape, (dp, None)))
        if name == "ctx":                       # (B, Tc, d)
            return NamedSharding(mesh, _spec(mesh, shape, (dp, None, None)))
        if name == "signals":                   # (R, S) raw reads
            return NamedSharding(mesh, _spec(mesh, shape, (dp, None)))
        return NamedSharding(mesh, P(*([None] * len(shape))))
    return jax.tree_util.tree_map_with_path(one, batch_abstract)


def cache_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    name = path.split("/")[-1]
    if name in ("k", "v", "k_scale", "v_scale"):   # (G, B, T, K, Dh|1)
        head_ax = _maybe(mesh, shape[3], "model")
        dh_ax = None if head_ax else _maybe(mesh, shape[4], "model")
        return P(None, _maybe(mesh, shape[1], dp), None, head_ax, dh_ax)
    if name == "state":                        # (G, B, H, N, P)
        return P(None, _maybe(mesh, shape[1], dp),
                 _maybe(mesh, shape[2], "model"), None, None)
    if name == "conv":                         # (G, B, W-1, d_inner)
        return P(None, _maybe(mesh, shape[1], dp), None,
                 _maybe(mesh, shape[3], "model"))
    return P(*([None] * len(shape)))


def cache_shardings(cache_abstract, mesh: Mesh):
    def one(kp, leaf):
        path = "/".join(k.key if hasattr(k, "key") else str(getattr(k, "idx", k))
                        for k in kp)
        return NamedSharding(mesh, cache_spec(path, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_abstract)


def replicated(tree_abstract, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P(*([None] * len(l.shape)))),
        tree_abstract)


# --------------------------------------------------------------------------- #
# MARS read mapping (data-parallel map_chunk)
# --------------------------------------------------------------------------- #
def mapping_chunk_shardings(mesh: Mesh, partitioned_index: bool = False):
    """Layouts for the sharded map_chunk path (core/pipeline.py): raw reads
    sharded over EVERY mesh axis (the MARS "channel stripe" — each chip
    maps its own reads); the reference index either replicated on all chips
    (default) or, with ``partitioned_index=True``, range-partitioned over
    the 'model' axis for the `query:ring` / `query:a2a` backends.

    Returns (signals_sharding for (R, S), index sharding[s]): a single
    replicated NamedSharding, or the per-leaf dict of
    ``partitioned_index_shardings``."""
    axes = tuple(mesh.axis_names)
    sig = NamedSharding(mesh, P(axes, None))
    if partitioned_index:
        return sig, partitioned_index_shardings(mesh)
    return sig, NamedSharding(mesh, P())


def partitioned_index_shardings(mesh: Mesh):
    """Shardings for the ``core/index.partition_index`` pytree: the leading
    partition axis of every leaf over ``index.INDEX_AXIS``, so each chip
    holds exactly its resident bucket-range partition (the flash-partition
    layout of paper Section 6.3)."""
    from repro.core.index import INDEX_AXIS, PARTITIONED_INDEX_KEYS
    return {k: NamedSharding(mesh, P(INDEX_AXIS))
            for k in PARTITIONED_INDEX_KEYS}
