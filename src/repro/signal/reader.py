"""Chunked streaming raw-signal reader (fast5-like container, simplified).

Binary layout:  header [magic u32 | n_reads u32 | signal_len u32 | dtype u8]
followed by n_reads contiguous int16 signal records.  The reader streams
fixed-size chunks with a one-chunk prefetch thread — the host-side analogue
of MARS's flash-to-DRAM load/compute overlap (Section 6.3).
"""
from __future__ import annotations

import pathlib
import queue
import struct
import threading
from typing import Iterator, Optional, Tuple

import numpy as np

MAGIC = 0x4D415253  # "MARS"
_HDR = struct.Struct("<IIIB")


def write_signals(path, signals: np.ndarray, scale: float = 64.0) -> None:
    """signals: (R, S) float32 — stored as int16 DAC-like counts."""
    path = pathlib.Path(path)
    q = np.clip(np.round(signals * scale), -32768, 32767).astype(np.int16)
    with open(path, "wb") as f:
        f.write(_HDR.pack(MAGIC, signals.shape[0], signals.shape[1], 1))
        f.write(q.tobytes())


def read_header(path) -> Tuple[int, int]:
    with open(path, "rb") as f:
        magic, n_reads, signal_len, _ = _HDR.unpack(f.read(_HDR.size))
    if magic != MAGIC:
        raise ValueError(f"{path}: bad magic {magic:#x}")
    return n_reads, signal_len


class SignalReader:
    """Iterate (chunk_idx, signals f32 (chunk, S)) with background prefetch.

    `start_chunk` supports resume-after-restart (checkpointed mapping jobs).
    """

    def __init__(self, path, chunk: int = 64, scale: float = 64.0,
                 start_chunk: int = 0, prefetch: int = 2):
        self.path = pathlib.Path(path)
        self.chunk = chunk
        self.scale = scale
        self.n_reads, self.signal_len = read_header(self.path)
        self.n_chunks = (self.n_reads + chunk - 1) // chunk
        self.start_chunk = start_chunk
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None

    def _producer(self):
        rec_bytes = self.signal_len * 2
        with open(self.path, "rb") as f:
            for ci in range(self.start_chunk, self.n_chunks):
                lo = ci * self.chunk
                n = min(self.chunk, self.n_reads - lo)
                f.seek(_HDR.size + lo * rec_bytes)
                buf = f.read(n * rec_bytes)
                arr = np.frombuffer(buf, np.int16).reshape(n, self.signal_len)
                sig = arr.astype(np.float32) / self.scale
                if n < self.chunk:  # pad tail chunk to static shape
                    pad = np.zeros((self.chunk - n, self.signal_len), np.float32)
                    sig = np.concatenate([sig, pad])
                self._q.put((ci, n, sig))
        self._q.put(None)

    def __iter__(self) -> Iterator[Tuple[int, int, np.ndarray]]:
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item
