"""Dataset registry mirroring the paper's Table 2 (scaled for CPU).

The paper evaluates five real datasets (SARS-CoV-2 .. human HG001).  Our
reproduction generates synthetic equivalents: the genome LENGTH is scaled so
index build + mapping run on one CPU core, while `paper_*` fields keep the
original magnitudes so the analytic hardware model can extrapolate measured
per-read workload counts to paper scale (workload.Workload.scale).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.config import MarsConfig
from repro.signal import simulate


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    key: str
    organism: str
    genome_len: int            # scaled synthetic genome (bases)
    paper_genome_len: int      # real genome size (bp, Table 2)
    paper_reads: int           # Table 2
    paper_bases: float         # Table 2 (bases sequenced)
    paper_bytes: float         # Table 2 dataset size (raw signal bytes)
    bench_reads: int           # reads to simulate for benchmarks
    large: bool                # 'large genome' filter thresholds (Section 5.1)
    seed: int = 0

    @property
    def scale_factor(self) -> float:
        """Deprecated read-count factor; prefer bytes_scale_factor."""
        return self.paper_reads / self.bench_reads

    def bytes_scale_factor(self, bench_bytes_raw: int) -> float:
        """paper raw bytes / bench raw bytes — the extrapolation factor for
        the analytic HW model (workload counts scale with signal volume)."""
        return float(self.paper_bytes) / float(bench_bytes_raw)

    @property
    def genome_scale_factor(self) -> float:
        """paper genome size / scaled genome size — collision-driven counts
        (spurious seed hits in the unfiltered baseline) grow with genome
        size; used to extrapolate the uncapped hit counter."""
        return self.paper_genome_len / self.genome_len


DATASETS: Dict[str, DatasetSpec] = {
    "D1": DatasetSpec("D1", "SARS-CoV-2", 29_903, 29_903, 1_382_016,
                      594e6, 11e9, 128, large=False, seed=11),
    "D2": DatasetSpec("D2", "E. coli", 400_000, 5_000_000, 353_317,
                      2_365e6, 27e9, 128, large=False, seed=12),
    "D3": DatasetSpec("D3", "Yeast", 600_000, 12_000_000, 49_989,
                      380e6, 39e9, 96, large=False, seed=13),
    "D4": DatasetSpec("D4", "Green Algae", 1_000_000, 111_000_000, 29_933,
                      609e6, 74e9, 96, large=True, seed=14),
    "D5": DatasetSpec("D5", "Human HG001", 2_000_000, 3_117_000_000, 269_507,
                      1_584e6, 39e9, 64, large=True, seed=15),
}


def config_for(spec: DatasetSpec, base: MarsConfig = MarsConfig()) -> MarsConfig:
    """Dataset-dependent thresholds (Section 5.1): (freq, vote, window) =
    (2000,5,256) small / (20000,2,256) large, scaled to our genome sizes.
    The scaled freq thresholds keep the same *fraction* of the index as the
    paper's absolute values do at paper scale."""
    if spec.large:
        return base.replace(thresh_freq=24, thresh_voting=2)
    return base.replace(thresh_freq=12, thresh_voting=4)


def build(spec: DatasetSpec, cfg: MarsConfig, signal_len: int = 1024):
    ref = simulate.make_reference(spec.genome_len, seed=spec.seed)
    reads = simulate.sample_reads(ref, spec.bench_reads,
                                  signal_len=signal_len,
                                  seed=spec.seed + 1, junk_frac=0.08)
    return ref, reads
