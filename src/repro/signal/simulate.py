"""Synthetic nanopore raw-signal simulator.

Generates a random reference genome, samples reads from both strands and
synthesizes their raw current signals with per-base dwell times and Gaussian
noise, mirroring how RawHash2's evaluation datasets behave.  The simulator is
the ground-truth oracle for the accuracy experiments (paper Table 3).

Coordinate convention ("double genome"): the reference event sequence is the
concatenation of forward-strand events (length Le) and reverse-complement
events (length Le).  A target position t in [0, Le) is forward; t in
[Le, 2*Le) is reverse.  `to_forward_coord` converts a reverse-coordinate
mapping back to forward-strand coordinates for accuracy scoring.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core import pore_model as pm


@dataclasses.dataclass
class Reference:
    bases: np.ndarray          # (L,) int8 in {0..3}
    events_fwd: np.ndarray     # (Le,) float32 expected levels, forward strand
    events_rc: np.ndarray      # (Le,) float32 expected levels, reverse strand
    table: np.ndarray          # (4096,) pore model

    @property
    def n_events(self) -> int:
        return int(self.events_fwd.shape[0])

    @property
    def events_concat(self) -> np.ndarray:
        return np.concatenate([self.events_fwd, self.events_rc])


@dataclasses.dataclass
class ReadSet:
    signals: np.ndarray        # (R, S) float32 raw signal
    true_pos: np.ndarray       # (R,) int32 forward-strand start (event coords)
    true_strand: np.ndarray    # (R,) int8 0=fwd, 1=rev
    n_bases: np.ndarray        # (R,) int32 bases consumed by each signal
    mappable: np.ndarray       # (R,) bool — False for junk/random reads


def make_reference(length: int, seed: int = 0) -> Reference:
    rng = np.random.default_rng(seed)
    bases = rng.integers(0, 4, size=length, dtype=np.int8)
    table = pm.pore_table()
    ev_f = pm.expected_events(bases, table)
    ev_r = pm.expected_events(pm.revcomp(bases), table)
    return Reference(bases=bases, events_fwd=ev_f, events_rc=ev_r, table=table)


def _signal_for_bases(levels: np.ndarray, signal_len: int, dwell_lo: int,
                      dwell_hi: int, noise_sigma: float,
                      rng: np.random.Generator) -> Tuple[np.ndarray, int]:
    """Emit `signal_len` samples walking `levels` with random dwell."""
    dwells = rng.integers(dwell_lo, dwell_hi + 1, size=levels.shape[0])
    reps = np.repeat(levels, dwells)
    n_bases = levels.shape[0]
    if reps.shape[0] < signal_len:                      # pad by re-walking
        reps = np.concatenate([reps, np.full(signal_len - reps.shape[0], reps[-1])])
    else:
        # how many full events fit
        csum = np.cumsum(dwells)
        n_bases = int(np.searchsorted(csum, signal_len, side="right")) + 1
        reps = reps[:signal_len]
    sig = reps + rng.normal(0.0, noise_sigma, size=signal_len)
    return sig.astype(np.float32), n_bases


def sample_reads(ref: Reference, n_reads: int, signal_len: int = 1024,
                 seed: int = 1, dwell: Tuple[int, int] = (5, 11),
                 noise_sigma: float = 1.5, junk_frac: float = 0.0) -> ReadSet:
    """Sample reads uniformly from both strands; optionally add unmappable
    junk reads (random signal) to exercise precision."""
    rng = np.random.default_rng(seed)
    Le = ref.n_events
    # enough bases that dwell-walking always fills signal_len
    span = signal_len // dwell[0] + pm.K + 2
    signals = np.zeros((n_reads, signal_len), np.float32)
    true_pos = np.zeros(n_reads, np.int32)
    true_strand = np.zeros(n_reads, np.int8)
    n_bases = np.zeros(n_reads, np.int32)
    mappable = np.ones(n_reads, bool)
    n_junk = int(round(junk_frac * n_reads))
    for i in range(n_reads):
        if i < n_junk:
            signals[i] = rng.normal(pm.LEVEL_MEAN, pm.LEVEL_SPAN / 4,
                                    size=signal_len).astype(np.float32)
            mappable[i] = False
            true_pos[i] = -1
            continue
        strand = int(rng.integers(0, 2))
        start = int(rng.integers(0, Le - span))
        if strand == 0:
            levels = ref.events_fwd[start:start + span]
        else:
            levels = ref.events_rc[start:start + span]
        sig, nb = _signal_for_bases(levels, signal_len, dwell[0], dwell[1],
                                    noise_sigma, rng)
        signals[i] = sig
        n_bases[i] = nb
        true_strand[i] = strand
        # ground truth in forward coordinates
        if strand == 0:
            true_pos[i] = start
        else:
            true_pos[i] = Le - 1 - (start + nb - 1)  # fwd coord of read end
    return ReadSet(signals=signals, true_pos=true_pos, true_strand=true_strand,
                   n_bases=n_bases, mappable=mappable)


def to_forward_coord(t_pos: np.ndarray, span: np.ndarray, n_events: int):
    """Convert double-genome target coords to (forward_pos, strand)."""
    t_pos = np.asarray(t_pos)
    strand = (t_pos >= n_events).astype(np.int8)
    fwd = np.where(strand == 0, t_pos, n_events - 1 - ((t_pos - n_events) + span - 1))
    return fwd.astype(np.int64), strand
