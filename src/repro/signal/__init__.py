"""Raw-signal data substrate: simulation, datasets, streaming reader."""
