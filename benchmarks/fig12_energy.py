"""Paper Fig. 12: energy reduction of each system over RH2.

``--model {analytic,sim}`` selects the costmodel backend (sim charges
static power over the simulated runtime; dynamic energies are shared)."""
from __future__ import annotations

import argparse
import statistics

from benchmarks import common
from benchmarks.fig11_speedup import MODE_FOR, results
from repro.core import costmodel, ssd_model
from repro.signal import datasets

PAPER_AVG = {"MARS/RH2": 79.4, "MARS/BC": 427.0, "MARS/GenPIP": 72.0,
             "MS-EXT/RH2": 22.3}


def run(emit, model="analytic") -> None:
    res = results(model)
    acc = {k: [] for k in PAPER_AVG}
    for ds, row in res.items():
        rh2 = row["RH2"]["energy"]
        parts = [f"{s}={rh2/row[s]['energy']:.1f}x"
                 for s in ssd_model.SYSTEMS if s != "RH2"]
        emit(common.csv_line(f"fig12/{ds}", row["MARS"]["energy"], ";".join(parts)))
        acc["MARS/RH2"].append(rh2 / row["MARS"]["energy"])
        acc["MARS/BC"].append(row["BC"]["energy"] / row["MARS"]["energy"])
        acc["MARS/GenPIP"].append(row["GenPIP"]["energy"] / row["MARS"]["energy"])
        acc["MS-EXT/RH2"].append(rh2 / row["MS-EXT"]["energy"])
    for k, vals in acc.items():
        emit(common.csv_line(
            f"fig12/avg/{k}", 0.0,
            f"ours={statistics.mean(vals):.1f}x;paper={PAPER_AVG[k]:.1f}x"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="analytic",
                    choices=sorted(costmodel.MODELS))
    args = ap.parse_args(argv)
    run(print, model=args.model)


if __name__ == "__main__":
    main()
