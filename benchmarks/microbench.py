"""Persistent per-stage-group microbenchmark of the mapping pipeline.

Times warmed-up, jit-compiled wall clock for one ``map_chunk`` workload,
split by stage group:

    cheap         detect -> quantize -> seed -> query -> vote (every read)
    chain_fast    the filter-aware chaining fast path of core/pipeline.py
                  (read compaction + select-then-sort width ladder +
                  ring-buffer banded DP) on the cheap phase's real outputs
    chain_pre     the pre-fast-path chaining implementation on the SAME
                  inputs: full E*H anchor sort + dynamic-slice banded DP
                  (chaining.sort_anchors_reference / chain_dp_reference)
    map_chunk     the full fused chunk program (fast path on)
    map_chunk_pre the full chunk program with chain_compaction disabled

``scripts/bench_pipeline.py`` drives this and appends the results to
``BENCH_pipeline.json`` at the repo root so every PR records the perf
trajectory (see EXPERIMENTS.md).

All timings are min-over-repeats of a blocking call AFTER a warm-up call,
so compile time is excluded and cache effects are steady-state.
"""
from __future__ import annotations

import subprocess
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MarsConfig, build_index, chaining, stages
from repro.core import pipeline
from repro.core.index import index_arrays
from repro.signal import simulate


def git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def time_fn(fn, *args, repeats: int = 5) -> float:
    """Min-of-repeats wall seconds for ``fn(*args)``; one warm-up call first
    (compiles + primes caches)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def make_workload(n_reads: int = 32, ref_events: int = 20_000,
                  junk_frac: float = 0.5, seed: int = 0):
    """One benchmark chunk: a synthetic reference + a read mix where
    ``junk_frac`` of the reads are unmappable noise (the population the
    filters — and therefore the compaction gate — are built for)."""
    cfg = MarsConfig(hash_bits=14).with_mode("ms_fixed")
    ref = simulate.make_reference(ref_events, seed=seed)
    reads = simulate.sample_reads(ref, n_reads, signal_len=cfg.signal_len,
                                  seed=seed + 1, junk_frac=junk_frac)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    return cfg, jnp.asarray(reads.signals), arrays


def _chain_programs(cfg: MarsConfig, signals, arrays, backend: str):
    """Jit the cheap phase and the pre/fast chaining programs of one
    backend; returns (cheap_call, fast_call, pre_call) where the chain
    calls are argless closures over the cheap phase's real outputs."""
    plan = stages.resolve_plan(cfg, backend)
    prims = stages.chain_primitives(plan, cfg)
    if prims is None:
        raise ValueError(
            f"backend {backend!r} resolves to a plan whose chain stages "
            "expose no primitives; the chaining microbenchmark cannot "
            f"time it (plan: {plan})")
    sorter, dp = prims

    cheap_j = jax.jit(
        lambda s: pipeline.cheap_phase(s, arrays, cfg, plan))
    q_pos, t_pos, hv, counters = cheap_j(signals)
    cnt = counters["n_anchors_postvote"]

    fast_j = jax.jit(lambda qp, tp, h, c: pipeline._chain_outputs(
        qp, tp, h, c, cfg, prims))

    def pre_read(qp, tp, h):
        # the pre-fast-path chain program: full-width sort + the
        # dynamic-slice reference DP ("pre" side of the speedup claim).
        # For accelerated backends the sort still runs on the backend's
        # sorter (full width); the reference DP is the pre-PR algorithm.
        sq, st, sv = chaining.sort_anchors_reference(qp, tp, h, cfg,
                                                     sorter=sorter)
        if backend == stages.REFERENCE:
            f, d = chaining.chain_dp_reference(sq, st, sv, cfg)
        else:
            f, d = dp(sq, st, sv)
        res = chaining.best_chain(f, d, sv, cfg)
        return res.t_start, res.score, res.mapped

    pre_j = jax.jit(lambda qp, tp, h: jax.vmap(pre_read)(qp, tp, h))

    return (lambda: cheap_j(signals),
            lambda: fast_j(q_pos, t_pos, hv, cnt),
            lambda: pre_j(q_pos, t_pos, hv))


def _interleaved(fast_c, pre_c, rounds: int):
    """Paired pre/fast timing: both programs per round, so machine-speed
    swings between rounds hit both equally.  Returns (min fast, min pre,
    median per-round pre/fast ratio) — the median paired ratio is stable
    to a few % where separately-measured absolute times swing ~40% on a
    shared CPU."""
    jax.block_until_ready(fast_c())
    jax.block_until_ready(pre_c())
    tf = tp = float("inf")
    ratios = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        jax.block_until_ready(fast_c())
        tf_k = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(pre_c())
        tp_k = time.perf_counter() - t0
        tf, tp = min(tf, tf_k), min(tp, tp_k)
        ratios.append(tp_k / tf_k)
    return tf, tp, float(np.median(ratios))


def bench_backend(cfg: MarsConfig, signals, arrays, backend: str,
                  repeats: int = 5) -> Dict[str, float]:
    """Stage-group timings (seconds) for one registry backend."""
    cheap_c, fast_c, pre_c = _chain_programs(cfg, signals, arrays, backend)
    plan = stages.resolve_plan(cfg, backend)
    chunk_j = lambda: pipeline.map_chunk(signals, arrays, cfg, plan=plan)
    cfg_pre = cfg.replace(chain_compaction=False)
    plan_pre = stages.resolve_plan(cfg_pre, backend)
    chunk_pre_j = lambda: pipeline.map_chunk(signals, arrays, cfg_pre,
                                             plan=plan_pre)

    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds=max(3 * repeats, 15))
    groups = {
        "cheap": time_fn(cheap_c, repeats=repeats),
        "chain_fast": tf,
        "chain_pre": tp,
        "chain_speedup": ratio,
        "map_chunk": time_fn(chunk_j, repeats=repeats),
        "map_chunk_pre": time_fn(chunk_pre_j, repeats=repeats),
    }
    return groups


def bench_chain_ratio(cfg: MarsConfig, signals, arrays,
                      backend: str = stages.REFERENCE,
                      rounds: int = 25) -> Dict[str, float]:
    """Machine-speed-independent chaining measurement for the regression
    gate.

    Absolute ms are not comparable across runs on a shared/containerized
    CPU (whole-process speed swings ~1.5x), so the pre and fast chain
    programs are timed in INTERLEAVED rounds — each round yields a paired
    pre/fast ratio under the same instantaneous machine state — and the
    MEDIAN of the per-round ratios is the estimator (stable to ~3% across
    processes where min-of-N absolute times swing ~40%)."""
    _, fast_c, pre_c = _chain_programs(cfg, signals, arrays, backend)
    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds)
    return {"chain_fast_min": tf, "chain_pre_min": tp, "rounds": rounds,
            "chain_speedup_median": ratio}


def run(n_reads: int = 32, ref_events: int = 20_000, junk_frac: float = 0.5,
        repeats: int = 5, backends=(stages.REFERENCE, stages.PALLAS),
        seed: int = 0) -> Dict:
    cfg, signals, arrays = make_workload(n_reads, ref_events, junk_frac, seed)
    rec = {
        "git_sha": git_sha(),
        "workload": dict(n_reads=n_reads, ref_events=ref_events,
                         junk_frac=junk_frac, repeats=repeats, seed=seed,
                         signal_len=cfg.signal_len,
                         max_anchors=cfg.max_anchors,
                         chain_band=cfg.chain_band,
                         chain_widths=list(cfg.chain_widths),
                         chain_capacity_frac=cfg.chain_capacity_frac),
        "backends": {},
    }
    for b in backends:
        rec["backends"][b] = bench_backend(cfg, signals, arrays, b,
                                           repeats=repeats)
    return rec
