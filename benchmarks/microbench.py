"""Persistent per-stage-group microbenchmark of the mapping pipeline.

Times warmed-up, jit-compiled wall clock for one ``map_chunk`` workload,
split by stage group:

    cheap         the shipped cheap phase (batch-level detect/query/vote,
                  packed-entry gathers) over the whole chunk
    cheap_pre     the pre-fast-path cheap phase on the SAME signals:
                  per-read vmap with two-median normalization, scatter
                  segment means, unpacked four-gather query and per-read
                  vote scatters (for the pallas backend: the unit-batch
                  vmapped detect kernel)
    detect/query/vote (+ _pre)   the cheap phase's stage groups timed
                  individually on the pipeline's real intermediate data
    chain_fast    the filter-aware chaining fast path of core/pipeline.py
                  (read compaction + select-then-sort width ladder +
                  ring-buffer banded DP) on the cheap phase's real outputs
    chain_pre     the pre-fast-path chaining implementation on the SAME
                  inputs: full E*H anchor sort + dynamic-slice banded DP
                  (chaining.sort_anchors_reference / chain_dp_reference)
    map_chunk     the full fused chunk program (fast path on)
    map_chunk_pre the full chunk program with chain_compaction disabled
    serving_fast  continuous-batching multi-stream serving (ServeDriver):
                  many short streams packed across stream boundaries into
                  full chunks
    serving_pre   the single-tenant serving baseline on the SAME streams:
                  each stream mapped separately through the driver loop,
                  so every stream pays its own padded partial chunk
    cache         the out-of-core tiered-index group (top-level ``cache``
                  key, not per-backend): the same reads through the
                  ``query:tiered`` hot-tile cache (host-resident tiles,
                  a device cache several times smaller than the index,
                  prefetching driver loop) vs the fully-resident table,
                  plus the cache's hit-rate / paged-bytes telemetry
    fused         the whole-phase mega-kernel group (top-level ``fused``
                  key): the cheap phase through kernels/cheap_fused (ONE
                  kernel launch, DMA-streamed index tiles) vs the same
                  pallas plan's per-stage program
                  (``pipeline.cheap_phase(use_fused=False)``)
    fairness      the multi-tenant fair-serving group (top-level
                  ``fairness`` key): one flooded two-tenant trace served
                  with vs without per-tenant shed budgets
                  (``ServeDriver(tenant_budgets=...)``); the gated metric
                  is the well-behaved tenant's victim count (sheds +
                  rejects), measured on the VIRTUAL clock — fully
                  deterministic, no wall time involved

``scripts/bench_pipeline.py`` drives this and appends the results to
``BENCH_pipeline.json`` at the repo root so every PR records the perf
trajectory (see EXPERIMENTS.md).

All timings are min-over-repeats of a blocking call AFTER a warm-up call,
so compile time is excluded and cache effects are steady-state.

Quick-profile honesty rule: the interpret-mode pallas groups may run on a
REDUCED read grid (``run(pallas_reduced_reads=...)``) to keep CI bench
wall time bounded; every reduced record carries explicit ``grid_reads`` /
``grid_reduced`` markers, and pre/fast pairs always share the same grid so
the gated RATIOS stay honest.
"""
from __future__ import annotations

import subprocess
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MarsConfig, build_index, chaining, seeding, stages
from repro.core import events, pipeline, vote
from repro.core.index import index_arrays, index_arrays_unpacked
from repro.signal import simulate


def git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def hardware_key() -> Dict[str, object]:
    """The hardware/software fingerprint stamped into every measured
    profile and gate record, so numbers measured on different machines are
    never silently compared (absolute ms are machine-bound; the gate's
    pre/fast ratios are not)."""
    import os
    import platform
    return dict(machine=platform.machine(), system=platform.system(),
                cpu_count=os.cpu_count() or 0,
                python=platform.python_version(), jax=jax.__version__,
                jax_backend=jax.default_backend())


def time_fn(fn, *args, repeats: int = 5) -> float:
    """Min-of-repeats wall seconds for ``fn(*args)``; one warm-up call first
    (compiles + primes caches)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def make_workload(n_reads: int = 32, ref_events: int = 20_000,
                  junk_frac: float = 0.5, seed: int = 0):
    """One benchmark chunk: a synthetic reference + a read mix where
    ``junk_frac`` of the reads are unmappable noise (the population the
    filters — and therefore the compaction gate — are built for)."""
    cfg = MarsConfig(hash_bits=14).with_mode("ms_fixed")
    ref = simulate.make_reference(ref_events, seed=seed)
    reads = simulate.sample_reads(ref, n_reads, signal_len=cfg.signal_len,
                                  seed=seed + 1, junk_frac=junk_frac)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    arrays["_unpacked"] = {k: jnp.asarray(v)
                           for k, v in index_arrays_unpacked(idx).items()}
    arrays["_index"] = idx                  # host Index (tiered-cache group)
    return cfg, jnp.asarray(reads.signals), arrays


def _split_arrays(arrays):
    """(packed online pytree, unpacked oracle pytree) from make_workload's
    arrays dict — the jit-facing packed dict must not carry the oracle or
    the host-side "_"-prefixed extras."""
    unpacked = arrays.get("_unpacked")
    packed = {k: v for k, v in arrays.items() if not k.startswith("_")}
    if unpacked is None:
        if "entries_key" not in packed:
            raise ValueError(
                "cheap-phase microbenchmark needs the unpacked oracle "
                "planes: use make_workload (which embeds them under "
                "'_unpacked') or pass index_arrays_unpacked output")
        unpacked = packed                # caller brought an unpacked dict
    return packed, unpacked


def _chain_programs(cfg: MarsConfig, signals, arrays, backend: str):
    """Jit the cheap phase and the pre/fast chaining programs of one
    backend; returns (cheap_call, fast_call, pre_call) where the chain
    calls are argless closures over the cheap phase's real outputs."""
    arrays, _ = _split_arrays(arrays)
    plan = stages.resolve_plan(cfg, backend)
    prims = stages.chain_primitives(plan, cfg)
    if prims is None:
        raise ValueError(
            f"backend {backend!r} resolves to a plan whose chain stages "
            "expose no primitives; the chaining microbenchmark cannot "
            f"time it (plan: {plan})")
    sorter, dp = prims

    cheap_j = jax.jit(
        lambda s: pipeline.cheap_phase(s, arrays, cfg, plan))
    q_pos, t_pos, hv, counters = cheap_j(signals)
    cnt = counters["n_anchors_postvote"]

    fast_j = jax.jit(lambda qp, tp, h, c: pipeline._chain_outputs(
        qp, tp, h, c, cfg, prims))

    def pre_read(qp, tp, h):
        # the pre-fast-path chain program: full-width sort + the
        # dynamic-slice reference DP ("pre" side of the speedup claim).
        # For accelerated backends the sort still runs on the backend's
        # sorter (full width); the reference DP is the pre-PR algorithm.
        sq, st, sv = chaining.sort_anchors_reference(qp, tp, h, cfg,
                                                     sorter=sorter)
        if backend == stages.REFERENCE:
            f, d = chaining.chain_dp_reference(sq, st, sv, cfg)
        else:
            f, d = dp(sq, st, sv)
        res = chaining.best_chain(f, d, sv, cfg)
        return res.t_start, res.score, res.mapped

    pre_j = jax.jit(lambda qp, tp, h: jax.vmap(pre_read)(qp, tp, h))

    return (lambda: cheap_j(signals),
            lambda: fast_j(q_pos, t_pos, hv, cnt),
            lambda: pre_j(q_pos, t_pos, hv))


def _cheap_programs(cfg: MarsConfig, signals, arrays, backend: str):
    """Jit the pre/fast cheap-phase programs of one backend, whole-phase and
    per stage group (detect / query / vote), all on the pipeline's real
    intermediate data.

    Returns (fast_calls, pre_calls): dicts keyed "cheap"/"detect"/"query"/
    "vote" of argless closures.  The "pre" side reconstructs the pre-fast-
    path configuration: per-read vmap, two-median normalization + scatter
    segment means (``events.detect_events_reference``; for the pallas
    backend the unit-batch vmapped kernel), unpacked four-gather query
    (``seeding.query_index_reference``) and per-read vote scatters
    (``vote.vote_filter_reference``).
    """
    packed, unpacked = _split_arrays(arrays)
    plan = stages.resolve_plan(cfg, backend)
    prims = stages.cheap_primitives(plan, cfg)
    if prims is None:
        raise ValueError(f"backend {backend!r} has no batch-level cheap "
                         f"phase to time (plan: {plan})")
    gather = prims.gather

    # ---- detect ----
    if prims.detector is not None:
        det_fast = jax.jit(prims.detector)
        det_prim = stages.get_backend("detect", backend).primitive
        det_pre = jax.jit(jax.vmap(
            lambda s: tuple(x[0] for x in det_prim(s[None], cfg))))
    else:
        det_fast = jax.jit(jax.vmap(
            lambda s: events.detect_events(s, cfg)[:2]))
        det_pre = jax.jit(jax.vmap(
            lambda s: events.detect_events_reference(s, cfg)[:2]))

    # real intermediate data for the later stage groups
    q_pos, t_pos, hit_valid, counters = jax.jit(
        lambda s: pipeline.cheap_phase(s, packed, cfg, plan))(signals)
    means, _n = det_fast(signals)

    def quant_seed(ev, n):
        st = stages.execute_stages({"events": ev, "n_events": n,
                                    "counters": {}},
                                   packed, cfg, plan, ("quantize", "seed"))
        return st["keys"], st["seed_valid"]
    keys, seed_valid = jax.jit(jax.vmap(quant_seed))(
        means, counters["n_events"])

    # ---- query ----
    query_fast = jax.jit(lambda k, v: seeding.query_index(
        k, v, packed, cfg, gather=gather))
    query_pre = jax.jit(jax.vmap(lambda k, v: seeding.query_index_reference(
        k, v, unpacked, cfg, gather=gather)))

    # ---- vote ----
    vote_fast = jax.jit(lambda q, t, h: vote.vote_filter(q, t, h, cfg))
    vote_pre = jax.jit(jax.vmap(
        lambda q, t, h: vote.vote_filter_reference(q, t, h, cfg)))

    # ---- whole cheap phase ----
    cheap_fast = jax.jit(lambda s: pipeline.cheap_phase(s, packed, cfg, plan))

    def cheap_pre_read(signal):
        ev, n, _ = (events.detect_events_reference(signal, cfg)
                    if prims.detector is None else
                    tuple(x[0] for x in det_prim(signal[None], cfg)) + (None,))
        st = stages.execute_stages({"events": ev, "n_events": n,
                                    "counters": {}},
                                   packed, cfg, plan, ("quantize", "seed"))
        tp, hv, _c = seeding.query_index_reference(
            st["keys"], st["seed_valid"], unpacked, cfg, gather=gather)
        qp = jnp.broadcast_to(
            jnp.arange(cfg.max_events, dtype=jnp.int32)[:, None], tp.shape)
        hv, _c2 = vote.vote_filter_reference(qp, tp, hv, cfg)
        return qp, tp, hv
    cheap_pre = jax.jit(jax.vmap(cheap_pre_read))

    fast_calls = {
        "cheap": lambda: cheap_fast(signals),
        "detect": lambda: det_fast(signals),
        "query": lambda: query_fast(keys, seed_valid),
        "vote": lambda: vote_fast(q_pos, t_pos, hit_valid),
    }
    pre_calls = {
        "cheap": lambda: cheap_pre(signals),
        "detect": lambda: det_pre(signals),
        "query": lambda: query_pre(keys, seed_valid),
        "vote": lambda: vote_pre(q_pos, t_pos, hit_valid),
    }
    return fast_calls, pre_calls


def _interleaved(fast_c, pre_c, rounds: int):
    """Paired pre/fast timing: both programs per round, so machine-speed
    swings between rounds hit both equally.  Returns (min fast, min pre,
    median per-round pre/fast ratio) — the median paired ratio is stable
    to a few % where separately-measured absolute times swing ~40% on a
    shared CPU."""
    jax.block_until_ready(fast_c())
    jax.block_until_ready(pre_c())
    tf = tp = float("inf")
    ratios = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        jax.block_until_ready(fast_c())
        tf_k = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(pre_c())
        tp_k = time.perf_counter() - t0
        tf, tp = min(tf, tf_k), min(tp, tp_k)
        ratios.append(tp_k / tf_k)
    return tf, tp, float(np.median(ratios))


def bench_backend(cfg: MarsConfig, signals, arrays, backend: str,
                  repeats: int = 5,
                  include_serving: bool = True) -> Dict[str, float]:
    """Stage-group timings (seconds) for one registry backend.

    ``include_serving=False`` skips the serving pre/post group — on the
    pallas backend it runs the interpret-mode kernels through the whole
    driver loop many times (~tens of seconds) and the quick profile does
    not gate on it."""
    cheap_c, fast_c, pre_c = _chain_programs(cfg, signals, arrays, backend)
    packed, _ = _split_arrays(arrays)
    plan = stages.resolve_plan(cfg, backend)
    chunk_j = lambda: pipeline.map_chunk(signals, packed, cfg, plan=plan)
    cfg_pre = cfg.replace(chain_compaction=False)
    plan_pre = stages.resolve_plan(cfg_pre, backend)
    chunk_pre_j = lambda: pipeline.map_chunk(signals, packed, cfg_pre,
                                             plan=plan_pre)

    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds=max(3 * repeats, 15))
    groups = {
        "cheap": time_fn(cheap_c, repeats=repeats),
        "chain_fast": tf,
        "chain_pre": tp,
        "chain_speedup": ratio,
        "map_chunk": time_fn(chunk_j, repeats=repeats),
        "map_chunk_pre": time_fn(chunk_pre_j, repeats=repeats),
    }

    # cheap-phase pre/post groups (pre side is expensive on the pallas
    # backend — the unit-batch vmapped kernel — so fewer rounds)
    cf, cp = _cheap_programs(cfg, signals, arrays, backend)
    ctf, ctp, cratio = _interleaved(cf["cheap"], cp["cheap"],
                                    rounds=max(repeats, 3))
    groups.update(cheap_fast=ctf, cheap_pre=ctp, cheap_speedup=cratio)
    for g in ("detect", "query", "vote"):
        gtf, gtp, gratio = _interleaved(cf[g], cp[g], rounds=max(repeats, 3))
        groups.update({f"{g}_fast": gtf, f"{g}_pre": gtp,
                       f"{g}_speedup": gratio})

    # serving pre/post group (continuous batching across streams)
    if include_serving:
        groups.update(bench_serving(cfg, signals, arrays, backend,
                                    repeats=repeats))
    else:
        groups["serving_skipped"] = True
    return groups


# --------------------------------------------------------------------------- #
# Serving (continuous batching across streams)
# --------------------------------------------------------------------------- #
class _PlanMapper:
    """Minimal Mapper stand-in over pre-built index arrays: exactly the
    ``cfg`` + ``chunk_fn()`` surface ServeDriver needs (no Index object,
    no device re-upload per construction)."""

    def __init__(self, arrays, cfg: MarsConfig, plan):
        self.arrays, self.cfg, self.plan = arrays, cfg, plan

    def chunk_fn(self):
        return lambda sig, nv: pipeline.map_chunk(
            jnp.asarray(sig), self.arrays, self.cfg, n_valid=nv,
            plan=self.plan)


def _serving_programs(cfg: MarsConfig, signals, arrays, backend: str,
                      stream_len: int = 2, chunk: int = 8):
    """(fast_call, pre_call, mapper, streams): the serving pre/post pair on
    one fixed multi-stream workload.

    The workload is R reads split into R/stream_len single-tenant streams
    (short streams — the sequencer-channel shape).  ``pre`` maps each
    stream separately through the unified driver loop, so every stream
    pays its own padded partial chunk (the single-tenant driver this PR
    replaces); ``fast`` serves the identical reads through ServeDriver,
    which packs ready reads across stream boundaries into full chunks.
    Outputs are bit-identical (tests/test_server.py); the speedup is the
    padding the packer eliminates."""
    from repro.core import driver
    from repro.core.server import ServeDriver

    arrays, _ = _split_arrays(arrays)
    plan = stages.resolve_plan(cfg, backend)
    mapper = _PlanMapper(arrays, cfg, plan)
    fn = mapper.chunk_fn()
    n = (signals.shape[0] // stream_len) * stream_len
    streams = [np.asarray(signals[i:i + stream_len], np.float32)
               for i in range(0, n, stream_len)]

    def pre_call():
        return [driver.collect(driver.stream_map(
            fn, driver.array_chunks(s, chunk))) for s in streams]

    def fast_call():
        sd = ServeDriver(mapper, chunk=chunk)
        for si, s in enumerate(streams):
            sd.submit(f"s{si}", s)
        sd.drain()
        return [sd.results(f"s{si}").t_start for si in range(len(streams))]

    return fast_call, pre_call, mapper, streams


def bench_serving(cfg: MarsConfig, signals, arrays, backend: str,
                  repeats: int = 5, offered_load: float = 0.7,
                  chunk: int = 8) -> Dict[str, float]:
    """The serving pre/post group: interleaved single-tenant vs
    continuous-batching timings, plus wall-clock streams/sec and the
    virtual-time p99 latency at a fixed offered load (Poisson arrivals at
    ``offered_load`` x chunk capacity)."""
    from repro.core.server import ServeDriver

    fast_c, pre_c, mapper, streams = _serving_programs(
        cfg, signals, arrays, backend, chunk=chunk)
    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds=max(repeats, 3))
    out = {"serving_fast": tf, "serving_pre": tp, "serving_speedup": ratio,
           "serving_streams": len(streams), "serving_chunk": chunk}

    # throughput + tail latency at fixed offered load (virtual clock:
    # 1 unit = one full-length chunk service)
    rng = np.random.default_rng(0)
    n = len(streams) * streams[0].shape[0]
    times = np.cumsum(rng.exponential(1.0 / (offered_load * chunk), n))
    flat = np.concatenate(streams)
    trace = [(float(times[k]), f"s{k % len(streams)}", flat[k])
             for k in range(n)]

    def serve():
        sd = ServeDriver(mapper, chunk=chunk)
        return sd, sd.serve_trace(trace)

    serve()                                   # warm-up
    t0 = time.perf_counter()
    sd, reports = serve()
    wall = time.perf_counter() - t0
    p99 = float(np.max([r.p99_latency for r in reports.values()]))
    out.update(serving_offered_load=offered_load,
               serving_wall_s=wall,
               serving_streams_per_sec=len(streams) / wall,
               serving_reads_per_sec=n / wall,
               serving_p99_virtual=p99,
               serving_pad_rows=sd.n_pad_rows,
               serving_chunks=sd.n_chunks)
    return out


def bench_serving_ratio(cfg: MarsConfig, signals, arrays,
                        backend: str = stages.REFERENCE,
                        rounds: int = 25) -> Dict[str, float]:
    """The serving twin of ``bench_chain_ratio``: interleaved single-tenant
    (pre) vs continuous-batching (fast) rounds over the same streams,
    median paired ratio as the machine-speed-independent gate estimator."""
    fast_c, pre_c, _, _ = _serving_programs(cfg, signals, arrays, backend)
    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds)
    return {"serving_fast_min": tf, "serving_pre_min": tp, "rounds": rounds,
            "serving_speedup_median": ratio}


# --------------------------------------------------------------------------- #
# Fairness (multi-tenant shed budgets)
# --------------------------------------------------------------------------- #
def _fairness_runs(cfg: MarsConfig, signals, arrays, backend: str,
                   chunk: int = 8):
    """One flooded two-tenant trace, served twice: ``run(False)`` is the
    budget-free legacy driver, ``run(True)`` adds per-tenant shed budgets.

    acme: two short in-budget streams (half the bench reads); flood: one
    stream of ``5*chunk`` identical reads at HIGHER priority with an
    empty budget — the starvation shape of tests/test_tenants.py, where
    the legacy shed rule serves the flooder first and sheds acme.  All
    arrivals and sheds live on the driver's virtual clock, so both runs
    are deterministic: the gated ratio never moves with machine speed."""
    from repro.core.server import ServeDriver, TenantBudget

    arrays_p, _ = _split_arrays(arrays)
    plan = stages.resolve_plan(cfg, backend)
    mapper = _PlanMapper(arrays_p, cfg, plan)
    acme = np.asarray(signals[:max(signals.shape[0] // 2, 2)], np.float32)
    flood = np.repeat(np.asarray(signals[-1:], np.float32), 5 * chunk,
                      axis=0)
    budgets = (TenantBudget("acme", rate=float(chunk)),
               TenantBudget("flood", rate=0.0, burst=1.0))

    def run(with_budgets: bool) -> "ServeDriver":
        sd = ServeDriver(mapper, chunk=chunk, shed=True, shed_window=2.0,
                         cost_model="sim",
                         tenant_budgets=budgets if with_budgets else None)
        half = acme.shape[0] // 2
        sd.submit("a0", acme[:half], tenant="acme", t=0.0)
        sd.submit("a1", acme[half:], tenant="acme", t=0.0)
        sd.submit("f0", flood, tenant="flood", priority=1, t=0.0)
        sd.drain()
        return sd

    return run


def _acme_victims(sd) -> int:
    # n_rejected is the total not-served count (closed-loop sheds are a
    # subset of it), so it IS the victim count — no double counting
    return sum(sd.stream(s).n_rejected for s in ("a0", "a1"))


def bench_fairness(cfg: MarsConfig, signals, arrays,
                   backend: str = stages.REFERENCE,
                   chunk: int = 8) -> Dict[str, object]:
    """The fairness pre/post group: the flooded trace without (pre) and
    with (fast) per-tenant shed budgets.  The headline metric is the
    well-behaved tenant's victim count — its reads not served (shed or
    rejected) — which budgets drive to zero by charging the flooder's
    own overflow instead (tests/test_tenants.py asserts the isolation
    bit-exactly)."""
    run = _fairness_runs(cfg, signals, arrays, backend, chunk=chunk)
    legacy, fair = run(False), run(True)
    vl, vf = _acme_victims(legacy), _acme_victims(fair)
    tr = fair.tenant_report()
    return {"fairness_acme_victims_legacy": vl,
            "fairness_acme_victims_fair": vf,
            "fairness_shed_total_legacy": int(legacy.n_shed),
            "fairness_shed_total_fair": int(fair.n_shed),
            "fairness_flood_shed_fair": int(tr["flood"].n_shed),
            "fairness_flood_over_budget": int(tr["flood"].n_over_budget),
            "fairness_speedup": (1.0 + vl) / (1.0 + vf),
            "fairness_chunk": chunk, "fairness_backend": backend}


def bench_fairness_ratio(cfg: MarsConfig, signals, arrays,
                         backend: str = stages.REFERENCE,
                         rounds: int = 1) -> Dict[str, object]:
    """The fairness twin of ``bench_chain_ratio`` for the regression gate:
    ``(1 + legacy acme victims) / (1 + budgeted acme victims)`` on the
    flooded trace.  Unlike the timing gates this is a VIRTUAL-clock count
    ratio — deterministic by construction, so one round suffices and the
    gate can never be machine-noise flaky."""
    run = _fairness_runs(cfg, signals, arrays, backend)
    vl, vf = _acme_victims(run(False)), _acme_victims(run(True))
    return {"fairness_acme_victims_legacy": vl,
            "fairness_acme_victims_fair": vf,
            "rounds": 1, "deterministic": True,
            "fairness_speedup_median": (1.0 + vl) / (1.0 + vf)}


def _cache_programs(cfg: MarsConfig, signals, arrays, n_tiles: int = 16,
                    cache_slots: int = 4, chunk: int = 8):
    """(tiered_call, resident_call, tiered_mapper): the SAME read stream
    mapped through the out-of-core tiered backend (host-resident tiles,
    ``cache_slots``-slot device cache, prefetching driver loop —
    core/tiered.py) vs the fully-resident table.  The index spans
    ``n_tiles`` tiles, several times the cache, so the tiered side really
    pages; outputs are bit-identical (tests/test_tiered.py), the timing
    difference is the paging + traffic-pre-pass overhead the hot-tile
    cache has to keep small."""
    idx = arrays.get("_index")
    if idx is None:
        raise ValueError(
            "cache microbenchmark needs the host Index: use make_workload "
            "(which embeds it under '_index')")
    tiered = pipeline.Mapper(idx, cfg, backend="tiered", tiles=n_tiles,
                             cache_slots=cache_slots)
    resident = pipeline.Mapper(idx, cfg)
    sig = np.asarray(signals, np.float32)
    return (lambda: tiered.map_signals(sig, chunk=chunk),
            lambda: resident.map_signals(sig, chunk=chunk), tiered)


def bench_cache(cfg: MarsConfig, signals, arrays, repeats: int = 5,
                n_tiles: int = 16, cache_slots: int = 4,
                chunk: int = 8) -> Dict[str, float]:
    """The tiered-index cache group: interleaved tiered-vs-resident
    timings plus the cache's traffic telemetry (hit rate, host->device
    paged bytes) on an index several times the cache size."""
    fast_c, pre_c, mapper = _cache_programs(cfg, signals, arrays, n_tiles,
                                            cache_slots, chunk)
    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds=max(repeats, 3))
    cache = mapper.cache
    cache.reset_stats()
    fast_c()                               # one counted steady-state pass
    return {
        "cache_tiered": tf, "cache_resident": tp, "cache_speedup": ratio,
        "cache_hit_rate": cache.hit_rate,
        "cache_hits": cache.hits, "cache_misses": cache.misses,
        "cache_paged_bytes": cache.paged_bytes,
        "cache_n_tiles": n_tiles, "cache_slots": cache.n_slots,
        "cache_tile_nbytes": cache.tiered.tile_nbytes,
        "cache_nbytes": cache.cache_nbytes,
        "cache_index_nbytes": cache.tiered.nbytes,
    }


def bench_cache_ratio(cfg: MarsConfig, signals, arrays,
                      backend: str = stages.REFERENCE,
                      rounds: int = 25) -> Dict[str, float]:
    """The cache twin of ``bench_chain_ratio``: interleaved resident (pre)
    vs tiered-with-small-cache (fast) rounds over the same reads, median
    paired ratio as the machine-speed-independent gate estimator.  The
    ratio is below 1 (out-of-core paging costs something); the gate
    catches it getting WORSE."""
    del backend                            # tiered vs resident is the pair
    fast_c, pre_c, _ = _cache_programs(cfg, signals, arrays)
    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds)
    return {"cache_fast_min": tf, "cache_pre_min": tp, "rounds": rounds,
            "cache_speedup_median": ratio}


def _fused_programs(cfg: MarsConfig, signals, arrays):
    """(fast_call, pre_call): the whole-phase fused mega-kernel
    (kernels/cheap_fused — ONE launch, detect..vote resident, index tiles
    DMA-streamed through scratch) vs the SAME pallas plan's per-stage
    batch program (``pipeline.cheap_phase(use_fused=False)``: separate
    detect kernel, pLUTo gathers and segment-sum vote with every
    intermediate materialized between launches).  Outputs are bit-identical
    (tests/kernels/test_cheap_fused.py); the timing difference is the
    launch + HBM round-trip overhead the fusion removes."""
    packed, _ = _split_arrays(arrays)
    plan = stages.resolve_plan(cfg, stages.PALLAS)
    prims = stages.cheap_primitives(plan, cfg)
    if prims is None or prims.fused is None:
        raise ValueError(
            f"plan {plan} resolves no fused cheap kernel "
            "(stages.register_fused_cheap); the fused microbenchmark "
            "cannot time it")
    fast_j = jax.jit(
        lambda s: pipeline.cheap_phase(s, packed, cfg, plan))
    pre_j = jax.jit(
        lambda s: pipeline.cheap_phase(s, packed, cfg, plan,
                                       use_fused=False))
    return (lambda: fast_j(signals)), (lambda: pre_j(signals))


# Default read-grid cap for the fused gate phase: the pre side runs the
# full per-stage interpret-mode pallas program, so the gate trims the grid
# to keep `run_tier1.sh --bench` wall time bounded (the reduction is
# recorded in the gate record; both sides share the grid).
FUSED_GATE_READS = 8


def bench_fused(cfg: MarsConfig, signals, arrays,
                repeats: int = 5) -> Dict[str, float]:
    """The fused mega-kernel group: interleaved fused-vs-per-stage cheap
    phase on the pallas plan, plus the grid markers."""
    fast_c, pre_c = _fused_programs(cfg, signals, arrays)
    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds=max(repeats, 3))
    return {"fused_fast": tf, "fused_pre": tp, "fused_speedup": ratio,
            "fused_n_reads": int(signals.shape[0]),
            "fused_mode": ("interpret" if jax.default_backend() == "cpu"
                           else jax.default_backend())}


def bench_fused_ratio(cfg: MarsConfig, signals, arrays,
                      backend: str = stages.PALLAS,
                      rounds: int = 25,
                      n_reads: int = FUSED_GATE_READS) -> Dict[str, float]:
    """The fused twin of ``bench_chain_ratio``: interleaved per-stage-pallas
    (pre) vs mega-kernel (fast) rounds over the same reads, median paired
    ratio as the machine-speed-independent gate estimator."""
    del backend              # the fused/per-stage pair IS the pallas backend
    if n_reads and n_reads < signals.shape[0]:
        signals = signals[:n_reads]
    fast_c, pre_c = _fused_programs(cfg, signals, arrays)
    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds)
    return {"fused_fast_min": tf, "fused_pre_min": tp, "rounds": rounds,
            "n_reads": int(signals.shape[0]),
            "fused_speedup_median": ratio}


def bench_chain_ratio(cfg: MarsConfig, signals, arrays,
                      backend: str = stages.REFERENCE,
                      rounds: int = 25) -> Dict[str, float]:
    """Machine-speed-independent chaining measurement for the regression
    gate.

    Absolute ms are not comparable across runs on a shared/containerized
    CPU (whole-process speed swings ~1.5x), so the pre and fast chain
    programs are timed in INTERLEAVED rounds — each round yields a paired
    pre/fast ratio under the same instantaneous machine state — and the
    MEDIAN of the per-round ratios is the estimator (stable to ~3% across
    processes where min-of-N absolute times swing ~40%)."""
    _, fast_c, pre_c = _chain_programs(cfg, signals, arrays, backend)
    tf, tp, ratio = _interleaved(fast_c, pre_c, rounds)
    return {"chain_fast_min": tf, "chain_pre_min": tp, "rounds": rounds,
            "chain_speedup_median": ratio}


def bench_cheap_ratio(cfg: MarsConfig, signals, arrays,
                      backend: str = stages.REFERENCE,
                      rounds: int = 25) -> Dict[str, float]:
    """The cheap-phase twin of ``bench_chain_ratio``: interleaved pre/fast
    whole-cheap-phase rounds, median paired ratio as the gate estimator."""
    fast_calls, pre_calls = _cheap_programs(cfg, signals, arrays, backend)
    tf, tp, ratio = _interleaved(fast_calls["cheap"], pre_calls["cheap"],
                                 rounds)
    return {"cheap_fast_min": tf, "cheap_pre_min": tp, "rounds": rounds,
            "cheap_speedup_median": ratio}


def run(n_reads: int = 32, ref_events: int = 20_000, junk_frac: float = 0.5,
        repeats: int = 5, backends=(stages.REFERENCE, stages.PALLAS),
        seed: int = 0, pallas_serving: bool = True,
        pallas_reduced_reads: int = 0) -> Dict:
    """One full profile record.  ``pallas_reduced_reads`` > 0 caps the
    pallas backend's bench groups (and the fused group) to that many reads
    — the interpret-mode per-read "pre" programs dominate bench wall time
    — with the reduction marked in the record (``grid_reads`` /
    ``grid_reduced``) so the recorded ratios stay honest: the pre/fast
    pair of every group shares one grid."""
    cfg, signals, arrays = make_workload(n_reads, ref_events, junk_frac, seed)
    rec = {
        "git_sha": git_sha(),
        "machine": hardware_key(),
        "workload": dict(n_reads=n_reads, ref_events=ref_events,
                         junk_frac=junk_frac, repeats=repeats, seed=seed,
                         signal_len=cfg.signal_len,
                         max_anchors=cfg.max_anchors,
                         chain_band=cfg.chain_band,
                         chain_widths=list(cfg.chain_widths),
                         chain_capacity_frac=cfg.chain_capacity_frac),
        "backends": {},
    }
    reduced = (0 < pallas_reduced_reads < n_reads)
    sig_pallas = signals[:pallas_reduced_reads] if reduced else signals
    for b in backends:
        inc = pallas_serving or b != stages.PALLAS
        sig_b = sig_pallas if b == stages.PALLAS else signals
        rec["backends"][b] = bench_backend(cfg, sig_b, arrays, b,
                                           repeats=repeats,
                                           include_serving=inc)
        rec["backends"][b].update(grid_reads=int(sig_b.shape[0]),
                                  grid_reduced=bool(sig_b.shape[0]
                                                    < n_reads))
    rec["cache"] = bench_cache(cfg, signals, arrays, repeats=repeats)
    rec["fused"] = bench_fused(cfg, sig_pallas, arrays, repeats=repeats)
    rec["fairness"] = bench_fairness(cfg, signals, arrays)
    return rec
