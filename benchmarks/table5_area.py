"""Paper Table 5: area per MARS component (as published; Synopsys DC is not
re-run — the table is the paper's own, checked for internal consistency)."""
from __future__ import annotations

from benchmarks import common
from repro.core import ssd_model


def run(emit) -> None:
    total_dram = 0.0
    total_ctrl = 0.0
    for name, row in ssd_model.area_table().items():
        emit(common.csv_line(
            f"table5/{name}", 0.0,
            f"instances={row['instances']};per_unit_mm2={row['per_unit']};"
            f"total_mm2={row['total']:.3f}"))
        if name in ("Arithmetic", "Querying"):
            total_dram += row["total"]
        else:
            total_ctrl += row["total"]
    emit(common.csv_line(
        "table5/summary", 0.0,
        f"dram_overhead_mm2={total_dram:.2f};paper=16.78;"
        f"controller_mm2={total_ctrl:.2f};ssd_area_budget_mm2=6400"))


def main() -> None:
    run(print)


if __name__ == "__main__":
    main()
