"""Paper Fig. 5: RawHash2 runtime breakdown (I/O, event detection, seeding,
chaining) per dataset, from the calibrated host model over measured
workloads."""
from __future__ import annotations

from benchmarks import common
from repro.core import ssd_model
from repro.signal import datasets


def run(emit) -> None:
    rates = common.calibrated_host()
    for ds in datasets.DATASETS:
        w = common.workload_for(ds, "rh2")
        t = ssd_model.host_latency(w, rates)
        tot = t["total"]
        paper = common.FIG5_FRACTIONS[ds]
        emit(common.csv_line(
            f"fig5/{ds}", tot * 1e6,
            f"io={t['io']/tot:.2f};event={t['event']/tot:.2f};"
            f"seed={t['seed']/tot:.2f};chain={t['chain']/tot:.2f};"
            f"paper=io{paper[0]:.2f}/ev{paper[1]:.2f}/"
            f"se{paper[2]:.2f}/ch{paper[3]:.2f}"))


def main() -> None:
    run(print)


if __name__ == "__main__":
    main()
