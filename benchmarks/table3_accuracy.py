"""Paper Table 3: mapping accuracy of RH2 / MS-CPU_Fixed / MS-CPU_Float
across the five datasets (measured end-to-end on the real pipeline)."""
from __future__ import annotations

import time

from benchmarks import common
from repro.signal import datasets

# paper Table 3 F1 values for qualitative comparison
PAPER_F1 = {
    ("D1", "rh2"): 0.9267, ("D1", "ms_fixed"): 0.9803, ("D1", "ms_float"): 0.9867,
    ("D2", "rh2"): 0.9282, ("D2", "ms_fixed"): 0.9712, ("D2", "ms_float"): 0.9753,
    ("D3", "rh2"): 0.9079, ("D3", "ms_fixed"): 0.9588, ("D3", "ms_float"): 0.9603,
    ("D4", "rh2"): 0.8139, ("D4", "ms_fixed"): 0.9141, ("D4", "ms_float"): 0.9354,
    ("D5", "rh2"): 0.5582, ("D5", "ms_fixed"): 0.7300, ("D5", "ms_float"): 0.7612,
}


def run(emit) -> None:
    for ds in datasets.DATASETS:
        for mode in ("rh2", "ms_float", "ms_fixed"):
            t0 = time.time()
            rec = common.pipeline_run(ds, mode)
            us = (time.time() - t0) * 1e6
            a = rec["accuracy"]
            paper = PAPER_F1.get((ds, mode), float("nan"))
            emit(common.csv_line(
                f"table3/{ds}/{mode}", us,
                f"P={a['precision']:.3f};R={a['recall']:.3f};"
                f"F1={a['f1']:.3f};paper_F1={paper:.3f}"))


def main() -> None:
    run(print)


if __name__ == "__main__":
    main()
