"""Calibrate the analytic serving-latency model against measured
``ServeDriver`` virtual-time traces.

``ssd_model.serving_latency`` predicts p50/p99 sojourn from an M/D/c
queueing core; ``ServeDriver`` (core/server.py) *measures* per-read
sojourn on its virtual clock (every dispatched chunk costs ``chunk_cost``
and completes up to ``chunk`` reads).  ``serving_latency_virtual`` maps
the same core onto the driver's clock — c = chunk parallel servers of
deterministic service ``chunk_cost`` — so the two are directly
comparable: run a Poisson arrival trace at a fraction of chunk capacity
through the real pipeline, pool the admitted per-read latencies, and
compare percentiles against the model.

    python benchmarks/calibrate_serving.py          # table over load fracs

tests/test_ssd_model.py asserts the modeled p50 tracks the measured trace
percentile within a stated tolerance below saturation, so the model and
the driver cannot silently drift apart (the PR-5 open calibration
thread).
"""
from __future__ import annotations

import math
from typing import Dict, Sequence

import numpy as np


def measure_trace(mapper, chunk: int, offered_load: float, n_reads: int,
                  n_streams: int = 4, chunk_cost: float = 1.0,
                  seed: int = 0) -> Dict[str, float]:
    """Serve one Poisson arrival trace (rate ``offered_load`` reads per
    virtual unit) through a fresh ``ServeDriver`` over ``mapper`` and pool
    the admitted finite per-read virtual latencies across streams.

    Returns measured p50/p99/mean plus the trace size.  Deterministic
    given ``seed``: arrivals, stream assignment and the driver's packing
    are all reproducible.
    """
    from repro.core.server import ServeDriver

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / offered_load, n_reads))
    signals = mapper_signals(mapper, n_reads, seed + 1)
    trace = [(float(arrivals[k]), f"s{k % n_streams}", signals[k])
             for k in range(n_reads)]
    sd = ServeDriver(mapper, chunk=chunk, chunk_cost=chunk_cost)
    sd.serve_trace(trace)
    lat = np.asarray([l for st in sd._streams.values()
                      for l, a in zip(st.latency, st.admitted)
                      if a and math.isfinite(l)], np.float64)
    return dict(p50=float(np.percentile(lat, 50)),
                p99=float(np.percentile(lat, 99)),
                mean=float(lat.mean()), n=int(lat.size),
                n_chunks=sd.n_chunks)


def mapper_signals(mapper, n_reads: int, seed: int) -> np.ndarray:
    """Reads shaped for ``mapper.cfg`` from the shared simulator (sampled
    against an arbitrary small reference — the latency calibration only
    needs realistic per-chunk work, not mapping accuracy)."""
    from repro.signal import simulate
    ref = simulate.make_reference(4_000, seed=seed)
    return simulate.sample_reads(ref, n_reads,
                                 signal_len=mapper.cfg.signal_len,
                                 seed=seed + 1).signals


def calibrate(mapper, chunk: int = 8, load_fracs: Sequence[float] =
              (0.3, 0.5, 0.7), n_reads: int = 96, chunk_cost: float = 1.0,
              seed: int = 0, model="analytic"):
    """Measured-vs-modeled rows, one per offered-load fraction of the
    driver's chunk capacity (chunk/chunk_cost reads per virtual unit).
    ``model`` selects the costmodel backend the measured trace is compared
    against (analytic M/D/c closed form or the discrete-event serving
    simulator)."""
    from repro.core import costmodel

    cm = costmodel.get_model(model)
    capacity = chunk / chunk_cost
    rows = []
    for f in load_fracs:
        load = f * capacity
        m = measure_trace(mapper, chunk, load, n_reads,
                          chunk_cost=chunk_cost, seed=seed)
        model = cm.serving_virtual(chunk, load, chunk_cost)
        rows.append(dict(load_frac=f, offered_load=load,
                         measured_p50=m["p50"], model_p50=model["p50"],
                         measured_p99=m["p99"], model_p99=model["p99"],
                         measured_mean=m["mean"], model_mean=model["mean"],
                         p50_ratio=model["p50"] / m["p50"],
                         n_reads=m["n"], n_chunks=m["n_chunks"],
                         saturated=model["saturated"]))
    return rows


def default_mapper(hash_bits: int = 12, ref_events: int = 8_000,
                   seed: int = 3):
    from repro.core import MarsConfig, Mapper, build_index
    from repro.signal import simulate

    cfg = MarsConfig(hash_bits=hash_bits).with_mode("ms_fixed")
    ref = simulate.make_reference(ref_events, seed=seed)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    return Mapper(idx, cfg)


def main(argv=None) -> None:
    import argparse

    from repro.core import costmodel
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="analytic",
                    choices=sorted(costmodel.MODELS))
    args = ap.parse_args(argv)
    rows = calibrate(default_mapper(), model=args.model)
    hdr = ("load  measured_p50  model_p50  ratio   measured_p99  model_p99"
           "   chunks")
    print(hdr)
    for r in rows:
        print(f"{r['load_frac']:.2f}  {r['measured_p50']:12.3f}  "
              f"{r['model_p50']:9.3f}  {r['p50_ratio']:5.2f}  "
              f"{r['measured_p99']:12.3f}  {r['model_p99']:9.3f}  "
              f"{r['n_chunks']:7d}")


if __name__ == "__main__":
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
    main()
