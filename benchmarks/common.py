"""Shared benchmark machinery: cached pipeline runs + paper-scale workloads
+ host-model calibration.

Every benchmark module draws from the same measured runs (one per
dataset x mode, cached under results/bench/) so figures are consistent.

Calibration: the paper's own evaluation is simulation-based; its absolute
RH2 runtimes are derived from Table 4 (exact MARS throughputs) and the
average speedups of Fig. 11 with a small->large genome profile (documented
in EXPERIMENTS.md).  Host component rates are least-squares fitted so the
modeled RH2 matches those totals and the Fig. 5 stage fractions.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict

import numpy as np

from repro.core import MarsConfig, Mapper, build_index, score_accuracy
from repro.core import ssd_model, stages, workload
from repro.signal import datasets, simulate

CACHE = pathlib.Path("results/bench")

# --- paper-derived anchors (see EXPERIMENTS.md Calibration) ---------------- #
# Table 4 MARS throughputs (bp/s) -> exact MARS runtimes:
PAPER_MARS_T = {k: datasets.DATASETS[k].paper_bases / tp for k, tp in
                dict(D1=46_655_128, D2=5_274_148, D3=1_202_660,
                     D4=1_277_764, D5=286_728).items()}
# Fig. 11 speedup profile over RH2 (avg 28x, larger for small genomes):
RH2_SPEEDUP = dict(D1=54.2, D2=36.1, D3=22.6, D4=18.1, D5=9.0)
PAPER_RH2_T = {k: PAPER_MARS_T[k] * s for k, s in RH2_SPEEDUP.items()}
# Fig. 5 stage fractions of RH2 runtime (io, event, seed, chain):
FIG5_FRACTIONS = {
    "D1": (0.41, 0.205, 0.06, 0.331),
    "D2": (0.30, 0.15, 0.07, 0.48),
    "D3": (0.25, 0.10, 0.06, 0.59),
    "D4": (0.10, 0.05, 0.05, 0.80),
    "D5": (0.02, 0.01, 0.043, 0.927),
}


def pipeline_run(ds_key: str, mode: str, force: bool = False,
                 backend: str = stages.REFERENCE, mesh=None) -> Dict:
    """Run (or load cached) one dataset x mode mapping; returns counters,
    accuracy, wall time and raw sizes.

    ``backend`` selects the stage-registry backend plan ("reference",
    "pallas", or — with a ``mesh`` — the partitioned-index query schedules
    "ring"/"a2a"); counters follow stages.CHUNK_COUNTER_SCHEMA in every
    case, so the hardware model consumes all of them identically."""
    CACHE.mkdir(parents=True, exist_ok=True)
    suffix = "" if backend == stages.REFERENCE else f"_{backend}"
    if mesh is not None:      # distributed runs cache per mesh shape
        suffix += "_" + "x".join(f"{a}{n}" for a, n in mesh.shape.items())
    f = CACHE / f"{ds_key}_{mode}{suffix}.json"
    if f.exists() and not force:
        return json.loads(f.read_text())
    spec = datasets.DATASETS[ds_key]
    cfg = datasets.config_for(spec).with_mode(mode)
    ref, reads = datasets.build(spec, cfg)
    index = build_index(ref.events_concat, ref.n_events, cfg)
    mapper = Mapper(index, cfg, backend=backend, mesh=mesh)
    # explicit warm-up: map one chunk's worth of reads first so the timed
    # run below is steady-state (jit compile of the (32, S) chunk program
    # excluded from wall_time)
    mapper.map_signals(reads.signals[:1], chunk=32)
    t0 = time.time()
    out = mapper.map_signals(reads.signals, chunk=32)
    wall = time.time() - t0
    acc = score_accuracy(out, reads.true_pos, reads.true_strand,
                         reads.mappable, reads.n_bases, ref.n_events)
    from benchmarks.microbench import git_sha
    rec = dict(
        dataset=ds_key, mode=mode, backend=backend, git_sha=git_sha(),
        mesh=None if mesh is None else dict(mesh.shape),
        plan=[list(p) for p in mapper.plan],
        counters={k: int(v) for k, v in out.counters.items()},
        accuracy={k: float(v) for k, v in acc.items()},
        wall_time=wall,
        index_bytes=int(index.nbytes),
        bench_bytes_raw=int(out.counters["n_samples"]) * 2,
        n_reads=int(spec.bench_reads),
    )
    f.write_text(json.dumps(rec))
    return rec


def workload_for(ds_key: str, mode: str) -> workload.Workload:
    """Paper-scale workload for the analytic hardware model.

    Two extrapolation factors: signal volume (paper_bytes/bench_bytes)
    scales everything linearly; genome size additionally inflates
    collision-driven counts (seed hits / anchors / DP pairs): spurious
    candidate positions grow linearly with reference length, and the
    paper's frequency thresholds scale UP with genome size (2000 -> 20000,
    Section 5.1) so the filter does not cancel the growth — exponent 1.0
    (see EXPERIMENTS.md Calibration)."""
    rec = pipeline_run(ds_key, mode)
    spec = datasets.DATASETS[ds_key]
    cfg = datasets.config_for(spec).with_mode(mode)
    w = workload.from_counters(rec["counters"], cfg, rec["index_bytes"])
    factor = spec.bytes_scale_factor(rec["bench_bytes_raw"])
    w = w.scale(factor)
    g = spec.genome_scale_factor ** 1.0
    for f in ("n_hits_raw", "n_hits_exact", "n_hits_postfreq", "n_votes",
              "n_anchors_postvote", "n_sorted", "n_dp_pairs"):
        setattr(w, f, int(getattr(w, f) * g))
    # the index itself scales with genome size, not signal volume
    w.bytes_index = int(rec["index_bytes"] * spec.genome_scale_factor)
    return w


_CALIB_CACHE = None


def calibrated_host() -> ssd_model.HostRates:
    """Closed-form per-stage calibration: for every dataset the paper gives
    (total RH2 runtime, stage fraction); each stage's inverse rate is the
    geometric mean over datasets of  frac * T_total / W_stage.  Per-stage
    closed form avoids the scale pathologies of a joint least-squares fit
    (the io byte counts are ~6 orders larger than anchor counts)."""
    global _CALIB_CACHE
    if _CALIB_CACHE is not None:
        return _CALIB_CACHE
    stage_names = ("io", "event", "seed", "chain")
    per_stage = {s: [] for s in stage_names}
    for ds in datasets.DATASETS:
        w = workload_for(ds, "rh2")
        comp = ssd_model.host_components(w)
        total = PAPER_RH2_T[ds]
        for i, s in enumerate(stage_names):
            if comp[s] > 0:
                per_stage[s].append(FIG5_FRACTIONS[ds][i] * total / comp[s])
    gm = {s: float(np.exp(np.mean(np.log(v)))) for s, v in per_stage.items()}
    _CALIB_CACHE = ssd_model.HostRates(
        inv_io=gm["io"], inv_event=gm["event"], inv_seed=gm["seed"],
        inv_chain=gm["chain"])
    return _CALIB_CACHE


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
