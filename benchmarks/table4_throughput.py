"""Paper Table 4: MARS throughput (bp/s) vs real-time requirements
(single nanopore 450 bp/s; full MinION 230,400 bp/s)."""
from __future__ import annotations

from benchmarks import common
from repro.core import ssd_model
from repro.signal import datasets

PAPER = dict(D1=46_655_128, D2=5_274_148, D3=1_202_660, D4=1_277_764,
             D5=286_728)
MINION = 230_400


def run(emit) -> None:
    for ds, spec in datasets.DATASETS.items():
        w = common.workload_for(ds, "ms_fixed")
        lat = ssd_model.system_latency_energy("MARS", w)
        bases = spec.paper_bases
        tp = bases / lat["total"]
        emit(common.csv_line(
            f"table4/{ds}", lat["total"] * 1e6,
            f"bp_per_s={tp:.0f};x_minion={tp/MINION:.1f};"
            f"paper_bp_per_s={PAPER[ds]};ratio_to_paper={tp/PAPER[ds]:.2f}"))


def main() -> None:
    run(print)


if __name__ == "__main__":
    main()
