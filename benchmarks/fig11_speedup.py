"""Paper Fig. 11: end-to-end speedup of every evaluated system over RH2.

Each system's latency is modeled from the workload measured in its OWN
pipeline mode (rh2 for RH2/BC, ms_float for MS-CPU_Float, ms_fixed for the
hardware systems).

``--model {analytic,sim}`` selects the performance backend through the
unified ``core/costmodel.py`` interface: the closed forms (default) or the
discrete-event in-storage simulator for the MARS path (host baselines are
analytic either way — see costmodel docstring)."""
from __future__ import annotations

import argparse
import statistics

from benchmarks import common
from repro.core import costmodel, ssd_model
from repro.signal import datasets

MODE_FOR = {"BC": "rh2", "RH2": "rh2", "MS-CPU_Float": "ms_float",
            "MS-CPU_Fixed": "ms_fixed", "MS-EXT": "ms_fixed",
            "MS-SIMDRAM": "ms_fixed", "GenPIP": "rh2",
            "MS-SmartSSD": "ms_fixed", "MARS": "ms_fixed"}

PAPER_AVG = {"MARS/RH2": 28.0, "MARS/BC": 93.0, "MARS/GenPIP": 40.0,
             "MARS/MS-EXT": 3.1, "MARS/MS-SIMDRAM": 21.4}


def results(model="analytic"):
    m = costmodel.get_model(model)
    rates = common.calibrated_host()
    out = {}
    for ds in datasets.DATASETS:
        row = {}
        for system in ssd_model.SYSTEMS:
            w = common.workload_for(ds, MODE_FOR[system])
            row[system] = m.system_latency_energy(system, w, rates)
        out[ds] = row
    return out


def run(emit, model="analytic") -> None:
    res = results(model)
    ratios = {k: [] for k in PAPER_AVG}
    for ds, row in res.items():
        rh2 = row["RH2"]["total"]
        parts = [f"{s}={rh2/row[s]['total']:.1f}x"
                 for s in ssd_model.SYSTEMS if s != "RH2"]
        emit(common.csv_line(f"fig11/{ds}", row["MARS"]["total"] * 1e6,
                             ";".join(parts)))
        m = row["MARS"]["total"]
        ratios["MARS/RH2"].append(rh2 / m)
        ratios["MARS/BC"].append(row["BC"]["total"] / m)
        ratios["MARS/GenPIP"].append(row["GenPIP"]["total"] / m)
        ratios["MARS/MS-EXT"].append(row["MS-EXT"]["total"] / m)
        ratios["MARS/MS-SIMDRAM"].append(row["MS-SIMDRAM"]["total"] / m)
    for k, vals in ratios.items():
        emit(common.csv_line(
            f"fig11/avg/{k}", 0.0,
            f"ours={statistics.mean(vals):.1f}x;paper={PAPER_AVG[k]:.1f}x"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="analytic",
                    choices=sorted(costmodel.MODELS))
    args = ap.parse_args(argv)
    run(print, model=args.model)


if __name__ == "__main__":
    main()
