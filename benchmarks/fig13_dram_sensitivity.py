"""Paper Fig. 13: MARS runtime sensitivity to SSD-internal DRAM size
(2/4/8 GB).  Paper: ~1.70x average speedup per doubling.

``--model {analytic,sim}`` routes the sweep through the unified
``core/costmodel.py`` interface (closed forms vs the discrete-event
in-storage simulator)."""
from __future__ import annotations

import argparse

from benchmarks import common
from repro.core import costmodel
from repro.signal import datasets


def run(emit, model="analytic") -> None:
    m = costmodel.get_model(model)
    for ds in datasets.DATASETS:
        w = common.workload_for(ds, "ms_fixed")
        sens = m.dram_sensitivity(w)
        t2, t4, t8 = (sens[2 << 30], sens[4 << 30], sens[8 << 30])
        emit(common.csv_line(
            f"fig13/{ds}", t4 * 1e6,
            f"t_2GB={t2:.2f}s;t_4GB={t4:.2f}s;t_8GB={t8:.2f}s;"
            f"speedup_2to4={t2/t4:.2f};4to8={t4/t8:.2f};paper_avg=1.70"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="analytic",
                    choices=sorted(costmodel.MODELS))
    args = ap.parse_args(argv)
    run(print, model=args.model)


if __name__ == "__main__":
    main()
