"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  The first invocation runs
the full pipeline per (dataset x mode) and caches results under
results/bench/; later invocations are fast.

    PYTHONPATH=src python -m benchmarks.run            # all tables
    PYTHONPATH=src python -m benchmarks.run fig11      # one table
"""
import sys

from benchmarks import (fig5_breakdown, fig6_io_impact, fig11_speedup,
                        fig12_energy, fig13_dram_sensitivity,
                        table3_accuracy, table4_throughput, table5_area)

MODULES = {
    "table3": table3_accuracy,
    "fig5": fig5_breakdown,
    "fig6": fig6_io_impact,
    "fig11": fig11_speedup,
    "fig12": fig12_energy,
    "table4": table4_throughput,
    "table5": table5_area,
    "fig13": fig13_dram_sensitivity,
}


def main() -> None:
    which = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    for key in which:
        MODULES[key].run(print)


if __name__ == "__main__":
    main()
