"""Paper Fig. 6: I/O share of end-to-end runtime as seeding+chaining are
accelerated by 10%..100% — the motivation study for in-storage processing."""
from __future__ import annotations

from benchmarks import common
from repro.core import ssd_model
from repro.signal import datasets


def run(emit) -> None:
    rates = common.calibrated_host()
    for ds in datasets.DATASETS:
        w = common.workload_for(ds, "rh2")
        t = ssd_model.host_latency(w, rates)
        shares = []
        for red in (0.0, 0.5, 0.9, 1.0):
            acc = t["seed"] * (1 - red) + t["chain"] * (1 - red)
            total = t["io"] + t["event"] + acc
            shares.append(t["io"] / total)
        emit(common.csv_line(
            f"fig6/{ds}", t["total"] * 1e6,
            f"io_share_0%={shares[0]:.2f};50%={shares[1]:.2f};"
            f"90%={shares[2]:.2f};100%={shares[3]:.2f}"))


def main() -> None:
    run(print)


if __name__ == "__main__":
    main()
