"""Quickstart: build a reference index, map a batch of raw-signal reads,
score accuracy — the MARS pipeline end-to-end in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import MarsConfig, Mapper, build_index, score_accuracy
from repro.signal import simulate

# 1. a synthetic reference genome + its expected-event sequence
cfg = MarsConfig().with_mode("ms_fixed")        # the full MARS pipeline
ref = simulate.make_reference(length=50_000, seed=0)

# 2. offline indexing (paper Fig. 1 stage A)
index = build_index(ref.events_concat, ref.n_events, cfg)
print(f"index: {index.n_entries} entries, {index.nbytes/1e6:.1f} MB")

# 3. simulate nanopore reads (with 10% unmappable junk)
reads = simulate.sample_reads(ref, n_reads=32, signal_len=cfg.signal_len,
                              seed=1, junk_frac=0.1)

# 4. online mapping (paper Fig. 1 stage B: events -> seeds -> vote -> chain)
mapper = Mapper(index, cfg)
out = mapper.map_signals(reads.signals)

# 5. inspect + score
for i in range(8):
    state = f"pos={out.t_start[i]:>7d} score={out.score[i]:5.1f}" \
        if out.mapped[i] else "unmapped"
    print(f"read{i:02d}: {state}")
acc = score_accuracy(out, reads.true_pos, reads.true_strand, reads.mappable,
                     reads.n_bases, ref.n_events)
print(f"precision={acc['precision']:.3f} recall={acc['recall']:.3f} "
      f"F1={acc['f1']:.3f}")
