"""Ablation of MARS's software techniques (paper Section 5): frequency
filter, seed-and-vote, early quantization, fixed point — accuracy and
chaining-workload impact of each.

    PYTHONPATH=src python examples/filter_ablation.py
"""
import numpy as np

from repro.core import MarsConfig, Mapper, build_index, score_accuracy
from repro.signal import simulate

VARIANTS = {
    "none (raw RawHash-like)": dict(use_freq_filter=False,
                                    use_vote_filter=False,
                                    early_quantization=False,
                                    fixed_point=False),
    "+freq filter": dict(use_freq_filter=True, use_vote_filter=False,
                         early_quantization=False, fixed_point=False),
    "+seed-and-vote": dict(use_freq_filter=True, use_vote_filter=True,
                           early_quantization=False, fixed_point=False),
    "+early quantization": dict(use_freq_filter=True, use_vote_filter=True,
                                early_quantization=True, fixed_point=False),
    "+fixed point (MARS)": dict(use_freq_filter=True, use_vote_filter=True,
                                early_quantization=True, fixed_point=True),
}

if __name__ == "__main__":
    ref = simulate.make_reference(400_000, seed=0)
    base = MarsConfig()
    reads = simulate.sample_reads(ref, 96, signal_len=base.signal_len,
                                  seed=1, junk_frac=0.1)
    print(f"{'variant':28s} {'P':>6s} {'R':>6s} {'F1':>6s} "
          f"{'anchors':>8s} {'dp_pairs':>9s}")
    for name, kw in VARIANTS.items():
        cfg = base.replace(**kw)
        idx = build_index(ref.events_concat, ref.n_events, cfg)
        out = Mapper(idx, cfg).map_signals(reads.signals)
        acc = score_accuracy(out, reads.true_pos, reads.true_strand,
                             reads.mappable, reads.n_bases, ref.n_events)
        print(f"{name:28s} {acc['precision']:6.3f} {acc['recall']:6.3f} "
              f"{acc['f1']:6.3f} {out.counters['n_anchors_postvote']:8d} "
              f"{out.counters['n_dp_pairs']:9d}")
