"""End-to-end serving driver (the paper-kind workload): stream raw-signal
chunks from a container file with a double-buffered reader, map them with
the jit pipeline, checkpoint progress for restartability, emit PAF.

This wraps the production launcher; see repro/launch/map_reads.py for the
moving parts (reader overlap = MARS Section 6.3 flash/compute overlap).

    PYTHONPATH=src python examples/map_reads_e2e.py
"""
from repro.launch import map_reads

if __name__ == "__main__":
    acc = map_reads.main([
        "--dataset", "D1",
        "--mode", "ms_fixed",
        "--workdir", "/tmp/mars_e2e",
        "--out", "/tmp/mars_e2e/out.paf",
        "--reads", "96",
    ])
    assert acc["f1"] > 0.9, acc
    print("e2e driver OK")
