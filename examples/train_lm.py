"""Train a reduced-config LM for a few hundred steps on the synthetic token
stream, with checkpointing — exercises the full training substrate
(optimizer, sharding, monitor, checkpoint/resume).

    PYTHONPATH=src python examples/train_lm.py [--arch qwen3-4b] [--steps 200]
"""
import argparse
import sys

from repro.launch import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    train.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/mars_train_lm",
        "--save-every", "50", "--log-every", "20",
    ])
