"""Fault tolerance: a training job killed mid-run resumes from the latest
valid checkpoint and finishes — including on a different device count
(elastic restart)."""
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def _train(ckpt_dir, steps, devices, timeout=None, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-4b", "--reduced", "--lr", "3e-4",
           "--steps", str(steps), "--batch", "4", "--seq", "64",
           "--ckpt-dir", str(ckpt_dir), "--save-every", "4",
           "--log-every", "4", "--mesh", "auto", *extra]
    try:
        return subprocess.run(cmd, capture_output=True, text=True, env=env,
                              cwd=REPO, timeout=timeout)
    except subprocess.TimeoutExpired as e:
        return e          # simulated preemption


def test_kill_and_resume(tmp_path):
    ckpt = tmp_path / "ck"
    # phase 1: run; SIGKILL via timeout once some checkpoints exist
    # (compile ~10-20s, then ~0.1-0.3 s/step; steps sized so no machine
    # finishes 8000 steps inside the 70 s window)
    r1 = _train(ckpt, steps=8000, devices=2, timeout=70)
    assert isinstance(r1, subprocess.TimeoutExpired), (
        "expected the run to be killed mid-flight; it finished instead "
        "(machine too fast? raise steps)")
    from repro.train import checkpoint as ckpt_lib
    step1 = ckpt_lib.latest_step(ckpt)
    assert step1 is not None and 0 < step1 < 8000

    # phase 2: resume on HALF the devices (elastic) and finish a short run
    r2 = _train(ckpt, steps=step1 + 8, devices=1, timeout=300)
    assert not isinstance(r2, subprocess.TimeoutExpired)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert f"resumed from step {step1}" in r2.stdout, r2.stdout
    assert "done:" in r2.stdout
