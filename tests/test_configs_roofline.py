"""Config registry + roofline math unit tests."""
import pytest

from repro.analysis import roofline as rl
from repro.configs import ARCHS, SHAPES, SHAPE_ORDER, cell_applicable, get_config


def test_ten_archs_assigned():
    assert len(ARCHS) == 10
    assert set(SHAPE_ORDER) == set(SHAPES)


def test_cell_applicability_matrix():
    """40 cells total; long_500k runs only for sub-quadratic archs."""
    cells = [(a, s) for a in ARCHS for s in SHAPE_ORDER]
    assert len(cells) == 40
    runnable = [(a, s) for a, s in cells
                if cell_applicable(get_config(a), SHAPES[s])[0]]
    skipped = [(a, s) for a, s in cells
               if not cell_applicable(get_config(a), SHAPES[s])[0]]
    assert all(s == "long_500k" for _, s in skipped)
    long_ok = {a for a, s in runnable if s == "long_500k"}
    assert long_ok == {"h2o-danube-1.8b", "hymba-1.5b", "mamba2-780m"}
    assert len(skipped) == 7


def test_reduced_configs_stay_in_family():
    for a in ARCHS:
        cfg = get_config(a)
        r = cfg.reduced()
        assert r.family == cfg.family
        assert r.d_model <= 128 and r.vocab <= 512
        assert (r.n_experts > 0) == (cfg.n_experts > 0)
        assert (r.ssm_state > 0) == (cfg.ssm_state > 0)


def test_roofline_terms_math():
    c = rl.CellResult(
        arch="x", shape="train_4k", mesh="single", chips=256,
        flops_per_device=197e12,        # exactly 1s of compute per chip
        bytes_per_device=819e9,         # exactly 1s of HBM per chip
        wire_bytes_per_device=100e9,    # 2s of link
        collective_detail={}, peak_memory_per_device=None,
        model_flops=197e12 * 256 / 2,   # useful = half the HLO flops
        model_flops_basis="6ND", tokens=1)
    assert c.t_compute == pytest.approx(1.0)
    assert c.t_memory == pytest.approx(1.0)
    assert c.t_collective == pytest.approx(2.0)
    assert c.bottleneck == "collective"
    assert c.useful_flops_ratio == pytest.approx(0.5)
    assert c.roofline_fraction == pytest.approx(0.25)
    assert "TP degree" in c.suggestion or "FSDP" in c.suggestion


def test_suggestions_cover_all_bottlenecks():
    for arch in ("llama3-405b", "qwen3-moe-30b-a3b", "mars-rsga"):
        for b in ("compute", "memory", "collective"):
            for basis in ("6ND", "2ND"):
                assert len(rl.suggest(arch, b, basis)) > 10
