"""Dry-run machinery test: one real cell compiles under 512 virtual devices
(subprocess — device count locks at first jax init)."""
import json
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_dryrun_smallest_cell(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)          # dryrun sets its own
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-780m", "--shape", "long_500k",
         "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[ok] mamba2-780m long_500k single" in r.stdout
    cell = json.loads(
        (tmp_path / "mamba2-780m__long_500k__single.json").read_text())
    assert cell["status"] == "ok"
    assert cell["chips"] == 256
    assert cell["wire_bytes_per_device"] > 0
    assert cell["bottleneck"] in ("compute", "memory", "collective")


def test_skip_rule_recorded(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-4b", "--shape", "long_500k",
         "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    cell = json.loads(
        (tmp_path / "qwen3-4b__long_500k__single.json").read_text())
    assert cell["status"] == "skip"
    assert "full-attention" in cell["note"]
