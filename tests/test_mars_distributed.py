"""Legacy distributed-mapper wrapper == single-device pipeline (both
schedules), on an 8-virtual-device multi-pod mesh (subprocess).  The wrapper
is a thin shim over the shared stage-engine chunk program, so results and
the FULL counter schema must match bit-exactly."""
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

SCRIPT = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import MarsConfig, build_index, stages
from repro.core import distributed as D
from repro.core.pipeline import map_chunk
from repro.core.index import index_arrays
from repro.launch.mesh import make_mesh
from repro.signal import simulate

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = MarsConfig(hash_bits=14).with_mode("ms_fixed")
ref = simulate.make_reference(50_000, seed=3)
reads = simulate.sample_reads(ref, 16, signal_len=cfg.signal_len, seed=4,
                              junk_frac=0.1)
idx = build_index(ref.events_concat, ref.n_events, cfg)
arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
out_ref = map_chunk(jnp.asarray(reads.signals), arrays, cfg)
parts = D.partition_index(idx, mesh.shape["model"])
sig_sh, part_sh = D.input_shardings(mesh)
signals = jax.device_put(jnp.asarray(reads.signals), sig_sh)
parts_dev = {k: jax.device_put(jnp.asarray(v), part_sh[k])
             for k, v in parts.items()}
for sched in ("ring", "a2a"):
    fn = D.make_distributed_mapper(cfg, mesh, schedule=sched)
    t_start, score, mapped, counters = fn(signals, parts_dev)
    assert np.array_equal(np.asarray(out_ref.mapped), np.asarray(mapped)), sched
    assert np.array_equal(np.asarray(out_ref.t_start), np.asarray(t_start)), sched
    assert np.array_equal(np.asarray(out_ref.score), np.asarray(score)), sched
    # counter pytree is derived from CHUNK_COUNTER_SCHEMA — never a
    # hand-listed subset that can drift
    assert set(counters) == set(stages.CHUNK_COUNTER_SCHEMA), sched
    for k in stages.CHUNK_COUNTER_SCHEMA:
        assert int(counters[k]) == int(out_ref.counters[k]), (sched, k)
print("ok")
"""


def test_distributed_mapper_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ok" in r.stdout
