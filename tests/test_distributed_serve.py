"""Bit-exact serving parity on a multi-device CPU mesh: ServeDriver over
``map_chunk_sharded`` and over the partitioned-index ``query:ring`` /
``query:a2a`` backends plus the out-of-core ``query:tiered`` hot-tile
cache — per-stream results and counter totals equal the single-device
``Mapper.map_signals`` (early_term off) / ``map_realtime`` (early_term
on) for random stream interleavings (subprocess, forced 4 CPU devices —
run by scripts/run_tier1.sh's distributed pass)."""
import os
import pathlib
import subprocess
import sys

import pytest

# run_tier1.sh runs this whole file in its dedicated distributed pass
# (under 4 forced CPU devices) after the fast pass — not twice
pytestmark = pytest.mark.slow

REPO = pathlib.Path(__file__).resolve().parents[1]

SCRIPT = """
import numpy as np
from repro.core import MarsConfig, Mapper, ServeDriver, build_index
from repro.core.realtime import map_realtime
from repro.launch.mesh import make_mesh
from repro.signal import simulate

mesh = make_mesh((2, 2), ("data", "model"))
cfg = MarsConfig(hash_bits=14).with_mode("ms_fixed")
ref = simulate.make_reference(50_000, seed=3)
reads = simulate.sample_reads(ref, 16, signal_len=cfg.signal_len, seed=4,
                              junk_frac=0.25)
idx = build_index(ref.events_concat, ref.n_events, cfg)
CHUNK = 8
LADDER = (cfg.signal_len // 2, cfg.signal_len)

# single-device oracles
solo = Mapper(idx, cfg)
rt = map_realtime(reads.signals, idx, cfg, stages=LADDER, chunk=CHUNK)

def interleave(seed):
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, 3, 16)
    order = rng.permutation(16)
    return order, {f"s{k}": [int(r) for r in order if owner[r] == k]
                   for k in range(3)}

def submit_all(sd, order, streams):
    for r in order:
        sid = next(s for s, rows in streams.items() if int(r) in rows)
        sd.submit(sid, reads.signals[int(r)])

for backend in ("reference", "ring", "a2a", "tiered"):
    mapper = Mapper(idx, cfg, backend=backend, mesh=mesh)
    for seed in (0, 1, 2):
        order, streams = interleave(seed)
        # ---- early_term off: parity vs single-device map_signals ----
        sd = ServeDriver(mapper, chunk=CHUNK)
        submit_all(sd, order, streams)
        sd.drain()
        flat = [r for rows in streams.values() for r in rows]
        want_all = solo.map_signals(reads.signals[np.asarray(flat)],
                                    chunk=CHUNK)
        assert sd.counters == {k: int(v)
                               for k, v in want_all.counters.items()}, \\
            (backend, seed, sd.counters, want_all.counters)
        for sid, rows in streams.items():
            if not rows:
                continue
            want = solo.map_signals(reads.signals[np.asarray(rows)],
                                    chunk=CHUNK)
            got = sd.results(sid)
            tag = (backend, seed, sid)
            np.testing.assert_array_equal(got.t_start,
                                          np.asarray(want.t_start),
                                          err_msg=str(tag))
            np.testing.assert_array_equal(got.score, np.asarray(want.score),
                                          err_msg=str(tag))
            np.testing.assert_array_equal(got.mapped,
                                          np.asarray(want.mapped),
                                          err_msg=str(tag))
            np.testing.assert_array_equal(got.n_events,
                                          np.asarray(want.n_events),
                                          err_msg=str(tag))
        # ---- early_term on: parity vs single-device map_realtime ----
        sd = ServeDriver(mapper, chunk=CHUNK, early_term=True,
                         prefix_stages=LADDER)
        submit_all(sd, order, streams)
        sd.drain()
        for sid, rows in streams.items():
            if not rows:
                continue
            sel = np.asarray(rows)
            got = sd.results(sid)
            st = sd.stream(sid)
            tag = (backend, seed, sid, "et")
            np.testing.assert_array_equal(got.t_start, rt.t_start[sel],
                                          err_msg=str(tag))
            np.testing.assert_array_equal(got.score, rt.score[sel],
                                          err_msg=str(tag))
            np.testing.assert_array_equal(got.mapped, rt.mapped[sel],
                                          err_msg=str(tag))
            np.testing.assert_array_equal(np.asarray(st.samples_used),
                                          rt.samples_used[sel],
                                          err_msg=str(tag))
print("ok")
"""


def test_served_streams_match_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "ok" in r.stdout


# --------------------------------------------------------------------------- #
# Tenant fairness + hot-tile replication on the sharded mesh
# --------------------------------------------------------------------------- #
FAIR_SCRIPT = """
import numpy as np
from repro.core import MarsConfig, Mapper, ServeDriver, build_index
from repro.core.server import TenantBudget
from repro.launch.mesh import make_mesh
from repro.signal import simulate

mesh = make_mesh((2, 2), ("data", "model"))
cfg = MarsConfig(hash_bits=14).with_mode("ms_fixed")
ref = simulate.make_reference(50_000, seed=3)
reads = simulate.sample_reads(ref, 16, signal_len=cfg.signal_len, seed=4,
                              junk_frac=0.25)
idx = build_index(ref.events_concat, ref.n_events, cfg)
CHUNK = 8
BUDGETS = (TenantBudget("acme", rate=10.0),
           TenantBudget("flood", rate=0.0, burst=1.0))

def drive(mapper, flood_n):
    sd = ServeDriver(mapper, chunk=CHUNK, shed=True, shed_window=2.0,
                     cost_model="sim", tenant_budgets=BUDGETS)
    sd.submit("a0", reads.signals[:6], tenant="acme", t=0.0)
    sd.submit("a1", reads.signals[6:12], tenant="acme", t=0.0)
    if flood_n:
        sd.submit("f0", np.repeat(reads.signals[12:13], flood_n, axis=0),
                  tenant="flood", t=0.0)
    sd.drain()
    return sd

for backend in ("reference", "a2a", "tiered"):
    mapper = Mapper(idx, cfg, backend=backend, mesh=mesh)
    solo = drive(mapper, 0)
    both = drive(Mapper(idx, cfg, backend=backend, mesh=mesh), 40)
    tr = both.tenant_report()
    assert tr["acme"].n_shed == 0 and tr["acme"].n_rejected == 0, backend
    assert tr["flood"].n_shed == both.n_shed > 0, backend
    for sid in ("a0", "a1"):
        a, b = solo.results(sid), both.results(sid)
        np.testing.assert_array_equal(a.t_start, b.t_start)
        np.testing.assert_array_equal(a.score, b.score)
        np.testing.assert_array_equal(a.mapped, b.mapped)
        assert all(both.stream(sid).admitted), (backend, sid)

# hot-tile replication under shard_map: bit-identical to the resident
# single-device path for several (cache size, K) points
solo_out = Mapper(idx, cfg).map_signals(reads.signals, chunk=CHUNK)
for slots, K in ((1, 2), (2, 3), (4, 8)):
    m = Mapper(idx, cfg, backend="tiered", mesh=mesh, tiles=16,
               cache_slots=slots, cache_replicas=K)
    out = m.map_signals(reads.signals, chunk=CHUNK)
    np.testing.assert_array_equal(np.asarray(out.t_start),
                                  np.asarray(solo_out.t_start))
    np.testing.assert_array_equal(np.asarray(out.score),
                                  np.asarray(solo_out.score))
    np.testing.assert_array_equal(np.asarray(out.mapped),
                                  np.asarray(solo_out.mapped))
    assert {k: int(v) for k, v in out.counters.items()} == \\
        {k: int(v) for k, v in solo_out.counters.items()}, (slots, K)
print("ok")
"""


def test_tenant_fairness_and_replication_sharded():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", FAIR_SCRIPT],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "ok" in r.stdout
