"""Multi-device behaviour (8 virtual CPU devices via subprocess): sharded
training, checkpoint/restore with resharding (elastic), int8 collectives,
pipeline stages."""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(script: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_loss_decreases():
    _run("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.train import optimizer as opt, steps as S
from repro.data.tokens import TokenStream
mesh = make_mesh((2,2,2), ("pod","data","model"))
cfg = get_config("qwen3-4b").reduced()
step, jit_for, sh = S.make_train_step(cfg, mesh, opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20))
fn = jit_for(S.make_batch_abstract(cfg, ShapeSpec("t", 32, 4, "train")))
params = jax.device_put(M.init_params(cfg, jax.random.key(0)), sh["params"])
ostate = jax.jit(opt.init_state, out_shardings=sh["opt"])(params)
ts = TokenStream(cfg.vocab, 4, 32)
losses = []
for _ in range(5):
    b = {k: jnp.asarray(v) for k, v in ts.next_batch().items()}
    params, ostate, m = fn(params, ostate, b)
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print("ok", losses)
""")


def test_sharded_prefill_decode():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.distributed import sharding as sh
from repro.train import steps as S
mesh = make_mesh((4,2), ("data","model"))
cfg = get_config("h2o-danube-1.8b").reduced()
params_abs = M.abstract_params(cfg)
p_sh = sh.param_shardings(params_abs, mesh)
params = jax.device_put(M.init_params(cfg, jax.random.key(0)), p_sh)
B, Sq, T = 4, 16, 32
cache = M.init_cache(cfg, B, T)
cache = jax.device_put(cache, sh.cache_shardings(jax.eval_shape(lambda: M.init_cache(cfg, B, T)), mesh))
toks = jax.random.randint(jax.random.key(1), (B, Sq+1), 0, cfg.vocab)
logits_full, _, _ = M.forward(params, toks, cfg)
_, cache = M.prefill(params, toks[:, :Sq], cfg, cache=cache)
got, _ = M.decode_step(params, toks[:, Sq:], cfg, cache=cache, cache_index=Sq)
np.testing.assert_allclose(np.asarray(got), np.asarray(logits_full[:, -1, :]), rtol=5e-2, atol=5e-2)
print("ok")
""")


def test_checkpoint_restore_and_elastic_reshard():
    _run("""
import tempfile, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.distributed import sharding as sh
from repro.train import checkpoint as ckpt

cfg = get_config("qwen3-4b").reduced()
mesh8 = make_mesh((4,2), ("data","model"))
params = jax.device_put(M.init_params(cfg, jax.random.key(0)),
                        sh.param_shardings(M.abstract_params(cfg), mesh8))
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 7, params, data_state=dict(seed=1, step=7))
    assert ckpt.latest_step(d) == 7
    # restore onto a DIFFERENT mesh (elastic: 8 -> 4 devices used)
    mesh4 = make_mesh((2,2), ("data","model"))
    restored, step, ds, _ = ckpt.restore(
        d, M.abstract_params(cfg),
        shardings=sh.param_shardings(M.abstract_params(cfg), mesh4))
    assert step == 7 and ds["step"] == 7
    a = jax.tree_util.tree_leaves(params)[3]
    b = jax.tree_util.tree_leaves(restored)[3]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # corruption detection
    import pathlib
    f = sorted(pathlib.Path(d).glob("step_*/arr_00000.npy"))[0]
    f.write_bytes(b"garbage")
    try:
        ckpt.restore(d, M.abstract_params(cfg))
        raise SystemExit("corruption not detected")
    except IOError:
        pass
print("ok")
""")


def test_int8_psum_collective():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_mesh
from repro.distributed.collectives import psum_int8
mesh = make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.key(0), (8, 1024), jnp.float32)
def body(xl):
    return psum_int8(xl[0], "data")[None]
got = shard_map(body, mesh=mesh, in_specs=P("data"), out_specs=P("data"))(x)
want = x.sum(axis=0)
err = np.abs(np.asarray(got[0]) - np.asarray(want))
rel = err.max() / (np.abs(np.asarray(want)).max() + 1e-9)
assert rel < 0.02, rel       # int8 block-scaled: ~1% worst-case error
print("ok", rel)
""")


def test_pipeline_stages():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.distributed.pipeline import pipeline_apply
mesh = make_mesh((4,), ("pipe",))
# stage transform: y = x @ W_s (per-stage weight)
W = jax.random.normal(jax.random.key(0), (4, 16, 16)) * 0.3
x = jax.random.normal(jax.random.key(1), (8, 16))
def fn_stage(w, xb):
    return jnp.tanh(xb @ w)
got = pipeline_apply(fn_stage, x, W, mesh, n_micro=4, axis="pipe")
want = x
for s in range(4):
    want = jnp.tanh(want @ W[s])
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)
print("ok")
""")


def test_quantized_collective_unit():
    """Single-device quantizer roundtrip properties."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.collectives import (dequantize_int8,
                                               quantize_int8,
                                               quantize_kv_int8,
                                               dequantize_kv_int8)
    x = jax.random.normal(jax.random.key(0), (1000,), jnp.float32) * 5
    q, s, n = quantize_int8(x)
    y = dequantize_int8(q, s, n, x.shape)
    err = np.abs(np.asarray(x - y)).max()
    scale_max = float(np.asarray(s).max())
    assert err <= scale_max * 0.51 + 1e-6
    kv = jax.random.normal(jax.random.key(1), (2, 8, 4, 64), jnp.bfloat16)
    qkv, sc = quantize_kv_int8(kv)
    back = dequantize_kv_int8(qkv, sc)
    rel = np.abs(np.asarray(back, np.float32) - np.asarray(kv, np.float32)).max()
    assert rel < 0.1
