"""Real-time early-termination mapping (Read Until)."""
import numpy as np

from repro.core import build_index, score_accuracy
from repro.core.pipeline import MapOutput
from repro.core.realtime import map_realtime
from repro.signal import simulate


def test_early_termination_saves_signal(small_ref, cfg_fixed, small_index):
    reads = simulate.sample_reads(small_ref, 32,
                                  signal_len=cfg_fixed.signal_len, seed=9,
                                  junk_frac=0.1)
    res = map_realtime(reads.signals, small_index, cfg_fixed)
    mappable = reads.mappable
    # most mappable reads should resolve before the full read
    early = res.samples_used[mappable] < cfg_fixed.signal_len
    assert early.mean() > 0.5, res.samples_used[mappable]
    assert res.mean_fraction_used < 0.8
    # accuracy of early decisions must hold up
    out = MapOutput(t_start=res.t_start, score=res.score, mapped=res.mapped,
                    n_events=np.zeros_like(res.t_start), counters={})
    acc = score_accuracy(out, reads.true_pos, reads.true_strand,
                         reads.mappable, reads.n_bases, small_ref.n_events)
    assert acc["precision"] >= 0.85, acc
    assert acc["recall"] >= 0.75, acc


def test_junk_reads_not_resolved_early(small_ref, cfg_fixed, small_index):
    rng = np.random.default_rng(12)
    junk = rng.normal(100, 15, (8, cfg_fixed.signal_len)).astype(np.float32)
    res = map_realtime(junk, small_index, cfg_fixed)
    # junk must consume the whole signal (no confident early call)
    assert (res.samples_used == cfg_fixed.signal_len).mean() >= 0.75
    assert res.mapped.sum() <= 1
