"""Infrastructure tests: token stream, monitor, reader, HLO analyzer."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo
from repro.data.tokens import TokenStream, TokenStreamState
from repro.signal import reader as reader_lib
from repro.train.monitor import StepMonitor


def test_token_stream_deterministic_resume():
    a = TokenStream(1000, 4, 32, seed=5)
    batches = [a.next_batch() for _ in range(5)]
    # resume at step 3
    b = TokenStream(1000, 4, 32, seed=5, start_step=3)
    again = b.next_batch()
    np.testing.assert_array_equal(batches[3]["tokens"], again["tokens"])


def test_monitor_detects_straggler():
    mon = StepMonitor(warmup_steps=1, threshold=1.8)
    for i in range(6):
        mon.start()
        time.sleep(0.25 if i == 4 else 0.02)
        mon.stop()
    assert len(mon.events) == 1
    assert mon.events[0].step == 5


def test_signal_reader_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    sig = rng.normal(100, 15, (10, 64)).astype(np.float32)
    f = tmp_path / "x.mars"
    reader_lib.write_signals(f, sig)
    rd = reader_lib.SignalReader(f, chunk=4)
    chunks = list(rd)
    assert [c[0] for c in chunks] == [0, 1, 2]
    assert chunks[-1][1] == 2                      # valid reads in tail
    got = np.concatenate([c[2][:c[1]] for c in chunks])
    np.testing.assert_allclose(got, sig, atol=0.02)


def test_signal_reader_resume(tmp_path):
    rng = np.random.default_rng(1)
    sig = rng.normal(100, 15, (12, 32)).astype(np.float32)
    f = tmp_path / "y.mars"
    reader_lib.write_signals(f, sig)
    rd = reader_lib.SignalReader(f, chunk=4, start_chunk=2)
    chunks = list(rd)
    assert [c[0] for c in chunks] == [2]


def test_hlo_analyzer_counts_loop_trips():
    """The motivating experiment: a 10-step scanned matmul must report 10x
    the flops of a single matmul (XLA's own cost_analysis reports 1x)."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def single(x, w):
        return x @ w

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    t1 = jax.jit(single).lower(x, w).compile().as_text()
    t10 = jax.jit(scanned).lower(x, w).compile().as_text()
    f1 = hlo.analyze(t1)["flops"]
    f10 = hlo.analyze(t10)["flops"]
    assert f1 == pytest.approx(2 * 128**3, rel=0.01)
    assert f10 == pytest.approx(10 * f1, rel=0.05)


def test_hlo_analyzer_dot_flops_with_resolved_operands():
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    text = jax.jit(lambda a, b: a @ b).lower(a, b).compile().as_text()
    res = hlo.analyze(text)
    assert res["flops"] == pytest.approx(2 * 64 * 256 * 32, rel=0.01)
