"""Bit-exact parity: the partitioned-index `query:ring` / `query:a2a` stage
backends vs single-device map_chunk — results AND every CHUNK_COUNTER_SCHEMA
counter, with and without the chaining fast path (chain_compaction), plus
pad-row (n_valid) masking — on a multi-device CPU mesh (subprocess)."""
import os
import pathlib
import subprocess
import sys

import pytest

# run_tier1.sh runs this whole file in its dedicated distributed pass
# (under 4 forced CPU devices) after the fast pass — not twice
pytestmark = pytest.mark.slow

REPO = pathlib.Path(__file__).resolve().parents[1]

SCRIPT = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import MarsConfig, Mapper, build_index, driver, stages
from repro.core import partition_index
from repro.core.index import index_arrays
from repro.core.pipeline import map_chunk, map_chunk_sharded
from repro.distributed.sharding import partitioned_index_shardings
from repro.launch.mesh import make_mesh
from repro.signal import simulate

mesh = make_mesh((2, 2), ("data", "model"))
ref = simulate.make_reference(50_000, seed=3)

def check(cfg, reads, idx, n_valid=None):
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    out_ref = map_chunk(jnp.asarray(reads.signals), arrays, cfg,
                        n_valid=n_valid)
    parts = partition_index(idx, mesh.shape["model"])
    sh = partitioned_index_shardings(mesh)
    parts_dev = {k: jax.device_put(jnp.asarray(v), sh[k])
                 for k, v in parts.items()}
    for backend in ("ring", "a2a"):
        plan = stages.resolve_plan(cfg, backend)
        # only the query stage is distributed; everything else is the
        # reference per-read program
        assert dict(plan)["query"] == backend, plan
        assert stages.plan_index_kind(plan) == "partitioned"
        assert all(b == stages.REFERENCE for s, b in plan if s != "query")
        out = map_chunk_sharded(jnp.asarray(reads.signals), parts_dev, cfg,
                                mesh, plan=plan, n_valid=n_valid)
        tag = (backend, cfg.chain_compaction, n_valid)
        # counter pytree is derived from the schema — it can never drift
        assert set(out.counters) == set(stages.CHUNK_COUNTER_SCHEMA), tag
        np.testing.assert_array_equal(np.asarray(out_ref.t_start),
                                      np.asarray(out.t_start), err_msg=str(tag))
        np.testing.assert_array_equal(np.asarray(out_ref.score),
                                      np.asarray(out.score), err_msg=str(tag))
        np.testing.assert_array_equal(np.asarray(out_ref.mapped),
                                      np.asarray(out.mapped), err_msg=str(tag))
        np.testing.assert_array_equal(np.asarray(out_ref.n_events),
                                      np.asarray(out.n_events), err_msg=str(tag))
        for k in stages.CHUNK_COUNTER_SCHEMA:
            assert int(out.counters[k]) == int(out_ref.counters[k]), (tag, k)

for compaction in (True, False):
    cfg = MarsConfig(hash_bits=14,
                     chain_compaction=compaction).with_mode("ms_fixed")
    reads = simulate.sample_reads(ref, 16, signal_len=cfg.signal_len, seed=4,
                                  junk_frac=0.25)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    check(cfg, reads, idx)
    if compaction:
        check(cfg, reads, idx, n_valid=13)      # pad rows masked identically

# Mapper + unified driver host loop over the partitioned backend
cfg = MarsConfig(hash_bits=14).with_mode("ms_fixed")
reads = simulate.sample_reads(ref, 16, signal_len=cfg.signal_len, seed=4,
                              junk_frac=0.25)
idx = build_index(ref.events_concat, ref.n_events, cfg)
got = Mapper(idx, cfg, backend="ring", mesh=mesh).map_signals(
    reads.signals[:14], chunk=8)
want = driver.collect(driver.stream_map(
    Mapper(idx, cfg).chunk_fn(), driver.array_chunks(reads.signals[:14], 8)))
np.testing.assert_array_equal(got.t_start, want.t_start)
np.testing.assert_array_equal(got.mapped, want.mapped)
assert got.counters == want.counters
print("ok")
"""


def test_partitioned_query_backends_match_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "ok" in r.stdout


PREPASS_SCRIPT = """
import numpy as np
import jax
from jax.sharding import Mesh
from repro.core import MarsConfig, build_index
from repro.core.pipeline import Mapper
from repro.signal import simulate

cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
ref = simulate.make_reference(20_000, seed=3)
reads = simulate.sample_reads(ref, 16, signal_len=cfg.signal_len, seed=4,
                              junk_frac=0.3)
idx = build_index(ref.events_concat, ref.n_events, cfg)
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))

outs = {}
for name, kw in (("mesh_reuse", dict(mesh=mesh, reuse_prepass=True)),
                 ("mesh_noreuse", dict(mesh=mesh, reuse_prepass=False)),
                 ("single", dict(reuse_prepass=True))):
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4, **kw)
    # the sharded path no longer forces reuse_prepass off under a mesh
    assert m.cache.reuse_prepass == kw["reuse_prepass"], name
    outs[name] = m.chunk_fn()(reads.signals, 16)

base = outs["single"]
for name in ("mesh_reuse", "mesh_noreuse"):
    o = outs[name]
    for f in ("t_start", "score", "mapped", "n_events"):
        np.testing.assert_array_equal(np.asarray(getattr(o, f)),
                                      np.asarray(getattr(base, f)),
                                      err_msg=f"{name}.{f}")
    for k in base.counters:
        np.testing.assert_array_equal(np.asarray(o.counters[k]),
                                      np.asarray(base.counters[k]),
                                      err_msg=f"{name}.{k}")
print("ok")
"""


def test_tiered_prepass_reuse_sharded_parity():
    """Satellite of the fused-kernel PR: the tiered prepass planes
    (t_pre_keys / t_pre_valid / t_pre_nev) now flow through shard_map
    in_specs sharded per-read over the mesh 'data' axis — reuse on the
    sharded path must be bit-identical to reuse off AND to the
    single-device mapper."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", PREPASS_SCRIPT],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "ok" in r.stdout


def test_partitioned_plan_rejected_single_device():
    """A partitioned-index plan must not silently run against a replicated
    table on one device."""
    import jax.numpy as jnp
    from repro.core import MarsConfig, stages
    from repro.core.pipeline import map_chunk

    cfg = MarsConfig(hash_bits=14)
    plan = stages.resolve_plan(cfg, "ring")
    sig = jnp.zeros((4, cfg.signal_len), jnp.float32)
    with pytest.raises(ValueError, match="partitioned"):
        map_chunk(sig, {}, cfg, plan=plan)


def test_no_duplicated_per_read_program():
    """The drift that motivated this PR: core/distributed.py must hold no
    second per-read program or hand-listed counter pytree — schedules are
    registered `query` backends and the counter specs flow from
    stages.CHUNK_COUNTER_SCHEMA via the shared sharded chunk program."""
    import inspect
    import repro.core.distributed as D
    from repro.core import stages

    src = inspect.getsource(D)
    assert "out_specs" not in src        # no hand-rolled shard_map program
    assert "chain_anchors" not in src    # no duplicated post-query tail
    assert "vote_filter" not in src
    for name in ("ring", "a2a"):
        b = stages.get_backend("query", name)
        assert b.index_kind == "partitioned"
