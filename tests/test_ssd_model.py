"""Analytic hardware model invariants."""
import dataclasses

import pytest

from repro.core import ssd_model as S
from repro.core.workload import Workload


def _w(scale=1.0, fixed=True):
    return Workload(
        n_reads=int(1e4 * scale), n_samples=int(1e9 * scale),
        n_events=int(1.2e8 * scale), n_seeds=int(1.1e8 * scale),
        n_lookups=int(1.1e8 * scale), n_hits_raw=int(3e8 * scale),
        n_hits_exact=int(4e8 * scale), n_hits_postfreq=int(2.5e8 * scale),
        n_votes=int(5e8 * scale), n_anchors_postvote=int(1e8 * scale),
        n_sorted=int(1e8 * scale), n_dp_pairs=int(3.2e9 * scale),
        bytes_raw=int(2e9 * scale), bytes_index=int(5e8),
        bytes_intermediate=int(3e9 * scale), fixed_point=fixed)


def test_more_work_more_time():
    t1 = S.mars_latency(_w(1.0))["total"]
    t2 = S.mars_latency(_w(2.0))["total"]
    assert t2 > t1


def test_mars_faster_than_cpu():
    w = _w()
    rates = S.HostRates()
    mars = S.system_latency_energy("MARS", w, rates)
    rh2 = S.system_latency_energy("RH2", w, rates)
    assert mars["total"] < rh2["total"]
    assert mars["energy"] < rh2["energy"]


def test_simdram_tradeoff():
    """Paper Section 8.2/8.3: SIMDRAM slower than MARS but lower energy
    (component-level accounting: bit-serial rows beat ALU logic on energy
    even though the run is ~21x longer)."""
    w = _w()
    mars = S.system_latency_energy("MARS", w)
    sim = S.system_latency_energy("MS-SIMDRAM", w)
    assert sim["total"] > mars["total"]
    # dynamic component energy (the paper's accounting) favors SIMDRAM
    assert sim["energy_dynamic"] < mars["energy_dynamic"]


def test_ext_slower_than_mars():
    w = _w()
    mars = S.system_latency_energy("MARS", w)
    ext = S.system_latency_energy("MS-EXT", w)
    assert ext["total"] > mars["total"]


def test_fixed_point_helps():
    t_fixed = S.mars_latency(_w(fixed=True))["compute"]
    t_float = S.mars_latency(_w(fixed=False))["compute"]
    assert t_float > t_fixed


def test_dram_sensitivity_monotone():
    sens = S.dram_size_sensitivity(_w())
    sizes = sorted(sens)
    assert sens[sizes[0]] > sens[sizes[1]] > sens[sizes[2]]


def test_area_matches_paper_table5():
    t = S.area_table()
    dram = t["Arithmetic"]["total"] + t["Querying"]["total"]
    assert abs(dram - 16.78) < 0.1          # paper: 16.78 mm^2
    assert t["Sorter"]["total"] == pytest.approx(6.24)


def test_all_systems_run():
    w = _w()
    for s in S.SYSTEMS:
        r = S.system_latency_energy(s, w)
        assert r["total"] > 0 and r["energy"] > 0, s
