"""Analytic hardware model invariants."""
import dataclasses

import pytest

from repro.core import ssd_model as S
from repro.core.workload import Workload


def _w(scale=1.0, fixed=True):
    return Workload(
        n_reads=int(1e4 * scale), n_samples=int(1e9 * scale),
        n_events=int(1.2e8 * scale), n_seeds=int(1.1e8 * scale),
        n_lookups=int(1.1e8 * scale), n_hits_raw=int(3e8 * scale),
        n_hits_exact=int(4e8 * scale), n_hits_postfreq=int(2.5e8 * scale),
        n_votes=int(5e8 * scale), n_anchors_postvote=int(1e8 * scale),
        n_sorted=int(1e8 * scale), n_dp_pairs=int(3.2e9 * scale),
        bytes_raw=int(2e9 * scale), bytes_index=int(5e8),
        bytes_intermediate=int(3e9 * scale), fixed_point=fixed)


def test_more_work_more_time():
    t1 = S.mars_latency(_w(1.0))["total"]
    t2 = S.mars_latency(_w(2.0))["total"]
    assert t2 > t1


def test_mars_faster_than_cpu():
    w = _w()
    rates = S.HostRates()
    mars = S.system_latency_energy("MARS", w, rates)
    rh2 = S.system_latency_energy("RH2", w, rates)
    assert mars["total"] < rh2["total"]
    assert mars["energy"] < rh2["energy"]


def test_simdram_tradeoff():
    """Paper Section 8.2/8.3: SIMDRAM slower than MARS but lower energy
    (component-level accounting: bit-serial rows beat ALU logic on energy
    even though the run is ~21x longer)."""
    w = _w()
    mars = S.system_latency_energy("MARS", w)
    sim = S.system_latency_energy("MS-SIMDRAM", w)
    assert sim["total"] > mars["total"]
    # dynamic component energy (the paper's accounting) favors SIMDRAM
    assert sim["energy_dynamic"] < mars["energy_dynamic"]


def test_ext_slower_than_mars():
    w = _w()
    mars = S.system_latency_energy("MARS", w)
    ext = S.system_latency_energy("MS-EXT", w)
    assert ext["total"] > mars["total"]


def test_fixed_point_helps():
    t_fixed = S.mars_latency(_w(fixed=True))["compute"]
    t_float = S.mars_latency(_w(fixed=False))["compute"]
    assert t_float > t_fixed


def test_dram_sensitivity_monotone():
    sens = S.dram_size_sensitivity(_w())
    sizes = sorted(sens)
    assert sens[sizes[0]] > sens[sizes[1]] > sens[sizes[2]]


def test_area_matches_paper_table5():
    t = S.area_table()
    dram = t["Arithmetic"]["total"] + t["Querying"]["total"]
    assert abs(dram - 16.78) < 0.1          # paper: 16.78 mm^2
    assert t["Sorter"]["total"] == pytest.approx(6.24)


def test_all_systems_run():
    w = _w()
    for s in S.SYSTEMS:
        r = S.system_latency_energy(s, w)
        assert r["total"] > 0 and r["energy"] > 0, s


# --------------------------------------------------------------------------- #
# Multi-SSD array + serving queueing term
# --------------------------------------------------------------------------- #
def test_array_latency_scales_down():
    """Bucket-range partitioning: each doubling of the array roughly halves
    batch latency (compute and index stream split evenly; host merge and
    dispatch grow only mildly)."""
    w = _w()
    t = [S.mars_array_latency(w, S.SSDArrayConfig(n_ssds=n))["total"]
         for n in (1, 2, 4, 8)]
    assert t[0] > t[1] > t[2] > t[3]
    assert t[0] / t[1] > 1.5                    # near-linear at small N


def test_array_one_drive_matches_single_ssd():
    w = _w()
    arr = S.SSDArrayConfig(n_ssds=1)
    lat = S.mars_array_latency(w, arr)
    base = S.mars_latency(w)["total"]
    assert lat["per_ssd"] == pytest.approx(base)
    assert lat["total"] == pytest.approx(
        base + lat["merge"] + lat["orchestration"])


def test_array_power_of_two_guard():
    with pytest.raises(ValueError, match="power of two"):
        S.SSDArrayConfig(n_ssds=3)


def test_array_energy_accounting():
    """Dynamic energy is workload-proportional (sums back across drives);
    the array pays extra static power for the extra drives but over a
    shorter run — total energy stays within a small factor."""
    w = _w()
    e1 = S.mars_array_energy(w, S.SSDArrayConfig(n_ssds=1))
    e4 = S.mars_array_energy(w, S.SSDArrayConfig(n_ssds=4))
    assert 0.5 < e4 / e1 < 2.0


def test_serving_percentiles_ordering():
    w = _w()
    arr = S.SSDArrayConfig(n_ssds=4)
    cap = 4.0 / (S.mars_array_latency(w, arr)["total"] / w.n_reads * 4)
    sv = S.serving_latency(w, offered_load=0.6 * cap, arr=arr)
    assert not sv["saturated"]
    assert sv["p99"] >= sv["p50"] >= sv["service"] > 0
    assert sv["p99"] >= sv["mean"] - 1e-12 or sv["wait_prob"] < 0.5


def test_serving_latency_monotone_in_load():
    w = _w()
    arr = S.SSDArrayConfig(n_ssds=4)
    cap = 4.0 / (S.mars_array_latency(w, arr)["total"] / w.n_reads * 4)
    p99 = [S.serving_latency(w, offered_load=f * cap, arr=arr)["p99"]
           for f in (0.3, 0.6, 0.9)]
    assert p99[0] <= p99[1] <= p99[2]
    assert p99[2] > p99[0]                       # tail grows toward saturation


def test_serving_more_drives_cut_tail_latency():
    """At matched utilization, a bigger array has a shorter tail (classic
    M/D/c pooling win)."""
    w = _w()
    out = []
    for n in (2, 8):
        arr = S.SSDArrayConfig(n_ssds=n)
        service = S.mars_array_latency(w, arr)["total"] / w.n_reads * n
        out.append(S.serving_latency(w, offered_load=0.7 * n / service,
                                     arr=arr)["p99"])
    assert out[1] < out[0]


def test_serving_saturation():
    w = _w()
    sv = S.serving_latency(w, offered_load=1e15)
    assert sv["saturated"]
    assert sv["p99"] == float("inf") and sv["p50"] == float("inf")
    with pytest.raises(ValueError, match="offered_load"):
        S.serving_latency(w, offered_load=0.0)


def test_serving_latency_virtual_shape():
    """The virtual twin keeps the core's contract: percentiles ordered,
    sojourn >= one chunk dispatch even when idle, saturation at capacity."""
    sv = S.serving_latency_virtual(chunk=8, offered_load=0.5 * 8)
    assert not sv["saturated"]
    assert sv["p99"] >= sv["p50"] >= sv["chunk_cost"]
    assert S.serving_latency_virtual(8, offered_load=8.0)["saturated"]
    with pytest.raises(ValueError, match="offered_load"):
        S.serving_latency_virtual(8, offered_load=0.0)


def test_queueing_validation():
    """The shared M/D/c core rejects degenerate inputs loudly (both
    serving wrappers inherit these guards)."""
    with pytest.raises(ValueError, match="service time"):
        S.queueing_percentiles(0.0, 4, 1.0)
    with pytest.raises(ValueError, match="service time"):
        S.queueing_percentiles(-1.0, 4, 1.0)
    with pytest.raises(ValueError, match="n_servers"):
        S.queueing_percentiles(1.0, 0, 1.0)
    with pytest.raises(ValueError, match="must be >= 0"):
        S.queueing_percentiles(1.0, 4, -0.5)
    with pytest.raises(ValueError, match="idle system"):
        S.queueing_percentiles(1.0, 4, 0.0)


def test_queueing_rho_exactly_one_boundary():
    """rho == 1.0 exactly is saturated (no steady state): percentiles and
    mean are inf, wait probability 1; just below, everything is finite."""
    at = S.queueing_percentiles(1.0, 4, 4.0)        # rho = 1.0 exactly
    assert at["saturated"] and at["utilization"] == 1.0
    assert at["mean"] == float("inf") and at["wait_prob"] == 1.0
    assert at["p50"] == float("inf") and at["p99"] == float("inf")
    below = S.queueing_percentiles(1.0, 4, 4.0 * (1 - 1e-6))
    assert not below["saturated"]
    assert below["mean"] < float("inf") and below["p99"] < float("inf")
    virt = S.serving_latency_virtual(8, offered_load=8.0, chunk_cost=1.0)
    assert virt["saturated"] and virt["utilization"] == 1.0


def test_degraded_array_config():
    arr = S.SSDArrayConfig(n_ssds=4, n_failed=1)
    assert arr.n_serving == 2
    assert S.SSDArrayConfig(n_ssds=4).n_serving == 4
    with pytest.raises(ValueError, match="n_failed"):
        S.SSDArrayConfig(n_ssds=4, n_failed=2)
    with pytest.raises(ValueError, match="survivor"):
        S.SSDArrayConfig(n_ssds=1, n_failed=1)


def test_degraded_array_matches_halved_array():
    """The analytic twin of ``repartition_index``: a degraded N-drive
    array serves exactly like a healthy N/2-drive array (each survivor
    carries the doubled post-rebalance share), and is strictly slower
    than the healthy N-drive array."""
    w = _w()
    degraded = S.SSDArrayConfig(n_ssds=4, n_failed=1)
    halved = S.SSDArrayConfig(n_ssds=2)
    healthy = S.SSDArrayConfig(n_ssds=4)
    assert (S.mars_array_latency(w, degraded)["total"]
            == pytest.approx(S.mars_array_latency(w, halved)["total"]))
    assert (S.mars_array_energy(w, degraded)
            == pytest.approx(S.mars_array_energy(w, halved)))
    assert (S.mars_array_latency(w, degraded)["total"]
            > S.mars_array_latency(w, healthy)["total"])
    load = 0.5 / (S.mars_array_latency(w, healthy)["total"] / w.n_reads)
    sv_h = S.serving_latency(w, offered_load=load, arr=healthy)
    sv_d = S.serving_latency(w, offered_load=load, arr=degraded)
    assert sv_d["utilization"] > sv_h["utilization"]
    assert sv_d["p99"] > sv_h["p99"]


def test_serving_model_tracks_serve_driver_trace():
    """Calibration contract (benchmarks/calibrate_serving.py): below
    saturation the modeled p50 sojourn tracks the percentile of measured
    ``ServeDriver`` virtual-time traces within 15%."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks import calibrate_serving

    mapper = calibrate_serving.default_mapper(hash_bits=12, ref_events=8_000)
    rows = calibrate_serving.calibrate(mapper, chunk=8,
                                       load_fracs=(0.3, 0.6), n_reads=96)
    for r in rows:
        assert not r["saturated"], r
        assert abs(r["p50_ratio"] - 1.0) <= 0.15, r
