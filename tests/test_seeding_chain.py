"""Hashing / index / seeding / vote / chaining unit tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import chaining, hashing, index as index_lib, seeding, vote
from repro.core.config import MarsConfig
from repro.core.index import index_arrays


def test_pack_seeds_matches_numpy_twin():
    cfg = MarsConfig()
    rng = np.random.default_rng(0)
    sym = rng.integers(0, cfg.quant_levels, 64)
    keys_np = hashing.pack_seeds_np(sym, cfg)
    keys_j, valid = hashing.pack_seeds(jnp.asarray(sym.astype(np.int32)),
                                       jnp.int32(64), cfg)
    n = 64 - cfg.seed_width + 1
    np.testing.assert_array_equal(np.asarray(keys_j)[:n], keys_np)
    assert np.asarray(valid)[:n].all()
    assert not np.asarray(valid)[n:].any()


def test_query_matches_bruteforce(small_ref, cfg_fixed, small_index):
    """Index query == brute-force dict lookup for every seed."""
    cfg = cfg_fixed
    idx = small_index
    # build a brute-force map key -> positions
    from collections import defaultdict
    brute = defaultdict(list)
    for k, p in zip(idx.entries_key, idx.entries_pos):
        brute[int(k)].append(int(p))
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    rng = np.random.default_rng(1)
    some_keys = rng.choice(idx.entries_key, 50, replace=False)
    keys = jnp.asarray(some_keys.astype(np.uint32))
    valid = jnp.ones(50, bool)
    t_pos, hit_valid, counters = seeding.query_index(keys, valid, arrays, cfg)
    for i in range(50):
        expect = set(brute[int(some_keys[i])])
        if len(expect) > cfg.thresh_freq or len(expect) > cfg.max_hits_per_seed:
            continue
        got = set(np.asarray(t_pos[i])[np.asarray(hit_valid[i])].tolist())
        assert got == expect, (i, got, expect)


def test_freq_filter_drops_frequent_seeds(small_ref, cfg_fixed):
    cfg = cfg_fixed.replace(thresh_freq=2)
    idx = index_lib.build_index(small_ref.events_concat, small_ref.n_events,
                                cfg)
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    # pick a key occurring > 2 times
    vals, counts = np.unique(idx.entries_key, return_counts=True)
    frequent = vals[counts > 2]
    if frequent.size == 0:
        pytest.skip("no frequent seeds in this reference")
    keys = jnp.asarray(frequent[:8].astype(np.uint32))
    valid = jnp.ones(keys.shape[0], bool)
    _, hit_valid, counters = seeding.query_index(keys, valid, arrays, cfg)
    assert int(counters["n_hits_postfreq"]) == 0
    assert int(counters["n_hits_raw"]) > 0


def test_vote_filter_keeps_colinear_drops_scattered():
    cfg = MarsConfig(thresh_voting=4)
    E, H = 32, 4
    q = np.tile(np.arange(E)[:, None], (1, H)).astype(np.int32)
    t = np.zeros((E, H), np.int32)
    # colinear cluster: diag 5000 for slot 0; scattered for slot 1
    t[:, 0] = 5000 + q[:, 0]
    rng = np.random.default_rng(0)
    t[:, 1] = rng.integers(0, 10**6, E)
    valid = np.zeros((E, H), bool)
    valid[:, :2] = True
    keep, counters = vote.vote_filter(jnp.asarray(q), jnp.asarray(t),
                                      jnp.asarray(valid), cfg)
    keep = np.asarray(keep)
    assert keep[:, 0].all(), "colinear anchors must survive"
    assert keep[:, 1].sum() < E // 4, "scattered anchors must mostly die"


def test_chain_score_bounded_by_anchor_count():
    cfg = MarsConfig(max_anchors=64, chain_band=16)
    rng = np.random.default_rng(2)
    E, H = 16, 4
    q = rng.integers(0, 100, (E, H)).astype(np.int32)
    t = rng.integers(0, 5000, (E, H)).astype(np.int32)
    v = rng.random((E, H)) < 0.7
    res, counters = chaining.chain_anchors(jnp.asarray(q), jnp.asarray(t),
                                           jnp.asarray(v), cfg)
    n_valid = int(np.asarray(v).sum())
    assert float(res.score) <= cfg.anchor_score * n_valid + 1e-6


def test_chain_finds_planted_colinear_run():
    cfg = MarsConfig(max_anchors=64, chain_band=16, min_chain_score=4.0)
    E, H = 32, 2
    q = np.tile(np.arange(E)[:, None], (1, H)).astype(np.int32)
    t = np.zeros((E, H), np.int32)
    t[:, 0] = 7000 + q[:, 0] * 2          # near-colinear planted chain
    rng = np.random.default_rng(3)
    t[:, 1] = rng.integers(0, 10**6, E)
    v = np.ones((E, H), bool)
    res, _ = chaining.chain_anchors(jnp.asarray(q), jnp.asarray(t),
                                    jnp.asarray(v), cfg)
    assert bool(res.mapped)
    assert abs(int(res.t_start) - 7000) < 200
