"""Minimizer winnowing: index shrinks, accuracy holds."""
import numpy as np
import pytest

from repro.core import MarsConfig, Mapper, build_index, score_accuracy
from repro.core import hashing
from repro.signal import simulate


def test_minimizer_mask_np_keeps_local_minima():
    keys = np.array([5, 3, 9, 1, 7, 2, 8], np.uint32)
    keep = hashing.minimizer_mask_np(keys, 1)
    # local minima within +-1: 3 (vs 5,9), 1 (vs 9,7), 2 (vs 7,8)
    np.testing.assert_array_equal(keep, [False, True, False, True, False,
                                         True, False])


def test_jnp_np_twins_agree():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**32, 200, dtype=np.uint64).astype(np.uint32)
    np_mask = hashing.minimizer_mask_np(keys, 2)
    j_mask = np.asarray(hashing.minimizer_mask(
        jnp.asarray(keys), jnp.ones(200, bool), 2))
    np.testing.assert_array_equal(np_mask, j_mask)


def test_minimizer_shrinks_index_keeps_accuracy(small_ref):
    """Winnowing at radius 1 with rescaled thresholds (fewer seeds => a
    confident chain needs fewer anchors) matches the full-seed F1 at ~3x
    fewer index entries — RawHash2's minimizer trade."""
    base = MarsConfig().with_mode("ms_fixed")
    mini = base.replace(minimizer_radius=1, min_chain_score=2.0,
                        thresh_voting=2)
    idx_full = build_index(small_ref.events_concat, small_ref.n_events, base)
    idx_mini = build_index(small_ref.events_concat, small_ref.n_events, mini)
    ratio = idx_mini.n_entries / idx_full.n_entries
    assert ratio < 0.45, ratio          # centered-window keep rate ~1/3

    reads = simulate.sample_reads(small_ref, 32, signal_len=base.signal_len,
                                  seed=31, junk_frac=0.1)
    acc_full = score_accuracy(
        Mapper(idx_full, base).map_signals(reads.signals),
        reads.true_pos, reads.true_strand, reads.mappable, reads.n_bases,
        small_ref.n_events)
    acc_mini = score_accuracy(
        Mapper(idx_mini, mini).map_signals(reads.signals),
        reads.true_pos, reads.true_strand, reads.mappable, reads.n_bases,
        small_ref.n_events)
    assert acc_mini["f1"] >= acc_full["f1"] - 0.02, (acc_full, acc_mini)
    assert acc_mini["precision"] >= 0.95
