"""Multi-tenant fair serving: per-tenant shed budgets (ServeDriver).

The fairness contract under test (core/server.py):

  * budgets never hard-reject — they steer shed/eviction victim choice;
  * budget-exhausted tenants shed FIRST, even when priority would have
    picked someone else (the starvation case budgets exist to prevent);
  * the SLO shed exemption beats budgets: an unsheddable read is never a
    victim, in or out of budget;
  * isolation: a within-budget tenant's admitted set, per-stream results
    AND latency trace are unchanged by a co-tenant's flood — the flood's
    out-of-budget overflow is shed at its own admission, as if it had
    never been sent;
  * no budgets configured => bit-identical to the tenant-free driver
    (tenant tags are observation-only).

Backends: single-device reference and out-of-core tiered here; the
sharded mesh run rides tests/test_distributed_serve.py.
"""
import math

import numpy as np
import pytest

from repro.core import MarsConfig, Mapper, build_index
from repro.core.server import SLOClass, ServeDriver, TenantBudget
from repro.signal import simulate


@pytest.fixture(scope="module")
def setup():
    cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
    ref = simulate.make_reference(8_000, seed=5)
    reads = simulate.sample_reads(ref, 24, signal_len=cfg.signal_len,
                                  seed=6, junk_frac=0.25)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    return cfg, reads, idx


def _mapper(setup, backend):
    cfg, _, idx = setup
    if backend == "tiered":
        return Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4)
    return Mapper(idx, cfg)


BUDGETS = (TenantBudget("acme", rate=10.0),
           TenantBudget("flood", rate=0.0, burst=1.0))


def _drive(mapper, flood_n, flood_sig, acme_sig, **kw):
    """acme: two well-behaved streams (6 reads each, under capacity at
    chunk=8 / shed_window=2); flood: one stream of ``flood_n`` identical
    reads with an empty budget — the overload source."""
    sd = ServeDriver(mapper, chunk=8, shed=True, shed_window=2.0,
                     cost_model="sim", tenant_budgets=BUDGETS, **kw)
    sd.submit("a0", acme_sig[:6], tenant="acme", t=0.0)
    sd.submit("a1", acme_sig[6:12], tenant="acme", t=0.0)
    if flood_n:
        sd.submit("f0", np.repeat(flood_sig, flood_n, axis=0),
                  tenant="flood", t=0.0)
    sd.drain()
    return sd


# --------------------------------------------------------------------------- #
# Isolation: the flood is invisible to the within-budget tenant
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["reference", "tiered"])
def test_flood_sheds_charged_to_flooder(setup, backend):
    _, reads, _ = setup
    m = _mapper(setup, backend)
    solo = _drive(m, 0, reads.signals[12:13], reads.signals)
    both = _drive(_mapper(setup, backend), 40, reads.signals[12:13],
                  reads.signals)
    tr = both.tenant_report()
    # every shed lands in the flooder's row; acme is untouched
    assert tr["acme"].n_shed == 0 and tr["acme"].n_rejected == 0
    assert tr["acme"].n_over_budget == 0
    assert tr["flood"].n_shed > 0
    assert tr["flood"].n_shed == both.n_shed
    assert tr["flood"].n_over_budget > 0
    # acme's per-stream results are bit-identical with or without the flood
    for sid in ("a0", "a1"):
        a, b = solo.results(sid), both.results(sid)
        np.testing.assert_array_equal(a.t_start, b.t_start, err_msg=sid)
        np.testing.assert_array_equal(a.score, b.score, err_msg=sid)
        np.testing.assert_array_equal(a.mapped, b.mapped, err_msg=sid)
        np.testing.assert_array_equal(a.n_events, b.n_events, err_msg=sid)
        assert all(both.stream(sid).admitted)


def test_flood_excess_is_as_if_never_sent(setup):
    """The exact isolation statement: the full flood run equals the run
    where the flooder only ever sent the reads that were admitted — same
    acme results AND same acme latency trace, read for read.  (Every
    out-of-budget shed hits the arriving read at its own admission, so
    it never perturbs the queue.)"""
    _, reads, _ = setup
    full = _drive(_mapper(setup, "reference"), 40, reads.signals[12:13],
                  reads.signals)
    k = int(sum(full.stream("f0").admitted))
    assert 0 < k < 40                        # some admitted, most shed
    trunc = _drive(_mapper(setup, "reference"), k, reads.signals[12:13],
                   reads.signals)
    assert trunc.n_shed == 0
    for sid in ("a0", "a1"):
        got, want = full.stream(sid), trunc.stream(sid)
        assert got.latency == want.latency, sid
        np.testing.assert_array_equal(full.results(sid).t_start,
                                      trunc.results(sid).t_start)


def test_exhausted_tenant_shed_before_priority(setup):
    """The starvation case: the flooder submits at HIGHER priority, which
    the legacy shed rule serves first (shedding acme).  Budgets flip it:
    out-of-budget beats priority, so the flooder's own overflow is shed
    and acme survives untouched."""
    _, reads, _ = setup

    def run(budgets):
        sd = ServeDriver(_mapper(setup, "reference"), chunk=8, shed=True,
                         shed_window=2.0, cost_model="sim",
                         tenant_budgets=budgets)
        sd.submit("a0", reads.signals[:12], tenant="acme", t=0.0)
        sd.submit("f0", np.repeat(reads.signals[12:13], 40, axis=0),
                  tenant="flood", priority=1, t=0.0)
        sd.drain()
        return sd

    legacy = run(None)
    fair = run(BUDGETS)
    assert legacy.stream("a0").n_shed > 0          # priority starves acme
    assert fair.stream("a0").n_shed == 0           # budgets isolate acme
    assert all(fair.stream("a0").admitted)
    assert fair.tenant_report()["flood"].n_shed == fair.n_shed > 0


def test_unsheddable_class_beats_budget(setup):
    """The SLO shed exemption is absolute: a budget-exhausted tenant's
    unsheddable reads are never shed — budgets only reorder victims among
    the sheddable."""
    _, reads, _ = setup
    gold = SLOClass("gold", priority=1, sheddable=False)
    sd = ServeDriver(_mapper(setup, "reference"), chunk=8, shed=True,
                     shed_window=2.0, cost_model="sim",
                     slo_classes=(gold,), tenant_budgets=BUDGETS)
    sd.submit("a0", reads.signals[:12], tenant="acme", t=0.0)
    sd.submit("g0", np.repeat(reads.signals[13:14], 8, axis=0),
              tenant="flood", slo="gold", t=0.0)
    sd.submit("f0", np.repeat(reads.signals[12:13], 32, axis=0),
              tenant="flood", t=0.0)
    sd.drain()
    assert all(sd.stream("g0").admitted)           # exempt despite budget
    assert sd.stream("g0").n_shed == 0
    assert sd.stream("f0").n_shed > 0              # sheddable tail pays
    assert sd.stream("a0").n_shed == 0


# --------------------------------------------------------------------------- #
# Full-queue eviction charges the over-budget tenant
# --------------------------------------------------------------------------- #
def test_eviction_prefers_over_budget_tenant(setup):
    """With the queue full, an in-budget arrival evicts an over-budget
    tenant's read at EQUAL rank (legacy eviction needs a strictly better
    rank, so the flooder would otherwise squat the queue)."""
    _, reads, _ = setup

    def run(budgets):
        sd = ServeDriver(_mapper(setup, "reference"), chunk=8, max_queue=4,
                         tenant_budgets=budgets)
        sd.submit("f0", np.repeat(reads.signals[12:13], 4, axis=0),
                  tenant="flood", t=0.0)
        n = sd.submit("a0", reads.signals[:2], tenant="acme", t=0.0)
        sd.drain()
        return sd, n

    legacy, n_legacy = run(None)
    fair, n_fair = run(BUDGETS)
    assert n_legacy == 0                          # equal rank: squatted out
    assert legacy.stream("a0").n_rejected == 2
    assert n_fair == 2                            # budgets evict the squat
    assert all(fair.stream("a0").admitted)
    assert fair.tenant_report()["flood"].n_shed == 2
    assert fair.tenant_report()["acme"].n_shed == 0


# --------------------------------------------------------------------------- #
# No budgets => today's driver; accounting plumbing
# --------------------------------------------------------------------------- #
def test_tenant_tags_alone_change_nothing(setup):
    """With no budgets configured, tenant tags are observation-only: the
    run is bit-identical (events, results, reports) to the untagged one."""
    _, reads, _ = setup

    def run(tag):
        sd = ServeDriver(_mapper(setup, "reference"), chunk=8, shed=True,
                         shed_window=2.0, cost_model="sim")
        sd.submit("a0", reads.signals[:8],
                  tenant="acme" if tag else None, t=0.0)
        sd.submit("f0", reads.signals[8:24],
                  tenant="flood" if tag else None, t=0.0)
        sd.drain()
        return sd

    tagged, plain = run(True), run(False)
    assert tagged.events == plain.events
    assert tagged.counters == plain.counters
    for sid in ("a0", "f0"):
        np.testing.assert_array_equal(tagged.results(sid).t_start,
                                      plain.results(sid).t_start)
        assert tagged.stream(sid).n_shed == plain.stream(sid).n_shed
    assert set(tagged.tenant_report()) == {"acme", "flood"}
    assert set(plain.tenant_report()) == {None}


def test_token_bucket_refills_over_virtual_clock(setup):
    """The bucket refills at ``rate`` per virtual-time unit up to
    ``burst`` — measured on the driver's own clock."""
    _, reads, _ = setup
    sd = ServeDriver(_mapper(setup, "reference"), chunk=8,
                     tenant_budgets=(TenantBudget("t", rate=2.0,
                                                  burst=4.0),))
    assert sd.tenant_tokens("t") == 4.0            # starts full
    sd.submit("s", reads.signals[:3], tenant="t", t=0.0)
    assert sd.tenant_tokens("t") == 1.0
    sd.submit("s", reads.signals[3:5], tenant="t", t=0.0)
    assert sd.tenant_tokens("t") == 0.0            # 1 spent + 1 over
    assert sd.tenant_report()["t"].n_over_budget == 1
    sd.clock = 1.5                                 # refill 2.0/unit
    assert sd.tenant_tokens("t") == 3.0
    sd.clock = 10.0
    assert sd.tenant_tokens("t") == 4.0            # capped at burst
    sd.drain()


def test_tenant_validation(setup):
    _, reads, _ = setup
    with pytest.raises(ValueError, match="name"):
        TenantBudget("", rate=1.0)
    with pytest.raises(ValueError, match="rate"):
        TenantBudget("t", rate=-1.0)
    with pytest.raises(ValueError, match="burst"):
        TenantBudget("t", rate=1.0, burst=0.0)
    sd = ServeDriver(_mapper(setup, "reference"), chunk=8)
    sd.submit("s", reads.signals[:1], tenant="acme")
    with pytest.raises(ValueError, match="re-bind"):
        sd.submit("s", reads.signals[1:2], tenant="emca")
    # rebinding to the SAME tenant (or omitting it) is fine
    sd.submit("s", reads.signals[1:2], tenant="acme")
    sd.submit("s", reads.signals[2:3])
    sd.drain()
    assert sd.tenant_report()["acme"].n_reads == 3


def test_serve_trace_tenant_column(setup):
    """serve_trace rows carry the tenant in column 6 and the report
    aggregates latencies per tenant."""
    _, reads, _ = setup
    sd = ServeDriver(_mapper(setup, "reference"), chunk=8,
                     tenant_budgets=BUDGETS)
    trace = [(0.0, "a0", reads.signals[:4], None, None, None, "acme"),
             (0.5, "f0", reads.signals[4:8], None, None, None, "flood")]
    sd.serve_trace(trace)
    tr = sd.tenant_report()
    assert tr["acme"].n_reads == 4 and tr["flood"].n_reads == 4
    assert math.isfinite(tr["acme"].p50_latency)
    assert math.isfinite(tr["flood"].mean_latency)
