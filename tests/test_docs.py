"""Doc/CLI drift guards.

The documentation layer (README.md, docs/, ROADMAP.md) cites paths,
scripts and serve_rsga flags by name.  These tests pin the docs to the
tree: scripts/check_docs.py must pass (every cited path resolves), its
checker must actually reject broken cites, and every ``--flag`` the
README's serving examples name must be a real serve_rsga argparse flag.
"""
import importlib.util
import pathlib
import re
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
CHECK_DOCS = ROOT / "scripts" / "check_docs.py"


def _load_check_docs():
    spec = importlib.util.spec_from_file_location("check_docs", CHECK_DOCS)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_docs_passes():
    # the CI docs gate: every path README/ROADMAP/docs cite must exist
    proc = subprocess.run([sys.executable, str(CHECK_DOCS)],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_check_docs_rejects_broken_cites():
    # the checker is not a rubber stamp: a missing path fails, the
    # shorthand/skip rules behave as documented
    m = _load_check_docs()
    names, segs = m.tree_names(), m.known_first_segments()
    assert m.path_like("core/tiered.py", segs)
    assert m.resolves("core/tiered.py", names)          # src/repro shorthand
    assert m.resolves("core/index.TieredIndex", names)  # module-attr cite
    assert not m.resolves("core/definitely_missing.py", names)
    assert not m.resolves("scripts/no_such_script.py", names)
    assert not m.path_like("Stage/Backend", segs)       # prose alternation
    assert not m.path_like("--tenants", segs)           # CLI flag
    assert not m.path_like("/root/somewhere", segs)     # absolute path


def _readme_fenced_blocks():
    text = (ROOT / "README.md").read_text(encoding="utf-8")
    return re.findall(r"```sh\n(.*?)```", text, flags=re.S)


def test_readme_quickstart_commands_exist():
    blocks = _readme_fenced_blocks()
    assert blocks, "README quickstart lost its fenced sh blocks"
    cited = [tok for b in blocks for tok in b.split()
             if tok.endswith((".py", ".sh", ".txt"))]
    assert cited, "README quickstart cites no scripts"
    for tok in cited:
        assert (ROOT / tok).exists(), f"README cites missing {tok}"


def test_readme_serving_flags_exist():
    # every --flag in README blocks that invoke serve_rsga must be a
    # real argparse option (catches flag renames breaking the docs)
    flags = {tok.split("=", 1)[0]
             for b in _readme_fenced_blocks() if "serve_rsga" in b
             for tok in b.replace("\\", " ").split()
             if tok.startswith("--")}
    assert flags, "README lost its serve_rsga example"
    from repro.launch import serve_rsga
    import contextlib
    import io
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf), pytest.raises(SystemExit) as e:
        serve_rsga.main(["--help"])
    assert e.value.code == 0
    helptext = buf.getvalue()
    for flag in sorted(flags):
        assert flag in helptext, f"README names unknown serve_rsga flag {flag}"
