"""ServeDriver: continuous-batching serving over the stage engine.

Bit-parity (the serving contract): for ANY admission interleaving, each
stream's per-read results equal ``Mapper.map_signals`` on that stream's
reads alone (early_term off) / ``realtime.map_realtime`` (early_term on),
and summed counters equal the one-batch totals — chunk composition is
invisible.  Plus routing/fairness under adversarial interleavings and
the bounded-queue backpressure contract.
"""
import math

import numpy as np
import pytest

from repro.core import Mapper, ServeDriver, driver
from repro.core.realtime import map_realtime

CHUNK = 8


def _interleave(n_reads, n_streams, seed):
    """A random adversarial interleaving: submission order + stream
    ownership both randomized."""
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, n_streams, n_reads)
    order = rng.permutation(n_reads)
    streams = {f"s{k}": [int(r) for r in order if owner[r] == k]
               for k in range(n_streams)}
    return order, streams


def _submit_interleaved(sd, signals, order, streams, **kw):
    pos = {sid: 0 for sid in streams}
    for r in order:
        sid = next(s for s, rows in streams.items() if int(r) in rows)
        sd.submit(sid, signals[int(r)], **kw)
        pos[sid] += 1


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stream_routing_parity(small_index, cfg_fixed, small_reads, seed):
    """>=3 random interleavings: per-stream results == mapping that stream
    alone; total counters == one concatenated batch job."""
    mapper = Mapper(small_index, cfg_fixed)
    order, streams = _interleave(16, 3, seed)
    sd = ServeDriver(mapper, chunk=CHUNK)
    _submit_interleaved(sd, small_reads.signals, order, streams)
    sd.drain()

    for sid, rows in streams.items():
        if not rows:
            continue
        want = mapper.map_signals(small_reads.signals[np.asarray(rows)],
                                  chunk=CHUNK)
        got = sd.results(sid)
        np.testing.assert_array_equal(got.t_start, np.asarray(want.t_start))
        np.testing.assert_array_equal(got.score, np.asarray(want.score))
        np.testing.assert_array_equal(got.mapped, np.asarray(want.mapped))
        np.testing.assert_array_equal(got.n_events,
                                      np.asarray(want.n_events))
    flat = [r for rows in streams.values() for r in rows]
    want_all = mapper.map_signals(small_reads.signals[np.asarray(flat)],
                                  chunk=CHUNK)
    assert sd.counters == {k: int(v) for k, v in want_all.counters.items()}


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_early_termination_parity(small_index, cfg_fixed, small_reads, seed):
    """ET mode equals batch map_realtime bit for bit — decisions, samples
    consumed and ladder stage — for any interleaving."""
    mapper = Mapper(small_index, cfg_fixed)
    rt = map_realtime(small_reads.signals, small_index, cfg_fixed,
                      chunk=CHUNK)
    order, streams = _interleave(16, 3, seed)
    sd = ServeDriver(mapper, chunk=CHUNK, early_term=True)
    _submit_interleaved(sd, small_reads.signals, order, streams)
    sd.drain()
    for sid, rows in streams.items():
        if not rows:
            continue
        sel = np.asarray(rows)
        got = sd.results(sid)
        st = sd.stream(sid)
        np.testing.assert_array_equal(got.t_start, rt.t_start[sel])
        np.testing.assert_array_equal(got.score, rt.score[sel])
        np.testing.assert_array_equal(got.mapped, rt.mapped[sel])
        np.testing.assert_array_equal(np.asarray(st.samples_used),
                                      rt.samples_used[sel])
        np.testing.assert_array_equal(np.asarray(st.stage_of),
                                      rt.stage_of[sel])


def test_early_termination_frees_slots(small_index, cfg_fixed, small_reads):
    """The Read Until win carries over to serving: mappable reads resolve
    at short prefixes, so the ET driver runs FEWER full-length chunk rows
    than the non-ET driver."""
    mapper = Mapper(small_index, cfg_fixed)
    sd = ServeDriver(mapper, chunk=CHUNK, early_term=True)
    sd.submit("s0", small_reads.signals)
    sd.drain()
    st = sd.stream("s0")
    early = np.asarray(st.samples_used) < cfg_fixed.signal_len
    assert early.mean() > 0.5
    # early-resolved reads never reached the final ladder stage
    assert max(np.asarray(st.stage_of)[early]) < len(sd.stages) - 1


def test_priority_ordering(small_index, cfg_fixed, small_reads):
    """Higher-priority reads are packed first: with both streams queued
    before the drain, every high-priority read finishes (virtual clock)
    before any low-priority read."""
    mapper = Mapper(small_index, cfg_fixed)
    sd = ServeDriver(mapper, chunk=4)
    sd.submit("low", small_reads.signals[:8], priority=0)
    sd.submit("high", small_reads.signals[8:16], priority=5)
    sd.drain()
    lat_low = np.asarray(sd.stream("low").latency)
    lat_high = np.asarray(sd.stream("high").latency)
    assert lat_high.max() < lat_low.min()
    # routing still exact under preemption
    want = mapper.map_signals(small_reads.signals[8:16], chunk=4)
    np.testing.assert_array_equal(sd.results("high").t_start,
                                  np.asarray(want.t_start))


def test_deadline_ordering(small_index, cfg_fixed, small_reads):
    """Equal priority: earlier deadline is served first (EDF)."""
    mapper = Mapper(small_index, cfg_fixed)
    sd = ServeDriver(mapper, chunk=4)
    sd.submit("late", small_reads.signals[:8], deadline=100.0)
    sd.submit("soon", small_reads.signals[8:16], deadline=1.0)
    sd.drain()
    assert (np.asarray(sd.stream("soon").latency).max()
            < np.asarray(sd.stream("late").latency).min())


def test_fifo_fairness_no_starvation(small_index, cfg_fixed, small_reads):
    """Equal priority + equal deadline degrade to FIFO by admission order:
    round-robin interleaved streams finish interleaved (neither stream
    starves), and completion follows admission order chunk by chunk."""
    mapper = Mapper(small_index, cfg_fixed)
    sd = ServeDriver(mapper, chunk=4)
    for i in range(8):
        sd.submit(f"s{i % 2}", small_reads.signals[i])
    sd.drain()
    l0 = np.asarray(sd.stream("s0").latency)
    l1 = np.asarray(sd.stream("s1").latency)
    # reads 0..7 packed in admission order into chunks of 4: the first
    # chunk holds two reads of each stream — so both streams finish their
    # first two reads at the same clock
    np.testing.assert_allclose(sorted(l0)[:2], sorted(l1)[:2])


def test_backpressure_bounded_queue(small_index, cfg_fixed, small_reads):
    """Overload: the ready queue is bounded; excess reads are rejected,
    higher-priority arrivals evict strictly-worse queued reads, and the
    drained results still route exactly for every admitted read."""
    mapper = Mapper(small_index, cfg_fixed)
    sd = ServeDriver(mapper, chunk=4, max_queue=4)
    admitted = sd.submit("bulk", small_reads.signals[:10], priority=0)
    assert admitted == 4
    assert sd.stream("bulk").n_rejected == 6
    # a higher-priority read evicts a queued priority-0 read
    assert sd.submit("vip", small_reads.signals[10], priority=3) == 1
    assert sd.stream("bulk").n_rejected == 7
    # an equal-priority read does NOT evict (no churn at same rank)
    assert sd.submit("bulk2", small_reads.signals[11], priority=0) == 0
    assert sd.stream("bulk2").n_rejected == 1
    sd.drain()
    bulk = sd.stream("bulk")
    adm = np.asarray(bulk.admitted)
    assert adm.sum() == 3                      # 4 admitted - 1 evicted
    # rejected reads read as unmapped zeros and never ran
    res = sd.results("bulk")
    assert not res.mapped[~adm].any()
    assert np.isinf(np.asarray(bulk.latency)[~adm]).all()
    # admitted reads still bit-exact vs solo mapping
    want = mapper.map_signals(small_reads.signals[:10][adm], chunk=4)
    np.testing.assert_array_equal(res.t_start[adm], np.asarray(want.t_start))
    np.testing.assert_array_equal(res.mapped[adm], np.asarray(want.mapped))


def test_drop_expired_deadlines(small_index, cfg_fixed, small_reads):
    mapper = Mapper(small_index, cfg_fixed)
    sd = ServeDriver(mapper, chunk=4, drop_expired=True)
    sd.submit("s", small_reads.signals[:4], deadline=math.inf)
    sd.clock = 10.0
    sd.submit("x", small_reads.signals[4:8], deadline=5.0)  # already past
    sd.drain()
    assert sd.stream("x").n_rejected == 4
    assert sd.stream("s").n_rejected == 0
    assert np.asarray(sd.stream("s").samples_used).min() > 0


def test_serve_trace_report(small_index, cfg_fixed, small_reads):
    """Trace-driven serving: arrivals admitted at their virtual times,
    per-stream p50/p99 reported, makespan covers the last arrival."""
    mapper = Mapper(small_index, cfg_fixed)
    sd = ServeDriver(mapper, chunk=4)
    trace = [(float(k), f"s{k % 2}", small_reads.signals[k])
             for k in range(8)]
    reports = sd.serve_trace(trace)
    assert set(reports) == {"s0", "s1"}
    for r in reports.values():
        assert r.n_reads == 4 and r.n_rejected == 0
        assert r.p99_latency >= r.p50_latency > 0
    assert sd.clock >= 7.0
    # late-arriving reads still route exactly
    want = mapper.map_signals(small_reads.signals[0:8:2], chunk=4)
    np.testing.assert_array_equal(sd.results("s0").t_start,
                                  np.asarray(want.t_start))


def test_mapper_serve_convenience(small_index, cfg_fixed, small_reads):
    sd = Mapper(small_index, cfg_fixed).serve(chunk=CHUNK)
    assert isinstance(sd, ServeDriver)
    sd.submit("s", small_reads.signals[:4])
    sd.drain()
    assert sd.stream("s").n_done == 4


def test_submit_shape_guard(small_index, cfg_fixed):
    sd = ServeDriver(Mapper(small_index, cfg_fixed), chunk=4)
    with pytest.raises(ValueError, match="signals"):
        sd.submit("s", np.zeros((2, 3), np.float32))


def test_prefix_ladder_guard(small_index, cfg_fixed):
    with pytest.raises(ValueError, match="signal_len"):
        ServeDriver(Mapper(small_index, cfg_fixed), early_term=True,
                    prefix_stages=(256, 512))


def test_partial_chunks_match_driver_padding(small_index, cfg_fixed,
                                             small_reads):
    """A lone 3-read stream forces a padded partial chunk; results match
    the unified driver's own padded chunking (pad_rows + n_valid)."""
    mapper = Mapper(small_index, cfg_fixed)
    sd = ServeDriver(mapper, chunk=CHUNK)
    sd.submit("s", small_reads.signals[:3])
    sd.drain()
    want = driver.collect(driver.stream_map(
        mapper.chunk_fn(), driver.array_chunks(small_reads.signals[:3],
                                               CHUNK)))
    got = sd.results("s")
    np.testing.assert_array_equal(got.t_start, want.t_start)
    np.testing.assert_array_equal(got.mapped, want.mapped)
    assert sd.counters == want.counters
    assert sd.n_pad_rows == CHUNK - 3
