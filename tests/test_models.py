"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model as M

BS, SEQ = 2, 64


def _batch(cfg, rng):
    tokens = jax.random.randint(rng, (BS, SEQ), 0, cfg.vocab)
    batch = dict(tokens=tokens, labels=jnp.roll(tokens, -1, axis=1))
    if cfg.n_ctx_tokens:
        batch["ctx"] = jax.random.normal(
            rng, (BS, cfg.n_ctx_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_grad_smoke(arch):
    cfg = get_config(arch).reduced()
    rng = jax.random.key(0)
    params = M.init_params(cfg, rng)
    batch = _batch(cfg, jax.random.key(1))

    logits, _, aux = M.forward(params, batch["tokens"], cfg,
                               ctx=batch.get("ctx"))
    assert logits.shape == (BS, SEQ, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN/inf logits"

    loss, metrics = M.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_consistency(arch):
    """Prefill(S) then decode(1) must equal forward(S+1) at the last token."""
    cfg = get_config(arch).reduced()
    if cfg.family == "audio":
        ctxlen = cfg.n_ctx_tokens
    rng = jax.random.key(2)
    params = M.init_params(cfg, rng)
    S = 16
    tokens = jax.random.randint(jax.random.key(3), (1, S + 1), 0, cfg.vocab)
    ctx = (jax.random.normal(jax.random.key(4),
                             (1, cfg.n_ctx_tokens, cfg.d_model))
           if cfg.n_ctx_tokens else None)

    # reference: full forward over S+1 tokens
    logits_full, _, _ = M.forward(params, tokens, cfg, ctx=ctx)
    want = np.asarray(logits_full[:, -1, :])

    # prefill S, then one decode step
    cache = M.init_cache(cfg, 1, S + 8)
    _, cache = M.prefill(params, tokens[:, :S], cfg, cache=cache, ctx=ctx)
    got, _ = M.decode_step(params, tokens[:, S:S + 1], cfg, cache=cache,
                           cache_index=S, ctx=ctx)
    got = np.asarray(got)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_param_counts_full_configs():
    """Full-config parameter counts are in the advertised ballpark."""
    expect = {
        "h2o-danube-1.8b": (1.4e9, 2.4e9),
        "llama3-405b": (3.7e11, 4.4e11),
        # granite-20b-code uses a 2-matrix GELU MLP; our uniform SwiGLU
        # (3 matrices) at the assigned d_ff inflates the total ~1.3x.
        "granite-20b": (1.6e10, 3.0e10),
        "qwen3-4b": (3.0e9, 5.5e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "llama4-maverick-400b-a17b": (3.0e11, 4.8e11),
        "qwen3-moe-30b-a3b": (2.4e10, 3.6e10),
        "llama-3.2-vision-11b": (8e9, 1.3e10),
        "whisper-medium": (5e8, 1.1e9),
        "mamba2-780m": (6e8, 1.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = M.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:.3e} params outside [{lo:.1e},{hi:.1e}]"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    total = M.param_count(cfg)
    active = M.active_param_count(cfg)
    assert active < 0.2 * total          # a3b: ~3B of ~30B


@pytest.mark.parametrize("arch", ["qwen3-4b", "h2o-danube-1.8b"])
def test_int8_kv_cache_decode(arch):
    """int8 KV cache (quantize-on-write, dequantize-per-chunk) stays within
    ~1% of the bf16-cache logits — MARS arithmetic conversion for serving."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(2))
    S = 16
    tokens = jax.random.randint(jax.random.key(3), (1, S + 1), 0, cfg.vocab)
    logits_full, _, _ = M.forward(params, tokens, cfg)
    want = np.asarray(logits_full[:, -1, :])
    cache = M.init_cache(cfg, 1, S + 8, kv_dtype=jnp.int8)
    _, cache = M.prefill(params, tokens[:, :S], cfg, cache=cache)
    got, _ = M.decode_step(params, tokens[:, S:S + 1], cfg, cache=cache,
                           cache_index=S)
    err = np.abs(np.asarray(got) - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 0.08, err
