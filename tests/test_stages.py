"""Stage-graph engine: registry semantics, backend parity, sharded parity."""
import inspect
import os
import pathlib
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MarsConfig, build_index, map_chunk, stages
from repro.core.index import index_arrays
from repro.signal import simulate

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
    ref = simulate.make_reference(5_000, seed=5)
    reads = simulate.sample_reads(ref, 4, signal_len=cfg.signal_len, seed=6)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    return cfg, jnp.asarray(reads.signals), arrays


# --------------------------------------------------------------------------- #
# Registry / plan resolution
# --------------------------------------------------------------------------- #
def test_reference_plan_covers_every_stage():
    plan = stages.resolve_plan(MarsConfig(), stages.REFERENCE)
    assert tuple(s for s, _ in plan) == stages.STAGE_ORDER
    assert all(b == stages.REFERENCE for _, b in plan)


def test_pallas_plan_uses_registered_kernels():
    plan = dict(stages.resolve_plan(MarsConfig().with_mode("ms_fixed"),
                                    stages.PALLAS))
    assert plan["detect"] == stages.PALLAS
    assert plan["query"] == stages.PALLAS
    assert plan["sort"] == stages.PALLAS
    assert plan["dp"] == stages.PALLAS
    # stages without an accelerated backend fall back to reference
    assert plan["quantize"] == stages.REFERENCE
    assert plan["finalize"] == stages.REFERENCE


def test_unsupported_backend_falls_back():
    """The fixed-point event-detect kernel cannot serve float configs."""
    plan = dict(stages.resolve_plan(MarsConfig().with_mode("rh2"),
                                    stages.PALLAS))
    assert plan["detect"] == stages.REFERENCE
    assert plan["query"] == stages.PALLAS   # config-independent kernels stay


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        stages.resolve_plan(MarsConfig(), "bogus")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        stages.register_backend("vote", stages.REFERENCE, lambda s, c, i: s)
    with pytest.raises(ValueError):
        stages.register_backend("no_such_stage", "x", lambda s, c, i: s)


def test_map_chunk_accepts_no_per_stage_callables():
    """Acceptance criterion: backend selection flows only through the
    registry/config — no gather/sorter/dp/detector kwargs."""
    params = set(inspect.signature(map_chunk.__wrapped__).parameters)
    assert params.isdisjoint({"gather", "sorter", "dp", "detector"})
    assert {"plan", "use_kernels", "n_valid"} <= params


# --------------------------------------------------------------------------- #
# Backend parity
# --------------------------------------------------------------------------- #
def test_counter_schema_uniform(tiny_setup):
    cfg, sig, arrays = tiny_setup
    for use_kernels in (False, True):
        out = map_chunk(sig, arrays, cfg, use_kernels)
        assert set(out.counters) == set(stages.CHUNK_COUNTER_SCHEMA)


@pytest.mark.parametrize("stage", ["detect", "query", "sort", "dp"])
def test_single_stage_pallas_parity(tiny_setup, stage):
    """Each accelerated backend, swapped in alone, reproduces the full
    reference pipeline output on the same inputs."""
    cfg, sig, arrays = tiny_setup
    ref_plan = stages.resolve_plan(cfg, stages.REFERENCE)
    mixed = tuple((s, stages.PALLAS if s == stage else b)
                  for s, b in ref_plan)
    out_ref = map_chunk(sig, arrays, cfg, plan=ref_plan)
    out_mix = map_chunk(sig, arrays, cfg, plan=mixed)
    np.testing.assert_array_equal(np.asarray(out_ref.t_start),
                                  np.asarray(out_mix.t_start))
    np.testing.assert_array_equal(np.asarray(out_ref.mapped),
                                  np.asarray(out_mix.mapped))
    np.testing.assert_allclose(np.asarray(out_ref.score),
                               np.asarray(out_mix.score), rtol=1e-5)
    for k in stages.CHUNK_COUNTER_SCHEMA:
        assert int(out_ref.counters[k]) == int(out_mix.counters[k]), k


def test_padded_rows_do_not_inflate_counters(tiny_setup):
    cfg, sig, arrays = tiny_setup
    out_full = map_chunk(sig, arrays, cfg)
    out_masked = map_chunk(sig, arrays, cfg, n_valid=2)
    assert int(out_masked.counters["n_reads"]) == 2
    assert int(out_masked.counters["n_samples"]) == 2 * sig.shape[1]
    for k in stages.COUNTER_SCHEMA:
        assert int(out_masked.counters[k]) <= int(out_full.counters[k]), k
    # pad rows never report as mapped
    assert not np.asarray(out_masked.mapped)[2:].any()


# --------------------------------------------------------------------------- #
# Sharded map_chunk == single-device map_chunk (8 virtual devices)
# --------------------------------------------------------------------------- #
SHARD_SCRIPT = """
import numpy as np, jax.numpy as jnp
from repro.core import MarsConfig, build_index, map_chunk, map_chunk_sharded
from repro.core.index import index_arrays
from repro.launch.mesh import make_mesh
from repro.signal import simulate

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
ref = simulate.make_reference(20_000, seed=3)
reads = simulate.sample_reads(ref, 16, signal_len=cfg.signal_len, seed=4,
                              junk_frac=0.1)
idx = build_index(ref.events_concat, ref.n_events, cfg)
arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
sig = jnp.asarray(reads.signals)
for n_valid in (None, 13):
    a = map_chunk(sig, arrays, cfg, n_valid=n_valid)
    b = map_chunk_sharded(sig, arrays, cfg, mesh, n_valid=n_valid)
    assert np.array_equal(np.asarray(a.t_start), np.asarray(b.t_start))
    assert np.array_equal(np.asarray(a.score), np.asarray(b.score))
    assert np.array_equal(np.asarray(a.mapped), np.asarray(b.mapped))
    assert np.array_equal(np.asarray(a.n_events), np.asarray(b.n_events))
    ca = {k: int(v) for k, v in a.counters.items()}
    cb = {k: int(v) for k, v in b.counters.items()}
    assert ca == cb, (n_valid, ca, cb)
print("ok")
"""


def test_sharded_map_chunk_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ok" in r.stdout
