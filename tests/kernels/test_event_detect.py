"""event_detect kernel vs the core pipeline's pure-jnp path."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import MarsConfig
from repro.kernels.event_detect import ops, ref
from repro.signal import simulate


@pytest.mark.parametrize("signal_len,max_events", [(512, 96), (1024, 192),
                                                   (2048, 256)])
def test_event_detect_shapes(signal_len, max_events, small_ref):
    cfg = MarsConfig(signal_len=signal_len,
                     max_events=max_events).with_mode("ms_fixed")
    reads = simulate.sample_reads(small_ref, 4, signal_len=signal_len, seed=4)
    sig = jnp.asarray(reads.signals)
    m_k, n_k = ops.event_detect(sig, cfg)
    m_r, n_r = ref.event_detect_ref(sig, cfg)
    np.testing.assert_array_equal(np.asarray(n_k), np.asarray(n_r))
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("tau,w,peak_r", [(2.0, 3, 2), (2.5, 4, 3),
                                          (4.0, 6, 4)])
def test_event_detect_params(tau, w, peak_r, small_ref):
    cfg = MarsConfig(tstat_threshold=tau, tstat_window=w,
                     peak_window=peak_r).with_mode("ms_fixed")
    reads = simulate.sample_reads(small_ref, 3, signal_len=cfg.signal_len,
                                  seed=int(tau * 10))
    sig = jnp.asarray(reads.signals)
    m_k, n_k = ops.event_detect(sig, cfg)
    m_r, n_r = ref.event_detect_ref(sig, cfg)
    np.testing.assert_array_equal(np.asarray(n_k), np.asarray(n_r))
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r),
                               rtol=1e-6, atol=1e-6)


def test_event_detect_junk_signal():
    """Pure-noise input must not crash and must agree with the oracle."""
    cfg = MarsConfig().with_mode("ms_fixed")
    rng = np.random.default_rng(0)
    sig = jnp.asarray(rng.normal(100, 15, size=(2, cfg.signal_len))
                      .astype(np.float32))
    m_k, n_k = ops.event_detect(sig, cfg)
    m_r, n_r = ref.event_detect_ref(sig, cfg)
    np.testing.assert_array_equal(np.asarray(n_k), np.asarray(n_r))
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r),
                               rtol=1e-6, atol=1e-6)
