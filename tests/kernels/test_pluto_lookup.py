"""pluto_lookup kernel vs pure-jnp oracle: shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pluto_lookup import ops
from repro.kernels.pluto_lookup import ref


@pytest.mark.parametrize("n,q", [(16, 5), (100, 37), (512, 256),
                                 (1000, 513), (2048, 64), (4096, 1)])
def test_lookup_int32_sweep(n, q):
    rng = np.random.default_rng(n * 1000 + q)
    table = rng.integers(-2**31, 2**31, size=n, dtype=np.int64).astype(np.int32)
    idx = rng.integers(0, n, size=q).astype(np.int32)
    out = ops.lookup(jnp.asarray(table), jnp.asarray(idx))
    exp = ref.lookup_ref(jnp.asarray(table), jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.int16])
def test_lookup_dtypes(dtype):
    rng = np.random.default_rng(0)
    info = np.iinfo(dtype)
    table = rng.integers(info.min, int(info.max) + 1, size=300,
                         dtype=np.int64).astype(dtype)
    idx = rng.integers(0, 300, size=77).astype(np.int32)
    out = ops.lookup(jnp.asarray(table), jnp.asarray(idx))
    exp = ref.lookup_ref(jnp.asarray(table), jnp.asarray(idx))
    assert out.dtype == exp.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_lookup_2d_indices_and_clip():
    rng = np.random.default_rng(1)
    table = rng.integers(0, 1000, size=50).astype(np.int32)
    idx = rng.integers(-10, 90, size=(4, 33)).astype(np.int32)  # out of range
    out = ops.lookup(jnp.asarray(table), jnp.asarray(idx))
    exp = ref.lookup_ref(jnp.asarray(table), jnp.asarray(idx))
    assert out.shape == (4, 33)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("n,q,w", [(16, 5, 2), (100, 37, 2), (700, 513, 2),
                                   (2048, 64, 3)])
def test_lookup_packed_rows(n, q, w):
    """The packed-row sweep returns every word of each queried row — one
    table sweep, full int32 range, clip semantics, any idx shape."""
    rng = np.random.default_rng(n + q + w)
    table = rng.integers(-2**31, 2**31, size=(w, n),
                         dtype=np.int64).astype(np.int32)
    idx = rng.integers(-4, n + 4, size=(q,)).astype(np.int32)
    out = ops.lookup(jnp.asarray(table), jnp.asarray(idx))
    exp = ref.lookup_ref(jnp.asarray(table), jnp.asarray(idx))
    assert out.shape == (w, q)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    # multi-dim idx keeps the row axis leading
    idx2 = rng.integers(0, n, size=(3, 4, 5)).astype(np.int32)
    out2 = ops.lookup(jnp.asarray(table), jnp.asarray(idx2))
    exp2 = ref.lookup_ref(jnp.asarray(table), jnp.asarray(idx2))
    assert out2.shape == (w, 3, 4, 5)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(exp2))
