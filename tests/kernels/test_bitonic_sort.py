"""bitonic_sort kernel vs oracle: shape sweeps + property test."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # deterministic fallback
    from repro.testing import given, settings
    from repro.testing import strategies as st

from repro.kernels.bitonic_sort import ops, ref


@pytest.mark.parametrize("b,l", [(1, 128), (4, 100), (2, 1000), (3, 4096),
                                 (1, 7), (8, 129), (1, 8192)])
def test_sort_sweep(b, l):
    rng = np.random.default_rng(b * 100 + l)
    keys = rng.integers(-2**30, 2**31 - 2, size=(b, l)).astype(np.int32)
    out = ops.sort_batch(jnp.asarray(keys))
    exp = ref.sort_ref(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_sort_vmap():
    rng = np.random.default_rng(9)
    keys = rng.integers(0, 2**31 - 2, size=(5, 512)).astype(np.int32)
    out = jax.vmap(ops.sort1d)(jnp.asarray(keys))
    exp = ref.sort_ref(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(-2**31, 2**31 - 2), min_size=1, max_size=300))
def test_sort_is_ordered_permutation(xs):
    keys = jnp.asarray(np.array(xs, np.int32))
    out = np.asarray(ops.sort1d(keys))
    assert (np.diff(out.astype(np.int64)) >= 0).all()
    assert sorted(xs) == out.tolist()


def test_duplicates_and_sentinels():
    keys = jnp.asarray(np.array([5, 5, 5, 0x7FFFFFFF, -1, 0x7FFFFFFF, 5],
                                np.int32))
    out = np.asarray(ops.sort1d(keys))
    exp = np.sort(np.asarray(keys))
    np.testing.assert_array_equal(out, exp)
