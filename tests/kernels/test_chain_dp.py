"""chain_dp kernel vs the core pipeline's scan implementation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import MarsConfig
from repro.kernels.chain_dp import ops, ref


def _anchors(rng, R, A, t_range=4000, q_range=180, p_valid=0.8):
    t = np.sort(rng.integers(0, t_range, size=(R, A))).astype(np.int32)
    q = rng.integers(0, q_range, size=(R, A)).astype(np.int32)
    order = np.lexsort((q, t), axis=-1)
    t = np.take_along_axis(t, order, -1)
    q = np.take_along_axis(q, order, -1)
    v = rng.random((R, A)) < p_valid
    return jnp.asarray(q), jnp.asarray(t), jnp.asarray(v)


@pytest.mark.parametrize("R,A,B", [(2, 64, 8), (4, 128, 16), (1, 512, 32),
                                   (3, 256, 64)])
def test_chain_dp_sweep(R, A, B):
    cfg = MarsConfig(max_anchors=A, chain_band=B)
    q, t, v = _anchors(np.random.default_rng(R * A + B), R, A)
    f_k, d_k = ops.chain_dp(q, t, v, cfg)
    f_r, d_r = ref.chain_dp_ref(q, t, v, cfg)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))


def test_chain_dp_colinear_run_scores():
    """A perfectly colinear run of anchors should chain to ~run length."""
    cfg = MarsConfig(max_anchors=64, chain_band=16)
    A = 64
    t = (np.arange(A) * 3).astype(np.int32)     # dt == dq == 3: no gap cost
    q = (np.arange(A) * 3).astype(np.int32)
    v = np.ones(A, bool)
    f_k, _ = ops.chain_dp(jnp.asarray(q)[None], jnp.asarray(t)[None],
                          jnp.asarray(v)[None], cfg)
    expected_last = cfg.anchor_score * A - (A - 1) * cfg.skip_cost * 3
    assert abs(float(f_k[0, -1]) - expected_last) < 1e-3


def test_chain_dp_all_invalid():
    cfg = MarsConfig(max_anchors=32, chain_band=8)
    q, t, v = _anchors(np.random.default_rng(0), 1, 32, p_valid=0.0)
    f_k, d_k = ops.chain_dp(q, t, v, cfg)
    f_r, d_r = ref.chain_dp_ref(q, t, v, cfg)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r), rtol=1e-6)
    assert (np.asarray(f_k) < -1e8).all()
