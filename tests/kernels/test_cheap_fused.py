"""Fused cheap-phase mega-kernel vs the per-stage programs.

The contract under test: for every supported config the ONE-launch
mega-kernel (detect -> quantize -> seed -> query -> vote, intermediates
kernel-resident, index planes DMA-streamed tile by tile) is bit-identical
to ``pipeline.cheap_phase(..., use_fused=False)`` (the per-stage batch
program) and to ``pipeline.cheap_phase_vmap`` (the per-read reference
ladder) — arrays AND every counter.  Unsupported configs must resolve to
``prims.fused is None`` and fall through the ladder unchanged.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MarsConfig, build_index, pipeline, stages
from repro.core.index import index_arrays
from repro.kernels.cheap_fused import FusedTile, cheap_fused
from repro.kernels.cheap_fused import ref as fused_ref
from repro.signal import simulate


@pytest.fixture(scope="module")
def setup():
    cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
    ref = simulate.make_reference(6_000, seed=9)
    reads = simulate.sample_reads(ref, 6, signal_len=cfg.signal_len,
                                  seed=10, junk_frac=0.3)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    return cfg, jnp.asarray(reads.signals), index_arrays(idx)


def _assert_cheap_equal(got, want):
    gq, gt, gv, gc = got
    wq, wt, wv, wc = want
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gq), np.asarray(wq))
    np.testing.assert_array_equal(np.asarray(gt), np.asarray(wt))
    assert set(gc) == set(wc)
    for k in wc:
        np.testing.assert_array_equal(np.asarray(gc[k]), np.asarray(wc[k]),
                                      err_msg=f"counter {k!r}")


def test_fused_engages_on_supported_plan(setup):
    """A pallas plan on the fixed/early-quant config must resolve the
    whole-phase kernel, not just per-stage primitives."""
    cfg, _, _ = setup
    plan = stages.resolve_plan(cfg, stages.PALLAS)
    assert stages.fused_cheap_backend(plan, cfg) is not None
    prims = stages.cheap_primitives(plan, cfg)
    assert prims is not None and prims.fused is not None


def test_fused_matches_per_stage_and_vmap(setup):
    """cheap_phase (fused) == cheap_phase(use_fused=False) ==
    cheap_phase_vmap, arrays and all counters."""
    cfg, signals, arrays = setup
    plan = stages.resolve_plan(cfg, stages.PALLAS)
    fused = pipeline.cheap_phase(signals, arrays, cfg, plan)
    per_stage = pipeline.cheap_phase(signals, arrays, cfg, plan,
                                     use_fused=False)
    vmapped = pipeline.cheap_phase_vmap(signals, arrays, cfg, plan)
    _assert_cheap_equal(fused, per_stage)
    # the vmap ladder carries the same uniform counters; compare on the
    # intersection (batch programs may add debug counters)
    fq, ft, fv, fc = fused
    vq, vt, vv, vc = vmapped
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(vv))
    np.testing.assert_array_equal(np.asarray(fq), np.asarray(vq))
    np.testing.assert_array_equal(np.asarray(ft), np.asarray(vt))
    for k in set(fc) & set(vc):
        np.testing.assert_array_equal(np.asarray(fc[k]), np.asarray(vc[k]),
                                      err_msg=f"counter {k!r}")


@pytest.mark.parametrize("n_reads,tile", [
    (1, FusedTile(r_blk=1, bt=512)),
    (3, FusedTile(r_blk=2, bt=128)),    # row padding: 3 reads, blocks of 2
    (5, FusedTile(r_blk=3, bt=64)),     # 5 reads, blocks of 3
])
def test_fused_odd_shapes_and_tiles(setup, n_reads, tile):
    """Read counts that do not divide the row block + small DMA tiles that
    force many partial index sweeps must stay bit-exact."""
    cfg, signals, arrays = setup
    got = cheap_fused(signals[:n_reads], arrays, cfg, tile=tile)
    want = fused_ref.cheap_fused_ref(signals[:n_reads], arrays, cfg)
    gq, gt, gv, gc = got
    wq, wt, wv, wc = want
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gq), np.asarray(wq))
    np.testing.assert_array_equal(np.asarray(gt), np.asarray(wt))
    for k in set(gc) & set(wc):
        np.testing.assert_array_equal(np.asarray(gc[k]), np.asarray(wc[k]),
                                      err_msg=f"counter {k!r}")


def test_fused_index_tile_boundary_probes(setup):
    """A bucket whose entry range straddles a DMA tile edge must gather the
    same entries as the untiled per-stage gather.  bt=32 on a 2^12-bucket /
    multi-thousand-entry index guarantees straddling probes."""
    cfg, signals, arrays = setup
    plan = stages.resolve_plan(cfg, stages.PALLAS)
    got = cheap_fused(signals, arrays, cfg, tile=FusedTile(r_blk=2, bt=32))
    want = pipeline.cheap_phase(signals, arrays, cfg, plan, use_fused=False)
    _assert_cheap_equal(got, want)


def test_supports_gate_rejects_tstat_overflow():
    """tstat_window=13 overflows the int32 fixed-point boundary test — the
    fused kernel's supports gate must reject it (the reference path fails
    fast at trace time for the same reason, so no ladder run here)."""
    cfg = MarsConfig(hash_bits=12, tstat_window=13).with_mode("ms_fixed")
    plan = stages.resolve_plan(cfg, stages.PALLAS)
    assert stages.fused_cheap_backend(plan, cfg) is None
    prims = stages.cheap_primitives(plan, cfg)
    assert prims is None or prims.fused is None


@pytest.mark.parametrize("mode", ["ms_float", "rh2"])
def test_supports_gate_falls_back(mode):
    """Configs the kernel cannot serve bit-exactly must resolve to no fused
    backend, and the ladder must still agree with the vmap reference."""
    cfg = MarsConfig(hash_bits=12).with_mode(mode)
    plan = stages.resolve_plan(cfg, stages.PALLAS)
    assert stages.fused_cheap_backend(plan, cfg) is None
    prims = stages.cheap_primitives(plan, cfg)
    assert prims is None or prims.fused is None
    ref = simulate.make_reference(4_000, seed=11)
    reads = simulate.sample_reads(ref, 3, signal_len=cfg.signal_len,
                                  seed=12, junk_frac=0.3)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    arrays = index_arrays(idx)
    signals = jnp.asarray(reads.signals)
    got = pipeline.cheap_phase(signals, arrays, cfg, plan)   # use_fused=True
    want = pipeline.cheap_phase_vmap(signals, arrays, cfg, plan)
    gq, gt, gv, _ = got
    wq, wt, wv, _ = want
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gq), np.asarray(wq))
    np.testing.assert_array_equal(np.asarray(gt), np.asarray(wt))


def test_tiered_plan_never_fuses(setup):
    """The tiered query consumes the hot-tile index view, which the fused
    kernel cannot stream — the plan must not resolve a fused backend."""
    cfg, _, _ = setup
    plan = stages.resolve_plan(cfg, "tiered")
    assert stages.fused_cheap_backend(plan, cfg) is None


def test_minimizer_radius_supported(setup):
    """Minimizer winnowing changes the seed plane; the fused kernel
    replicates it (not gated out)."""
    cfg, signals, arrays0 = setup
    cfg2 = cfg.replace(minimizer_radius=2)
    ref = simulate.make_reference(6_000, seed=9)
    idx = build_index(ref.events_concat, ref.n_events, cfg2)
    arrays = index_arrays(idx)
    got = cheap_fused(signals, arrays, cfg2)
    want = fused_ref.cheap_fused_ref(signals, arrays, cfg2)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
