"""Quantization property tests (hypothesis, with deterministic fallback)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # deterministic fallback
    from repro.testing import given, settings
    from repro.testing import strategies as st

from repro.core import quantization as Q
from repro.core.config import MarsConfig

CFG = MarsConfig()


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-5, 5, allow_nan=False), min_size=8, max_size=64))
def test_symbols_in_range(vals):
    e = jnp.asarray(np.array(vals, np.float32))
    v = jnp.ones(e.shape, bool)
    for fixed in (False, True):
        cfg = CFG.replace(fixed_point=fixed)
        sym = np.asarray(Q.quantize_events(e, v, cfg))
        assert ((sym >= 0) & (sym < cfg.quant_levels)).all()


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_monotone_in_input(seed):
    """Larger event values never get smaller symbols (same read stats)."""
    rng = np.random.default_rng(seed)
    e = np.sort(rng.normal(0, 1, 32)).astype(np.float32)
    v = jnp.ones(32, bool)
    sym = np.asarray(Q.quantize_events(jnp.asarray(e), v, CFG))
    assert (np.diff(sym) >= 0).all()


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_fixed_matches_float_mostly(seed):
    rng = np.random.default_rng(seed)
    e = rng.normal(0, 1, 48).astype(np.float32)
    v = jnp.ones(48, bool)
    sf = np.asarray(Q.quantize_events(jnp.asarray(e), v,
                                      CFG.replace(fixed_point=False)))
    sx = np.asarray(Q.quantize_events(jnp.asarray(e), v,
                                      CFG.replace(fixed_point=True)))
    # fixed-point may differ by at most one bucket at boundaries
    assert (np.abs(sf - sx) <= 1).all()
    assert (sf == sx).mean() > 0.8


def test_invalid_events_ignored_in_stats():
    e = jnp.asarray(np.array([1, 2, 3, 4, 1000, -1000], np.float32))
    v = jnp.asarray(np.array([1, 1, 1, 1, 0, 0], bool))
    sym = np.asarray(Q.quantize_events(e, v, CFG))
    # the valid prefix should span the alphabet sensibly (outliers masked)
    assert sym[:4].max() < CFG.quant_levels
    assert sym[:4].min() >= 0
    assert sym[3] > sym[0]
