"""Paper Table 3 analogue: accuracy claims validated end-to-end.

Claims under test (Section 8.1):
  1. fixed-point arithmetic only minimally decreases accuracy vs float;
  2. MARS filters + early quantization give F1 >= the unfiltered
     RawHash-like baseline (and clearly better precision under junk);
  3. accuracy is 'on par' overall (absolute F1 high on small genomes).
"""
import numpy as np
import pytest

from repro.core import MarsConfig, Mapper, build_index, score_accuracy
from repro.signal import simulate


@pytest.fixture(scope="module")
def setup():
    ref = simulate.make_reference(200_000, seed=21)
    base = MarsConfig()
    reads = simulate.sample_reads(ref, 64, signal_len=base.signal_len,
                                  seed=22, junk_frac=0.125)
    out = {}
    for name, cfg in {
        "nofilter": base.replace(use_freq_filter=False,
                                 use_vote_filter=False,
                                 early_quantization=False,
                                 fixed_point=False),
        "rh2": base.with_mode("rh2"),
        "ms_float": base.with_mode("ms_float"),
        "ms_fixed": base.with_mode("ms_fixed"),
    }.items():
        idx = build_index(ref.events_concat, ref.n_events, cfg)
        o = Mapper(idx, cfg).map_signals(reads.signals)
        out[name] = score_accuracy(o, reads.true_pos, reads.true_strand,
                                   reads.mappable, reads.n_bases,
                                   ref.n_events)
    return out


def test_fixed_point_minimal_loss(setup):
    assert setup["ms_fixed"]["f1"] >= setup["ms_float"]["f1"] - 0.05


def test_filters_beat_unfiltered_baseline(setup):
    assert setup["ms_fixed"]["f1"] >= setup["nofilter"]["f1"]


def test_absolute_accuracy(setup):
    assert setup["ms_fixed"]["f1"] >= 0.85
    assert setup["ms_fixed"]["precision"] >= 0.9


def test_on_par_with_rh2(setup):
    assert setup["ms_fixed"]["f1"] >= setup["rh2"]["f1"] - 0.03
