"""Cheap-phase fast path parity: packed-entry gathers, prefix-sum event
reduction and batch-level detect/query/vote must be bit-identical to the
seed implementations.

Mirrors the fast path's structure (and tests/test_chain_fastpath.py):

  (a) event reduction: one-sort ``robust_normalize`` vs the two-median
      reference; cumsum-at-boundary ``segment_means`` vs the segment-sum
      reference; full ``detect_events`` vs ``detect_events_reference`` —
      swept over the fixed-point x early-quant x float mode grid;
  (b) the int32 overflow guard of the integer boundary test (satellite:
      ``diff * diff * w`` wraps beyond tstat_window=12 at frac_bits=8);
  (c) packed-entry query (two fused gathers) vs the unpacked four-gather
      ``query_index_reference``, per-read and whole-chunk batched;
  (d) the fused batch vote filter vs the per-read reference, plus the
      diag clip guard + ``n_votes_clipped`` debug counter;
  (e) the batched cheap phase vs the per-read vmap of the stage bodies,
      for reference AND pallas plans, and whole-chunk ``map_chunk`` across
      backends (the sharded + ring/a2a parity of the same program runs in
      tests/test_distributed_stages.py under a multi-device mesh).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MarsConfig, build_index, map_chunk, seeding, stages,
                        vote)
from repro.core import events, pipeline
from repro.core.index import index_arrays, index_arrays_unpacked
from repro.signal import simulate

MODES = ("ms_fixed", "ms_float", "rh2")


@pytest.fixture(scope="module", params=MODES)
def mode_setup(request):
    cfg = MarsConfig(hash_bits=12).with_mode(request.param)
    ref = simulate.make_reference(6_000, seed=9)
    reads = simulate.sample_reads(ref, 6, signal_len=cfg.signal_len,
                                  seed=10, junk_frac=0.3)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    return cfg, jnp.asarray(reads.signals), idx


# --------------------------------------------------------------------------- #
# (a) prefix-sum event reduction vs reference oracles
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S", [7, 8, 255, 256, 1024])
def test_robust_normalize_matches_reference(S):
    """One shared sort + rank-merged MAD == two jnp.median sorts, bitwise
    (odd/even lengths, heavy ties)."""
    rng = np.random.default_rng(S)
    for trial in range(4):
        x = rng.normal(100, 25, (3, S)).astype(np.float32)
        if trial % 2:
            x = np.round(x)                    # ties exercise rank merging
        got = events.robust_normalize(jnp.asarray(x))
        want = events.robust_normalize_reference(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segment_means_matches_reference_fixed():
    """Cumsum-at-boundary gathers == segment-sum scatters on the integer
    (fixed-point) path, including valid_len masking and the E-1 overflow
    clip."""
    rng = np.random.default_rng(1)
    S, E = 512, 48
    for valid_len, p in [(S, 0.05), (S // 3, 0.05), (S, 0.6), (17, 0.3)]:
        x = rng.integers(-2048, 2048, S).astype(np.int32)
        b = rng.random(S) < p
        got = events.segment_means(jnp.asarray(x), jnp.asarray(b),
                                   valid_len, E, max_abs=2048)
        want = events.segment_means_reference(jnp.asarray(x), jnp.asarray(b),
                                              valid_len, E)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_segment_means_guards_prefix_sum_exactness():
    """The f32 prefix sum is only exact below 2^24: an uncertified bound or
    S * max_abs beyond it must fall back to the scatter reference (whose
    jaxpr carries a scatter-add; the fast path is gather-only)."""
    import jax
    S, E = 1 << 14, 48                      # 2^14 * 2048 = 2^25 > 2^24
    args = (jnp.ones(S, jnp.int32), jnp.zeros(S, bool), S, E)

    def has_scatter(max_abs):
        jaxpr = jax.make_jaxpr(
            lambda x, b: events.segment_means(x, b, S, E, max_abs=max_abs)
        )(args[0], args[1])
        return "scatter" in str(jaxpr)

    assert has_scatter(None)                # uncertified bound
    assert has_scatter(2048)                # bound certified but too large
    S2 = 1024
    jaxpr = jax.make_jaxpr(
        lambda x, b: events.segment_means(x, b, S2, E, max_abs=2048)
    )(jnp.ones(S2, jnp.int32), jnp.zeros(S2, bool))
    assert "scatter" not in str(jaxpr)      # in-range -> gather fast path


def test_detect_events_matches_reference(mode_setup):
    """Full detect (normalize + boundary + reduce) vs the pre-fast-path
    reference, per mode.  Float modes keep the scatter-based reduction, so
    equality is bitwise there too."""
    cfg, signals, _ = mode_setup
    got = jax.vmap(lambda s: events.detect_events(s, cfg))(signals)
    want = jax.vmap(lambda s: events.detect_events_reference(s, cfg))(signals)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# --------------------------------------------------------------------------- #
# (b) integer boundary test: int32 overflow guard
# --------------------------------------------------------------------------- #
def test_boundary_mask_fixed_safe_at_bound_matches_int64_oracle():
    """tstat_window=12 is the largest safe window at frac_bits=8: the
    adversarial max-amplitude step signal stays below 2^31 and the int32
    mask equals an unbounded int64 numpy evaluation."""
    cfg = MarsConfig(signal_len=256, tstat_window=12).with_mode("ms_fixed")
    assert events.fixed_tstat_in_range(cfg)
    S, w = 256, cfg.tstat_window
    xq = np.full(S, -2048, np.int16)
    xq[S // 2:] = 2047                        # extreme step at the midpoint
    got = events.boundary_mask_fixed(jnp.asarray(xq), cfg)

    # unbounded int64 oracle of the same integer test + peak pick
    x = xq.astype(np.int64)
    c = np.concatenate([[0], np.cumsum(x)])
    c2 = np.concatenate([[0], np.cumsum(x * x)])
    i = np.arange(S)
    lo, hi = np.maximum(i - w, 0), np.minimum(i + w, S)
    sum_l, sum_r = c[i] - c[lo], c[hi] - c[i]
    sq_l, sq_r = c2[i] - c2[lo], c2[hi] - c2[i]
    diff = (sum_r - sum_l) >> 2
    ssd = (w * sq_l - sum_l**2) + (w * sq_r - sum_r**2)
    tau2 = int(round(cfg.tstat_threshold ** 2))
    eps = 1 << (2 * cfg.frac_bits - 8)
    lhs = diff * diff * w
    rhs = tau2 * ((ssd >> 4) + eps)
    assert lhs.max() >= (1 << 30), "signal must stress the bound"
    above = lhs > rhs
    score = lhs.astype(np.float32) / (rhs.astype(np.float32) + 1.0)
    want = np.asarray(events._peak_pick(jnp.asarray(score),
                                        jnp.asarray(above), cfg))
    np.testing.assert_array_equal(np.asarray(got), want)


def test_boundary_mask_fixed_rejects_overflowing_window():
    """One past the bound: diff^2 * w exceeds int31 in the worst case and
    the guard fails statically instead of wrapping."""
    cfg = MarsConfig(signal_len=256, tstat_window=13).with_mode("ms_fixed")
    assert not events.fixed_tstat_in_range(cfg)
    assert events.fixed_tstat_bounds(cfg)["lhs"] >= (1 << 31)
    with pytest.raises(ValueError, match="tstat_window"):
        events.boundary_mask_fixed(jnp.zeros(256, jnp.int16), cfg)
    # the Pallas detect backend refuses the same configs, so plans fall
    # back instead of running the kernel's identical int32 expressions
    plan = dict(stages.resolve_plan(cfg, stages.PALLAS))
    assert plan["detect"] == stages.REFERENCE


# --------------------------------------------------------------------------- #
# (c) packed-entry query vs the unpacked four-gather oracle
# --------------------------------------------------------------------------- #
def test_query_packed_matches_unpacked(mode_setup):
    cfg, _, idx = mode_setup
    packed = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    unpacked = {k: jnp.asarray(v)
                for k, v in index_arrays_unpacked(idx).items()}
    rng = np.random.default_rng(2)
    E = cfg.max_events
    hit_keys = rng.choice(idx.entries_key, (3, E)).astype(np.uint32)
    miss_keys = rng.integers(0, 1 << 32, (1, E)).astype(np.uint32)
    keys = jnp.asarray(np.concatenate([hit_keys, miss_keys]))
    valid = jnp.asarray(rng.random(keys.shape) < 0.8)
    # batched (R, E) call
    tp1, hv1, c1 = seeding.query_index(keys, valid, packed, cfg)
    tp0, hv0, c0 = seeding.query_index_reference(keys, valid, unpacked, cfg)
    np.testing.assert_array_equal(np.asarray(hv0), np.asarray(hv1))
    np.testing.assert_array_equal(np.asarray(tp0), np.asarray(tp1))
    for k in c0:
        np.testing.assert_array_equal(np.asarray(c0[k]), np.asarray(c1[k]))
    # per-read calls agree with the batched rows
    for r in range(keys.shape[0]):
        tpr, hvr, cr = seeding.query_index(keys[r], valid[r], packed, cfg)
        np.testing.assert_array_equal(np.asarray(hvr), np.asarray(hv1[r]))
        for k in cr:
            assert int(cr[k]) == int(np.asarray(c1[k])[r]), k


def test_packed_plane_count_overflow_guard():
    """A count that does not fit the bucket-implied spare bits must fail at
    build/pack time, not corrupt a neighbour's key distinguisher."""
    from repro.core.index import pack_entries
    cfg = MarsConfig(hash_bits=12)
    keys = np.asarray([0x12345678], np.uint32)
    pos = np.asarray([7], np.int32)
    ok = pack_entries(keys, pos, np.asarray([cfg.n_buckets - 1], np.int64),
                      cfg)
    assert ok.shape == (2, 1)
    with pytest.raises(ValueError, match="spare bits"):
        pack_entries(keys, pos, np.asarray([cfg.n_buckets], np.int64), cfg)


# --------------------------------------------------------------------------- #
# (d) fused batch vote + clip guard
# --------------------------------------------------------------------------- #
def test_vote_filter_batch_matches_reference():
    cfg = MarsConfig(thresh_voting=3)
    rng = np.random.default_rng(3)
    R, E, H = 5, 64, 8
    q = np.tile(np.arange(E)[None, :, None], (R, 1, H)).astype(np.int32)
    t = rng.integers(0, 1 << 20, (R, E, H)).astype(np.int32)
    t[0, :, 0] = 5000 + q[0, :, 0]             # one colinear cluster
    v = rng.random((R, E, H)) < 0.4
    keep_b, c_b = vote.vote_filter(jnp.asarray(q), jnp.asarray(t),
                                   jnp.asarray(v), cfg)
    for r in range(R):
        keep_r, c_r = vote.vote_filter_reference(
            jnp.asarray(q[r]), jnp.asarray(t[r]), jnp.asarray(v[r]), cfg)
        np.testing.assert_array_equal(np.asarray(keep_b)[r],
                                      np.asarray(keep_r))
        for k in c_r:
            assert int(np.asarray(c_b[k])[r]) == int(c_r[k]), (r, k)
    assert "n_votes_clipped" in c_b
    assert "n_votes_clipped" not in stages.CHUNK_COUNTER_SCHEMA
    assert int(np.asarray(c_b["n_votes_clipped"]).sum()) == 0


def test_vote_filter_clips_underflowing_diag():
    """A diag below -2^20 must clip into bin 0 (counted), not wrap through
    the arithmetic shift into an arbitrary window."""
    cfg = MarsConfig(thresh_voting=1)
    E, H = 8, 2
    q = np.full((E, H), 1 << 21, np.int32)     # diag = -2^21 << -DIAG_SHIFT
    t = np.zeros((E, H), np.int32)
    v = np.ones((E, H), bool)
    keep, c = vote.vote_filter(jnp.asarray(q), jnp.asarray(t),
                               jnp.asarray(v), cfg)
    assert int(c["n_votes_clipped"]) == E * H
    # all clipped anchors land in the same (zero) window -> all survive at
    # thresh 1 and the vote tally is consistent
    assert np.asarray(keep).all()
    # in-range diags do not clip
    _, c2 = vote.vote_filter(jnp.asarray(np.zeros((E, H), np.int32)),
                             jnp.asarray(t), jnp.asarray(v), cfg)
    assert int(c2["n_votes_clipped"]) == 0


# --------------------------------------------------------------------------- #
# (e) batched cheap phase / whole-chunk parity across backends
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", [stages.REFERENCE, stages.PALLAS])
def test_cheap_phase_batch_matches_vmap(mode_setup, backend):
    """The batch-level cheap phase (batch detect kernel, whole-chunk packed
    gathers, fused vote) == the per-read vmap of the same plan's stage
    bodies — outputs AND per-read counters."""
    cfg, signals, idx = mode_setup
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    plan = stages.resolve_plan(cfg, backend)
    assert stages.cheap_primitives(plan, cfg) is not None
    fast = jax.jit(lambda s: pipeline.cheap_phase(s, arrays, cfg, plan))
    slow = jax.jit(lambda s: pipeline.cheap_phase_vmap(s, arrays, cfg, plan))
    q1, t1, h1, c1 = fast(signals)
    q0, t0, h0, c0 = slow(signals)
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))
    assert set(c0) == set(c1)
    for k in c0:
        np.testing.assert_array_equal(np.asarray(c0[k]), np.asarray(c1[k]),
                                      err_msg=k)


def test_map_chunk_parity_across_backends(mode_setup):
    """Whole-chunk outputs + the unchanged counter schema, reference vs
    pallas plans, fast path on and off."""
    cfg, signals, idx = mode_setup
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    outs = {}
    for compaction in (True, False):
        c = cfg.replace(chain_compaction=compaction)
        for backend in (stages.REFERENCE, stages.PALLAS):
            plan = stages.resolve_plan(c, backend)
            outs[(compaction, backend)] = map_chunk(signals, arrays, c,
                                                    plan=plan)
    base = outs[(True, stages.REFERENCE)]
    assert set(base.counters) == set(stages.CHUNK_COUNTER_SCHEMA)
    for tag, out in outs.items():
        assert set(out.counters) == set(stages.CHUNK_COUNTER_SCHEMA), tag
        np.testing.assert_array_equal(np.asarray(base.t_start),
                                      np.asarray(out.t_start), err_msg=str(tag))
        np.testing.assert_array_equal(np.asarray(base.mapped),
                                      np.asarray(out.mapped), err_msg=str(tag))
        np.testing.assert_allclose(np.asarray(base.score),
                                   np.asarray(out.score), rtol=1e-5,
                                   err_msg=str(tag))
        for k in stages.CHUNK_COUNTER_SCHEMA:
            assert int(base.counters[k]) == int(out.counters[k]), (tag, k)


@pytest.mark.slow
def test_cheap_phase_property_sweep():
    """Property sweep: random references/read mixes across the mode grid;
    batch cheap phase == per-read vmap every time."""
    for seed in range(3):
        for mode in MODES:
            cfg = MarsConfig(hash_bits=11, signal_len=512,
                             max_events=96).with_mode(mode)
            ref = simulate.make_reference(3_000, seed=20 + seed)
            reads = simulate.sample_reads(ref, 4, signal_len=cfg.signal_len,
                                          seed=30 + seed, junk_frac=0.5)
            idx = build_index(ref.events_concat, ref.n_events, cfg)
            arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
            plan = stages.resolve_plan(cfg, stages.REFERENCE)
            sig = jnp.asarray(reads.signals)
            got = pipeline.cheap_phase(sig, arrays, cfg, plan)
            want = pipeline.cheap_phase_vmap(sig, arrays, cfg, plan)
            for g, w in zip(got[:3], want[:3]):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
            for k in want[3]:
                np.testing.assert_array_equal(np.asarray(got[3][k]),
                                              np.asarray(want[3][k]),
                                              err_msg=(mode, seed, k))
