"""Out-of-core tiered index: streaming build byte-parity and the
`query:tiered` bit-exactness contract.

The whole point of the tiered backend is that tiling, cache size,
eviction order and paging schedule are INVISIBLE to results: every
MapOutput field and every CHUNK_COUNTER_SCHEMA counter must equal the
resident-index path (and the unpacked oracle) for any cache
configuration, including the cache-of-1 thrash regime where every chunk
overflows the persistent slots.
"""
import numpy as np
import pytest

from repro.core import MarsConfig, Mapper, build_index, map_chunk, stages
from repro.core.index import (build_index_streaming, index_arrays,
                              tier_index)
from repro.signal import simulate


@pytest.fixture(scope="module")
def setup():
    cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
    ref = simulate.make_reference(8_000, seed=5)
    reads = simulate.sample_reads(ref, 24, signal_len=cfg.signal_len,
                                  seed=6, junk_frac=0.25)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    return cfg, ref, reads, idx


@pytest.fixture(scope="module")
def base_out(setup):
    cfg, _, reads, idx = setup
    return Mapper(idx, cfg).map_signals(reads.signals, chunk=8)


def _assert_parity(base, out):
    np.testing.assert_array_equal(np.asarray(base.t_start),
                                  np.asarray(out.t_start))
    np.testing.assert_array_equal(np.asarray(base.score),
                                  np.asarray(out.score))
    np.testing.assert_array_equal(np.asarray(base.mapped),
                                  np.asarray(out.mapped))
    np.testing.assert_array_equal(np.asarray(base.n_events),
                                  np.asarray(out.n_events))
    assert base.counters == out.counters


# --------------------------------------------------------------------------- #
# Streaming build
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n_tiles", [1, 4, 16])
@pytest.mark.parametrize("chunk_events", [1 << 9, 1 << 12, 1 << 20])
def test_streaming_build_matches_in_memory(setup, n_tiles, chunk_events):
    """Per-tile planes from the external streaming build are byte-identical
    to tiling the in-memory build — for any block size (including one
    bigger than the whole stream)."""
    cfg, ref, _, idx = setup
    want = tier_index(idx, n_tiles)
    got = build_index_streaming(ref.events_concat, ref.n_events, cfg,
                                n_tiles, chunk_events=chunk_events)
    np.testing.assert_array_equal(want.tile_bucket_start,
                                  got.tile_bucket_start)
    np.testing.assert_array_equal(np.asarray(want.tile_entries_packed),
                                  np.asarray(got.tile_entries_packed))
    np.testing.assert_array_equal(want.tile_n_entries, got.tile_n_entries)
    assert want.n_entries == got.n_entries == idx.n_entries


def test_global_planes_roundtrip(setup):
    cfg, ref, _, idx = setup
    ti = build_index_streaming(ref.events_concat, ref.n_events, cfg, 8,
                               chunk_events=1 << 10)
    bs, packed = ti.global_planes()
    np.testing.assert_array_equal(bs, idx.bucket_start)
    np.testing.assert_array_equal(packed, idx.entries_packed)


def test_streaming_build_memmap(setup, tmp_path):
    """mmap_path keeps the padded entry plane in a memory-mapped file —
    same bytes, usable end to end."""
    cfg, ref, reads, idx = setup
    ti = build_index_streaming(ref.events_concat, ref.n_events, cfg, 8,
                               chunk_events=1 << 10,
                               mmap_path=tmp_path / "tiles.npy")
    assert isinstance(ti.tile_entries_packed, np.memmap)
    want = tier_index(idx, 8)
    np.testing.assert_array_equal(np.asarray(want.tile_entries_packed),
                                  np.asarray(ti.tile_entries_packed))
    base = Mapper(idx, cfg).map_signals(reads.signals, chunk=8)
    out = Mapper(ti, cfg, backend="tiered",
                 cache_slots=4).map_signals(reads.signals, chunk=8)
    _assert_parity(base, out)


# --------------------------------------------------------------------------- #
# query:tiered bit-exactness
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n_tiles", [2, 4, 16])
@pytest.mark.parametrize("cache_slots", [1, 2, 16])
def test_tiered_parity_tiles_x_cache(setup, base_out, n_tiles, cache_slots):
    """Bit-identical to the resident path for every (tile count, cache
    size) — cache_slots=1 with many tiles is the thrash regime where every
    chunk takes the transient overflow view."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=n_tiles,
               cache_slots=cache_slots)
    _assert_parity(base_out, m.map_signals(reads.signals, chunk=8))
    assert m.cache.n_chunks == 3
    assert m.cache.misses >= 1                  # cold start always pages
    assert m.cache.paged_bytes >= m.cache.misses * m.cache.tiered.tile_nbytes


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tiered_parity_random_eviction(setup, base_out, seed):
    """Eviction order must be invisible: the seeded random policy picks
    arbitrary victims and the results still match bit for bit."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=16, cache_slots=4,
               cache_policy="random", cache_seed=seed)
    _assert_parity(base_out, m.map_signals(reads.signals, chunk=8))


def test_tiered_parity_oracle(setup):
    """Against the unpacked reference oracle (query_index_reference), per
    chunk: same t_pos/hit_valid wherever hits exist, same counters."""
    import jax.numpy as jnp

    from repro.core.index import index_arrays_unpacked
    from repro.core import seeding

    cfg, _, reads, idx = setup
    unpacked = {k: jnp.asarray(v)
                for k, v in index_arrays_unpacked(idx).items()}
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=2)
    sig = reads.signals[:8]
    out_t = m.map_signals(sig, chunk=8)
    out_r = Mapper(idx, cfg).map_signals(sig, chunk=8)
    _assert_parity(out_r, out_t)
    # spot-check the query stage itself against the oracle on real keys
    plan = stages.resolve_plan(cfg)
    st = {"signal": jnp.asarray(sig[0]), "counters": {}}
    st = stages.execute_stages(st, arrays, cfg, plan,
                               ("detect", "quantize", "seed"))
    t_o, hv_o, c_o = seeding.query_index_reference(
        st["keys"], st["seed_valid"], unpacked, cfg)
    t_p, hv_p, c_p = seeding.query_index(st["keys"], st["seed_valid"],
                                         arrays, cfg)
    np.testing.assert_array_equal(np.asarray(hv_o), np.asarray(hv_p))
    np.testing.assert_array_equal(np.asarray(t_o)[np.asarray(hv_o)],
                                  np.asarray(t_p)[np.asarray(hv_p)])


def test_counter_schema_unchanged(setup):
    """The serving/workload contract: tiered chunks emit exactly
    CHUNK_COUNTER_SCHEMA — the cache telemetry rides DEBUG_COUNTER_SCHEMA
    and never reaches MapOutput.counters."""
    cfg, _, reads, idx = setup
    out = Mapper(idx, cfg, backend="tiered", tiles=8,
                 cache_slots=4).map_signals(reads.signals[:8], chunk=8)
    assert set(out.counters) == set(stages.CHUNK_COUNTER_SCHEMA)
    for k in ("n_tile_hits", "n_tile_misses", "n_tile_paged_bytes"):
        assert k in stages.DEBUG_COUNTER_SCHEMA


def test_tiered_requires_prepared_view(setup):
    """Feeding map_chunk a tiered plan with the resident arrays (no
    HotTileCache view) fails loudly, not silently wrong."""
    import jax.numpy as jnp

    cfg, _, reads, idx = setup
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    plan = stages.resolve_plan(cfg, "tiered")
    with pytest.raises(ValueError, match="HotTileCache"):
        map_chunk(jnp.asarray(reads.signals[:8]), arrays, cfg, plan=plan)


def test_cache_stats_and_prefetch(setup):
    """LRU keeps hot tiles resident across chunks (hit rate grows after the
    cold start) and the telemetry adds up."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=16, cache_slots=16)
    m.map_signals(reads.signals, chunk=8)
    c = m.cache
    touches = c.hits + c.misses
    assert touches > 0 and c.hits > 0            # warm chunks re-hit tiles
    assert c.hit_rate == c.hits / touches
    assert c.paged_bytes == c.misses * c.tiered.tile_nbytes
    # a second pass over the same reads is fully warm
    h0, m0 = c.hits, c.misses
    m.map_signals(reads.signals, chunk=8)
    assert c.misses == m0 and c.hits > h0


# --------------------------------------------------------------------------- #
# Sharded + serving
# --------------------------------------------------------------------------- #
def test_tiered_sharded_parity(setup, base_out):
    """map_chunk_sharded with the tiered view (replicated over a 1-device
    mesh — multi-device parity rides tests/test_distributed_serve.py)."""
    import jax
    from jax.sharding import Mesh

    cfg, _, reads, idx = setup
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    m = Mapper(idx, cfg, backend="tiered", mesh=mesh, tiles=8,
               cache_slots=4)
    _assert_parity(base_out, m.map_signals(reads.signals, chunk=8))


@pytest.mark.parametrize("cache_slots", [1, 4])
def test_tiered_serve_parity(setup, cache_slots):
    """ServeDriver over the tiered mapper: per-stream results equal mapping
    each stream alone, for an adversarial interleaving — chunk composition
    must not change which tiles are resident when a read is served."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=cache_slots)
    rng = np.random.default_rng(0)
    owner = rng.integers(0, 3, 16)
    order = rng.permutation(16)
    sd = m.serve(chunk=8)
    for r in order:
        sd.submit(f"s{owner[r]}", reads.signals[int(r)])
    sd.drain()
    for k in range(3):
        rows = [int(r) for r in order if owner[r] == k]
        if not rows:
            continue
        want = m.map_signals(reads.signals[np.asarray(rows)], chunk=8)
        got = sd.results(f"s{k}")
        np.testing.assert_array_equal(got.t_start, np.asarray(want.t_start))
        np.testing.assert_array_equal(got.score, np.asarray(want.score))
        np.testing.assert_array_equal(got.mapped, np.asarray(want.mapped))
    assert set(sd.counters) == set(stages.CHUNK_COUNTER_SCHEMA)


# --------------------------------------------------------------------------- #
# Pre-pass reuse (the probe's detect/quantize/seed feeds the main pass)
# --------------------------------------------------------------------------- #
def test_prepass_reuse_bit_parity(setup, base_out):
    """Reusing the traffic pre-pass's detect->quantize->seed outputs in
    the main pass (the default) is bit-identical to recomputing them AND
    to the resident-index path — outputs and every counter."""
    cfg, _, reads, idx = setup
    on = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4)
    off = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
                 reuse_prepass=False)
    assert on.cache.reuse_prepass and not off.cache.reuse_prepass
    _assert_parity(base_out, on.map_signals(reads.signals, chunk=8))
    _assert_parity(base_out, off.map_signals(reads.signals, chunk=8))


def test_prepass_planes_in_view(setup):
    """The prepared view carries the PREPASS_KEYS planes exactly when
    reuse is on — including on the overflow (wide-view) path — and the
    planes equal the cheap phase's own detect/quantize/seed outputs."""
    import jax
    import jax.numpy as jnp

    from repro.core import stages as stages_mod
    from repro.core.tiered import PREPASS_KEYS

    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4)
    sig = reads.signals[:8]
    view = m.cache.prepare(sig, cfg, m.plan)
    assert all(k in view for k in PREPASS_KEYS)

    def one(signal):
        st = stages_mod.execute_stages({"signal": signal, "counters": {}},
                                       {}, cfg, m.plan,
                                       ("detect", "quantize", "seed"))
        return st["keys"], st["seed_valid"], st["n_events"]
    keys, valid, nev = jax.vmap(one)(jnp.asarray(sig))
    np.testing.assert_array_equal(np.asarray(view["t_pre_keys"]),
                                  np.asarray(keys))
    np.testing.assert_array_equal(np.asarray(view["t_pre_valid"]),
                                  np.asarray(valid))
    np.testing.assert_array_equal(np.asarray(view["t_pre_nev"]),
                                  np.asarray(nev))

    thrash = Mapper(idx, cfg, backend="tiered", tiles=16, cache_slots=1)
    wide = thrash.cache.prepare(sig, cfg, thrash.plan)
    assert all(k in wide for k in PREPASS_KEYS)

    no = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
                reuse_prepass=False)
    bare = no.cache.prepare(sig, cfg, no.plan)
    assert not any(k in bare for k in PREPASS_KEYS)


# --------------------------------------------------------------------------- #
# Hot-tile replication (skewed traffic)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("cache_slots", [1, 2, 4, 16])
@pytest.mark.parametrize("replicas", [0, 1, 2, 5, 16])
def test_replication_parity_cache_x_k(setup, base_out, cache_slots,
                                      replicas):
    """Replication is result-invisible by construction: every (cache size,
    replication K) combination — including K > n_tiles and the cache-of-1
    thrash regime — is bit-identical to the resident path, outputs and
    CHUNK_COUNTER_SCHEMA counters alike."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=16,
               cache_slots=cache_slots, cache_replicas=replicas)
    _assert_parity(base_out, m.map_signals(reads.signals, chunk=8))
    if replicas:
        assert m.cache.n_replicas == min(replicas, 16)
        assert m.cache.replica_loads >= 1        # some tile got traffic
        assert m.cache.replica_bytes == \
            m.cache.replica_loads * m.cache.tiered.tile_nbytes


@pytest.mark.parametrize("policy,seed", [("lru", 0), ("random", 1),
                                         ("random", 2)])
def test_replication_parity_eviction_order(setup, base_out, policy, seed):
    """Replica routing composes with any eviction order of the primary
    slots — still bit-exact."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=16, cache_slots=2,
               cache_policy=policy, cache_seed=seed, cache_replicas=3)
    _assert_parity(base_out, m.map_signals(reads.signals, chunk=8))


def test_replication_shields_hot_tiles(setup, base_out):
    """The functional win: with a thrashing primary cache, pinning the
    hottest tiles into replica slots converts their misses into hits —
    strictly better hit rate than the unreplicated cache, same results."""
    cfg, _, reads, idx = setup
    plain = Mapper(idx, cfg, backend="tiered", tiles=16, cache_slots=2)
    repl = Mapper(idx, cfg, backend="tiered", tiles=16, cache_slots=2,
                  cache_replicas=4)
    _assert_parity(base_out, plain.map_signals(reads.signals, chunk=8))
    _assert_parity(base_out, repl.map_signals(reads.signals, chunk=8))
    assert repl.cache.hits > plain.cache.hits
    assert repl.cache.misses < plain.cache.misses
    # the replicated tiles are exactly the traffic top-K the histogram
    # names (ties to the lower tile id)
    traffic = repl.cache.tile_traffic()
    hot = np.nonzero(traffic > 0)[0]
    want = hot[np.lexsort((hot, -traffic[hot]))][:repl.cache.n_replicas]
    got = repl.cache._slot_tile[repl.cache.n_slots:]
    np.testing.assert_array_equal(np.sort(got[got >= 0]), np.sort(want))


def test_replication_serve_parity(setup):
    """ServeDriver over a replicated tiered mapper: per-stream results
    equal mapping each stream alone (chunk mixing must not perturb the
    replica set's result-invisibility)."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=16, cache_slots=2,
               cache_replicas=3)
    rng = np.random.default_rng(7)
    owner = rng.integers(0, 3, 16)
    order = rng.permutation(16)
    sd = m.serve(chunk=8)
    for r in order:
        sd.submit(f"s{owner[r]}", reads.signals[int(r)])
    sd.drain()
    for k in range(3):
        rows = [int(r) for r in order if owner[r] == k]
        if not rows:
            continue
        want = m.map_signals(reads.signals[np.asarray(rows)], chunk=8)
        got = sd.results(f"s{k}")
        np.testing.assert_array_equal(got.t_start, np.asarray(want.t_start))
        np.testing.assert_array_equal(got.score, np.asarray(want.score))
        np.testing.assert_array_equal(got.mapped, np.asarray(want.mapped))


def test_replication_validation(setup):
    cfg, _, _, idx = setup
    with pytest.raises(ValueError, match="replicas"):
        Mapper(idx, cfg, backend="tiered", tiles=8, cache_replicas=-1)
