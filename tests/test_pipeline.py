"""End-to-end pipeline behaviour tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MarsConfig, Mapper, build_index, map_chunk,
                        score_accuracy)
from repro.core.index import index_arrays
from repro.signal import simulate


def test_end_to_end_accuracy(small_ref, cfg_fixed, small_index, small_reads):
    mapper = Mapper(small_index, cfg_fixed)
    out = mapper.map_signals(small_reads.signals)
    acc = score_accuracy(out, small_reads.true_pos, small_reads.true_strand,
                         small_reads.mappable, small_reads.n_bases,
                         small_ref.n_events)
    assert acc["f1"] >= 0.85, acc
    assert acc["precision"] >= 0.9, acc


def test_kernel_backed_pipeline_matches_reference():
    cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
    ref = simulate.make_reference(5_000, seed=5)
    reads = simulate.sample_reads(ref, 4, signal_len=cfg.signal_len, seed=6)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    sig = jnp.asarray(reads.signals)
    out_ref = map_chunk(sig, arrays, cfg, use_kernels=False)
    out_k = map_chunk(sig, arrays, cfg, use_kernels=True)
    np.testing.assert_array_equal(np.asarray(out_ref.t_start),
                                  np.asarray(out_k.t_start))
    np.testing.assert_array_equal(np.asarray(out_ref.mapped),
                                  np.asarray(out_k.mapped))
    np.testing.assert_allclose(np.asarray(out_ref.score),
                               np.asarray(out_k.score), rtol=1e-5)


def test_bounds_do_not_change_results(small_ref, small_reads):
    """Static bounds (H, max_anchors) sized per DESIGN Section 8: results on
    a small dataset must be identical with much larger bounds."""
    base = MarsConfig().with_mode("ms_fixed")
    big = base.replace(max_hits_per_seed=64, max_anchors=2048)
    o1 = Mapper(build_index(small_ref.events_concat, small_ref.n_events,
                            base), base).map_signals(small_reads.signals)
    o2 = Mapper(build_index(small_ref.events_concat, small_ref.n_events,
                            big), big).map_signals(small_reads.signals)
    agree = (np.asarray(o1.mapped) == np.asarray(o2.mapped)).mean()
    assert agree >= 0.95, agree
    both = np.asarray(o1.mapped) & np.asarray(o2.mapped)
    np.testing.assert_array_equal(np.asarray(o1.t_start)[both],
                                  np.asarray(o2.t_start)[both])


def test_counters_are_consistent(small_index, cfg_fixed, small_reads):
    out = Mapper(small_index, cfg_fixed).map_signals(small_reads.signals)
    c = out.counters
    assert c["n_hits_postfreq"] <= c["n_hits_raw"]
    assert c["n_anchors_postvote"] <= c["n_hits_postfreq"]
    assert c["n_sorted"] <= c["n_anchors_postvote"] + 1
    assert c["n_seeds"] <= c["n_events"]
    assert c["n_dp_pairs"] == c["n_sorted"] * cfg_fixed.chain_band


def test_junk_reads_not_mapped(small_index, cfg_fixed):
    rng = np.random.default_rng(7)
    junk = rng.normal(100, 15, (8, cfg_fixed.signal_len)).astype(np.float32)
    out = Mapper(small_index, cfg_fixed).map_signals(junk)
    assert np.asarray(out.mapped).sum() <= 1   # precision on pure noise


def test_reverse_strand_reads_map(small_ref, cfg_fixed, small_index):
    reads = simulate.sample_reads(small_ref, 24,
                                  signal_len=cfg_fixed.signal_len, seed=11)
    out = Mapper(small_index, cfg_fixed).map_signals(reads.signals)
    acc = score_accuracy(out, reads.true_pos, reads.true_strand,
                         reads.mappable, reads.n_bases, small_ref.n_events)
    rev = reads.true_strand == 1
    mapped_rev = np.asarray(out.mapped)[rev]
    assert mapped_rev.mean() > 0.7, "reverse-strand reads must map"
