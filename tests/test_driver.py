"""Unified streaming driver: chunking, double-buffered streaming, resume."""
import json

import numpy as np
import jax.numpy as jnp

from repro.core import Mapper, driver, map_chunk


def test_array_chunks_pad_and_trim():
    sig = np.arange(10 * 4, dtype=np.float32).reshape(10, 4)
    chunks = list(driver.array_chunks(sig, chunk=4))
    assert [(ci, nv) for ci, nv, _ in chunks] == [(0, 4), (1, 4), (2, 2)]
    assert all(c.shape == (4, 4) for _, _, c in chunks)
    np.testing.assert_array_equal(chunks[2][2][2:], 0.0)   # zero pad
    # resume skips already-done chunks
    assert [ci for ci, _, _ in driver.array_chunks(sig, 4, start_chunk=2)] == [2]


def test_stream_map_matches_direct_map(small_index, cfg_fixed, small_reads):
    mapper = Mapper(small_index, cfg_fixed)
    streamed = driver.collect(driver.stream_map(
        mapper.chunk_fn(), driver.array_chunks(small_reads.signals, 5)))
    direct = map_chunk(jnp.asarray(small_reads.signals), mapper.arrays,
                       cfg_fixed)
    np.testing.assert_array_equal(streamed.t_start, np.asarray(direct.t_start))
    np.testing.assert_array_equal(streamed.mapped, np.asarray(direct.mapped))
    for k, v in direct.counters.items():
        assert streamed.counters[k] == int(v), k


def test_stream_map_preserves_order_and_trims(small_index, cfg_fixed,
                                              small_reads):
    mapper = Mapper(small_index, cfg_fixed)
    seen = list(driver.stream_map(
        mapper.chunk_fn(), driver.array_chunks(small_reads.signals, 6)))
    assert [ci for ci, _, _ in seen] == list(range(len(seen)))
    assert [nv for _, nv, _ in seen] == [6, 6, 4]          # 16 reads
    assert all(out.t_start.shape[0] == nv for _, nv, out in seen)


def test_collect_empty_stream():
    from repro.core import MarsConfig, stages
    from repro.core import workload

    out = driver.collect(iter([]))
    assert out.t_start.shape == (0,)
    # zero-filled schema: workload/ssd_model consumers work on a 0-read job
    assert out.counters == {k: 0 for k in stages.CHUNK_COUNTER_SCHEMA}
    w = workload.from_counters(out.counters, MarsConfig(), index_bytes=0)
    assert w.n_reads == 0 and w.n_samples == 0


def test_progress_log_append_and_resume(tmp_path):
    log = driver.ProgressLog(tmp_path / "p.jsonl", compact_every=100)
    assert log.load() == (0, [])
    log.append(1, [(10, 1.5, True), (20, 0.0, False)])
    log.append(2, [(30, 2.5, True)])
    # a fresh instance (simulated restart) replays the log
    log2 = driver.ProgressLog(tmp_path / "p.jsonl")
    nxt, rows = log2.load()
    assert nxt == 2
    assert rows == [(10, 1.5, True), (20, 0.0, False), (30, 2.5, True)]
    # file is line-per-append, not a rewritten blob
    lines = (tmp_path / "p.jsonl").read_text().strip().splitlines()
    assert len(lines) == 2
    assert json.loads(lines[0])["next"] == 1


def test_progress_log_compaction(tmp_path):
    log = driver.ProgressLog(tmp_path / "p.jsonl", compact_every=3)
    for ci in range(7):
        log.append(ci + 1, [(ci, float(ci), True)])
    lines = (tmp_path / "p.jsonl").read_text().strip().splitlines()
    assert len(lines) < 7                     # compaction collapsed history
    nxt, rows = driver.ProgressLog(tmp_path / "p.jsonl").load()
    assert nxt == 7
    assert rows == [(ci, float(ci), True) for ci in range(7)]


def test_progress_log_torn_tail(tmp_path):
    """A kill mid-append leaves a partial final line; load must recover
    the consistent prefix and truncate the tear so appends stay clean."""
    p = tmp_path / "p.jsonl"
    log = driver.ProgressLog(p, compact_every=100)
    log.append(1, [(10, 1.0, True)])
    log.append(2, [(20, 2.0, True)])
    data = p.read_bytes()
    p.write_bytes(data[:-9])               # tear the last line
    log2 = driver.ProgressLog(p)
    nxt, rows = log2.load()
    assert nxt == 1
    assert rows == [(10, 1.0, True)]
    log2.append(2, [(21, 2.5, False)])     # re-mapped chunk appends cleanly
    nxt, rows = driver.ProgressLog(p).load()
    assert nxt == 2
    assert rows == [(10, 1.0, True), (21, 2.5, False)]


def test_progress_log_crash_resume_any_tear_offset(tmp_path):
    """Kill mid-append at EVERY byte offset of the torn final line: load
    must always recover exactly the consistent prefix, truncate the tear,
    and keep accepting appends (the chunk whose append was cut short is
    simply remapped)."""
    p = tmp_path / "p.jsonl"
    log = driver.ProgressLog(p, compact_every=100)
    log.append(1, [(10, 1.0, True)])
    log.append(2, [(20, 2.0, True)])
    data = p.read_bytes()
    line1_end = data.index(b"\n") + 1
    for cut in range(line1_end, len(data)):       # every mid-append kill
        p.write_bytes(data[:cut])
        nxt, rows = driver.ProgressLog(p).load()
        assert nxt == 1, cut
        assert rows == [(10, 1.0, True)], cut
        # resume: the torn chunk is remapped and appends cleanly
        log2 = driver.ProgressLog(p)
        log2.load()
        log2.append(2, [(21, 2.5, False)])
        nxt, rows = driver.ProgressLog(p).load()
        assert (nxt, rows) == (2, [(10, 1.0, True), (21, 2.5, False)]), cut


def test_progress_log_crash_during_compaction(tmp_path):
    """Compaction is atomic (tmp + rename): a crash that leaves a stale
    .tmp behind must not corrupt the log or block later compactions."""
    p = tmp_path / "p.jsonl"
    log = driver.ProgressLog(p, compact_every=100)
    for ci in range(4):
        log.append(ci + 1, [(ci, float(ci), True)])
    # simulate a crash after writing the tmp but before the rename
    tmp = p.with_name(p.name + ".tmp")
    tmp.write_text("{\"partial")
    nxt, rows = driver.ProgressLog(p).load()
    assert nxt == 4 and len(rows) == 4            # original log intact
    log2 = driver.ProgressLog(p, compact_every=2)
    log2.load()
    log2.append(5, [(4, 4.0, True)])              # triggers compaction
    assert not tmp.exists() or tmp.read_text() != "{\"partial"
    nxt, rows = driver.ProgressLog(p).load()
    assert nxt == 5 and len(rows) == 5


def test_progress_log_resume_continues_mapping(small_index, cfg_fixed,
                                               small_reads, tmp_path):
    """End-to-end crash-resume: map, kill after chunk k, reload, continue
    from start_chunk — the stitched results equal an uninterrupted run."""
    mapper = Mapper(small_index, cfg_fixed)
    chunk = 6
    p = tmp_path / "progress.jsonl"

    log = driver.ProgressLog(p)
    for ci, n_valid, out in driver.stream_map(
            mapper.chunk_fn(), driver.array_chunks(small_reads.signals,
                                                   chunk)):
        log.append(ci + 1, [(int(out.t_start[i]), float(out.score[i]),
                             bool(out.mapped[i])) for i in range(n_valid)])
        if ci == 0:
            break                                  # "crash" after chunk 0
    # a fresh process resumes where the log stopped
    log2 = driver.ProgressLog(p)
    start_chunk, rows = log2.load()
    assert start_chunk == 1 and len(rows) == chunk
    for ci, n_valid, out in driver.stream_map(
            mapper.chunk_fn(),
            driver.array_chunks(small_reads.signals, chunk,
                                start_chunk=start_chunk)):
        log2.append(ci + 1, [(int(out.t_start[i]), float(out.score[i]),
                              bool(out.mapped[i])) for i in range(n_valid)])
    want = mapper.map_signals(small_reads.signals, chunk=chunk)
    assert len(log2.rows) == small_reads.signals.shape[0]
    np.testing.assert_array_equal(
        np.asarray([r[0] for r in log2.rows]), np.asarray(want.t_start))
    np.testing.assert_array_equal(
        np.asarray([r[2] for r in log2.rows]), np.asarray(want.mapped))


def test_progress_log_clear(tmp_path):
    log = driver.ProgressLog(tmp_path / "p.jsonl")
    log.append(1, [(0, 0.0, False)])
    log.clear()
    assert not (tmp_path / "p.jsonl").exists()
    assert log.load() == (0, [])
