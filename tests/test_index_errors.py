"""core/index.py error paths and invariants: the packed-entry overflow
guard's exact boundary, layout guards shared by every bucket-range split
(partition/tier/streaming build), and the entries_packed memoization the
device-upload paths rely on."""
import numpy as np
import pytest

from repro.core import MarsConfig, build_index
from repro.core.index import (build_index_streaming, pack_entries,
                              partition_index, tier_index)
from repro.signal import simulate


@pytest.fixture(scope="module")
def tiny_cfg():
    # 16 buckets -> 4 bucket-implied spare bits for the in-entry count
    return MarsConfig(hash_bits=4).with_mode("ms_fixed")


def _entries(cfg, cnt_max, n=8):
    keys = np.arange(n, dtype=np.uint32) * np.uint32(cfg.n_buckets)
    pos = np.arange(n, dtype=np.int64)
    cnt = np.full(n, cnt_max, np.int64)
    return keys, pos, cnt


def test_pack_entries_count_boundary(tiny_cfg):
    """cnt == n_buckets - 1 is the largest representable in-entry count;
    one more would corrupt the neighbouring key distinguisher bits."""
    keys, pos, cnt = _entries(tiny_cfg, tiny_cfg.n_buckets - 1)
    packed = pack_entries(keys, pos, cnt, tiny_cfg)
    assert packed.shape == (2, keys.size) and packed.dtype == np.int32
    # the count really lives in the low bits, the key in the high bits
    got = packed[0].view(np.uint32)
    assert np.all((got & np.uint32(tiny_cfg.n_buckets - 1)) == cnt)
    assert np.all((got & ~np.uint32(tiny_cfg.n_buckets - 1)) == keys)

    keys, pos, cnt = _entries(tiny_cfg, tiny_cfg.n_buckets)
    with pytest.raises(ValueError, match="spare bits"):
        pack_entries(keys, pos, cnt, tiny_cfg)


@pytest.fixture(scope="module")
def small_idx():
    cfg = MarsConfig(hash_bits=10).with_mode("ms_fixed")
    ref = simulate.make_reference(3_000, seed=11)
    return build_index(ref.events_concat, ref.n_events, cfg), ref


def test_partition_index_rejects_non_power_of_two(small_idx):
    idx, _ = small_idx
    with pytest.raises(ValueError, match="power of two"):
        partition_index(idx, 3)
    with pytest.raises(ValueError, match="power of two"):
        tier_index(idx, 6)


def test_build_index_streaming_rejects_non_power_of_two_tiles(small_idx):
    _, ref = small_idx
    cfg = MarsConfig(hash_bits=10).with_mode("ms_fixed")
    with pytest.raises(ValueError, match="power of two"):
        build_index_streaming(ref.events_concat, ref.n_events, cfg, 3)


def test_entries_packed_memoized(small_idx):
    """index_arrays / partition_index / tier_index all read the packed
    planes; the property must hand back the SAME array every time (one
    pack + one overflow check per build, no per-upload repacking)."""
    idx, _ = small_idx
    assert idx.entries_packed is idx.entries_packed
