"""Chaining fast path parity: the filter-aware sort/dp/compaction
optimizations must be bit-identical to the seed implementations.

Three layers, mirroring the fast path's structure:

  (a) select-then-sort (count- and topk-selection) vs the full anchor sort;
  (b) ring-buffer ``chain_dp`` (and the Pallas kernel) vs the dynamic-slice
      ``chain_dp_reference`` across band/anchor-count edge cases;
  (c) compacted ``map_chunk`` / ``map_chunk_sharded`` vs the uncompacted
      chunk program on chunks with 0% / ~50% / 100% vote-filter survival.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MarsConfig, build_index, chaining, map_chunk, stages
from repro.core.index import index_arrays
from repro.signal import simulate

REPO = pathlib.Path(__file__).resolve().parents[1]


def _anchor_grid(rng, E, H, n_valid, t_range=20_000):
    q = np.tile(np.arange(E, dtype=np.int32)[:, None], (1, H))
    t = rng.integers(0, t_range, (E, H)).astype(np.int32)
    v = np.zeros((E, H), bool)
    flat = rng.choice(E * H, size=n_valid, replace=False)
    v.reshape(-1)[flat] = True
    return jnp.asarray(q), jnp.asarray(t), jnp.asarray(v)


# --------------------------------------------------------------------------- #
# (a) select-then-sort vs full sort
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n_valid", [0, 1, 40, 64, 100])
@pytest.mark.parametrize("width", [64, 128])
def test_select_then_sort_matches_full_sort_prefix(n_valid, width):
    """When the surviving anchor count fits the width, select-then-sort
    equals the full sort's first ``width`` slots — for both strategies."""
    rng = np.random.default_rng(n_valid * 1000 + width)
    cfg = MarsConfig()
    q, t, v = _anchor_grid(rng, cfg.max_events, cfg.max_hits_per_seed,
                           n_valid)
    key = chaining.pack_anchor_keys(q, t, v)
    full = jnp.sort(key)[:width]
    count_sel = jnp.sort(chaining.select_smallest_count(key, width))
    topk_sel = jnp.sort(chaining.select_smallest_topk(key, width))
    if n_valid <= width:
        np.testing.assert_array_equal(np.asarray(full), np.asarray(count_sel))
    # topk selection is exact for ANY count
    np.testing.assert_array_equal(np.asarray(full), np.asarray(topk_sel))


def test_sort_anchors_width_matches_reference():
    rng = np.random.default_rng(7)
    cfg = MarsConfig()
    q, t, v = _anchor_grid(rng, cfg.max_events, cfg.max_hits_per_seed, 50)
    ref = chaining.sort_anchors_reference(q, t, v, cfg)
    for select in ("count", "topk"):
        got = chaining.sort_anchors(q, t, v, cfg.replace(anchor_select=select),
                                    width=64)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a)[:64], np.asarray(b))


def test_packing_fields_round_trip():
    """The packed key is [t : T_BITS | q : 8] in a non-negative int32.

    The largest t_pos the index guard admits is 2^T_BITS - 2 (a double
    genome of 2^T_BITS - 1 events): the (2^T_BITS - 1, 255) corner would
    collide with the _INVALID_KEY sentinel."""
    assert chaining.T_BITS == 31 - chaining._Q_BITS == 23
    t = jnp.asarray([[0, (1 << chaining.T_BITS) - 2]], jnp.int32)
    q = jnp.asarray([[5, (1 << chaining._Q_BITS) - 1]], jnp.int32)
    v = jnp.ones((1, 2), bool)
    key = chaining.pack_anchor_keys(q, t, v)
    assert (np.asarray(key) >= 0).all()
    sq, st, sv = chaining.decode_anchor_keys(key)
    np.testing.assert_array_equal(np.asarray(st), t.reshape(-1))
    np.testing.assert_array_equal(np.asarray(sq), q.reshape(-1))
    assert np.asarray(sv).all()


def test_index_build_rejects_key_overflow():
    cfg = MarsConfig()
    too_big = np.zeros(1 << chaining.T_BITS, np.float32)
    with pytest.raises(ValueError, match="sort key"):
        build_index(too_big, too_big.shape[0] // 2, cfg)
    with pytest.raises(ValueError, match="q_pos"):
        build_index(np.zeros(64, np.float32), 32,
                    cfg.replace(max_events=1 << (chaining._Q_BITS + 1)))


# --------------------------------------------------------------------------- #
# (b) ring-buffer DP vs dynamic-slice reference
# --------------------------------------------------------------------------- #
def _sorted_anchors(rng, A, p_valid=0.8, t_range=4000, dup_every=0):
    t = np.sort(rng.integers(0, t_range, size=A)).astype(np.int32)
    q = rng.integers(0, 180, size=A).astype(np.int32)
    order = np.lexsort((q, t))
    t, q = t[order], q[order]
    if dup_every:
        for i in range(dup_every, A, dup_every):
            t[i], q[i] = t[i - 1], q[i - 1]     # exact duplicates: argmax ties
    v = rng.random(A) < p_valid
    return jnp.asarray(q), jnp.asarray(t), jnp.asarray(v)


# band/anchor-count edge cases: B > A, A == B (exactly one band), A not a
# multiple of B, A a multiple, band 1, wide band
@pytest.mark.parametrize("A,B", [(8, 32), (32, 32), (100, 32), (512, 32),
                                 (64, 1), (48, 16), (96, 64)])
def test_ring_dp_matches_reference(A, B):
    cfg = MarsConfig(max_anchors=A, chain_band=B)
    rng = np.random.default_rng(A * 100 + B)
    q, t, v = _sorted_anchors(rng, A, dup_every=7)
    f_r, d_r = chaining.chain_dp_reference(q, t, v, cfg)
    f_n, d_n = chaining.chain_dp(q, t, v, cfg)
    np.testing.assert_array_equal(np.asarray(f_r), np.asarray(f_n))
    np.testing.assert_array_equal(np.asarray(d_r), np.asarray(d_n))


def test_ring_dp_all_invalid_is_empty_result():
    cfg = MarsConfig(max_anchors=64, chain_band=16)
    key = jnp.full((64,), chaining._INVALID_KEY, jnp.int32)
    sq, st, sv = chaining.decode_anchor_keys(key)
    f_r, d_r = chaining.chain_dp_reference(sq, st, sv, cfg)
    f_n, d_n = chaining.chain_dp(sq, st, sv, cfg)
    np.testing.assert_array_equal(np.asarray(f_r), np.asarray(f_n))
    np.testing.assert_array_equal(np.asarray(d_r), np.asarray(d_n))
    res = chaining.best_chain(f_n, d_n, sv, cfg)
    empty = chaining.empty_chain_result(cfg)
    for a, b in zip(res, empty):
        assert np.asarray(a) == np.asarray(b), (res, empty)


def test_ring_dp_vmapped_batch():
    cfg = MarsConfig(max_anchors=128, chain_band=32)
    rng = np.random.default_rng(3)
    qs, ts, vs = zip(*[_sorted_anchors(rng, 128, dup_every=5)
                       for _ in range(6)])
    q, t, v = jnp.stack(qs), jnp.stack(ts), jnp.stack(vs)
    ref = jax.vmap(lambda a, b, c: chaining.chain_dp_reference(a, b, c, cfg))
    new = jax.vmap(lambda a, b, c: chaining.chain_dp(a, b, c, cfg))
    for x, y in zip(ref(q, t, v), new(q, t, v)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------- #
# (c) compacted vs uncompacted map_chunk
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def chunk_setup():
    cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
    ref = simulate.make_reference(6_000, seed=9)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
    return cfg, ref, arrays


def _signals(ref, cfg, junk_frac, seed=21, n=8):
    reads = simulate.sample_reads(ref, n, signal_len=cfg.signal_len,
                                  seed=seed, junk_frac=junk_frac)
    return jnp.asarray(reads.signals)


def _assert_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.t_start), np.asarray(b.t_start))
    np.testing.assert_array_equal(np.asarray(a.score), np.asarray(b.score))
    np.testing.assert_array_equal(np.asarray(a.mapped), np.asarray(b.mapped))
    np.testing.assert_array_equal(np.asarray(a.n_events),
                                  np.asarray(b.n_events))
    ca = {k: int(v) for k, v in a.counters.items()}
    cb = {k: int(v) for k, v in b.counters.items()}
    assert set(ca) == set(stages.CHUNK_COUNTER_SCHEMA)
    assert ca == cb


# survival fractions: 1.0 junk -> ~0% of reads keep anchors post-vote,
# 0.5 -> ~half, 0.0 -> ~all
@pytest.mark.parametrize("junk_frac", [1.0, 0.5, 0.0])
@pytest.mark.parametrize("use_kernels", [False, True])
def test_compacted_chunk_matches_uncompacted(chunk_setup, junk_frac,
                                             use_kernels):
    cfg, ref, arrays = chunk_setup
    sig = _signals(ref, cfg, junk_frac)
    base = map_chunk(sig, arrays, cfg.replace(chain_compaction=False),
                     use_kernels=use_kernels)
    fast = map_chunk(sig, arrays, cfg, use_kernels=use_kernels)
    _assert_identical(base, fast)
    # sanity: the survival mix matches the scenario
    n_anchors = int(base.counters["n_anchors_postvote"])
    if junk_frac == 1.0:
        assert n_anchors == 0
    else:
        assert n_anchors > 0


@pytest.mark.parametrize("kw", [dict(anchor_select="topk"),
                                dict(chain_widths=()),
                                dict(chain_widths=(16, 64, 128, 256)),
                                dict(chain_capacity_frac=0.25),
                                dict(chain_capacity_frac=1.0)])
def test_fastpath_config_variants_are_identical(chunk_setup, kw):
    """Every selection strategy / ladder shape / capacity bound must be
    invisible in the outputs (only the runtime branch taken changes)."""
    cfg, ref, arrays = chunk_setup
    sig = _signals(ref, cfg, junk_frac=0.5)
    base = map_chunk(sig, arrays, cfg.replace(chain_compaction=False))
    fast = map_chunk(sig, arrays, cfg.replace(**kw))
    _assert_identical(base, fast)


def test_compacted_chunk_with_pad_rows(chunk_setup):
    cfg, ref, arrays = chunk_setup
    sig = _signals(ref, cfg, junk_frac=0.5)
    base = map_chunk(sig, arrays, cfg.replace(chain_compaction=False),
                     n_valid=5)
    fast = map_chunk(sig, arrays, cfg, n_valid=5)
    _assert_identical(base, fast)
    assert not np.asarray(fast.mapped)[5:].any()


SHARD_SCRIPT = """
import numpy as np, jax.numpy as jnp
from repro.core import MarsConfig, build_index, map_chunk, map_chunk_sharded
from repro.core.index import index_arrays
from repro.launch.mesh import make_mesh
from repro.signal import simulate

mesh = make_mesh((2, 2), ("pod", "data"))
cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
ref = simulate.make_reference(6_000, seed=9)
reads = simulate.sample_reads(ref, 8, signal_len=cfg.signal_len, seed=21,
                              junk_frac=0.5)
idx = build_index(ref.events_concat, ref.n_events, cfg)
arrays = {k: jnp.asarray(v) for k, v in index_arrays(idx).items()}
sig = jnp.asarray(reads.signals)
base = map_chunk(sig, arrays, cfg.replace(chain_compaction=False))
for n_valid in (None, 5):
    b = map_chunk_sharded(sig, arrays, cfg, mesh, n_valid=n_valid)
    if n_valid is None:
        assert np.array_equal(np.asarray(base.t_start), np.asarray(b.t_start))
        assert np.array_equal(np.asarray(base.score), np.asarray(b.score))
        assert np.array_equal(np.asarray(base.mapped), np.asarray(b.mapped))
    a = map_chunk(sig, arrays, cfg, n_valid=n_valid)
    assert np.array_equal(np.asarray(a.t_start), np.asarray(b.t_start))
    assert np.array_equal(np.asarray(a.score), np.asarray(b.score))
    assert np.array_equal(np.asarray(a.mapped), np.asarray(b.mapped))
    ca = {k: int(v) for k, v in a.counters.items()}
    cb = {k: int(v) for k, v in b.counters.items()}
    assert ca == cb, (n_valid, ca, cb)
print("ok")
"""


def test_sharded_compacted_chunk_matches(chunk_setup):
    """Sharded + compacted == single-device + compacted == uncompacted,
    even when shards take different capacity/width branches locally."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ok" in r.stdout
