"""The discrete-event simulator's contracts (core/sim/ + core/costmodel.py).

Three pillars:

  * **Determinism** — a run is a pure function of its inputs: same
    workload/config (or same recorded trace + seed) -> identical event
    log, stats and totals.
  * **Degenerate identity** — on no-contention configs the simulator
    reproduces the analytic closed forms (``mars_latency`` /
    ``mars_array_latency`` / ``dram_size_sensitivity``) to <1%, swept
    over channel/die counts.  This is the calibration contract that keeps
    the two CostModel backends from drifting apart.
  * **Trace replay** — ``ServeDriver.events`` is sufficient input for the
    serving simulator: replaying the recorded dispatch law reproduces
    every recorded completion exactly (max_drift == 0).

Plus the CostModel interface itself (registry, routing, shed signal) and
the measured-queue-delay shed scenario the analytic offered-load signal
cannot see.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import Mapper, ServeDriver, costmodel, driver, ssd_model
from repro.core.sim import (replay_chunk_trace, simulate_array_latency,
                            simulate_batch, simulate_dram_sensitivity,
                            simulate_serving, simulate_serving_virtual)
from repro.core.workload import Workload


def make_workload(n_reads: int = 50_000) -> Workload:
    """A pinned mid-size raw-signal workload (no pipeline run needed)."""
    r = n_reads
    return Workload(
        n_reads=r, n_samples=4_000 * r, n_events=450 * r, n_seeds=420 * r,
        n_lookups=420 * r, n_hits_raw=3_400 * r, n_hits_exact=3_800 * r,
        n_hits_postfreq=900 * r, n_votes=900 * r,
        n_anchors_postvote=260 * r, n_sorted=260 * r, n_dp_pairs=4_160 * r,
        bytes_raw=8_000 * r, bytes_index=512 << 20,
        bytes_intermediate=30_000 * r, fixed_point=True)


# --------------------------------------------------------------------------- #
# Determinism
# --------------------------------------------------------------------------- #
def test_batch_sim_deterministic():
    w = make_workload()
    a = simulate_batch(w)
    b = simulate_batch(w)
    assert a["event_log"] == b["event_log"]
    assert a["total"] == b["total"]
    assert a["components"] == b["components"]
    assert a["controller"] == b["controller"]


def test_serving_sim_deterministic_per_seed():
    a = simulate_serving_virtual(8, 4.0, seed=3)
    b = simulate_serving_virtual(8, 4.0, seed=3)
    assert a == b
    c = simulate_serving_virtual(8, 4.0, seed=4)
    assert c["p50"] != a["p50"]         # the seed is actually consumed


def test_event_log_shape():
    w = make_workload()
    log = simulate_batch(w, n_stripes=4)["event_log"]
    assert log, "simulator produced no events"
    times = [t for t, _, _, _ in log]
    assert times == sorted(times)       # logged in simulated-time order
    kinds = {k for _, _, k, _ in log}
    assert kinds == {"enqueue", "start", "done"}


# --------------------------------------------------------------------------- #
# Degenerate identity vs the closed forms
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("channels,chips", [(1, 1), (1, 8), (2, 2),
                                            (4, 4), (8, 8)])
def test_degenerate_matches_analytic(channels, chips):
    w = make_workload()
    ssd = dataclasses.replace(ssd_model.SSDConfig(), channels=channels,
                              chips_per_channel=chips)
    want = ssd_model.mars_latency(w, ssd)["total"]
    got = simulate_batch(w, ssd)["total"]
    assert abs(got - want) / want < 0.01


def test_degenerate_matches_compute_bound():
    """A compute-dominated workload (tiny byte volume) hits the other arm
    of the max/min overlap law."""
    w = make_workload()
    w = dataclasses.replace(w, bytes_raw=w.bytes_raw // 200,
                            bytes_index=w.bytes_index // 200)
    want = ssd_model.mars_latency(w)["total"]
    got = simulate_batch(w)["total"]
    assert abs(got - want) / want < 0.01


def test_array_matches_analytic():
    w = make_workload()
    for n_failed in (0, 1):
        arr = ssd_model.SSDArrayConfig(n_ssds=4, n_failed=n_failed)
        want = ssd_model.mars_array_latency(w, arr)["total"]
        got = simulate_array_latency(w, arr)["total"]
        assert abs(got - want) / want < 0.01


def test_dram_sensitivity_matches_analytic():
    w = make_workload()
    want = ssd_model.dram_size_sensitivity(w)
    got = simulate_dram_sensitivity(w)
    assert set(got) == set(want)
    for size in want:
        assert abs(got[size] - want[size]) / want[size] < 0.01


def test_serving_twins_agree_below_saturation():
    a = ssd_model.serving_latency_virtual(8, 4.0)
    s = simulate_serving_virtual(8, 4.0)
    assert not s["saturated"]
    assert abs(s["p50"] - a["p50"]) / a["p50"] < 0.10
    w = make_workload()
    arr = ssd_model.SSDArrayConfig(n_ssds=4)
    cap = w.n_reads / ssd_model.mars_array_latency(w, arr)["total"]
    aa = ssd_model.serving_latency(w, 0.5 * cap, arr)
    ss = simulate_serving(w, 0.5 * cap, arr)
    assert abs(ss["p50"] - aa["p50"]) / aa["p50"] < 0.10


def test_serving_sim_saturation_contract():
    with pytest.raises(ValueError):
        simulate_serving_virtual(8, 0.0)
    out = simulate_serving_virtual(8, 9.0)      # rho > 1
    assert out["saturated"] and math.isinf(out["p50"])


# --------------------------------------------------------------------------- #
# Component decomposition
# --------------------------------------------------------------------------- #
def test_component_stats_decomposition():
    w = make_workload()
    res = simulate_batch(w)
    comps = res["components"]
    names = set(comps)
    assert {"arith_units", "query_units", "sorter", "internal_dram"} <= names
    assert sum(1 for n in names if n.startswith("ch")) == 2 * 8  # ch + dies
    for name, c in comps.items():
        assert 0.0 <= c["utilization"] <= 1.0 + 1e-9, name
        assert c["busy_time"] >= 0.0 and c["queue_delay"] >= 0.0, name
        assert c["busy_time"] + c["idle_time"] == pytest.approx(
            res["total"] * (8 if name.endswith(".dies") else 1)), name
    ctrl = res["controller"]
    assert ctrl["busy_time"] == pytest.approx(res["compute"], rel=1e-6)
    assert ctrl["stall_flash"] >= 0.0


def test_contention_shows_in_breakdown():
    """Starve the flash side: the channels saturate and the compute units
    go idle — the observability the closed form cannot express."""
    w = make_workload()
    ssd = dataclasses.replace(ssd_model.SSDConfig(), channels=1,
                              chips_per_channel=1)
    comps = simulate_batch(w, ssd)["components"]
    assert comps["ch0"]["utilization"] > 0.95
    assert comps["arith_units"]["utilization"] < 0.5


# --------------------------------------------------------------------------- #
# ServeDriver trace -> simulator replay
# --------------------------------------------------------------------------- #
def test_serve_trace_replays_exactly(small_index, cfg_fixed, small_reads):
    mapper = Mapper(small_index, cfg_fixed)
    sd = ServeDriver(mapper, chunk=4)
    for k, sig in enumerate(small_reads.signals):
        sd.submit(f"s{k % 3}", sig)
    sd.drain()
    kinds = [e[0] for e in sd.events]
    assert kinds.count("dispatch") == sd.n_chunks
    assert kinds.count("complete") == sd.n_chunks
    rep = replay_chunk_trace(sd.events, chunk_cost=sd.chunk_cost)
    assert rep["n_chunks"] == sd.n_chunks
    assert rep["max_drift"] == 0.0
    assert rep["n_reads_arrived"] == small_reads.signals.shape[0]
    assert rep["makespan"] == pytest.approx(sd.clock)
    assert 0.0 < rep["dispatch_busy"] <= 1.0


def test_stream_map_records_trace(small_index, cfg_fixed, small_reads):
    mapper = Mapper(small_index, cfg_fixed)
    trace = []
    stream = driver.stream_map(mapper.chunk_fn(),
                               driver.array_chunks(small_reads.signals, 4),
                               trace=trace)
    n = sum(1 for _ in stream)
    kinds = [k for k, _, _, _ in trace]
    assert kinds.count("dispatch") == n and kinds.count("complete") == n
    # observation only: a trace-free run yields identical outputs
    want = mapper.map_signals(small_reads.signals, chunk=4)
    got = driver.collect(driver.stream_map(
        mapper.chunk_fn(), driver.array_chunks(small_reads.signals, 4),
        trace=[]))
    np.testing.assert_array_equal(np.asarray(want.mapped),
                                  np.asarray(got.mapped))
    assert want.counters == got.counters


# --------------------------------------------------------------------------- #
# CostModel interface
# --------------------------------------------------------------------------- #
def test_get_model_registry():
    assert costmodel.get_model(None).name == "analytic"
    assert costmodel.get_model("analytic").name == "analytic"
    assert costmodel.get_model("sim").name == "sim"
    m = costmodel.SimModel()
    assert costmodel.get_model(m) is m
    with pytest.raises(ValueError, match="unknown cost model"):
        costmodel.get_model("mqsim")


def test_costmodel_backends_agree():
    w = make_workload()
    ana = costmodel.get_model("analytic")
    sim = costmodel.get_model("sim")
    for system in ssd_model.SYSTEMS:
        a = ana.system_latency_energy(system, w)
        s = sim.system_latency_energy(system, w)
        if system != "MARS":        # host baselines share the analytic path
            assert a == s
        else:
            assert abs(s["total"] - a["total"]) / a["total"] < 0.01
            assert abs(s["energy"] - a["energy"]) / a["energy"] < 0.01
            # dynamic energy is shared by construction; only the static
            # term follows the backend's clock
            assert s["energy_dynamic"] == pytest.approx(a["energy_dynamic"],
                                                        rel=1e-6)


def test_shed_signal_offered_load_and_delay():
    for m in (costmodel.get_model("analytic"), costmodel.get_model("sim")):
        # saturation by offered load alone
        assert m.shed_signal(8, 1.0, offered_load=16.0)
        # healthy: below saturation, small measured delays
        assert not m.shed_signal(8, 1.0, offered_load=2.0,
                                 queue_delays=(0.5, 1.0))
        # capacity loss: low offered load but tripped measured delays
        assert m.shed_signal(8, 1.0, offered_load=2.0,
                             queue_delays=(10.0,) * 8)
        # zero-load edge (no serving_latency_virtual blow-up)
        assert not m.shed_signal(8, 1.0, offered_load=0.0)


def test_driver_sheds_on_measured_queue_delay(small_index, cfg_fixed,
                                              small_reads):
    """A burst backlog stretches dispatch delays while the offered load
    stays below saturation — only the measured-delay term can see it."""
    sigs = small_reads.signals

    def run(**kw):
        mapper = Mapper(small_index, cfg_fixed)
        sd = ServeDriver(mapper, chunk=2, shed_window=64.0, **kw)
        trace = [(0.0, f"a{k}", sigs[k % sigs.shape[0]]) for k in range(24)]
        trace += [(9.0, f"b{k}", sigs[k % sigs.shape[0]]) for k in range(4)]
        sd.serve_trace(trace)
        return sd

    # load never saturates: 28 arrivals / 64-unit window << 2 reads/unit
    sd = run(shed=True, shed_delay_limit=2.0)
    assert sd.n_shed > 0
    calm = run(shed=True, shed_delay_limit=1e6)
    assert calm.n_shed == 0
    off = run(shed=False)
    assert off.n_shed == 0 and off.n_chunks == calm.n_chunks


# --------------------------------------------------------------------------- #
# Skewed traffic + hot-tile replication pricing
# --------------------------------------------------------------------------- #
def test_skew_factors_closed_form():
    # uniform traffic: no imbalance, replication buys nothing
    assert costmodel.skew_factors([5, 5, 5, 5]) == (1.0, 1.0)
    assert costmodel.skew_factors([5, 5, 5, 5], replicas=2) == (1.0, 1.0)
    # fully concentrated: factor = n_tiles; one replica halves it
    f, fr = costmodel.skew_factors([0, 0, 80, 0, 0, 0, 0, 0], replicas=1)
    assert f == 8.0 and fr == 4.0
    # replicas=0 leaves the replicated factor equal to the skewed one
    f, fr = costmodel.skew_factors([1, 9], replicas=0)
    assert f == fr == 2 * 0.9
    # replicating a cold tile cannot push the factor below uniform 1.0
    f, fr = costmodel.skew_factors([1, 1], replicas=2)
    assert f == 1.0 and fr == 1.0
    # tie-break: highest traffic first, then lowest tile id — the same
    # order HotTileCache._refresh_replicas pins replicas in
    f, fr = costmodel.skew_factors([4, 4, 4, 0], replicas=1, copies=2)
    assert fr == 4 * 4 / 12               # tile 0 halved; tiles 1,2 still hot
    # degenerate inputs price as uniform
    assert costmodel.skew_factors([]) == (1.0, 1.0)
    assert costmodel.skew_factors([0, 0, 0]) == (1.0, 1.0)
    with pytest.raises(ValueError, match="replicas"):
        costmodel.skew_factors([1], replicas=-1)
    with pytest.raises(ValueError, match="copies"):
        costmodel.skew_factors([1], copies=0)


def test_query_scale_default_is_bit_exact():
    w = make_workload()
    a = simulate_batch(w, n_stripes=4)
    b = simulate_batch(w, n_stripes=4, query_scale=1.0)
    assert a["event_log"] == b["event_log"]
    assert a["total"] == b["total"]
    with pytest.raises(ValueError, match="query_scale"):
        simulate_batch(w, query_scale=0.0)


@pytest.mark.parametrize("model", ["analytic", "sim"])
def test_skewed_serving_uniform_equals_batch_latency(model):
    """Degenerate identity: uniform traffic prices exactly like the plain
    batch on BOTH backends, and replication reports speedup 1."""
    w = make_workload()
    m = costmodel.get_model(model)
    out = m.skewed_serving(w, [7, 7, 7, 7], replicas=2)
    assert out["factor"] == out["factor_replicated"] == 1.0
    assert out["replication_speedup"] == 1.0
    assert out["total"] == out["total_replicated"] == m.latency(w)["total"]


@pytest.mark.parametrize("model", ["analytic", "sim"])
def test_skewed_serving_prices_replication_win(model):
    """Hot-bucket skew costs; replicating the hot tiles wins it back —
    monotonically in K on both backends."""
    w = make_workload()
    m = costmodel.get_model(model)
    traffic = [100, 80, 8, 8, 8, 8, 8, 8]        # two hot tiles + cold tail
    base = m.latency(w)["total"]
    totals = []
    for k in (0, 1, 2):
        out = m.skewed_serving(w, traffic, replicas=k)
        assert out["total"] > base               # skew always costs
        assert out["replication_speedup"] >= 1.0
        totals.append(out["total_replicated"])
        assert out["total"] == totals[0]         # K only moves the repl arm
    assert totals[0] > totals[1] > totals[2]     # each replica helps here
    assert m.skewed_serving(w, traffic, replicas=1)["replication_speedup"] > 1


def test_skewed_serving_backends_agree():
    """Calibration: the DES twin agrees with the closed form to <1% on the
    default (no-contention) config, skewed or not."""
    w = make_workload()
    ana = costmodel.get_model("analytic")
    sim = costmodel.get_model("sim")
    for traffic, k in (([1, 1, 1, 1], 0), ([90, 5, 5, 0], 0),
                       ([90, 5, 5, 0], 1), ([50, 30, 10, 10], 2)):
        a = ana.skewed_serving(w, traffic, replicas=k)
        s = sim.skewed_serving(w, traffic, replicas=k)
        assert s["factor"] == a["factor"]
        assert s["factor_replicated"] == a["factor_replicated"]
        for key in ("total", "total_replicated"):
            assert abs(s[key] - a[key]) / a[key] < 0.01, (traffic, k, key)


def test_skewed_serving_consumes_cache_histogram(small_index, cfg_fixed,
                                                 small_reads):
    """End to end: HotTileCache.tile_traffic() is valid input — the
    measured skew of a real tiered run prices on both backends."""
    m = Mapper(small_index, cfg_fixed, backend="tiered", tiles=8,
               cache_slots=2, cache_replicas=2)
    m.map_signals(small_reads.signals, chunk=4)
    traffic = m.cache.tile_traffic()
    assert traffic.sum() > 0
    w = make_workload(1_000)
    for model in ("analytic", "sim"):
        out = costmodel.get_model(model).skewed_serving(
            w, traffic, replicas=m.cache.n_replicas)
        assert out["n_tiles"] == 8 and out["replicas"] == 2
        assert out["factor"] >= out["factor_replicated"] >= 1.0
        assert math.isfinite(out["total"])
        assert out["total"] >= out["total_replicated"]
