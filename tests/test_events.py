"""Event detection unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # deterministic fallback
    from repro.testing import given, settings
    from repro.testing import strategies as st

from repro.core import events
from repro.core.config import MarsConfig


def _step_signal(levels, dwell, noise, seed=0):
    rng = np.random.default_rng(seed)
    sig = np.repeat(np.asarray(levels, np.float32), dwell)
    return sig + rng.normal(0, noise, sig.shape).astype(np.float32)


def test_detects_clean_steps():
    """Well-separated levels with zero noise -> one event per level."""
    cfg = MarsConfig(signal_len=160, max_events=32).with_mode("ms_fixed")
    levels = [80, 120, 90, 130, 70, 110, 95, 125, 85, 115,
              75, 105, 100, 60, 140, 90]
    sig = _step_signal(levels, 10, 0.1)
    means, n, _ = events.detect_events(jnp.asarray(sig), cfg)
    # border windows can emit 1-2 spurious edge events (truncated t-stat
    # windows at the signal ends) — downstream seeding tolerates them
    assert abs(int(n) - len(levels)) <= 2, int(n)


def test_normalization_invariance():
    """Mapping must be invariant to affine signal transforms (gain/offset
    drift between sequencer channels)."""
    cfg = MarsConfig(signal_len=512, max_events=96).with_mode("ms_fixed")
    rng = np.random.default_rng(1)
    levels = rng.uniform(70, 130, 60)
    sig = _step_signal(levels, 8, 1.0, seed=2)[:512]
    m1, n1, _ = events.detect_events(jnp.asarray(sig), cfg)
    m2, n2, _ = events.detect_events(jnp.asarray(sig * 3.7 + 42.0), cfg)
    assert int(n1) == int(n2)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                               atol=2.0 / (1 << cfg.frac_bits))


def test_fixed_vs_float_paths_agree():
    """Fixed-point segmentation finds nearly the same events as float."""
    rng = np.random.default_rng(3)
    levels = rng.uniform(70, 130, 60)
    sig = _step_signal(levels, 8, 1.5, seed=4)[:480]
    base = MarsConfig(signal_len=480, max_events=96)
    mf, nf, _ = events.detect_events(
        jnp.asarray(sig), base.with_mode("ms_float"))
    mx, nx, _ = events.detect_events(
        jnp.asarray(sig), base.with_mode("ms_fixed"))
    assert abs(int(nf) - int(nx)) <= 5


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_event_count_bounded(seed):
    """Property: n_events never exceeds max_events, means stay finite."""
    cfg = MarsConfig(signal_len=256, max_events=48).with_mode("ms_fixed")
    rng = np.random.default_rng(seed)
    sig = rng.normal(100, 20, 256).astype(np.float32)
    means, n, _ = events.detect_events(jnp.asarray(sig), cfg)
    assert 1 <= int(n) <= cfg.max_events
    assert np.isfinite(np.asarray(means)).all()


def test_windowed_sums_match_numpy():
    cfg = MarsConfig()
    x = jnp.asarray(np.arange(20, dtype=np.float32))
    sl, sr, ql, qr = events._windowed_sums(x, 4)
    xn = np.arange(20, dtype=np.float64)
    for i in (0, 3, 7, 19):
        lo, hi = max(i - 4, 0), min(i + 4, 20)
        assert float(sl[i]) == xn[lo:i].sum()
        assert float(sr[i]) == xn[i:hi].sum()
