"""Fault-tolerant storage path: injection harness, checksummed paging,
drive-loss rebalancing, closed-loop shedding.

The three bit-parity oracles of the fault-tolerance layer:
  (a) a FaultPlan that injects NOTHING is byte-identical to no harness at
      all — MapOutput and CHUNK_COUNTER_SCHEMA counters, batch and
      serving;
  (b) ``repartition_index`` after a drive loss is bit-identical to a
      fresh ``partition_index`` at the surviving count;
  (c) every injected corruption is either healed by the checksummed
      retry loop (exact parity with the fault-free baseline) or raises a
      loud ``TileReadError`` — NO silent wrong answers, asserted over a
      seeded sweep of >= 50 plans.
"""
import math

import numpy as np
import pytest

from repro.core import (FaultPlan, InjectedPrefetchError, Mapper, MarsConfig,
                        SLOClass, TileReadError, build_index, driver,
                        partition_index, repartition_index,
                        sample_fault_plans, stages)
from repro.core.faults import FaultInjector, TransientTileError
from repro.core.index import build_index_streaming, tier_index, tile_checksum
from repro.core.tiered import HotTileCache
from repro.signal import simulate


@pytest.fixture(scope="module")
def setup():
    cfg = MarsConfig(hash_bits=12).with_mode("ms_fixed")
    ref = simulate.make_reference(8_000, seed=5)
    reads = simulate.sample_reads(ref, 24, signal_len=cfg.signal_len,
                                  seed=6, junk_frac=0.25)
    idx = build_index(ref.events_concat, ref.n_events, cfg)
    return cfg, ref, reads, idx


@pytest.fixture(scope="module")
def base_out(setup):
    cfg, _, reads, idx = setup
    return Mapper(idx, cfg).map_signals(reads.signals, chunk=8)


def _assert_parity(base, out):
    np.testing.assert_array_equal(np.asarray(base.t_start),
                                  np.asarray(out.t_start))
    np.testing.assert_array_equal(np.asarray(base.score),
                                  np.asarray(out.score))
    np.testing.assert_array_equal(np.asarray(base.mapped),
                                  np.asarray(out.mapped))
    np.testing.assert_array_equal(np.asarray(base.n_events),
                                  np.asarray(out.n_events))
    assert base.counters == out.counters


# --------------------------------------------------------------------------- #
# Oracle (a): zero-fault plan == no harness
# --------------------------------------------------------------------------- #
def test_zero_fault_plan_is_disabled():
    p = FaultPlan(seed=123)
    assert not p.enabled
    assert FaultPlan(seed=1, p_corrupt=0.1).enabled
    assert FaultPlan(sticky_corrupt_tiles={3}).enabled
    assert FaultPlan(prefetch_error_serials=[0]).enabled
    # failed_drive alone describes a rebalancing scenario, not a tile
    # fault — the paging path stays untouched
    assert not FaultPlan(failed_drive=2).enabled


def test_zero_fault_batch_parity(setup, base_out):
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
               fault_plan=FaultPlan(seed=9))
    assert m.cache._inj is None                  # harness dropped entirely
    out = m.map_signals(reads.signals, chunk=8)
    _assert_parity(base_out, out)
    assert m.cache.retries == 0 and m.cache.corruptions == 0
    assert m.cache.vtime_penalty == 0.0


def test_zero_fault_serve_parity(setup, base_out):
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
               fault_plan=FaultPlan(seed=9))
    sd = m.serve(chunk=8)
    sd.submit("s", reads.signals)
    sd.drain()
    out = sd.results("s")
    np.testing.assert_array_equal(out.t_start, np.asarray(base_out.t_start))
    np.testing.assert_array_equal(out.score, np.asarray(base_out.score))
    np.testing.assert_array_equal(out.mapped, np.asarray(base_out.mapped))
    assert set(sd.counters) == set(stages.CHUNK_COUNTER_SCHEMA)


def test_fault_plan_only_on_tiered_backend(setup):
    cfg, _, _, idx = setup
    with pytest.raises(ValueError, match="tiered"):
        Mapper(idx, cfg, fault_plan=FaultPlan(seed=1, p_corrupt=0.5))


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="probability"):
        FaultPlan(p_corrupt=1.5)
    with pytest.raises(ValueError, match="probability"):
        FaultPlan(p_read_error=-0.1)
    with pytest.raises(ValueError, match="latency_units"):
        FaultPlan(latency_units=-1.0)


def test_keyed_draws_are_call_order_independent():
    """The determinism contract: a draw depends only on (seed, site, key),
    never on how many draws happened before it."""
    plan = FaultPlan(seed=7, p_corrupt=0.5, p_read_error=0.3, p_latency=0.4)
    ent = np.arange(2 * 8, dtype=np.int32).reshape(2, 8)
    bs = np.arange(5, dtype=np.int32)

    def attempt(inj, tile, att):
        try:
            b, e, lat = inj.tile_read(tile, att, bs, ent)
            return ("ok", e.tobytes(), lat)
        except TransientTileError:
            return ("read_error",)

    a = FaultInjector(plan)
    fwd = {(t, k): attempt(a, t, k) for t in range(4) for k in range(3)}
    b = FaultInjector(plan)
    rev = {(t, k): attempt(b, t, k) for t in reversed(range(4))
           for k in reversed(range(3))}
    assert fwd == rev
    # the mix of outcomes is non-trivial at these probabilities
    assert len({v[0] for v in fwd.values()}) > 1


# --------------------------------------------------------------------------- #
# Oracle (c): no silent wrong answers over >= 50 seeded plans
# --------------------------------------------------------------------------- #
def test_sweep_no_silent_wrong_answers(setup, base_out):
    cfg, _, reads, idx = setup
    plans = sample_fault_plans(50, seed=0)
    assert len(plans) == 50
    healed = raised = 0
    for plan in plans:
        m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
                   fault_plan=plan)
        try:
            out = m.map_signals(reads.signals, chunk=8)
        except TileReadError:
            raised += 1
            continue
        _assert_parity(base_out, out)            # healed => exact parity
        healed += 1
    assert healed + raised == 50
    assert healed > 0 and raised > 0             # both regimes exercised


def test_sticky_corruption_always_raises(setup):
    """A tile that corrupts on EVERY attempt exhausts the retry budget:
    TileReadError, never a wrong answer."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
               fault_plan=FaultPlan(seed=1,
                                    sticky_corrupt_tiles=frozenset(range(8))))
    with pytest.raises(TileReadError):
        m.map_signals(reads.signals, chunk=8)
    assert m.cache.corruptions > 0


def test_retry_heals_and_accounts_virtual_time(setup, base_out):
    """Heavy transient read errors with a deep retry budget: results heal
    to exact parity while retries and backoff virtual time are counted."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
               fault_plan=FaultPlan(seed=2, p_read_error=0.5),
               cache_retries=64, cache_backoff=0.25)
    out = m.map_signals(reads.signals, chunk=8)
    _assert_parity(base_out, out)
    assert m.cache.retries > 0
    assert m.cache.vtime_penalty > 0.0


def test_latency_spikes_only_cost_time(setup, base_out):
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
               fault_plan=FaultPlan(seed=3, p_latency=1.0, latency_units=4.0))
    out = m.map_signals(reads.signals, chunk=8)
    _assert_parity(base_out, out)
    assert m.cache.vtime_penalty > 0.0 and m.cache.retries == 0


def test_checksum_detects_single_bit_flip(setup):
    cfg, _, _, idx = setup
    ti = tier_index(idx, 8)
    bs = np.asarray(ti.tile_bucket_start[0], np.int32)
    ent = np.array(ti.tile_entries_packed[0], np.int32, copy=True)
    want = ti.checksum(0)
    assert tile_checksum(bs, ent) == want
    ent.reshape(-1)[7] ^= 1 << 13
    assert tile_checksum(bs, ent) != want


def test_streaming_build_checksums_match(setup):
    cfg, ref, _, idx = setup
    want = tier_index(idx, 8)
    got = build_index_streaming(ref.events_concat, ref.n_events, cfg, 8,
                                chunk_events=1 << 9)
    np.testing.assert_array_equal(want.tile_checksums, got.tile_checksums)
    for t in range(8):
        assert want.checksum(t) == got.checksum(t)


# --------------------------------------------------------------------------- #
# Oracle (b): drive-loss rebalancing parity
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n_parts", [2, 4, 8])
def test_repartition_matches_fresh_partition(setup, n_parts):
    cfg, _, _, idx = setup
    fresh = partition_index(idx, n_parts // 2)
    for failed in range(n_parts):
        parts, remap = repartition_index(idx, n_parts, failed)
        for k in fresh:
            np.testing.assert_array_equal(parts[k], fresh[k])
        assert len(remap) == n_parts // 2
        assert failed not in remap
        for p, drive in enumerate(remap):
            assert drive in (2 * p, 2 * p + 1)   # a survivor of the pair


def test_repartition_validation(setup):
    cfg, _, _, idx = setup
    with pytest.raises(ValueError):
        repartition_index(idx, 3, 0)             # not a power of two
    with pytest.raises(ValueError):
        repartition_index(idx, 1, 0)             # nothing to fold onto
    with pytest.raises(ValueError):
        repartition_index(idx, 4, 4)             # failed out of range


# --------------------------------------------------------------------------- #
# HotTileCache error paths (satellite coverage)
# --------------------------------------------------------------------------- #
def test_overflow_view_at_exactly_slots_plus_one(setup):
    """needed == n_slots + 1 must overflow into a transient view padded to
    the next power of two, leaving the persistent slots alone."""
    cfg, _, _, idx = setup
    ti = tier_index(idx, 8)
    c = HotTileCache(ti, n_slots=4)
    before = c._slot_tile.copy()
    hist = np.zeros(8, np.int64)
    needed = np.arange(5)
    hist[needed] = 1
    view = c._overflow_view(needed, hist)
    assert view["t_bucket_start"].shape[0] == 8  # next pow2 above 5
    np.testing.assert_array_equal(c._slot_tile, before)
    slot_of = np.asarray(view["t_tile_slot"])
    assert (slot_of[:5] >= 0).all() and (slot_of[5:] == -1).all()


def test_eviction_when_all_slots_needed(setup):
    """Two back-to-back chunks each needing ALL slots with disjoint tile
    sets: every slot is evicted and reloaded, and the view stays exact."""
    cfg, _, _, idx = setup
    ti = tier_index(idx, 8)
    c = HotTileCache(ti, n_slots=4)
    h1 = np.zeros(8, np.int64)
    h1[:4] = 1
    c._serial += 1
    c._ensure_resident(np.arange(4), h1)
    assert sorted(int(t) for t in c._slot_tile) == [0, 1, 2, 3]
    h2 = np.zeros(8, np.int64)
    h2[4:] = 1
    c._serial += 1
    view = c._ensure_resident(np.arange(4, 8), h2)
    assert sorted(int(t) for t in c._slot_tile) == [4, 5, 6, 7]
    slot_of = np.asarray(view["t_tile_slot"])
    assert (slot_of[:4] == -1).all() and (slot_of[4:] >= 0).all()
    assert int(np.asarray(view["t_cache_stats"])[1]) == 4   # all misses


def test_failed_pagein_leaves_persistent_slots_unchanged(setup):
    """A page-in that exhausts its retries raises BEFORE touching device
    state: slot map and device planes are exactly as before."""
    cfg, _, _, idx = setup
    ti = tier_index(idx, 8)
    c = HotTileCache(ti, n_slots=4,
                     faults=FaultPlan(seed=1, sticky_corrupt_tiles={5}))
    h1 = np.zeros(8, np.int64)
    h1[:3] = 1
    c._serial += 1
    c._ensure_resident(np.arange(3), h1)
    slots_before = c._slot_tile.copy()
    bstart_before = np.asarray(c._dev_bstart).copy()
    ent_before = np.asarray(c._dev_ent).copy()
    h2 = np.zeros(8, np.int64)
    h2[5] = 1
    c._serial += 1
    with pytest.raises(TileReadError):
        c._ensure_resident(np.asarray([5]), h2)
    np.testing.assert_array_equal(c._slot_tile, slots_before)
    np.testing.assert_array_equal(np.asarray(c._dev_bstart), bstart_before)
    np.testing.assert_array_equal(np.asarray(c._dev_ent), ent_before)


def test_failed_prefetch_does_not_leak_memoization(setup):
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
               fault_plan=FaultPlan(seed=1, prefetch_error_serials={0}))
    sig = reads.signals[:8]
    with pytest.raises(InjectedPrefetchError):
        m.cache.prefetch(sig, cfg, m.plan)
    assert not m.cache._ready and not m.cache._keep
    # the next prefetch (serial 1) succeeds and memoizes normally
    m.cache.prefetch(sig, cfg, m.plan)
    assert id(sig) in m.cache._ready


# --------------------------------------------------------------------------- #
# driver.stream_map prefetch-exception regression (satellite)
# --------------------------------------------------------------------------- #
def test_stream_map_prefetch_exception_drains_inflight(setup):
    """A prefetch-hook exception must not abandon dispatched device work:
    every dispatched chunk is yielded, THEN the failure surfaces once."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4)
    calls = []

    def prefetch(sig, nv):
        calls.append(nv)
        if len(calls) == 3:                      # prefetch of chunk 2
            raise RuntimeError("boom at prefetch 3")

    got = []
    with pytest.raises(RuntimeError, match="boom at prefetch 3"):
        for item in driver.stream_map(m.chunk_fn(),
                                      driver.array_chunks(reads.signals, 8),
                                      prefetch=prefetch):
            got.append(item)
    # chunks 0 and 1 were in flight / dispatched before the failure — both
    # must have been surfaced through the iterator
    assert [ci for ci, _, _ in got] == [0, 1]
    base = Mapper(idx, cfg).map_signals(reads.signals[:16], chunk=8)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(o.mapped) for _, _, o in got]),
        np.asarray(base.mapped))


def test_stream_map_initial_prefetch_exception(setup):
    """Nothing in flight yet: the failure surfaces without any yields."""
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
               fault_plan=FaultPlan(seed=1, prefetch_error_serials={0}))
    with pytest.raises(InjectedPrefetchError):
        m.map_signals(reads.signals, chunk=8)


# --------------------------------------------------------------------------- #
# ServeDriver: non-finite rejection + SLO classes + closed-loop shedding
# --------------------------------------------------------------------------- #
def test_submit_rejects_nonfinite_rows(setup, base_out):
    cfg, _, reads, idx = setup
    m = Mapper(idx, cfg)
    sd = m.serve(chunk=8)
    bad = reads.signals.copy()
    bad[3, 10] = np.nan
    bad[7, 0] = np.inf
    assert sd.submit("s", bad) == bad.shape[0] - 2
    sd.drain()
    rep = sd.report()["s"]
    assert rep.n_nonfinite == 2 and rep.n_rejected == 2
    out = sd.results("s")
    good = np.isfinite(bad).all(axis=1)
    np.testing.assert_array_equal(np.asarray(out.mapped)[good],
                                  np.asarray(base_out.mapped)[good])
    assert not np.asarray(out.mapped)[~good].any()


def test_finite_submit_parity_unchanged(setup, base_out):
    """The admission screen is invisible for finite inputs."""
    cfg, _, reads, idx = setup
    sd = Mapper(idx, cfg).serve(chunk=8)
    sd.submit("s", reads.signals)
    sd.drain()
    out = sd.results("s")
    np.testing.assert_array_equal(out.t_start, np.asarray(base_out.t_start))
    np.testing.assert_array_equal(out.score, np.asarray(base_out.score))
    np.testing.assert_array_equal(out.mapped, np.asarray(base_out.mapped))
    rep = sd.report()["s"]
    assert rep.n_nonfinite == 0 and rep.n_shed == 0


def test_slo_class_defaults_and_validation(setup):
    cfg, _, reads, idx = setup
    classes = [SLOClass("gold", priority=3, deadline=10.0, sheddable=False)]
    sd = Mapper(idx, cfg).serve(chunk=8, slo_classes=classes)
    sd.submit("s", reads.signals[:2], slo="gold", t=5.0)
    slot = sd._queue[0]
    assert slot.priority == 3 and slot.deadline == 15.0 and not slot.sheddable
    with pytest.raises(ValueError, match="unknown SLO class"):
        sd.submit("s", reads.signals[:1], slo="nope")
    with pytest.raises(ValueError):
        SLOClass("bad", deadline=0.0)
    sd.drain()


def test_shedding_protects_unsheddable_class(setup, base_out):
    """Under saturation the closed loop sheds only the sheddable class;
    every read actually served still matches the batch mapper."""
    cfg, _, reads, idx = setup
    sig = reads.signals
    classes = [SLOClass("gold", priority=2, deadline=50.0, sheddable=False),
               SLOClass("bulk", priority=0, deadline=200.0)]
    sd = Mapper(idx, cfg).serve(chunk=8, shed=True, shed_window=4.0,
                                slo_classes=classes)
    trace = []
    for w in range(6):                            # far beyond capacity
        trace.append((w * 0.5, f"g{w}", sig[:12], None, None, "gold"))
        trace.append((w * 0.5, f"b{w}", sig[12:], None, None, "bulk"))
    sd.serve_trace(trace)
    cr = sd.class_report()
    assert sd.n_shed > 0
    assert cr["gold"].n_shed == 0
    assert cr["bulk"].n_shed == sd.n_shed
    assert math.isfinite(cr["gold"].p99_latency)
    for w in range(6):
        got = sd.results(f"g{w}")
        adm = np.asarray(sd.stream(f"g{w}").admitted)
        np.testing.assert_array_equal(np.asarray(got.mapped)[adm],
                                      np.asarray(base_out.mapped)[:12][adm])


def test_shed_off_is_todays_driver(setup):
    """shed defaults off: a saturating trace is fully served (bounded only
    by max_queue), byte-identical accounting to the pre-shed driver."""
    cfg, _, reads, idx = setup
    sd = Mapper(idx, cfg).serve(chunk=8)
    trace = [(w * 0.1, f"s{w % 3}", reads.signals[w % 24])
             for w in range(48)]
    reports = sd.serve_trace(trace)
    assert sd.n_shed == 0
    assert all(r.n_shed == 0 and r.n_rejected == 0
               for r in reports.values())


def test_early_term_first_under_overload(setup):
    """shed + early_term under saturation serves shortest prefixes first
    and still resolves every admitted read."""
    cfg, _, reads, idx = setup
    sd = Mapper(idx, cfg).serve(chunk=8, early_term=True, shed=True,
                                shed_window=2.0)
    trace = [(w * 0.05, f"s{w % 4}", reads.signals[w % 24])
             for w in range(48)]
    reports = sd.serve_trace(trace)
    served = sum(r.n_reads - r.n_rejected for r in reports.values())
    lat = [r.mean_latency for r in reports.values()
           if math.isfinite(r.mean_latency)]
    assert served > 0 and lat
    assert not sd._queue and not sd._inflight


def test_serve_retry_backoff_advances_clock(setup):
    """Virtual time lost to storage retries shows up on the serving clock
    (and only then)."""
    cfg, _, reads, idx = setup
    m_ok = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4)
    sd_ok = m_ok.serve(chunk=8)
    sd_ok.submit("s", reads.signals)
    sd_ok.drain()
    m_fault = Mapper(idx, cfg, backend="tiered", tiles=8, cache_slots=4,
                     fault_plan=FaultPlan(seed=2, p_read_error=0.5),
                     cache_retries=64, cache_backoff=0.5)
    sd = m_fault.serve(chunk=8)
    sd.submit("s", reads.signals)
    sd.drain()
    assert m_fault.cache.vtime_penalty > 0.0
    assert sd.clock > sd_ok.clock
    out = sd.results("s")
    np.testing.assert_array_equal(out.mapped,
                                  np.asarray(sd_ok.results("s").mapped))


def test_debug_counter_schema_has_fault_telemetry():
    for k in ("n_tile_retries", "n_tile_corruptions"):
        assert k in stages.DEBUG_COUNTER_SCHEMA
        assert k not in stages.CHUNK_COUNTER_SCHEMA
