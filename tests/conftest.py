"""Shared fixtures: small synthetic references/read sets.

NOTE: no XLA_FLAGS device-count overrides here — smoke tests and benches
must see the single real CPU device.  Only launch/dryrun.py forces 512
placeholder devices (and only in its own process).
"""
import numpy as np
import pytest

from repro.core import MarsConfig, build_index
from repro.signal import simulate


@pytest.fixture(scope="session")
def small_ref():
    return simulate.make_reference(50_000, seed=3)


@pytest.fixture(scope="session")
def medium_ref():
    return simulate.make_reference(200_000, seed=7)


@pytest.fixture(scope="session")
def cfg_fixed():
    return MarsConfig().with_mode("ms_fixed")


@pytest.fixture(scope="session")
def cfg_float():
    return MarsConfig().with_mode("ms_float")


@pytest.fixture(scope="session")
def cfg_rh2():
    return MarsConfig().with_mode("rh2")


@pytest.fixture(scope="session")
def small_index(small_ref, cfg_fixed):
    return build_index(small_ref.events_concat, small_ref.n_events, cfg_fixed)


@pytest.fixture(scope="session")
def small_reads(small_ref, cfg_fixed):
    return simulate.sample_reads(small_ref, 16,
                                 signal_len=cfg_fixed.signal_len, seed=4,
                                 junk_frac=0.125)
